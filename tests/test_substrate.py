"""Substrate tests: data pipeline, tier monitor, optimizer, checkpoint,
PS sparse path."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hard dep: deterministic fallback shim
    from _hypothesis_fallback import given, settings, st


from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import (
    AccessMonitor, PrefetchLoader, SyntheticTokenDataset, Tier, TierThresholds,
)
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.parallel.ps import segment_rowsum, sparse_pull, sparse_push

KEY = jax.random.PRNGKey(0)


class TestData:
    def test_deterministic_batches(self):
        ds = SyntheticTokenDataset(100, 4, 16, seed=3)
        a, b = ds.batch(7), ds.batch(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_distinct_steps_differ(self):
        ds = SyntheticTokenDataset(100, 4, 16)
        assert not np.array_equal(ds.batch(0)["tokens"], ds.batch(1)["tokens"])

    def test_labels_are_shifted_tokens(self):
        ds = SyntheticTokenDataset(1000, 2, 8)
        b = ds.batch(0)
        assert b["tokens"].shape == b["labels"].shape == (2, 8)

    def test_prefetch_loader_yields_in_order(self):
        ds = SyntheticTokenDataset(50, 2, 4)
        loader = PrefetchLoader(ds, depth=2)
        got = [next(loader) for _ in range(3)]
        loader.close()
        for i, b in enumerate(got):
            np.testing.assert_array_equal(b["tokens"], ds.batch(i)["tokens"])


class TestTierMonitor:
    def test_hot_rows_go_to_device(self):
        m = AccessMonitor(100, TierThresholds(hot_fraction=0.5,
                                              warm_fraction=0.9))
        m.record(np.array([1] * 100 + [2] * 5 + [3]))
        p = m.placement()
        assert p[1] == Tier.DEVICE
        assert p[50] == Tier.DISK  # never accessed

    def test_aging_decays_counts(self):
        m = AccessMonitor(10)
        m.record(np.array([0, 0, 0]))
        before = m.counts[0]
        m.age()
        assert m.counts[0] < before

    @given(st.lists(st.integers(0, 63), min_size=1, max_size=200))
    @settings(max_examples=20, deadline=None)
    def test_placement_total_partition(self, ids):
        m = AccessMonitor(64)
        m.record(np.array(ids))
        s = m.stats()
        assert s["device_rows"] + s["host_rows"] + s["disk_rows"] == 64


class TestOptim:
    def test_adamw_decreases_quadratic(self):
        params = {"w": jnp.array([3.0, -2.0])}
        opt = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, opt = adamw_update(params, grads, opt, lr=0.05)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_clip_scales_to_max_norm(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(20.0)
        total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
        assert float(total) == pytest.approx(1.0, rel=1e-5)

    def test_clip_noop_below_threshold(self):
        g = {"a": jnp.array([0.1, 0.1])}
        clipped, _ = clip_by_global_norm(g, 10.0)
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(g["a"]), rtol=1e-6)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        params = {"emb": jax.random.normal(KEY, (10, 4)),
                  "blocks": ({"w": jnp.ones((3, 3))},)}
        opt = adamw_init(params)
        save_checkpoint(str(tmp_path / "ck"), params=params, opt_state=opt,
                        step=17)
        p2, o2, step = load_checkpoint(str(tmp_path / "ck"),
                                       params_template=params,
                                       opt_template=opt)
        assert step == 17
        np.testing.assert_array_equal(np.asarray(params["emb"]),
                                      np.asarray(p2["emb"]))

    def test_shape_mismatch_raises(self, tmp_path):
        params = {"w": jnp.ones((2, 2))}
        save_checkpoint(str(tmp_path / "ck"), params=params)
        with pytest.raises(ValueError):
            load_checkpoint(str(tmp_path / "ck"),
                            params_template={"w": jnp.ones((3, 3))})


class TestSparsePS:
    def test_pull_matches_gather(self):
        table = jax.random.normal(KEY, (20, 8))
        ids = jnp.array([3, 3, 7])
        np.testing.assert_array_equal(np.asarray(sparse_pull(table, ids)),
                                      np.asarray(table[ids]))

    def test_pull_gradient_is_sparse_rowsum(self):
        table = jax.random.normal(KEY, (20, 8))
        ids = jnp.array([3, 3, 7])

        def f(t):
            return jnp.sum(sparse_pull(t, ids) * 2.0)

        g = jax.grad(f)(table)
        assert float(g[3].sum()) == pytest.approx(2.0 * 8 * 2)  # two pulls
        assert float(jnp.abs(g[0]).sum()) == 0.0

    def test_push_updates_only_touched_rows(self):
        table = jnp.zeros((10, 4))
        out = sparse_push(table, jnp.array([2]), jnp.ones((1, 4)), lr=0.5)
        assert float(out[2].sum()) == pytest.approx(-2.0)
        assert float(jnp.abs(out).sum()) == pytest.approx(2.0)

    def test_segment_rowsum_aggregates_duplicates(self):
        g = segment_rowsum(jnp.array([1, 1, 2]), jnp.ones((3, 4)), num_rows=5)
        assert float(g[1].sum()) == pytest.approx(8.0)
        assert float(g[2].sum()) == pytest.approx(4.0)
