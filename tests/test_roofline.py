"""Roofline extraction unit tests (HLO collective parsing + terms)."""

import pytest

from repro.roofline import (
    HBM_BW, LINK_BW, PEAK_FLOPS, collective_bytes_from_hlo, model_flops,
    roofline_terms,
)

HLO = """
  %ar = f32[16,4096] all-reduce(f32[16,4096] %x), replica_groups={}
  %ag = bf16[8,128,64] all-gather(bf16[8,128,64] %y), dimensions={0}
  %rs = f32[4,4] reduce-scatter(f32[4,4] %z), dimensions={0}
  %a2a = bf16[2,2] all-to-all(bf16[2,2] %w)
  %cp = f32[10] collective-permute(f32[10] %v)
  %ags = (f32[8,8], f32[8,8]) all-gather-start(f32[8,8] %q), dimensions={0}
  %agd = f32[8,8] all-gather-done(f32[8,8] %ags)
  %dot = f32[128,128] dot(f32[128,64] %a, f32[64,128] %b)
"""


class TestCollectiveParsing:
    def test_all_kinds_counted(self):
        r = collective_bytes_from_hlo(HLO)
        assert set(r["counts"]) == {"all-reduce", "all-gather",
                                    "reduce-scatter", "all-to-all",
                                    "collective-permute"}

    def test_bytes_exact(self):
        r = collective_bytes_from_hlo(HLO)
        assert r["bytes_by_kind"]["all-reduce"] == 16 * 4096 * 4
        # plain all-gather + async start (done not double counted)
        assert r["bytes_by_kind"]["all-gather"] == 8 * 128 * 64 * 2 + 8 * 8 * 4
        assert r["counts"]["all-gather"] == 2

    def test_non_collectives_ignored(self):
        r = collective_bytes_from_hlo("%dot = f32[4,4] dot(f32[4,4] %a)")
        assert r["total_bytes"] == 0


class TestTerms:
    def test_dominant_identification(self):
        t = roofline_terms(flops=PEAK_FLOPS, hbm_bytes=0.0, collective_bytes=0.0)
        assert t["dominant"] == "compute"
        assert t["compute_s"] == pytest.approx(1.0)
        t = roofline_terms(flops=0.0, hbm_bytes=HBM_BW * 2, collective_bytes=0.0)
        assert t["dominant"] == "memory"
        t = roofline_terms(flops=0.0, hbm_bytes=0.0, collective_bytes=LINK_BW * 3)
        assert t["dominant"] == "collective"
        assert t["collective_s"] == pytest.approx(3.0)

    def test_model_flops(self):
        assert model_flops(1e9, 1e6) == pytest.approx(6e15)
