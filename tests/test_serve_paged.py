"""Paged KV-cache decode + batched prefill + continuous-batching serve.

Parity pins (the acceptance gates of the paged subsystem):
  * paged decode == dense ring-buffer decode (the oracle) per step,
    across GQA / sliding-window / softcap / rope / qk-norm / partial-rope
    arch configs, with sequences spanning multiple pages;
  * batched prefill logits == full-attention forward logits, and decode
    continued from a prefilled cache == decode continued from a stepped
    cache (dense AND paged);
  * PagePool invariants under random admit/grow/evict traffic
    (hypothesis): no page owned by two live slots, free list conserved.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hard dep: deterministic fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.kernels.paged_attention import PagePool
from repro.models import decoder as dec
from repro.models.profile import kv_read_bytes_per_token, profile_arch

KEY = jax.random.PRNGKey(0)
#: GQA+rope (llama), window+softcap+post-norm (gemma2), qk-norm+MoE
#: (qwen3), partial rotary (chatglm)
PARITY_ARCHS = ["llama3.2-1b", "gemma2-2b", "qwen3-moe-30b-a3b",
                "chatglm3-6b"]


def _cfg(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.has_moe:
        # full capacity: routing drops would differ between runs only via
        # float noise; parity should not depend on drop order
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    return cfg


class TestPagedDecodeParity:
    @pytest.mark.parametrize("arch", PARITY_ARCHS)
    def test_paged_matches_dense_decode(self, arch):
        """Per-step logits of the paged path vs the dense oracle, over a
        sequence spanning 3 pages (page_size=4, S=12)."""
        cfg = _cfg(arch)
        params = dec.init_model(cfg, KEY)
        B, S, ps = 2, 12, 4
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        cache_d = dec.init_cache(cfg, B, 32, dtype=jnp.float32)
        pcfg = dataclasses.replace(cfg, kv_impl="paged")
        cache_p = dec.init_cache(pcfg, B, 32, dtype=jnp.float32, page_size=ps)
        for i in range(S):
            ld, cache_d = dec.decode_step(params, cfg, toks[:, i:i + 1],
                                          cache_d, jnp.int32(i),
                                          compute_dtype=jnp.float32)
            lp, cache_p = dec.decode_step(params, pcfg, toks[:, i:i + 1],
                                          cache_p, 0,
                                          compute_dtype=jnp.float32)
            np.testing.assert_allclose(np.asarray(lp), np.asarray(ld),
                                       atol=1e-4, rtol=1e-4)
        assert int(cache_p["length"][0]) == S

    @pytest.mark.parametrize("arch", PARITY_ARCHS + ["rwkv6-7b",
                                                     "jamba-v0.1-52b"])
    def test_prefill_matches_forward(self, arch):
        """ONE-forward prefill logits == the training forward's."""
        cfg = _cfg(arch)
        params = dec.init_model(cfg, KEY)
        B, S = 2, 10
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        full, _ = dec.forward(params, cfg, toks, compute_dtype=jnp.float32,
                              remat=False)
        cache = dec.init_cache(cfg, B, 32, dtype=jnp.float32)
        lg, _ = dec.prefill(params, cfg, toks, cache,
                            compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full),
                                   atol=1e-4, rtol=1e-4)

    @pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-2b",
                                      "rwkv6-7b", "jamba-v0.1-52b"])
    def test_prefill_cache_continues_like_stepping(self, arch):
        """Decode from a prefilled cache == decode from a stepped cache —
        the cache contents (KV rings / pools / recurrent state) agree."""
        cfg = _cfg(arch)
        params = dec.init_model(cfg, KEY)
        B, S = 2, 9
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        stepped = dec.init_cache(cfg, B, 32, dtype=jnp.float32)
        for i in range(S):
            lg_s, stepped = dec.decode_step(params, cfg, toks[:, i:i + 1],
                                            stepped, jnp.int32(i),
                                            compute_dtype=jnp.float32)
        prefilled = dec.init_cache(cfg, B, 32, dtype=jnp.float32)
        lg_p, prefilled = dec.prefill(params, cfg, toks, prefilled,
                                      compute_dtype=jnp.float32)
        nt = jnp.argmax(lg_p[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
        a, _ = dec.decode_step(params, cfg, nt, prefilled, jnp.int32(S),
                               compute_dtype=jnp.float32)
        b, _ = dec.decode_step(params, cfg, nt, stepped, jnp.int32(S),
                               compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=1e-4)

    def test_paged_prefill_then_decode(self):
        """Paged prefill fills the pool exactly like paged stepping."""
        cfg = _cfg("gemma2-2b")
        pcfg = dataclasses.replace(cfg, kv_impl="paged")
        params = dec.init_model(cfg, KEY)
        B, S, ps = 2, 11, 4
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        stepped = dec.init_cache(pcfg, B, 32, dtype=jnp.float32, page_size=ps)
        for i in range(S):
            lg_s, stepped = dec.decode_step(params, pcfg, toks[:, i:i + 1],
                                            stepped, 0,
                                            compute_dtype=jnp.float32)
        prefilled = dec.init_cache(pcfg, B, 32, dtype=jnp.float32,
                                   page_size=ps)
        lg_p, prefilled = dec.prefill(params, pcfg, toks, prefilled,
                                      compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg_p[:, -1:]),
                                   np.asarray(lg_s), atol=1e-4, rtol=1e-4)
        nt = jnp.argmax(lg_p[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
        a, _ = dec.decode_step(params, pcfg, nt, prefilled, 0,
                               compute_dtype=jnp.float32)
        b, _ = dec.decode_step(params, pcfg, nt, stepped, 0,
                               compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=1e-4)

    def test_ragged_prefill_masks_padding(self):
        """Right-padded batched prefill == per-sequence exact prefill at
        each sequence's own last position (attention-family arch)."""
        cfg = _cfg("llama3.2-1b")
        params = dec.init_model(cfg, KEY)
        lens = [5, 9]
        S = max(lens)
        toks = jax.random.randint(KEY, (2, S), 0, cfg.vocab)
        cache = dec.init_cache(cfg, 2, 32, dtype=jnp.float32)
        lg, cache = dec.prefill(params, cfg, toks, cache,
                                lengths=jnp.asarray(lens),
                                compute_dtype=jnp.float32)
        for b, ln in enumerate(lens):
            solo = dec.init_cache(cfg, 1, 32, dtype=jnp.float32)
            lg_solo, _ = dec.prefill(params, cfg, toks[b:b + 1, :ln], solo,
                                     compute_dtype=jnp.float32)
            np.testing.assert_allclose(
                np.asarray(lg[b, ln - 1]), np.asarray(lg_solo[0, -1]),
                atol=1e-4, rtol=1e-4)


class TestDecodeLoop:
    def test_loop_matches_stepping(self):
        """The fused lax.scan loop emits exactly the tokens the per-token
        host loop would."""
        cfg = _cfg("llama3.2-1b")
        params = dec.init_model(cfg, KEY)
        B, S, gen = 2, 6, 5
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        cache = dec.init_cache(cfg, B, 32, dtype=jnp.float32)
        lg, cache = dec.prefill(params, cfg, toks, cache,
                                compute_dtype=jnp.float32)
        tok = jnp.argmax(lg[:, -1:, :cfg.vocab], -1).astype(jnp.int32)

        # reference: python loop
        ref, rtok, rcache = [], tok, cache
        for i in range(gen):
            ref.append(np.asarray(rtok[:, 0]))
            lgs, rcache = dec.decode_step(params, cfg, rtok, rcache,
                                          jnp.int32(S + i),
                                          compute_dtype=jnp.float32)
            rtok = jnp.argmax(lgs[:, :, :cfg.vocab], -1).astype(jnp.int32)
        want = np.stack(ref, 1)
        got, _, _ = dec.decode_loop(params, cfg, tok, cache, jnp.int32(S),
                                    gen, compute_dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), want)


class TestSampling:
    def _logits(self, key, B=3, V=50):
        return jax.random.normal(key, (B, V)) * 4.0

    def test_fixed_key_is_deterministic(self):
        lg = self._logits(KEY)
        k = jax.random.PRNGKey(42)
        a = dec.sample_logits(lg, k, temperature=0.8, top_k=10, top_p=0.9)
        b = dec.sample_logits(lg, k, temperature=0.8, top_k=10, top_p=0.9)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == jnp.int32
        assert np.all((np.asarray(a) >= 0) & (np.asarray(a) < 50))

    def test_truncation_limits_collapse_to_argmax(self):
        """top_k=1, tiny top_p, and tiny temperature each pin the draw to
        the argmax token regardless of the key."""
        lg = self._logits(KEY)
        want = np.asarray(jnp.argmax(lg, -1))
        for kw in (dict(top_k=1), dict(top_p=1e-6),
                   dict(temperature=1e-7)):
            for s in range(3):
                got = dec.sample_logits(lg, jax.random.PRNGKey(s), **kw)
                np.testing.assert_array_equal(np.asarray(got), want)

    def test_top_k_restricts_support(self):
        lg = self._logits(KEY, B=64)
        allowed = np.asarray(jax.lax.top_k(lg, 5)[1])
        got = np.asarray(dec.sample_logits(lg, jax.random.PRNGKey(3),
                                           temperature=2.0, top_k=5))
        assert all(got[i] in allowed[i] for i in range(got.shape[0]))

    def test_decode_loop_sampled_is_reproducible_and_greedy_unchanged(self):
        cfg = _cfg("llama3.2-1b")
        params = dec.init_model(cfg, KEY)
        B, S, gen = 2, 6, 5
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)

        def run(key):
            cache = dec.init_cache(cfg, B, 32, dtype=jnp.float32)
            lg, cache = dec.prefill(params, cfg, toks, cache,
                                    compute_dtype=jnp.float32)
            tok = jnp.argmax(lg[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
            got, _, _ = dec.decode_loop(
                params, cfg, tok, cache, jnp.int32(S), gen,
                compute_dtype=jnp.float32, key=key,
                temperature=0.9, top_k=20, top_p=0.95)
            return np.asarray(got)

        k = jax.random.PRNGKey(11)
        a, b = run(k), run(k)
        np.testing.assert_array_equal(a, b)       # fixed key → same tokens
        assert np.all((a >= 0) & (a < cfg.vocab))
        c = run(jax.random.PRNGKey(12))
        # greedy path (key=None) is byte-identical to the pre-sampling
        # loop: covered by test_loop_matches_stepping; here just pin that
        # sampling actually depends on the key (vanishing odds otherwise)
        assert not np.array_equal(a[:, 1:], c[:, 1:]) or gen == 1

    def test_serve_sampling_reproducible_across_kv_impls(self):
        from repro.launch.serve import serve

        kw = dict(reduced=True, batch=2, prompt_len=8, gen=6, cache_len=32,
                  temperature=0.8, top_k=12, top_p=0.9, sample_seed=5)
        a = serve("llama3.2-1b", **kw)
        b = serve("llama3.2-1b", **kw)
        assert a["sampling"] and a["tokens"] == b["tokens"]
        assert a["tokens_in_vocab"]
        p = serve("llama3.2-1b", **kw, kv_impl="paged", page_size=4)
        assert a["tokens"] == p["tokens"]   # sampling is kv-layout-blind


class TestServeEndToEnd:
    def test_serve_paged_equals_dense_tokens(self):
        from repro.launch.serve import serve

        a = serve("llama3.2-1b", reduced=True, batch=2, prompt_len=8, gen=6,
                  cache_len=32)
        b = serve("llama3.2-1b", reduced=True, batch=2, prompt_len=8, gen=6,
                  cache_len=32, kv_impl="paged", page_size=4)
        assert a["tokens"] == b["tokens"]
        assert a["tokens_in_vocab"] and b["tokens_in_vocab"]
        assert b["kv_bytes_per_token"] < a["kv_bytes_per_token"]

    def test_serve_paged_rejects_capacity_overflow(self):
        """The pool does not ring-wrap: generating past cache_len must be
        an error, not silently dropped KV."""
        from repro.launch.serve import serve

        with pytest.raises(ValueError, match="paged serve"):
            serve("llama3.2-1b", reduced=True, batch=2, prompt_len=8,
                  gen=32, cache_len=32, kv_impl="paged", page_size=4)

    def test_serve_continuous_recycles_pages(self):
        from repro.launch.serve import serve_continuous

        out = serve_continuous(
            "llama3.2-1b", slots=3, page_size=4, decode_chunk=4,
            requests=[(5, 4), (9, 6), (3, 5), (12, 4), (7, 3)],
            num_pages=12,  # oversubscribed: forces admit to wait on evict
        )
        assert out["generated"] == [4, 6, 5, 4, 3]
        assert out["tokens_in_vocab"]
        assert out["pool_conserved"]
        assert out["kv_bytes_per_token_paged"] < out["kv_bytes_per_token_dense"]
        # pin every request's tokens against a solo dense-cache reference
        # (same prompt construction as serve_continuous) — a page-recycle
        # or length-mirroring bug would corrupt these, not just counts
        cfg = get_config("llama3.2-1b", reduced=True)
        key = jax.random.PRNGKey(0)
        params = dec.init_model(cfg, key)
        for rid, (plen, g) in enumerate([(5, 4), (9, 6), (3, 5), (12, 4),
                                         (7, 3)]):
            prompt = jax.random.randint(jax.random.fold_in(key, 1000 + rid),
                                        (1, plen), 0, cfg.vocab)
            cache = dec.init_cache(cfg, 1, 64, dtype=jnp.float32)
            lg, cache = dec.prefill(params, cfg, prompt, cache,
                                    compute_dtype=jnp.float32)
            tok = jnp.argmax(lg[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
            want, _, _ = dec.decode_loop(params, cfg, tok, cache,
                                         jnp.int32(plen), g,
                                         compute_dtype=jnp.float32)
            assert out["tokens"][rid] == np.asarray(want)[0].tolist()

    def test_serve_continuous_rejects_oversize_request(self):
        """A request the pool cannot hold even when empty must terminate
        with a typed ``rejected`` outcome (PR 10) — not hang waiting for
        an eviction that cannot help, and not crash the serve loop."""
        from repro.launch.serve import serve_continuous

        out = serve_continuous("llama3.2-1b", slots=2, page_size=8,
                               decode_chunk=4, requests=[(40, 10), (5, 4)],
                               max_seq_len=32)
        assert out["outcomes"] == ["rejected", "completed"]
        assert "pages_per_seq" in out["outcome_detail"][0]
        assert out["outcome_counts"]["rejected"] == 1
        assert out["pool_conserved"]

    def test_serve_continuous_rejects_decreasing_arrivals(self):
        from repro.launch.serve import serve_continuous

        with pytest.raises(ValueError, match="non-decreasing"):
            serve_continuous("llama3.2-1b", slots=2,
                             requests=[(5, 4), (5, 4)],
                             arrival_s=[1.0, 0.5])


class TestPagePoolInvariants:
    def _check(self, pool: PagePool):
        owned = [list(pool.owned_pages(s)) for s in range(pool.slots)]
        flat = [p for o in owned for p in o]
        # no page shared by two live sequences; scratch page never owned
        assert len(flat) == len(set(flat))
        assert 0 not in flat
        # free list conserved across admit/evict
        assert pool.free_pages + len(flat) == pool.num_pages - 1
        # live table rows point at the owned pages, in logical order
        for s, o in enumerate(owned):
            assert list(pool.table[s, :len(o)]) == o

    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_random_admit_grow_evict(self, seed):
        import random

        rng = random.Random(seed)
        slots, ps, pps = rng.randint(1, 4), rng.choice([2, 4, 8]), 8
        pool = PagePool(rng.randint(4, 40), ps, slots, pps)
        live: dict[int, int] = {}
        for _ in range(30):
            op = rng.random()
            s = rng.randrange(slots)
            if op < 0.45 and s not in live:
                want = rng.randint(1, ps * pps)
                if pool.can_admit(want):
                    pool.admit(s, want)
                    live[s] = want
            elif op < 0.7 and s in live:
                want = min(ps * pps, live[s] + rng.randint(0, 2 * ps))
                try:
                    pool.grow(s, want)
                    live[s] = max(live[s], want)
                except MemoryError:
                    pass  # exhausted pool keeps prior state — still valid
            elif s in live:
                pool.evict(s)
                del live[s]
            self._check(pool)
        for s in list(live):
            pool.evict(s)
        self._check(pool)
        assert pool.free_pages == pool.num_pages - 1

    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_random_preempt_reserve_interleavings(self, seed):
        """Any admit/preempt/resume(admit-from-reservation)/evict/reserve/
        cancel interleaving conserves the free list AND the reservation
        watermark: pages withheld by ``reserve`` are invisible to other
        admissions and to ``grow``, and every page comes back on evict."""
        import random

        rng = random.Random(seed)
        slots, ps, pps = rng.randint(1, 4), rng.choice([2, 4, 8]), 8
        pool = PagePool(rng.randint(4, 40), ps, slots, pps)
        live: dict[int, int] = {}
        reservations: list[int] = []   # outstanding reserve() token counts

        def check():
            self._check(pool)
            want_res = sum(pool.pages_for(t) for t in reservations)
            assert pool.reserved_pages == want_res
            assert 0 <= pool.reserved_pages <= pool.free_pages
            assert pool.available_pages == \
                pool.free_pages - pool.reserved_pages

        for _ in range(40):
            op = rng.random()
            s = rng.randrange(slots)
            if op < 0.30 and s not in live:
                want = rng.randint(1, ps * pps)
                if reservations and rng.random() < 0.5:
                    # resume path: consume an outstanding reservation
                    want = reservations.pop()
                    if pool.can_admit(want, from_reservation=True):
                        pool.admit(s, want, from_reservation=True)
                        live[s] = want
                    else:  # shouldn't happen: reserve() guaranteed pages
                        raise AssertionError("reservation not honoured")
                elif pool.can_admit(want):
                    pool.admit(s, want)
                    live[s] = want
            elif op < 0.45 and s in live:
                want = min(ps * pps, live[s] + rng.randint(0, 2 * ps))
                try:
                    pool.grow(s, want)
                    live[s] = max(live[s], want)
                except MemoryError:
                    pass  # exhausted/withheld pool keeps prior state
            elif op < 0.60 and s in live:
                freed = pool.preempt(s)
                assert freed == pool.pages_for(live[s])
                del live[s]
            elif op < 0.75:
                want = rng.randint(1, ps * pps)
                if pool.reserve(want):
                    reservations.append(want)
            elif op < 0.85 and reservations:
                pool.cancel_reservation(reservations.pop())
            elif s in live:
                pool.evict(s)
                del live[s]
            check()
        for t in reservations:
            pool.cancel_reservation(t)
        reservations.clear()
        for s in list(live):
            pool.evict(s)
        check()
        assert pool.free_pages == pool.num_pages - 1
        assert pool.reserved_pages == 0

    def test_reserve_withholds_pages_from_admission_and_grow(self):
        pool = PagePool(8, 4, 2, 4)   # 7 allocatable
        assert pool.reserve(16)       # 4 pages withheld
        assert pool.available_pages == 3
        assert not pool.can_admit(16)             # 4 > 3 available
        assert pool.can_admit(16, from_reservation=True)
        pool.admit(0, 12)                         # 3 pages: exactly fits
        with pytest.raises(MemoryError):
            pool.grow(0, 16)          # 4th page exists but is withheld
        pool.admit(1, 16, from_reservation=True)  # consumes the hold
        assert pool.reserved_pages == 0
        pool.evict(1)                 # pages return unreserved
        pool.grow(0, 16)              # no watermark left: grow succeeds

    def test_cancel_more_than_reserved_raises(self):
        pool = PagePool(8, 4, 2, 4)
        assert pool.reserve(4)
        with pytest.raises(ValueError):
            pool.cancel_reservation(8)
        pool.cancel_reservation(4)
        assert pool.reserved_pages == 0

    def test_preempt_returns_pages_and_counts(self):
        pool = PagePool(8, 4, 2, 4)
        pool.admit(0, 10)             # 3 pages
        assert pool.preempt(0) == 3
        assert pool.free_pages == 7 and pool.preempt_count == 1
        with pytest.raises(ValueError):
            pool.preempt(0)           # not live any more

    def test_double_admit_rejected(self):
        pool = PagePool(8, 4, 2, 4)
        pool.admit(0, 6)
        with pytest.raises(ValueError):
            pool.admit(0, 4)

    def test_exhaustion_raises(self):
        pool = PagePool(4, 4, 2, 4)  # 3 allocatable pages
        pool.admit(0, 12)
        with pytest.raises(MemoryError):
            pool.admit(1, 8)


class TestKVBytesAccounting:
    def test_paged_charges_used_pages_not_max_len(self):
        cfg = get_config("llama3.2-1b", reduced=True)
        dense = kv_read_bytes_per_token(cfg, 8, cache_len=4096)
        paged = kv_read_bytes_per_token(cfg, 8, cache_len=4096, page_size=16)
        assert paged < dense
        # one page of 16 positions vs the 4096-slot ring
        assert paged == pytest.approx(dense * 16 / 4096)

    def test_window_caps_both_layouts(self):
        cfg = get_config("gemma2-2b", reduced=True)  # window=32 + global
        near_full = kv_read_bytes_per_token(cfg, 4000, cache_len=4096,
                                            page_size=16)
        dense = kv_read_bytes_per_token(cfg, 4000, cache_len=4096)
        # the window layer reads ~32 positions in both; the global layer
        # dominates and pages≈ring at full occupancy
        assert near_full <= dense * 1.1

    def test_profile_arch_decode_mode(self):
        from repro.core import default_fleet

        fleet = default_fleet()
        base = profile_arch("llama3.2-1b", fleet)
        dense = profile_arch("llama3.2-1b", fleet, decode_kv_len=8,
                             kv_cache_len=4096)
        paged = profile_arch("llama3.2-1b", fleet, decode_kv_len=8,
                             kv_cache_len=4096, kv_page_size=16)
        # decode mode adds KV read traffic to the attention rows, and the
        # paged accounting charges (far) less of it at short lengths
        att = next(i for i, p in enumerate(base) if p.kind == "attention")
        assert dense[att].input_bytes > paged[att].input_bytes
        assert paged[att].input_bytes > base[att].input_bytes
