"""Overload-robust serving: deadline-aware admission, load shedding,
preempt-and-resume (PR 10).

Pins the robustness contract of ``serve_continuous``:
  * every request terminates in exactly one typed outcome — nothing
    hangs, including oversize requests and wall-budget shutdown;
  * the ``AdmissionPolicy`` math rejects only provable deadline misses
    and bounds the admission queue;
  * deadline enforcement (queued reap, mid-decode eviction) is
    deterministic under an injected virtual clock;
  * a preempted-then-resumed sequence emits a token stream bit-exact
    vs an un-preempted run — checked end-to-end through the serve loop
    AND at the decoder level for a mid-flight (chunk-boundary) cut;
  * the watchdog flags stalled decode chunks without killing the loop.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.configs import get_config
from repro.core.admission import (AdmissionPolicy, COMPLETED, OUTCOMES,
                                  PREEMPTED, REJECTED, TIMED_OUT)
from repro.models import decoder as dec

KEY = jax.random.PRNGKey(0)
ARCH = "llama3.2-1b"


def ticking_clock(dt=0.01, start=0.0):
    """A virtual clock advancing ``dt`` per call — the serve loop's
    ``clock=`` seam; makes arrival/deadline behaviour deterministic."""
    state = {"t": start}

    def clk():
        state["t"] += dt
        return state["t"]

    return clk


@pytest.fixture(autouse=True)
def _fresh_registry():
    obs.REGISTRY.reset()
    prev = obs.REGISTRY.enabled
    obs.REGISTRY.enabled = True
    yield
    obs.REGISTRY.enabled = prev
    obs.REGISTRY.reset()


class TestAdmissionPolicy:
    def test_unmeasured_rates_admit_everything(self):
        p = AdmissionPolicy(slots=2)
        assert p.admit_check(now=5.0, arrival=0.0, gen=1000,
                             ttft_deadline=0.001,
                             total_deadline=0.001) is None
        assert p.admitted == 1

    def test_queue_bound_rejects(self):
        p = AdmissionPolicy(slots=2, queue_bound=3)
        assert p.admit_check(now=0.0, arrival=0.0, gen=4,
                             queue_len=2) is None
        assert p.admit_check(now=0.0, arrival=0.0, gen=4,
                             queue_len=3) == "queue_full"
        assert p.rejections == {"queue_full": 1}

    def test_ttft_deadline_provable_miss(self):
        # τ=0.1 s/tok, c=2 → backlog of 40 tokens waits 2.0 s ≥ 0.5 s ttft
        p = AdmissionPolicy(slots=2, tpot_s=0.1, prefill_s=0.05)
        assert p.admit_check(now=1.0, arrival=1.0, gen=4,
                             ttft_deadline=0.5,
                             backlog_tokens=40) == "ttft_deadline"
        # no backlog: 0.05 s prefill fits easily
        assert p.admit_check(now=1.0, arrival=1.0, gen=4,
                             ttft_deadline=0.5, backlog_tokens=0) is None

    def test_total_deadline_provable_miss(self):
        p = AdmissionPolicy(slots=1, tpot_s=0.1)
        # 0 backlog but 20 tokens at 0.1 s/tok = 2.0 s > 1.0 s total
        assert p.admit_check(now=0.0, arrival=0.0, gen=20,
                             total_deadline=1.0) == "total_deadline"
        assert p.admit_check(now=0.0, arrival=0.0, gen=5,
                             total_deadline=1.0) is None

    def test_elapsed_queue_time_counts_against_deadline(self):
        p = AdmissionPolicy(slots=1, tpot_s=0.01)
        # arrived 0.9 s ago with a 1.0 s ttft deadline: even zero backlog
        # leaves only 0.1 s — prefill EMA 0.2 s makes it a provable miss
        p.prefill_s = 0.2
        assert p.admit_check(now=0.9, arrival=0.0, gen=2,
                             ttft_deadline=1.0) == "ttft_deadline"

    def test_ema_measurement_feedback(self):
        p = AdmissionPolicy(slots=1, ema=0.5)
        p.observe_tpot(0.2)
        assert p.tpot_s == pytest.approx(0.2)   # first sample seeds
        p.observe_tpot(0.4)
        assert p.tpot_s == pytest.approx(0.3)
        p.observe_prefill(1.0)
        assert p.prefill_s == pytest.approx(1.0)
        rep = p.report()
        assert rep["tpot_s"] == pytest.approx(0.3)

    def test_concurrency_clamped_to_slots(self):
        p = AdmissionPolicy(slots=4, max_concurrency=100)
        assert p.concurrency == 4
        p.max_concurrency = 0
        assert p.concurrency == 1


class TestDeadlineEnforcement:
    def test_queued_request_times_out_deterministically(self):
        """slots=1: the second request queues behind a long generation;
        its TTFT deadline passes on the virtual clock → ``timed_out``
        with the queued-reap detail, and a slack histogram sample."""
        from repro.launch.serve import serve_continuous

        out = serve_continuous(
            ARCH, slots=1, page_size=8, decode_chunk=4,
            requests=[(5, 16), (5, 4)],
            deadlines=[(None, None), (0.05, None)],
            clock=ticking_clock(dt=0.01))
        assert out["outcomes"] == [COMPLETED, TIMED_OUT]
        assert out["outcome_detail"][1] == "queued_past_deadline"
        assert out["outcome_counts"]["timed_out"] == 1
        assert out["pool_conserved"]
        assert obs.REGISTRY.value("serve.timed_out") == 1
        # the miss recorded a (negative) deadline-slack sample
        hists = [h for _, h in obs.REGISTRY.find("serve.deadline_slack_s")]
        assert hists and hists[0].snapshot()["count"] >= 1

    def test_mid_decode_total_deadline_evicts_with_partial_output(self):
        from repro.launch.serve import serve_continuous

        out = serve_continuous(
            ARCH, slots=1, page_size=8, decode_chunk=4,
            requests=[(5, 64)],
            deadlines=[(None, 0.5)],
            clock=ticking_clock(dt=0.01))
        assert out["outcomes"] == [TIMED_OUT]
        assert out["outcome_detail"][0] == "decode_past_deadline"
        # partial output kept, in whole chunks, short of the full 64
        assert 0 < out["generated"][0] < 64
        assert out["generated"][0] % 4 == 0
        assert out["pool_conserved"]

    def test_max_wall_budget_terminates_everything_typed(self):
        from repro.launch.serve import serve_continuous

        out = serve_continuous(
            ARCH, slots=2, page_size=8, decode_chunk=4,
            requests=[(5, 400), (5, 400), (5, 4), (5, 4)],
            max_wall_s=0.3, clock=ticking_clock(dt=0.01))
        assert all(o in OUTCOMES for o in out["outcomes"])
        assert PREEMPTED in out["outcomes"]     # in-flight at shutdown
        assert "shutdown" in [d for d in out["outcome_detail"]
                              if d is not None]
        assert out["pool_conserved"]

    def test_queue_bound_rejection_end_to_end(self):
        from repro.launch.serve import serve_continuous

        out = serve_continuous(
            ARCH, slots=1, page_size=8, decode_chunk=4,
            requests=[(5, 8)] * 4,
            admission=AdmissionPolicy(slots=1, queue_bound=1),
            clock=ticking_clock(dt=0.01))
        assert out["outcomes"][0] == COMPLETED
        assert REJECTED in out["outcomes"]
        assert "queue_full" in out["outcome_detail"]
        assert out["admission"]["rejections"].get("queue_full", 0) >= 1
        assert obs.REGISTRY.value("serve.rejected") >= 1


class TestPreemptResume:
    def test_preempt_resume_bit_exact_end_to_end(self):
        """r1 (small) blocked on pages preempts r0 (large remaining);
        r0 later resumes via prompt+generated prefill — both streams
        bit-exact vs solo un-preempted runs through the same loop."""
        from repro.launch.serve import serve_continuous

        kw = dict(page_size=4, decode_chunk=4, max_seq_len=36, num_pages=13)
        out = serve_continuous(ARCH, slots=2, requests=[(8, 24), (8, 4)],
                               preemption=True, **kw)
        assert out["outcomes"] == [COMPLETED, COMPLETED]
        assert out["preemptions"] >= 1 and out["resumes"] >= 1
        assert out["pool_conserved"]
        assert obs.REGISTRY.value("serve.preemptions") >= 1
        assert obs.REGISTRY.value("serve.resumes") >= 1
        # rid=0's prompt derives from fold_in(key, 1000+rid): a solo run
        # of the same request at rid=0 is the un-preempted reference
        solo = serve_continuous(ARCH, slots=1, requests=[(8, 24)], **kw)
        assert out["tokens"][0] == solo["tokens"][0]
        assert out["generated"] == [24, 4]

    def test_mid_flight_resume_bit_exact_decoder_level(self):
        """The serve loop's resume math, pinned deterministically at the
        decoder: cut after one decode chunk (the only place the loop can
        preempt), resume by prefilling prompt+emitted and feeding the
        SAVED next-token — the joined stream equals the uncut decode."""
        cfg = get_config(ARCH, reduced=True)
        params = dec.init_model(cfg, KEY)
        plen, chunk, total = 8, 4, 12
        prompt = jax.random.randint(jax.random.fold_in(KEY, 1000), (1, plen),
                                    0, cfg.vocab)

        def fresh():
            cache = dec.init_cache(cfg, 1, 32, dtype=jnp.float32)
            lg, cache = dec.prefill(params, cfg, prompt, cache,
                                    compute_dtype=jnp.float32)
            tok = jnp.argmax(lg[:, -1:, :cfg.vocab], -1).astype(jnp.int32)
            return tok, cache

        tok, cache = fresh()
        want, _, _ = dec.decode_loop(params, cfg, tok, cache,
                                     jnp.int32(plen), total,
                                     compute_dtype=jnp.float32)
        want = np.asarray(want)[0].tolist()

        # un-preempted first chunk: emits 4 tokens + the saved next-token
        tok, cache = fresh()
        emitted, ntok, _ = dec.decode_loop(params, cfg, tok, cache,
                                           jnp.int32(plen), chunk,
                                           compute_dtype=jnp.float32)
        emitted = np.asarray(emitted)[0].tolist()
        saved_tok = int(np.asarray(ntok)[0, 0])   # what the loop suspends

        # resume: fresh cache, prefill prompt+emitted, feed saved token
        # (NOT the argmax of the resume prefill — that would re-emit
        # emitted[-1]'s successor one step early)
        seq = jnp.concatenate(
            [prompt, jnp.asarray(emitted, jnp.int32)[None]], axis=1)
        cache = dec.init_cache(cfg, 1, 32, dtype=jnp.float32)
        _, cache = dec.prefill(params, cfg, seq, cache,
                               compute_dtype=jnp.float32)
        rest, _, _ = dec.decode_loop(
            params, cfg, jnp.asarray([[saved_tok]], jnp.int32), cache,
            jnp.int32(plen + chunk), total - chunk,
            compute_dtype=jnp.float32)
        got = emitted + np.asarray(rest)[0].tolist()
        assert got == want

    def test_preemption_off_blocks_instead(self):
        """Same pressure without ``preemption=True``: the blocked head
        waits for the eviction (legacy behaviour), nothing is preempted."""
        from repro.launch.serve import serve_continuous

        out = serve_continuous(ARCH, slots=2, page_size=4, decode_chunk=4,
                               requests=[(8, 24), (8, 4)],
                               max_seq_len=36, num_pages=13)
        assert out["outcomes"] == [COMPLETED, COMPLETED]
        assert out["preemptions"] == 0 and out["resumes"] == 0


class TestWatchdog:
    def test_stall_detection_flags_and_continues(self):
        from repro.launch.serve import serve_continuous

        # every real decode chunk exceeds a 1 ns threshold: the watchdog
        # fires per chunk yet the loop still completes every request
        out = serve_continuous(ARCH, slots=2, page_size=8, decode_chunk=4,
                               requests=[(5, 8), (7, 8)], watchdog_s=1e-9)
        assert out["outcomes"] == [COMPLETED, COMPLETED]
        assert obs.REGISTRY.value("serve.stalls") >= 1


class TestGoodputAccounting:
    def test_deadline_met_tokens_count_as_good(self):
        from repro.launch.serve import serve_continuous

        out = serve_continuous(ARCH, slots=2, page_size=8, decode_chunk=4,
                               requests=[(5, 4), (7, 6)],
                               deadlines=(1e9, 1e9))
        assert out["outcomes"] == [COMPLETED, COMPLETED]
        assert out["good_tokens"] == 10
        assert out["goodput_tok_per_s"] > 0
        assert obs.REGISTRY.value("serve.good_tokens") == 10

    def test_missed_deadline_tokens_are_not_good(self):
        from repro.launch.serve import serve_continuous

        # impossible total deadline on the virtual clock: the request is
        # reaped or evicted — zero good tokens either way
        out = serve_continuous(ARCH, slots=1, page_size=8, decode_chunk=4,
                               requests=[(5, 32)], deadlines=[(None, 0.02)],
                               clock=ticking_clock(dt=0.01))
        assert out["good_tokens"] == 0
        assert out["outcomes"][0] == TIMED_OUT
