"""Fast training-dynamics regression tests (default suite).

Guards the OLMoE training plateau (ROADMAP, fixed in PR 4): with
i.i.d. *uniform* synthetic tokens the CE floor is ``log V`` and the only
achievable descent — flattening the initial logit variance — is smaller
than batch noise for the untied-head MoE arch, so training looked flat.
``SyntheticTokenDataset`` now draws Zipfian unigram tokens (learnable
marginal, H ≪ log V); these tests pin that the loss actually descends,
at a scale small enough for the default (tier-1) suite, so the plateau
cannot silently return while the full 20-step check lives in the slow
suite (``test_system.py::test_moe_arch_trains``).
"""

import numpy as np

from repro.launch.train import train


class TestLossDescends:
    def test_moe_loss_drops_fast(self):
        """Reduced OLMoE: ≥10% loss drop within 12 steps, deterministic
        seed — the plateau regression proper."""
        s = train("olmoe-1b-7b", reduced=True, steps=12, batch=4, seq=32,
                  log_every=0)
        assert s["loss_decreased"], s
        assert s["last_loss"] < 0.9 * s["first_loss"], s

    def test_moe_loss_drops_with_ref_impl(self):
        """The plateau fix is about data/dynamics, not the new kernel
        path: the pure-JAX oracle MoE must descend identically."""
        import dataclasses

        from repro.configs import get_config
        cfg = dataclasses.replace(get_config("olmoe-1b-7b", reduced=True),
                                  moe_impl="ref")
        s = train(cfg, reduced=True, steps=12, batch=4, seq=32, log_every=0)
        assert s["last_loss"] < 0.9 * s["first_loss"], s

    def test_synthetic_data_has_learnable_skew(self):
        """The dataset's unigram entropy must sit well below log V —
        that's the headroom the regression tests rely on."""
        from repro.data import SyntheticTokenDataset

        ds = SyntheticTokenDataset(1024, 4, 64, seed=0)
        toks = np.concatenate([ds.batch(i)["tokens"].ravel()
                               for i in range(8)])
        counts = np.bincount(toks, minlength=1024).astype(np.float64)
        p = counts / counts.sum()
        ent = -(p[p > 0] * np.log(p[p > 0])).sum()
        assert ent < 0.8 * np.log(1024), ent
