"""Per-architecture smoke tests: REDUCED variants (≤2 pattern repeats,
d_model≤512, ≤4 experts) run one real forward/train/decode step on CPU,
asserting output shapes and no NaNs — the assignment's smoke requirement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import init_train_state, make_train_step
from repro.models import decode_step, forward, init_cache, init_model, loss_fn
#: system-scale tests — excluded from the default (tier-1) run via
#: `-m "not slow"`; run them with `pytest -m slow` or `-m ""`.
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    b = {"tokens": toks, "labels": toks}
    if cfg.cross_kv_len:
        n = cfg.encoder.frames if cfg.encoder else cfg.cross_kv_len
        b["context"] = jax.random.normal(KEY, (B, n, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_reduced_config_limits(self, arch):
        cfg = get_config(arch, reduced=True)
        assert cfg.num_layers <= 4
        assert cfg.d_model <= 512
        assert cfg.moe_experts <= 4

    def test_full_config_matches_assignment(self, arch):
        cfg = get_config(arch)
        cfg.validate()
        spec = {
            "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
            "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
            "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
            "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
            "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
            "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
            "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
            "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
            "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
            "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        }[arch]
        L, d, H, KV, ff, V = spec
        assert cfg.num_layers == L and cfg.d_model == d
        assert cfg.n_heads == H and cfg.n_kv_heads == KV
        assert cfg.d_ff == ff and cfg.vocab == V

    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch, reduced=True)
        params = init_model(cfg, KEY)
        b = _batch(cfg)
        logits, _ = forward(params, cfg, b["tokens"], context=b.get("context"),
                            compute_dtype=jnp.float32)
        assert logits.shape == (2, 32, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits)).all()

    def test_train_step_decreases_loss(self, arch):
        cfg = get_config(arch, reduced=True)
        params, opt = init_train_state(cfg, KEY)
        step = jax.jit(make_train_step(cfg, lr=1e-3, microbatch=None,
                                       compute_dtype=jnp.float32))
        b = _batch(cfg, B=4, S=16)
        l0 = float(loss_fn(params, cfg, b, compute_dtype=jnp.float32))
        for _ in range(3):
            params, opt, m = step(params, opt, b)
            assert np.isfinite(float(m["loss"]))
        l1 = float(loss_fn(params, cfg, b, compute_dtype=jnp.float32))
        assert l1 < l0  # same-batch overfit sanity

    def test_decode_step_shapes_and_finite(self, arch):
        cfg = get_config(arch, reduced=True)
        params = init_model(cfg, KEY)
        B = 2
        cache = init_cache(cfg, B, 64, dtype=jnp.float32)
        tok = jax.random.randint(KEY, (B, 1), 0, cfg.vocab)
        logits, new_cache = decode_step(params, cfg, tok, cache, jnp.int32(3),
                                        compute_dtype=jnp.float32)
        assert logits.shape == (B, 1, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits)).all()
        assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


class TestDecodeConsistency:
    """Teacher-forced decode must match the parallel forward (same math)."""

    @pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-2b", "rwkv6-7b",
                                      "jamba-v0.1-52b", "olmoe-1b-7b"])
    def test_decode_matches_forward(self, arch):
        import dataclasses

        cfg = get_config(arch, reduced=True)
        if cfg.has_moe:
            # disable capacity drops: batched routing drops tokens a
            # per-token decode wouldn't (GShard semantics); equivalence
            # holds at full capacity.
            cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
        params = init_model(cfg, KEY)
        B, S = 1, 12
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        full, _ = forward(params, cfg, toks, compute_dtype=jnp.float32,
                          remat=False)
        cache = init_cache(cfg, B, S, dtype=jnp.float32)
        outs = []
        for i in range(S):
            lg, cache = decode_step(params, cfg, toks[:, i : i + 1], cache,
                                    jnp.int32(i), compute_dtype=jnp.float32)
            outs.append(lg[:, 0])
        dec = jnp.stack(outs, axis=1)
        # MoE top-k ties can flip experts between batched/single-token
        # routing; tolerance covers that for the moe archs.
        tol = 2e-2 if cfg.has_moe else 2e-3
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                                   atol=tol, rtol=tol)
