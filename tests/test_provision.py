"""Provisioning tests (§5.1: load balance + Newton + static baselines)."""

import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hard dep: deterministic fallback shim
    from _hypothesis_fallback import given, settings, st


from repro.core import (
    SchedulingPlan, TrainingJob, build_stages, default_fleet,
    monetary_cost, paper_model_profiles, pipeline_throughput,
)
from repro.core.provision import provision, provision_sta_ratio, required_k

FLEET = default_fleet()
JOB = TrainingJob()


def _stages(plan=None):
    profs = paper_model_profiles("CTRDNN", FLEET)
    plan = plan or SchedulingPlan((0,) + (1,) * 15)
    return plan, profs, build_stages(plan, profs, FLEET)


class TestRequiredK:
    def test_monotone_in_throughput(self):
        _, _, stages = _stages()
        s = stages[0]
        ks = [required_k(s, t, 4096) for t in (1e4, 5e4, 1e5, 2e5)]
        assert all(a <= b for a, b in zip(ks, ks[1:]))

    def test_amdahl_ceiling_is_infeasible(self):
        """No replica count can beat the sequential fraction (Formula 13)."""
        _, _, stages = _stages()
        s = stages[0]
        ceiling = 64 / (s.oct * (1 - s.alpha))  # examples/s asymptote
        assert math.isinf(required_k(s, ceiling * 1.01, 4096))
        assert math.isfinite(required_k(s, ceiling * 0.9, 4096))


class TestProvision:
    def test_meets_throughput_constraint(self):
        plan, profs, stages = _stages()
        prov = provision(stages, FLEET, JOB)
        assert prov is not None
        assert pipeline_throughput(stages, prov, JOB.batch_size) >= JOB.throughput_limit

    def test_load_balance_no_gross_straggler(self):
        """§5.1: stage throughputs should be near-equal (≤ the integer
        rounding gap)."""
        from repro.core.cost_model import stage_throughput

        plan, profs, stages = _stages()
        prov = provision(stages, FLEET, JOB)
        tps = [stage_throughput(s, k, JOB.batch_size)
               for s, k in zip(stages, prov.k)]
        # the bottleneck stage is within ~2x of the fastest stage when its
        # k could still be decremented (integer effects allowed)
        assert min(tps) >= JOB.throughput_limit

    def test_ps_cores_added_for_accelerator_stages(self):
        plan, profs, stages = _stages()
        prov = provision(stages, FLEET, JOB)
        assert prov.ps_cores >= 1  # GPU stage present → PS cores

    def test_infeasible_job_returns_none(self):
        plan, profs, stages = _stages(SchedulingPlan((0,) * 16))
        assert provision(stages, FLEET, JOB) is None

    def test_beats_static_ratio_baselines(self):
        """Paper Fig. 4: our provisioning costs ≤ StaRatio/StaPSRatio."""
        plan, profs, stages = _stages()
        ours = provision(stages, FLEET, JOB)
        c_ours = monetary_cost(plan, ours, profs, FLEET, JOB)
        for with_ps in (False, True):
            sta = provision_sta_ratio(stages, FLEET, JOB, with_ps=with_ps)
            if sta is None:
                continue
            c_sta = monetary_cost(plan, sta, profs, FLEET, JOB)
            if math.isfinite(c_sta):
                assert c_ours <= c_sta * 1.001

    @given(st.floats(min_value=1e4, max_value=4e5))
    @settings(max_examples=20, deadline=None)
    def test_feasible_whenever_constraint_reachable(self, limit):
        plan, profs, stages = _stages()
        job = TrainingJob(throughput_limit=limit)
        prov = provision(stages, FLEET, job)
        if prov is not None:
            assert pipeline_throughput(stages, prov, job.batch_size) >= limit
