"""Observability spine tests (``repro.obs``).

Pins the contracts the rest of the system leans on:

* Histogram quantiles within a factor ``GROWTH`` of the true order
  statistic (property-tested), registry thread-safety under concurrent
  ``record()``;
* span tracing: ring capacity, disabled = no events, per-(pid, tid)
  monotonic timestamps after ``merged()`` — including the real thing, a
  multi-process trace collected from spawned PS shard workers;
* ``PSTelemetry`` bit-compatibility: the registry-backed refactor keeps
  ``totals``/``to_resource``/``embedding_odt`` arithmetic exactly as the
  pre-registry implementation (hand-computed expectations);
* the live cost-model bridge and the ``PSClient.close()`` drain span /
  final counters.
"""

from __future__ import annotations

import math
import os
import threading
from collections import defaultdict

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hard dep: deterministic fallback shim
    from _hypothesis_fallback import given, settings, st

from repro import obs
from repro.core.profiles import B_O
from repro.core.resources import CPU_CORE
from repro.obs import metrics, trace
from repro.obs.bridge import apply_measured_odt, snapshot_resources
from repro.ps.client import PSClient
from repro.ps.telemetry import PSTelemetry
from repro.ps.transport import make_transport

DIM = 8


@pytest.fixture()
def obs_enabled():
    """Obs on + clean global buffer/registry, restored afterwards."""
    was = obs.enabled()
    obs.configure(enabled=True)
    trace.BUFFER.drain()
    obs.REGISTRY.reset()
    try:
        yield
    finally:
        obs.configure(enabled=was)
        trace.BUFFER.drain()
        obs.REGISTRY.reset()


def _true_rank_value(values: list[float], q: float) -> float:
    vs = sorted(values)
    rank = min(len(vs) - 1, max(0, math.ceil(q * len(vs)) - 1))
    return vs[rank]


class TestHistogram:
    @given(st.lists(st.floats(min_value=1e-7, max_value=1e7),
                    min_size=1, max_size=200),
           st.floats(min_value=0.0, max_value=1.0))
    @settings(max_examples=60, deadline=None)
    def test_quantile_within_growth_of_order_statistic(self, values, q):
        reg = metrics.Registry("prop", enabled=True)
        h = reg.histogram("x")
        for v in values:
            h.record(v)
        est = h.quantile(q)
        true = _true_rank_value(values, q)
        assert true / metrics.GROWTH - 1e-12 <= est \
            <= true * metrics.GROWTH + 1e-12

    def test_edges(self):
        reg = metrics.Registry("edges", enabled=True)
        h = reg.histogram("x")
        assert h.quantile(0.5) == 0.0          # empty
        for v in (0.0, 5e-10, 1.0, 2.0):       # two land in the floor bucket
            h.record(v)
        assert h.quantile(0.0) == 0.0          # exact min
        assert h.quantile(1.0) == 2.0          # exact max
        assert h.quantile(0.25) == 0.0         # floor bucket → exact min
        assert h.count == 4 and h.min == 0.0 and h.max == 2.0

    def test_disabled_records_nothing(self):
        reg = metrics.Registry("off", enabled=False)
        h, c, g = reg.histogram("h"), reg.counter("c"), reg.gauge("g")
        h.record(1.0), c.inc(), g.set(3.0)
        assert h.count == 0 and c.value == 0.0 and g.value == 0.0

    def test_kind_clash_raises(self):
        reg = metrics.Registry("clash", enabled=True)
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.histogram("x")


class TestRegistryThreadSafety:
    def test_concurrent_record_exact_counts(self):
        reg = metrics.Registry("mt", enabled=True)
        threads, per = 8, 500

        def work(i):
            c = reg.counter("ops")          # shared get-or-create
            h = reg.histogram("lat")
            for k in range(per):
                c.inc()
                h.record(1e-3 * (1 + (i * per + k) % 97))

        ts = [threading.Thread(target=work, args=(i,))
              for i in range(threads)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert reg.counter("ops").value == threads * per
        h = reg.histogram("lat")
        assert h.count == threads * per
        assert sum(h._buckets.values()) == threads * per


class TestTrace:
    def test_ring_capacity(self):
        buf = trace.TraceBuffer(capacity=4)
        for i in range(10):
            buf.add({"ts": i})
        assert [e["ts"] for e in buf.events()] == [6, 7, 8, 9]
        assert buf.drain() and len(buf) == 0

    def test_disabled_span_is_noop(self):
        was = trace.enabled()
        trace.set_enabled(False)
        try:
            trace.BUFFER.drain()
            with trace.span("x") as sp:
                sp.args["k"] = 1            # annotating a noop is safe
            trace.instant("y")
            assert len(trace.BUFFER) == 0
        finally:
            trace.set_enabled(was)

    def test_span_nesting_and_merge_monotonic(self, obs_enabled):
        with trace.span("outer", "t"):
            with trace.span("inner", "t", k=1):
                pass
        trace.instant("mark", "t")
        evs = trace.merged(trace.BUFFER.events())
        names = [e["name"] for e in evs]
        # merged() sorts by ts: outer starts before inner
        assert names == ["outer", "inner", "mark"]
        ts = [e["ts"] for e in evs]
        assert ts == sorted(ts)
        assert evs[1]["args"] == {"k": 1}
        assert evs[0]["dur"] >= evs[1]["dur"]

    def test_multiproc_worker_lanes_merge(self, obs_enabled):
        """The acceptance trace shape: spans from the main process AND
        >=2 spawned shard workers, distinct pid lanes, each lane
        monotonically timestamped."""
        tr = make_transport("multiproc")
        try:
            for s in (0, 1):
                tr.add_shard(s, dim=DIM)
                tr.request(s, {"op": "create", "bucket": s,
                               "rows": np.zeros((4, DIM), np.float32)})
                tr.request(s, {"op": "pull",
                               "buckets": np.array([s, s]),
                               "ids": np.array([0, 1])})
            with trace.span("main.work", "test"):
                pass
        finally:
            tr.close()                       # ships worker events back
        evs = trace.merged(trace.BUFFER.events())
        pids = {e["pid"] for e in evs if e.get("ph") != "M"}
        assert os.getpid() in pids
        assert len(pids - {os.getpid()}) >= 2, f"worker lanes missing: {pids}"
        lane_names = {e["args"]["name"] for e in evs if e.get("ph") == "M"}
        assert {"ps-shard-0", "ps-shard-1"} <= lane_names
        shard_spans = [e for e in evs if e["name"].startswith("ps.shard.")]
        assert {e["name"] for e in shard_spans} >= {"ps.shard.create",
                                                    "ps.shard.pull"}
        lanes = defaultdict(list)
        for e in evs:
            if e.get("ph") != "M":
                lanes[(e["pid"], e["tid"])].append(e["ts"])
        assert len(lanes) >= 3
        for lane, ts in lanes.items():
            assert ts == sorted(ts), f"lane {lane} not monotonic"


class TestPSTelemetryBitCompat:
    """Hand-computed pins: the registry-backed refactor must reproduce
    the pre-registry arithmetic exactly."""

    def _loaded(self) -> PSTelemetry:
        tel = PSTelemetry(2)
        tel.record("pull", rows=np.array([4, 0]), bytes_=np.array([400, 0]),
                   seconds=0.5, hot_rows=np.array([1, 0]))
        tel.record("pull", rows=np.array([2, 6]),
                   bytes_=np.array([200, 600]), seconds=0.25)
        tel.record("push", rows=np.array([3, 3]),
                   bytes_=np.array([300, 300]), seconds=0.5)
        return tel

    def test_totals(self):
        t = self._loaded().totals()
        assert t["pull"] == {"ops": 2, "rows": 12, "bytes": 1200,
                             "seconds": 0.75, "bandwidth": 1200 / 0.75,
                             "hot_fraction": 1 / 12}
        assert t["push"] == {"ops": 1, "rows": 6, "bytes": 600,
                             "seconds": 0.5, "bandwidth": 600 / 0.5,
                             "hot_fraction": 0.0}

    def test_zero_row_shards_not_counted(self):
        tel = self._loaded()
        # the shard-1 entry of the first pull carried 0 rows: no op there
        assert tel.pull[1].ops == 1 and tel.pull[0].ops == 2

    def test_to_resource(self):
        res = self._loaded().to_resource(CPU_CORE)
        assert res.name == "cpu+ps"
        assert res.ingest_bw == pytest.approx(1200 / 0.75)
        assert res.net_bw == pytest.approx((1200 + 600) / (0.75 + 0.5))
        # unmeasured terms keep the nominal constants
        assert res.flops == CPU_CORE.flops

    def test_to_resource_no_traffic_keeps_base(self):
        res = PSTelemetry(2).to_resource(CPU_CORE)
        assert res.ingest_bw == CPU_CORE.ingest_bw
        assert res.net_bw == CPU_CORE.net_bw

    def test_embedding_odt(self):
        sync, act = self._loaded().embedding_odt(100)
        assert sync == pytest.approx((0.75 + 0.5) / 100 * B_O)
        assert act == pytest.approx(0.75 / 100 * B_O)
        assert PSTelemetry(2).embedding_odt(0) == (0.0, 0.0)

    def test_ensure_grows(self):
        tel = self._loaded()
        tel.ensure(4)
        assert tel.num_shards == 4 and tel.pull[3].ops == 0
        # history stays additive
        assert tel.totals()["pull"]["rows"] == 12


class TestBridge:
    def test_snapshot_with_telemetry(self):
        tel = PSTelemetry(1)
        tel.record("pull", rows=np.array([10]), bytes_=np.array([1000]),
                   seconds=0.1)
        snap = snapshot_resources(CPU_CORE, telemetry=tel, num_examples=10)
        assert snap["resource"].name == "cpu+ps"
        assert snap["resource"].ingest_bw == pytest.approx(1000 / 0.1)
        assert snap["embedding_odt"][1] == pytest.approx(0.1 / 10 * B_O)
        assert snap["ps"]["pull"]["bytes"] == 1000

    def test_snapshot_serve_signals(self, obs_enabled):
        reg = obs.REGISTRY
        reg.gauge("serve.queue_depth").set(3)
        reg.gauge("serve.pool_pages_total").set(28)
        reg.counter("serve.evictions").inc(2)
        for v in (0.1, 0.2, 0.4):
            reg.histogram("serve.ttft_s").record(v)
        snap = snapshot_resources(CPU_CORE)
        assert snap["resource"].name == "cpu+obs"
        sig = snap["serve"]
        assert sig["queue_depth"] == 3 and sig["evictions"] == 2
        assert sig["ttft"]["count"] == 3
        assert 0.1 <= sig["ttft"]["p50"] <= 0.4

    def test_apply_measured_odt(self):
        from repro.core.profiles import LayerProfile

        p = LayerProfile(index=0, kind="embedding", flops=1.0,
                         input_bytes=4.0, weight_bytes=8.0, output_bytes=4.0,
                         oct=(1.0, 2.0), odt_sync=(0.1, 0.1),
                         odt_act=(0.2, 0.2))
        q = apply_measured_odt(p, 0.5, 0.25)
        assert q.odt_sync == (0.5, 0.5) and q.odt_act == (0.25, 0.25)
        assert q.oct == p.oct


class _FakeTable:
    def __init__(self):
        self.pushes = 0

    def pull(self, ids):
        return np.zeros((len(ids), DIM), np.float32)

    def push(self, ids, grads, *, lr, dedup=True):
        self.pushes += 1


class TestClientDrain:
    def test_close_emits_drain_span_and_final_counters(self, obs_enabled):
        table = _FakeTable()
        loader = [{"ids": np.arange(4)} for _ in range(3)]
        client = PSClient(table, loader, depth=2)
        for batch, rows in client:
            client.push(batch["ids"], rows, lr=0.1)
        client.close()
        assert table.pushes == 3
        drains = [e for e in trace.BUFFER.events()
                  if e["name"] == "ps.client.drain"]
        assert len(drains) == 1
        assert drains[0]["args"]["dropped"] == 0
        assert {e["name"] for e in trace.BUFFER.events()} >= {
            "ps.client.pull", "ps.client.push_apply"}
        assert obs.REGISTRY.value("ps.client.steps_pulled") == 3
        assert obs.REGISTRY.value("ps.client.steps_pushed") == 3
        assert obs.REGISTRY.value("ps.client.pushes_dropped") == 0


class TestExportRoundTrip:
    def test_flush_writes_trace_and_metrics(self, obs_enabled, tmp_path):
        obs.configure(run_dir=str(tmp_path))
        try:
            with trace.span("work", "t"):
                obs.REGISTRY.counter("n").inc(5)
            paths = obs.flush()
            from repro.obs import export

            tr = export.read_trace(str(tmp_path))
            assert any(e["name"] == "work" for e in tr["traceEvents"])
            snaps = export.read_metrics(str(tmp_path))
            flat = [m for m in snaps[-1]["registries"]["default"]
                    if m["name"] == "n"]
            assert flat and flat[0]["value"] == 5.0
            assert paths["trace"].endswith("trace.json")
        finally:
            obs._run_dir = None
