"""Reactive re-planning loop: windowed deltas, drift triggers,
hysteresis/cooldown (no flapping), switch-cost margin, warm-start seam,
and the measurement-bug regressions (closed-registry skip, multi-stream
histogram merge)."""

from __future__ import annotations

import math

import numpy as np
import pytest

from repro.core.cost_model import TrainingJob, plan_cost
from repro.core.plan import SchedulingPlan
from repro.core.profiles import ctrdnn_layers, profile_layers
from repro.core.replan import (
    DriftDetector,
    ReplanConfig,
    ReplanController,
)
from repro.core.resources import default_fleet
from repro.core.schedulers.base import ScheduleResult
from repro.obs import bridge
from repro.obs import metrics as obs_metrics

FLEET = default_fleet()
JOB = TrainingJob()
SPECS = ctrdnn_layers()
CPU = FLEET[0]


def snap(pull_b=0.0, pull_s=0.0, push_b=0.0, push_s=0.0, *,
         queue=0.0, tokens=0.0, ttft=None, tpot=None,
         events=None, degraded=False, dead=()):
    """A snapshot_resources-shaped dict from raw cumulative numbers."""
    serve = {"queue_depth": queue, "tokens": tokens}
    if ttft is not None:
        serve["ttft"] = ttft
    if tpot is not None:
        serve["tpot"] = tpot
    out = {"resource": CPU, "embedding_odt": (0.0, 0.0), "serve": serve,
           "ps": {"pull": {"bytes": pull_b, "seconds": pull_s, "rows": 0},
                  "push": {"bytes": push_b, "seconds": push_s, "rows": 0}}}
    if events is not None or degraded or dead:
        out["ps_health"] = {"degraded": degraded,
                            "dead_shards": list(dead),
                            "events": dict(events or {})}
    return out


class TrafficFeed:
    """Cumulative fake PS traffic whose windowed rates are exact
    multiples of the CPU type's nominal bandwidths."""

    def __init__(self):
        self.pb = self.ps_ = self.qb = self.qs = 0.0

    def window(self, scale: float, **kw) -> dict:
        pull_b = scale * CPU.ingest_bw
        push_b = 2 * scale * CPU.net_bw - pull_b
        self.pb += pull_b
        self.ps_ += 1.0
        self.qb += push_b
        self.qs += 1.0
        return snap(self.pb, self.ps_, self.qb, self.qs, **kw)


class FakeScheduler:
    """Returns a fixed alternative plan costed relative to the warm
    start's true cost — lets tests pin the margin logic exactly."""

    def __init__(self, alt, factor):
        self.alt = tuple(alt)
        self.factor = factor
        self.calls = 0
        self.last_warm = None

    def schedule_many(self, specs, warm_starts=None):
        self.calls += 1
        self.last_warm = warm_starts
        profiles, fleet, job = specs[0]
        inc = warm_starts[0][0]
        inc_cost, _ = plan_cost(SchedulingPlan(tuple(inc)), profiles,
                                fleet, job)
        return [ScheduleResult(plan=SchedulingPlan(self.alt), prov=None,
                               cost=self.factor * inc_cost,
                               wall_time_s=0.0, evaluations=0)]


def make_controller(sched, cfg=None, initial=None):
    clock = {"t": 0.0}
    cfg = cfg or ReplanConfig(window_steps=1, bw_tolerance=0.5,
                              hysteresis_windows=2, cooldown_windows=2,
                              switch_margin=0.05)
    initial = initial if initial is not None else tuple(
        0 if k in ("embedding", "nce") else 1
        for k, *_ in SPECS)
    ctl = ReplanController(SPECS, FLEET, JOB, sched, snapshot_fn=lambda: None,
                           config=cfg, clock=lambda: clock["t"],
                           initial=initial)
    def observe(snapshot):
        clock["t"] += 5.0
        return ctl.observe(snapshot=snapshot)
    return ctl, observe


def calibrate(ctl, observe, feed):
    observe(feed.window(1.0))   # opens the first window
    d = observe(feed.window(1.0))
    assert d is not None and d["kind"] == "calibrate"
    assert ctl.calibrations == 1 and ctl.considered == 0
    return d


# --- windowed delta arithmetic -------------------------------------------

def test_snapshot_delta_interval_rates():
    prev = snap(100.0, 1.0, 50.0, 0.5, queue=2.0, tokens=10.0,
                ttft={"count": 3, "p99": 0.1},
                events={"kill": 0})
    cur = snap(700.0, 3.0, 250.0, 1.5, queue=5.0, tokens=25.0,
               ttft={"count": 8, "p99": 0.4},
               events={"kill": 1}, degraded=True, dead=[0])
    d = bridge.snapshot_delta(prev, cur, 10.0)
    assert d.seconds == 10.0
    assert d.pull_bytes == 600.0 and d.pull_seconds == 2.0
    assert d.push_bytes == 200.0 and d.push_seconds == 1.0
    # interval rates, NOT lifetime averages (700/3 would be the lifetime)
    assert d.ingest_bw == pytest.approx(300.0)
    assert d.net_bw == pytest.approx(800.0 / 3.0)
    assert d.tokens == 15.0
    assert d.queue_depth == 5.0 and d.queue_growth == 3.0
    assert d.ttft_completed == 5.0 and d.ttft["p99"] == 0.4
    assert d.ps_degraded and d.dead_shards == 1 and d.fleet_events == 1
    # re-anchoring keeps base constants where there is no traffic
    res = d.resource(CPU)
    assert res.ingest_bw == pytest.approx(300.0)
    empty = bridge.snapshot_delta(cur, cur, 1.0)
    assert not empty.has_ps_traffic
    assert empty.resource(CPU).ingest_bw == CPU.ingest_bw
    assert empty.embedding_odt(100) == (0.0, 0.0)


def test_snapshot_delta_embedding_odt_windowed():
    prev = snap(0.0, 0.0, 0.0, 0.0)
    cur = snap(10.0, 2.0, 10.0, 1.0)
    d = bridge.snapshot_delta(prev, cur, 1.0)
    from repro.core.profiles import B_O

    sync, act = d.embedding_odt(100)
    assert sync == pytest.approx(3.0 / 100 * B_O)
    assert act == pytest.approx(2.0 / 100 * B_O)


# --- drift detector -------------------------------------------------------

def det(cfg=None):
    cfg = cfg or ReplanConfig(bw_tolerance=0.5, hysteresis_windows=2,
                              ttft_slo_s=0.2, queue_growth=4.0)
    return DriftDetector(cfg, ingest_bw=100.0, net_bw=100.0), cfg


def delta(**kw):
    prev = snap()
    fields = dict(pull_b=kw.pop("pull_b", 0.0),
                  pull_s=kw.pop("pull_s", 0.0),
                  push_b=kw.pop("push_b", 0.0),
                  push_s=kw.pop("push_s", 0.0))
    return bridge.snapshot_delta(prev, snap(**fields, **kw), 1.0)


def test_detector_bandwidth_hysteresis():
    d, _ = det()
    drifted = delta(pull_b=20.0, pull_s=1.0, push_b=20.0, push_s=1.0)
    assert d.check(drifted) == []          # streak 1 < hysteresis 2
    assert d.check(drifted) == ["ingest_bw", "net_bw"]
    # an in-tolerance window resets the streak
    steady = delta(pull_b=100.0, pull_s=1.0, push_b=100.0, push_s=1.0)
    assert d.check(steady) == []
    assert d.check(drifted) == []          # streak restarted


def test_detector_min_traffic_gate():
    d, _ = det()
    tiny = delta(pull_b=1e-9, pull_s=1e-9)  # absurd rate, negligible traffic
    assert d.check(tiny) == []
    assert d.check(tiny) == []


def test_detector_edge_signals_fire_once():
    d, _ = det()
    kill = bridge.snapshot_delta(snap(events={"kill": 0}),
                                 snap(events={"kill": 1}, degraded=True,
                                      dead=[0]), 1.0)
    assert sorted(d.check(kill)) == ["fleet_events", "ps_degraded"]
    # persistently degraded, no new events: nothing re-fires
    still = bridge.snapshot_delta(snap(events={"kill": 1}, degraded=True),
                                  snap(events={"kill": 1}, degraded=True,
                                       dead=[0]), 1.0)
    assert d.check(still) == []


def test_detector_slo_and_queue():
    d, _ = det()
    bad = delta(ttft={"count": 5, "p99": 0.5}, queue=10.0)
    assert d.check(bad) == []
    assert sorted(d.check(bad)) == ["queue_growth", "ttft_slo"]
    # SLO violation with zero completions in the window must not count
    d2, _ = det()
    stale = bridge.snapshot_delta(snap(ttft={"count": 5, "p99": 0.5}),
                                  snap(ttft={"count": 5, "p99": 0.5}), 1.0)
    assert d2.check(stale) == []
    assert d2.check(stale) == []


def test_detector_reanchor_absorbs_shift():
    d, _ = det()
    drifted = delta(pull_b=20.0, pull_s=1.0, push_b=20.0, push_s=1.0)
    d.check(drifted)
    assert d.check(drifted) != []
    d.reanchor(ingest_bw=drifted.ingest_bw, net_bw=drifted.net_bw)
    assert d.check(drifted) == []
    assert d.check(drifted) == []          # the new normal


# --- controller: calibration, triggers, cooldown, margin ------------------

def test_controller_calibrates_then_stays_quiet():
    sched = FakeScheduler(alt=(1,) * len(SPECS), factor=10.0)
    ctl, observe = make_controller(sched)
    feed = TrafficFeed()
    calibrate(ctl, observe, feed)
    for _ in range(6):
        assert observe(feed.window(1.0)) is None
    assert ctl.considered == 0 and sched.calls == 1


def test_controller_exactly_one_replan_per_shift_no_flap():
    # worse-than-incumbent during calibration so the calibrate replan
    # does not already swap the plan; better after the shift
    sched = FakeScheduler(alt=(1,) * len(SPECS), factor=10.0)
    ctl, observe = make_controller(sched)
    feed = TrafficFeed()
    calibrate(ctl, observe, feed)
    sched.factor = 0.5
    decisions = [observe(feed.window(0.15)) for _ in range(10)]
    fired = [d for d in decisions if d is not None]
    assert len(fired) == 1 and fired[0]["kind"] == "drift"
    assert ctl.considered == 1 and ctl.applied == 1
    assert ctl.incumbent.assignment == sched.alt
    # the shift is the new baseline: further identical windows are quiet
    for _ in range(5):
        assert observe(feed.window(0.15)) is None
    assert ctl.considered == 1


def test_controller_switch_margin_keeps_incumbent():
    # candidate 4% better: inside the 5% switch margin -> not applied
    sched = FakeScheduler(alt=(1,) * len(SPECS), factor=10.0)
    ctl, observe = make_controller(sched)
    inc0 = ctl.incumbent.assignment
    feed = TrafficFeed()
    calibrate(ctl, observe, feed)
    sched.factor = 0.96
    fired = [d for d in (observe(feed.window(0.15)) for _ in range(6)) if d]
    assert len(fired) == 1 and fired[0]["applied"] is False
    assert ctl.incumbent.assignment == inc0
    assert ctl.considered == 1 and ctl.applied == 0
    # and the incumbent was re-scored against the live profiles
    assert ctl.incumbent.cost == pytest.approx(fired[0]["incumbent_cost"])


def test_controller_cooldown_blocks_next_window():
    # cooldown 3, then a *different* second shift right after the first
    sched = FakeScheduler(alt=(1,) * len(SPECS), factor=10.0)
    cfg = ReplanConfig(window_steps=1, bw_tolerance=0.5,
                       hysteresis_windows=1, cooldown_windows=3,
                       switch_margin=0.05)
    ctl, observe = make_controller(sched, cfg=cfg)
    feed = TrafficFeed()
    calibrate(ctl, observe, feed)
    sched.factor = 0.5
    assert observe(feed.window(0.15)) is not None    # hysteresis=1: fires
    # second, deeper shift lands inside the cooldown: suppressed
    for _ in range(3):
        assert observe(feed.window(0.02)) is None
    assert ctl.considered == 1
    # after cooldown the (still-shifted) rates CAN fire again
    assert observe(feed.window(0.02)) is not None
    assert ctl.considered == 2


def test_controller_passes_incumbent_as_warm_start():
    sched = FakeScheduler(alt=(1,) * len(SPECS), factor=10.0)
    ctl, observe = make_controller(sched)
    inc0 = ctl.incumbent.assignment
    feed = TrafficFeed()
    calibrate(ctl, observe, feed)
    assert sched.last_warm == [(inc0,)]


def test_controller_report_shape():
    sched = FakeScheduler(alt=(1,) * len(SPECS), factor=10.0)
    ctl, observe = make_controller(sched)
    feed = TrafficFeed()
    calibrate(ctl, observe, feed)
    sched.factor = 0.5
    [observe(feed.window(0.15)) for _ in range(4)]
    r = ctl.report()
    assert r["windows"] >= 5 and r["calibrations"] == 1
    assert r["considered"] == 1 and r["applied"] == 1
    assert r["decisions"][0]["kind"] == "calibrate"
    assert r["incumbent"]["assignment"] == list(ctl.incumbent.assignment)


# --- warm-start seam in the real scheduler --------------------------------

def test_rl_warm_start_never_worse_than_incumbent():
    from repro.core.schedulers.rl import RLScheduler

    profiles = profile_layers(SPECS, FLEET)
    warm = tuple(0 if p.kind in ("embedding", "nce") else 1
                 for p in profiles)
    warm_cost, _ = plan_cost(SchedulingPlan(warm), profiles, FLEET, JOB)
    assert math.isfinite(warm_cost)
    # a 2-round search finds nothing on its own — the warm anchor must
    # still bound the result
    sched = RLScheduler(rounds=2, plans_per_round=4, early_stop_rounds=2,
                        fused=False, seed=0)
    res = sched.schedule_many([(profiles, FLEET, JOB)],
                              warm_starts=[(warm,)])[0]
    assert res.cost <= warm_cost + 1e-9


def test_rl_warm_start_ignores_malformed():
    from repro.core.schedulers.rl import RLScheduler

    profiles = profile_layers(SPECS, FLEET)
    sched = RLScheduler(rounds=2, plans_per_round=4, early_stop_rounds=2,
                        fused=False, seed=0)
    bad = ((99,) * len(profiles), (0,) * (len(profiles) - 1))
    res = sched.schedule_many([(profiles, FLEET, JOB)],
                              warm_starts=[bad])[0]
    assert res.feasible


# --- measurement-bug regressions ------------------------------------------

def test_ps_traffic_skips_closed_registries():
    a = obs_metrics.Registry("replan-test-a", enabled=True)
    b = obs_metrics.Registry("replan-test-b", enabled=True)
    for reg, byts in ((a, 1000.0), (b, 500.0)):
        reg.counter("ps.bytes", dir="pull", shard=0).inc(byts)
        reg.counter("ps.seconds", dir="pull", shard=0).inc(1.0)
    a.close()
    out = bridge._ps_traffic(registries=[a, b])
    # the closed registry's stale cumulative traffic must not bleed in
    assert out["pull"]["bytes"] == 500.0
    assert out["pull"]["seconds"] == 1.0


def test_telemetry_close_marks_registry():
    from repro.ps.telemetry import PSTelemetry

    tel = PSTelemetry(2)
    assert not tel.registry.closed
    tel.close()
    assert tel.registry.closed
    assert tel.registry not in obs_metrics.live_registries()
    # reads keep working as history
    assert tel.totals()["pull"]["bytes"] == 0


def test_serve_signals_merges_multistream_histograms():
    reg = obs_metrics.Registry("replan-test-serve", enabled=True)
    h1 = reg.histogram("serve.ttft_s", stream="a")
    h2 = reg.histogram("serve.ttft_s", stream="b")
    for v in (0.01, 0.02, 0.03):
        h1.record(v)
    for v in (1.0, 2.0, 3.0):
        h2.record(v)
    sig = bridge._serve_signals(reg)
    # pooled, not last-writer-wins: count is the union and the p99 must
    # reflect the slow stream regardless of find() iteration order
    assert sig["ttft"]["count"] == 6
    assert sig["ttft"]["streams"] == 2
    assert sig["ttft"]["p99"] >= 3.0 / obs_metrics.GROWTH
    assert sig["ttft"]["min"] == pytest.approx(0.01)
    assert sig["ttft"]["max"] == pytest.approx(3.0)


def test_merge_histograms_matches_single():
    rng = np.random.default_rng(0)
    reg = obs_metrics.Registry("replan-test-merge", enabled=True)
    parts = [reg.histogram("h", i=i) for i in range(3)]
    union = reg.histogram("h", i="all")
    vals = rng.lognormal(0.0, 2.0, 300)
    for i, v in enumerate(vals):
        parts[i % 3].record(float(v))
        union.record(float(v))
    merged = obs_metrics.merge_histograms(parts)
    single = union.snapshot()
    for k in ("count", "sum", "min", "max", "p50", "p95", "p99"):
        assert merged[k] == pytest.approx(single[k]), k
    assert obs_metrics.merge_histograms([]) == {
        "count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0, "max": 0.0,
        "p50": 0.0, "p95": 0.0, "p99": 0.0}


# --- admission actuator (PR 10) -------------------------------------------

def adelta(**kw):
    """A SnapshotDelta with every field zeroed except the overrides —
    the actuator consumes deltas directly, no snapshots needed."""
    base = dict(seconds=5.0, pull_bytes=0.0, push_bytes=0.0,
                pull_seconds=0.0, push_seconds=0.0, tokens=0.0,
                queue_depth=0.0, queue_growth=0.0, ttft=None, tpot=None,
                ttft_completed=0.0, tpot_completed=0.0, ps_degraded=False,
                dead_shards=0, fleet_events=0)
    base.update(kw)
    return bridge.SnapshotDelta(**base)


def make_actuator(**kw):
    from repro.core.admission import AdmissionPolicy
    from repro.core.replan import AdmissionActuator

    policy = AdmissionPolicy(slots=kw.pop("slots", 4),
                             queue_bound=kw.pop("queue_bound", 8))
    return AdmissionActuator(policy, ttft_slo_s=kw.pop("ttft_slo_s", 0.1),
                             **kw), policy


def test_actuator_breach_decreases_queue_bound_first():
    act, policy = make_actuator()
    d = act.tune(adelta(ttft={"count": 5, "p99": 0.5}, ttft_completed=5.0,
                        completed=5.0))
    assert d["action"] == "decrease" and d["ttft_breach"]
    assert policy.queue_bound == 4           # multiplicative halving
    assert policy.max_concurrency == 4       # untouched on first breach


def test_actuator_consecutive_breaches_cut_concurrency():
    act, policy = make_actuator(concurrency_after=2)
    breach = adelta(timed_out=3.0)           # timeouts breach without p99
    act.tune(breach)
    assert policy.max_concurrency == 4
    d = act.tune(breach)
    assert d["breach_streak"] == 2
    assert policy.max_concurrency == 2
    assert policy.queue_bound == 2           # halved twice: 8 -> 4 -> 2


def test_actuator_healthy_windows_recover_additively():
    act, policy = make_actuator()
    act.tune(adelta(timed_out=1.0))
    act.tune(adelta(timed_out=1.0))
    assert (policy.queue_bound, policy.max_concurrency) == (2, 2)
    for _ in range(10):
        act.tune(adelta(completed=4.0, good_tokens=16.0,
                        ttft={"count": 4, "p99": 0.01}, ttft_completed=4.0))
    # climbed back to the ceilings, +1 per healthy window
    assert policy.queue_bound == 8
    assert policy.max_concurrency == 4       # capped at slots
    assert act.report()["breaches"] == 2


def test_actuator_idle_window_is_a_no_op():
    act, policy = make_actuator()
    assert act.tune(adelta()) is None
    assert (policy.queue_bound, policy.max_concurrency) == (8, 4)


def test_actuator_healthy_resets_breach_streak():
    act, policy = make_actuator(concurrency_after=2)
    act.tune(adelta(timed_out=1.0))
    act.tune(adelta(completed=2.0))          # healthy: streak resets
    act.tune(adelta(timed_out=1.0))          # 1st of a NEW streak
    assert policy.max_concurrency == 4       # never cut


def test_actuator_unbounded_policy_gets_finite_ceiling():
    from repro.core.admission import AdmissionPolicy
    from repro.core.replan import AdmissionActuator

    policy = AdmissionPolicy(slots=4)        # queue_bound=None
    act = AdmissionActuator(policy, ttft_slo_s=0.1)
    assert act.max_queue_bound == 32         # 8 * slots
    act.tune(adelta(timed_out=1.0))
    assert policy.queue_bound == 16          # bounded from the ceiling


def test_actuator_floors_hold_under_sustained_breach():
    act, policy = make_actuator(min_queue_bound=1, min_concurrency=1,
                                concurrency_after=1)
    for _ in range(10):
        act.tune(adelta(timed_out=1.0))
    assert policy.queue_bound == 1
    assert policy.max_concurrency == 1       # never 0: progress possible


def test_controller_tunes_admission_each_window():
    """The controller feeds every windowed delta to the actuator —
    independent of drift hysteresis/cooldown gating — and reports it."""
    from repro.core.admission import AdmissionPolicy
    from repro.core.replan import AdmissionActuator

    policy = AdmissionPolicy(slots=4, queue_bound=8)
    act = AdmissionActuator(policy, ttft_slo_s=0.1)
    sched = FakeScheduler(alt=(1,) * len(SPECS), factor=1.0)
    clock = {"t": 0.0}
    initial = tuple(0 if k in ("embedding", "nce") else 1
                    for k, *_ in SPECS)
    ctl = ReplanController(SPECS, FLEET, JOB, sched,
                           snapshot_fn=lambda: None,
                           config=ReplanConfig(window_steps=1),
                           clock=lambda: clock["t"], initial=initial,
                           admission=act)

    def observe(s):
        clock["t"] += 5.0
        return ctl.observe(snapshot=s)

    s0 = snap(tokens=10.0)
    s1 = snap(tokens=20.0)
    s1["serve"]["timed_out"] = 2.0           # breach window
    s2 = snap(tokens=30.0)
    s2["serve"]["timed_out"] = 2.0           # cumulative: no new timeouts
    s2["serve"]["completed"] = 3.0           # healthy window
    observe(s0)
    observe(s1)
    assert policy.queue_bound == 4           # breach acted on immediately
    observe(s2)
    assert policy.queue_bound == 5           # healthy: additive recovery
    rep = ctl.report()
    assert rep["admission"]["breaches"] == 1
    assert len(rep["admission"]["decisions"]) == 2
