"""RL policy-network unit tests (LSTM/RNN sampling & REINFORCE math)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TrainingJob, default_fleet, paper_model_profiles
from repro.core.schedulers import policy as pol

KEY = jax.random.PRNGKey(0)
FLEET = default_fleet()
PROFS = paper_model_profiles("NCE", FLEET)
T = len(FLEET)
FEATS = jnp.asarray(pol.layer_features(PROFS))
IN_DIM = FEATS.shape[1] + T


@pytest.fixture(scope="module", params=["lstm", "rnn"])
def cell_and_params(request):
    cell = request.param
    init = pol.init_lstm if cell == "lstm" else pol.init_rnn
    return cell, init(KEY, IN_DIM, 32, T)


class TestPolicy:
    def test_sampled_logp_matches_teacher_forced(self, cell_and_params):
        """Σ log P from sampling must equal the teacher-forced evaluation
        of the same action sequence (Formula 14 consistency)."""
        cell, params = cell_and_params
        actions, logp = pol.sample_plan(params, FEATS, KEY, cell=cell,
                                        num_types=T)
        logp2 = pol.plan_logp(params, FEATS, actions, cell=cell, num_types=T)
        assert float(jnp.abs(logp - logp2)) < 1e-5

    def test_actions_in_range(self, cell_and_params):
        cell, params = cell_and_params
        keys = jax.random.split(KEY, 16)
        actions, _ = pol.sample_batch(params, FEATS, keys, cell=cell,
                                      num_types=T)
        a = np.asarray(actions)
        assert a.shape == (16, len(PROFS))
        assert (a >= 0).all() and (a < T).all()

    def test_greedy_decode_deterministic(self, cell_and_params):
        cell, params = cell_and_params
        a1 = pol.greedy_plan(params, FEATS, cell=cell, num_types=T)
        a2 = pol.greedy_plan(params, FEATS, cell=cell, num_types=T)
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))

    def test_reinforce_gradient_raises_rewarded_logp(self, cell_and_params):
        """Ascending the REINFORCE surrogate must increase the log-prob of
        positively-advantaged plans (gradient direction sanity)."""
        cell, params = cell_and_params
        actions, _ = pol.sample_plan(params, FEATS, KEY, cell=cell, num_types=T)
        batch = actions[None]
        adv = jnp.ones((1,), jnp.float32)
        g = pol.reinforce_grad(params, FEATS, batch, adv, cell=cell,
                               num_types=T)
        lr = 0.05
        new = jax.tree.map(lambda p, gg: p + lr * gg, params, g)
        lp_old = pol.plan_logp(params, FEATS, actions, cell=cell, num_types=T)
        lp_new = pol.plan_logp(new, FEATS, actions, cell=cell, num_types=T)
        assert float(lp_new) > float(lp_old)

    def test_negative_advantage_lowers_logp(self, cell_and_params):
        cell, params = cell_and_params
        actions, _ = pol.sample_plan(params, FEATS, KEY, cell=cell, num_types=T)
        g = pol.reinforce_grad(params, FEATS, actions[None],
                               -jnp.ones((1,), jnp.float32), cell=cell,
                               num_types=T)
        new = jax.tree.map(lambda p, gg: p + 0.05 * gg, params, g)
        lp_old = pol.plan_logp(params, FEATS, actions, cell=cell, num_types=T)
        lp_new = pol.plan_logp(new, FEATS, actions, cell=cell, num_types=T)
        assert float(lp_new) < float(lp_old)


class TestFeatures:
    def test_feature_rows_per_layer(self):
        assert FEATS.shape[0] == len(PROFS)

    def test_fig3_features_present(self):
        """one-hot index + one-hot kind + (input, weight, comm) scalars."""
        f = np.asarray(FEATS)
        # index one-hot: row i has a 1 at column i
        for i in range(len(PROFS)):
            assert f[i, i] == 1.0
        # scalar block is finite and non-negative
        tail = f[:, -3:]
        assert np.isfinite(tail).all() and (tail >= 0).all()
