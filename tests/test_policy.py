"""RL policy-network unit tests (LSTM/RNN sampling & REINFORCE math)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TrainingJob, default_fleet, paper_model_profiles
from repro.core.schedulers import policy as pol

KEY = jax.random.PRNGKey(0)
FLEET = default_fleet()
PROFS = paper_model_profiles("NCE", FLEET)
T = len(FLEET)
FEATS = jnp.asarray(pol.layer_features(PROFS))
IN_DIM = FEATS.shape[1] + T


@pytest.fixture(scope="module", params=["lstm", "rnn"])
def cell_and_params(request):
    cell = request.param
    init = pol.init_lstm if cell == "lstm" else pol.init_rnn
    return cell, init(KEY, IN_DIM, 32, T)


class TestPolicy:
    def test_sampled_logp_matches_teacher_forced(self, cell_and_params):
        """Σ log P from sampling must equal the teacher-forced evaluation
        of the same action sequence (Formula 14 consistency)."""
        cell, params = cell_and_params
        actions, logp = pol.sample_plan(params, FEATS, KEY, cell=cell,
                                        num_types=T)
        logp2 = pol.plan_logp(params, FEATS, actions, cell=cell, num_types=T)
        assert float(jnp.abs(logp - logp2)) < 1e-5

    def test_actions_in_range(self, cell_and_params):
        cell, params = cell_and_params
        keys = jax.random.split(KEY, 16)
        actions, _ = pol.sample_batch(params, FEATS, keys, cell=cell,
                                      num_types=T)
        a = np.asarray(actions)
        assert a.shape == (16, len(PROFS))
        assert (a >= 0).all() and (a < T).all()

    def test_greedy_decode_deterministic(self, cell_and_params):
        cell, params = cell_and_params
        a1 = pol.greedy_plan(params, FEATS, cell=cell, num_types=T)
        a2 = pol.greedy_plan(params, FEATS, cell=cell, num_types=T)
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))

    def test_reinforce_gradient_raises_rewarded_logp(self, cell_and_params):
        """Ascending the REINFORCE surrogate must increase the log-prob of
        positively-advantaged plans (gradient direction sanity)."""
        cell, params = cell_and_params
        actions, _ = pol.sample_plan(params, FEATS, KEY, cell=cell, num_types=T)
        batch = actions[None]
        adv = jnp.ones((1,), jnp.float32)
        g = pol.reinforce_grad(params, FEATS, batch, adv, cell=cell,
                               num_types=T)
        lr = 0.05
        new = jax.tree.map(lambda p, gg: p + lr * gg, params, g)
        lp_old = pol.plan_logp(params, FEATS, actions, cell=cell, num_types=T)
        lp_new = pol.plan_logp(new, FEATS, actions, cell=cell, num_types=T)
        assert float(lp_new) > float(lp_old)

    def test_negative_advantage_lowers_logp(self, cell_and_params):
        cell, params = cell_and_params
        actions, _ = pol.sample_plan(params, FEATS, KEY, cell=cell, num_types=T)
        g = pol.reinforce_grad(params, FEATS, actions[None],
                               -jnp.ones((1,), jnp.float32), cell=cell,
                               num_types=T)
        new = jax.tree.map(lambda p, gg: p + 0.05 * gg, params, g)
        lp_old = pol.plan_logp(params, FEATS, actions, cell=cell, num_types=T)
        lp_new = pol.plan_logp(new, FEATS, actions, cell=cell, num_types=T)
        assert float(lp_new) < float(lp_old)


class TestMaskedPolicy:
    """Layer-mask support for the padded multi-model (vmapped) search."""

    def test_masked_logp_matches_unpadded(self, cell_and_params):
        """Teacher-forced log-prob with padded feature rows + mask must
        equal the unpadded evaluation (padding sits at the end, so real
        steps see identical inputs and padded steps are zero-weighted)."""
        cell, params = cell_and_params
        actions, logp = pol.sample_plan(params, FEATS, KEY, cell=cell,
                                        num_types=T)
        fpad, mask = pol.layer_features(PROFS, pad_to=len(PROFS) + 4,
                                        return_mask=True)
        apad = jnp.concatenate([actions, jnp.zeros(4, actions.dtype)])
        lp = pol.plan_logp(params, jnp.asarray(fpad), apad, cell=cell,
                           num_types=T, mask=jnp.asarray(mask))
        assert float(jnp.abs(lp - logp)) < 1e-5

    def test_masked_sampling_prefix_matches_unpadded(self, cell_and_params):
        """Real-layer actions are unchanged by trailing padding (each
        plan's per-step key stream is a prefix of the padded one)."""
        cell, params = cell_and_params
        actions, _ = pol.sample_plan(params, FEATS, KEY, cell=cell,
                                     num_types=T)
        fpad, mask = pol.layer_features(PROFS, pad_to=len(PROFS) + 4,
                                        return_mask=True)
        apad, _ = pol.sample_plan(params, jnp.asarray(fpad), KEY, cell=cell,
                                  num_types=T, mask=jnp.asarray(mask))
        np.testing.assert_array_equal(
            np.asarray(actions), np.asarray(apad)[: len(PROFS)])


class TestFeatures:
    def test_feature_rows_per_layer(self):
        assert FEATS.shape[0] == len(PROFS)

    def test_rejects_models_deeper_than_max_layers(self):
        """Regression: layers past MAX_LAYERS-1 used to silently share one
        index one-hot slot; now the overflow is a clear error."""
        from repro.core.profiles import ctrdnn_variant, profile_layers

        deep = profile_layers(
            ctrdnn_variant(pol.MAX_LAYERS + 2), FLEET
        )
        with pytest.raises(ValueError, match="MAX_LAYERS"):
            pol.layer_features(deep)
        # the boundary case still works and keeps distinct slots
        ok = profile_layers(ctrdnn_variant(pol.MAX_LAYERS), FLEET)
        f = pol.layer_features(ok)
        for i in range(pol.MAX_LAYERS):
            assert f[i, i] == 1.0
            assert f[i, : pol.MAX_LAYERS].sum() == 1.0

    def test_pad_to_and_mask(self):
        f, m = pol.layer_features(PROFS, pad_to=12, return_mask=True)
        assert f.shape[0] == 12 and m.shape == (12,)
        assert m[: len(PROFS)].all() and not m[len(PROFS):].any()
        assert (f[len(PROFS):] == 0.0).all()

    def test_pad_to_too_small_rejected(self):
        with pytest.raises(ValueError):
            pol.layer_features(PROFS, pad_to=len(PROFS) - 1)

    def test_fig3_features_present(self):
        """one-hot index + one-hot kind + (input, weight, comm) scalars."""
        f = np.asarray(FEATS)
        # index one-hot: row i has a 1 at column i
        for i in range(len(PROFS)):
            assert f[i, i] == 1.0
        # scalar block is finite and non-negative
        tail = f[:, -3:]
        assert np.isfinite(tail).all() and (tail >= 0).all()
