"""Sharded parameter-server subsystem (repro.ps) + AccessMonitor guards.

The load-bearing invariant: the sharded pull/push path is **bit-exact**
against the single-shard oracle (`repro.parallel.ps.SparseEmbedding`) for
random id streams — any routing, dedup or hot-cache change that perturbs
a single mantissa bit fails here.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data import AccessMonitor, Tier, TierThresholds
from repro.parallel.ps import SparseEmbedding, dedup_rows, sparse_pull
from repro.ps import (
    CTRConfig, PSClient, PSTelemetry, RoutingSpec, ShardedTable, TierPlacer,
    train_ctr_ps,
)

VOCAB, DIM = 101, 8
SHARD_CASES = [(s, p) for s in (1, 3, 4) for p in ("mod", "block")]


def _rand_ids(n=91, seed=0, vocab=VOCAB):
    return np.random.default_rng(seed).integers(
        0, vocab, (7, n // 7)).astype(np.int32)


def _rand_grads(ids, seed=1):
    return np.random.default_rng(seed).standard_normal(
        (*ids.shape, DIM)).astype(np.float32)


@pytest.fixture(scope="module")
def dense_table():
    return jax.random.normal(jax.random.PRNGKey(0), (VOCAB, DIM))


class TestRoutingSpec:
    @pytest.mark.parametrize("shards,partition", SHARD_CASES)
    def test_global_rows_partition_vocab(self, shards, partition):
        spec = RoutingSpec(VOCAB, DIM, shards, partition)
        assert sum(spec.shard_rows) == VOCAB
        all_rows = np.concatenate(
            [spec.global_rows(s) for s in range(shards)])
        assert np.array_equal(np.sort(all_rows), np.arange(VOCAB))

    @pytest.mark.parametrize("shards,partition", SHARD_CASES)
    def test_flatten_is_slab_order(self, shards, partition):
        spec = RoutingSpec(VOCAB, DIM, shards, partition)
        for s in range(shards):
            flat = spec.flatten(spec.global_rows(s))
            assert np.array_equal(
                flat, spec.offsets[s] + np.arange(spec.shard_rows[s]))

    def test_rejects_bad_args(self):
        with pytest.raises(ValueError):
            RoutingSpec(VOCAB, DIM, 4, "hash")
        with pytest.raises(ValueError):
            RoutingSpec(4, DIM, 8)


class TestShardedVsOracle:
    """`ShardedTable.pull/push` bit-exact vs `SparseEmbedding`."""

    @pytest.mark.parametrize("shards,partition", SHARD_CASES)
    def test_pull_bitexact(self, dense_table, shards, partition):
        t = ShardedTable.from_dense(dense_table, shards, partition=partition)
        for seed in range(3):
            ids = _rand_ids(seed=seed)
            got = np.asarray(t.pull(ids))
            want = np.asarray(sparse_pull(dense_table, jnp.asarray(ids)))
            assert np.array_equal(got, want)

    @pytest.mark.parametrize("shards,partition", SHARD_CASES)
    @pytest.mark.parametrize("dedup", [False, True])
    def test_push_bitexact(self, dense_table, shards, partition, dedup):
        ids, g = _rand_ids(), _rand_grads(_rand_ids())
        oracle = SparseEmbedding(VOCAB, DIM, jax.random.PRNGKey(1))
        oracle.table = jnp.asarray(dense_table)
        oracle.apply_sparse_grads(jnp.asarray(ids), jnp.asarray(g),
                                  lr=0.1, dedup=dedup)
        t = ShardedTable.from_dense(dense_table, shards, partition=partition)
        t.push(ids, g, lr=0.1, dedup=dedup)
        assert np.array_equal(np.asarray(t.to_dense()),
                              np.asarray(oracle.table))

    @pytest.mark.parametrize("shards,partition", SHARD_CASES)
    def test_dense_roundtrip(self, dense_table, shards, partition):
        t = ShardedTable.from_dense(dense_table, shards, partition=partition)
        assert np.array_equal(np.asarray(t.to_dense()),
                              np.asarray(dense_table))
        assert [s.shape[0] for s in t.shards] == list(t.spec.shard_rows)

    def test_out_of_range_ids_raise(self, dense_table):
        t = ShardedTable.from_dense(dense_table, 4)
        with pytest.raises(ValueError, match="out of range"):
            t.pull(np.array([0, VOCAB]))
        with pytest.raises(ValueError, match="out of range"):
            t.push(np.array([-1]), np.zeros((1, DIM), np.float32), lr=0.1)


class TestDedup:
    def test_dedup_rows_sums_duplicates_in_stream_order(self):
        ids = jnp.array([5, 2, 5, 2, 5])
        g = jnp.arange(5, dtype=jnp.float32)[:, None] * jnp.ones((5, 3))
        uids, summed = dedup_rows(ids, g, fill_id=10)
        assert np.asarray(uids).tolist() == [2, 5, 10, 10, 10]
        np.testing.assert_array_equal(np.asarray(summed[0]), [4.0] * 3)
        np.testing.assert_array_equal(np.asarray(summed[1]), [6.0] * 3)

    def test_sgd_sum_equivalence(self):
        """With plain SGD, pushing raw duplicates and pushing the deduped
        sum land on the same row values (up to fp association)."""
        ids, g = _rand_ids(), _rand_grads(_rand_ids())
        out = {}
        for dedup in (False, True):
            emb = SparseEmbedding(VOCAB, DIM, jax.random.PRNGKey(2))
            emb.apply_sparse_grads(jnp.asarray(ids), jnp.asarray(g),
                                   lr=0.05, dedup=dedup)
            out[dedup] = np.asarray(emb.table)
        np.testing.assert_allclose(out[True], out[False], rtol=0, atol=1e-5)


class TestHotCache:
    def test_write_through_keeps_cache_coherent(self, dense_table):
        """Interleaved repin/push/pull stays bit-exact vs the oracle —
        serving a row from the hot cache must be value-neutral."""
        rng = np.random.default_rng(3)
        oracle = SparseEmbedding(VOCAB, DIM, jax.random.PRNGKey(1))
        oracle.table = jnp.asarray(dense_table)
        monitor = AccessMonitor(VOCAB)
        t = ShardedTable.from_dense(dense_table, 3, monitor=monitor,
                                    telemetry=PSTelemetry(3), hot_capacity=16)
        placer = TierPlacer(t, monitor, interval=1)
        for round_ in range(4):
            ids = rng.integers(0, 40, (50,)).astype(np.int32)  # skewed head
            g = rng.standard_normal((50, DIM)).astype(np.float32)
            got = np.asarray(t.pull(ids))
            want = np.asarray(sparse_pull(oracle.table, jnp.asarray(ids)))
            assert np.array_equal(got, want), f"pull diverged at {round_}"
            t.push(ids, g, lr=0.1)
            oracle.apply_sparse_grads(jnp.asarray(ids), jnp.asarray(g), lr=0.1)
            placer.repin()
        assert np.array_equal(np.asarray(t.to_dense()),
                              np.asarray(oracle.table))
        assert placer.last_stats["cached_rows"] > 0
        # skewed pulls land in the DEVICE tier once the cache is populated
        assert t.telemetry.totals()["pull"]["hot_fraction"] > 0

    def test_capacity_truncation_keeps_hottest(self, dense_table):
        monitor = AccessMonitor(VOCAB, TierThresholds(hot_fraction=0.95))
        t = ShardedTable.from_dense(dense_table, 2, monitor=monitor,
                                    hot_capacity=2)
        monitor.record(np.array([7] * 50 + [3] * 30 + [9] * 10))
        placer = TierPlacer(t, monitor, interval=1)
        stats = placer.repin()
        assert stats["cached_rows"] == 2
        slot = np.asarray(t.slot_of)
        assert slot[7] >= 0 and slot[3] >= 0 and slot[9] < 0

    def test_placer_rejects_mismatched_monitor(self, dense_table):
        with pytest.raises(ValueError, match="monitor covers"):
            TierPlacer(ShardedTable.from_dense(dense_table, 2),
                       AccessMonitor(VOCAB + 1))


class TestAccessMonitorGuards:
    def test_out_of_range_record_raises(self):
        m = AccessMonitor(10)
        with pytest.raises(ValueError, match="row ids out of range"):
            m.record(np.array([0, 10]))
        with pytest.raises(ValueError, match="row ids out of range"):
            m.record(np.array([-1, 3]))
        assert m.counts.sum() == 0  # failed record must not half-apply

    def test_empty_record_is_noop(self):
        m = AccessMonitor(10)
        m.record(np.array([], dtype=np.int64))
        assert m.counts.sum() == 0

    def test_zero_row_table_placement(self):
        m = AccessMonitor(0)
        assert m.placement().shape == (0,)
        s = m.stats()
        assert (s["device_rows"], s["host_rows"], s["disk_rows"]) == (0, 0, 0)
        m.record(np.array([], dtype=np.int64))  # still a no-op

    def test_ema_aging_placement_drift(self):
        """The hot set follows a shifted access distribution after age():
        old traffic decays, new traffic takes over the DEVICE tier."""
        m = AccessMonitor(100, TierThresholds(hot_fraction=0.1, ema=0.5))
        region_a, region_b = np.arange(0, 10), np.arange(50, 60)
        m.record(np.repeat(region_a, 100))
        hot0 = np.flatnonzero(m.placement() == Tier.DEVICE)
        assert set(hot0) <= set(region_a) and hot0.size > 0
        # distribution shifts to region B; EMA ages A's counts away
        for _ in range(6):
            m.age()
            m.record(np.repeat(region_b, 100))
        hot1 = np.flatnonzero(m.placement() == Tier.DEVICE)
        assert hot1.size > 0 and set(hot1) <= set(region_b)


class TestPSClient:
    def _batches(self, n, seed=0, vocab=VOCAB):
        rng = np.random.default_rng(seed)
        return [{"ids": rng.integers(0, vocab, (13,)).astype(np.int32),
                 "step": i} for i in range(n)]

    def test_yields_in_order_with_correct_rows(self, dense_table):
        t = ShardedTable.from_dense(dense_table, 3)
        batches = self._batches(8)
        client = PSClient(t, iter(batches))
        seen = []
        for b, rows in client:
            seen.append(b["step"])
            want = np.asarray(dense_table)[b["ids"]]
            assert np.array_equal(np.asarray(rows), want)
        client.close()
        assert seen == list(range(8))

    def test_close_drains_all_pushes(self, dense_table):
        t = ShardedTable.from_dense(dense_table, 4)
        batches = self._batches(10, seed=4)
        client = PSClient(t, iter(batches))
        counts = np.zeros(VOCAB)
        for b, _rows in client:
            np.add.at(counts, b["ids"], 1.0)
            client.push(b["ids"], np.ones((13, DIM), np.float32), lr=0.5)
        client.close()
        assert client.stats()["steps_pushed"] == 10
        got = np.asarray(t.to_dense()) - np.asarray(dense_table)
        np.testing.assert_allclose(
            got, -0.5 * counts[:, None] * np.ones((VOCAB, DIM)),
            rtol=0, atol=1e-5)

    def test_push_after_close_raises(self, dense_table):
        t = ShardedTable.from_dense(dense_table, 2)
        client = PSClient(t, iter(self._batches(2)))
        list(client)
        client.close()
        with pytest.raises(RuntimeError, match="close"):
            client.push(np.array([1]), np.zeros((1, DIM), np.float32), lr=0.1)

    def test_close_on_stuck_queue_reports_dropped_pushes(self):
        """A table whose push hangs must not hang close(): the drain
        times out deterministically and reports how many pushes were
        dropped, with the counters staying consistent."""
        import threading

        release = threading.Event()

        class StuckTable:
            def push(self, ids, grads, lr, dedup):
                release.wait()

        client = PSClient(StuckTable(), iter([]), depth=8)
        try:
            for _ in range(3):
                client.push(np.array([1]), np.zeros((1, DIM), np.float32),
                            lr=0.1)
            with pytest.raises(TimeoutError, match=r"3 push\(es\) dropped"):
                client.close(timeout=0.2)
            s = client.stats()
            assert s["pushes_dropped"] == 3
            assert s["steps_pushed"] + s["pushes_dropped"] \
                == s["pushes_enqueued"]
            # close() is idempotent even after a failed close
            client.close(timeout=0.2)
        finally:
            release.set()

    def test_close_surfaces_pusher_error_with_dropped_count(self):
        class BrokenTable:
            def push(self, ids, grads, lr, dedup):
                raise ValueError("shard exploded")

        client = PSClient(BrokenTable(), iter([]), depth=8)
        client.push(np.array([1]), np.zeros((1, DIM), np.float32), lr=0.1)
        with pytest.raises(RuntimeError,
                           match=r"PS push failed: 1 push\(es\) dropped"):
            client.close(timeout=1.0)
        assert client.stats()["pushes_dropped"] == 1
        client.close()  # no-op, does not re-raise


class TestTelemetry:
    def test_pull_push_byte_accounting(self, dense_table):
        tel = PSTelemetry(2)
        t = ShardedTable.from_dense(dense_table, 2, telemetry=tel)
        ids = np.array([0, 1, 2, 3, 1], np.int32)   # one duplicate
        t.pull(ids)
        totals = tel.totals()
        assert totals["pull"]["rows"] == 5
        assert totals["pull"]["bytes"] == 5 * DIM * 4
        t.push(ids, np.ones((5, DIM), np.float32), lr=0.1)
        totals = tel.totals()
        # deduped wire: 4 distinct rows, each D floats + an id
        assert totals["push"]["rows"] == 4
        assert totals["push"]["bytes"] == 4 * (DIM * 4 + 4)
        per_shard = tel.shard_report()
        assert sum(r["pull_rows"] for r in per_shard) == 5

    def test_cost_model_bridge(self, dense_table):
        from repro.core.resources import CPU_CORE

        tel = PSTelemetry(2)
        t = ShardedTable.from_dense(dense_table, 2, telemetry=tel)
        for seed in range(3):
            t.pull(_rand_ids(seed=seed))
            t.push(_rand_ids(seed=seed),
                   _rand_grads(_rand_ids(seed=seed)), lr=0.1)
        res = tel.to_resource(CPU_CORE)
        assert res.name == "cpu+ps"
        assert res.ingest_bw > 0 and res.net_bw > 0
        assert res.price == CPU_CORE.price          # only bandwidths change
        sync_t, act_t = tel.embedding_odt(num_examples=300)
        assert sync_t > act_t > 0


class TestWorkload:
    def test_sync_and_async_train(self):
        cfg = CTRConfig(vocab=2000, emb_dim=8, slots=6, tower=(32,),
                        batch=64, lr=0.1)
        for mode in ("sync", "async"):
            s = train_ctr_ps(cfg, steps=25, num_shards=3, mode=mode,
                             repin_interval=10)
            assert s["steps"] == 25
            assert s["loss_decreased"], f"{mode}: {s['first_loss']} -> " \
                                        f"{s['last_loss']}"
            assert s["repins"] == 2
            assert s["pull_gb"] > 0 and s["push_gb"] > 0
            assert s["measured_ingest_bw"] > 0

    def test_bad_mode_rejected(self):
        with pytest.raises(ValueError, match="sync|async"):
            train_ctr_ps(CTRConfig(vocab=100), steps=1, mode="turbo")
