"""Equivalence tests: JAX-native soft cost model vs the NumPy oracle.

``jax_cost.soft_cost`` (the fused RL search's reward function) must agree
with ``batched_soft_plan_cost`` on soft cost, true cost, and feasibility
over randomized plans/fleets/jobs.  Documented tolerance (see
``jax_cost`` module docstring): ~1e-9 relative under
``jax.experimental.enable_x64()`` (the mode the fused scheduler actually
runs in), ~1e-1 on log10-cost in float32 (Newton/ceil rounding can flip
an integer replica count near a boundary).

Also covers ``CostCache.seed_from_device`` (the fused search's bulk
memo-table back-fill) and the layer-padding path used by the vmapped
multi-model search.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    INFEASIBLE,
    TrainingJob,
    batched_soft_plan_cost,
    default_fleet,
    jax_cost,
    make_fleet,
    paper_model_profiles,
)
from repro.core.schedulers.base import CostCache

JOB = TrainingJob()
MODELS = ("CTRDNN", "MATCHNET", "2EMB", "NCE")


def _random_plans(rng, n, L, T):
    A = rng.integers(0, T, (n, L))
    A[: min(T, n)] = np.arange(min(T, n))[:, None]   # homogeneous anchors
    if n > T + 1:
        A[T] = np.arange(L) % T                      # max-fragmentation plan
    return A


def _check_x64_equivalence(profiles, fleet, job, A, rel=1e-9):
    bc, soft_np = batched_soft_plan_cost(A, profiles, fleet, job)
    with jax.experimental.enable_x64():
        soft_j, cost_j, feas_j = jax_cost.jnp_soft_plan_cost(
            A, profiles, fleet, job
        )
    np.testing.assert_array_equal(feas_j, bc.feasible)
    np.testing.assert_array_equal(np.isfinite(cost_j), np.isfinite(bc.costs))
    fin = np.isfinite(bc.costs)
    np.testing.assert_allclose(cost_j[fin], bc.costs[fin], rtol=rel)
    np.testing.assert_allclose(soft_j, soft_np, rtol=rel)


class TestX64Equivalence:
    @pytest.mark.parametrize(
        "model,num_types", [("CTRDNN", 2), ("MATCHNET", 2), ("2EMB", 3), ("NCE", 4)]
    )
    def test_randomized_plans(self, model, num_types):
        fleet = default_fleet() if num_types == 2 else make_fleet(num_types)
        profiles = paper_model_profiles(model, fleet)
        rng = np.random.default_rng(hash((model, num_types)) % 2**32)
        A = _random_plans(rng, 48, len(profiles), num_types)
        _check_x64_equivalence(profiles, fleet, JOB, A)

    @given(
        st.sampled_from(MODELS),
        st.integers(2, 5),
        st.floats(min_value=5e3, max_value=2e6),
        st.sampled_from([256, 4096, 65536]),
        st.integers(0, 10_000),
    )
    @settings(max_examples=12, deadline=None)
    def test_hypothesis_randomized(self, model, num_types, limit, bs, seed):
        """Property: the jnp path agrees with the oracle for any model,
        fleet size, throughput limit (spanning all-feasible through
        mostly-infeasible), and batch size."""
        fleet = default_fleet() if num_types == 2 else make_fleet(num_types)
        profiles = paper_model_profiles(model, fleet)
        job = dataclasses.replace(JOB, throughput_limit=limit, batch_size=bs)
        rng = np.random.default_rng(seed)
        A = _random_plans(rng, 16, len(profiles), num_types)
        _check_x64_equivalence(profiles, fleet, job, A)

    def test_resource_limit_edge(self):
        """Per-type limits small enough that integer rounding decides
        feasibility (Formula 10 boundary)."""
        fleet = [
            dataclasses.replace(r, max_count=max(2, r.max_count // 80))
            for r in default_fleet()
        ]
        profiles = paper_model_profiles("NCE", fleet)
        for limit in (5_000.0, 50_000.0, 200_000.0):
            job = dataclasses.replace(JOB, throughput_limit=limit)
            rng = np.random.default_rng(int(limit))
            A = _random_plans(rng, 16, len(profiles), len(fleet))
            _check_x64_equivalence(profiles, fleet, job, A)


class TestF32Tolerance:
    def test_f32_log_cost_agreement(self):
        """Without x64, agreement is loose but bounded: integer-rounding
        flips can move a replica count by one, so individual soft costs
        drift up to ~20% — but log10-cost (the actual RL reward) stays
        within 0.5 everywhere and within 0.01 for most plans."""
        fleet = default_fleet()
        profiles = paper_model_profiles("MATCHNET", fleet)
        rng = np.random.default_rng(3)
        A = _random_plans(rng, 64, len(profiles), len(fleet))
        _, soft_np = batched_soft_plan_cost(A, profiles, fleet, JOB)
        soft_j, _, _ = jax_cost.jnp_soft_plan_cost(A, profiles, fleet, JOB)
        logdiff = np.abs(np.log10(soft_np) - np.log10(soft_j))
        assert logdiff.max() < 0.5
        assert np.median(logdiff) < 0.01


class TestLayerPadding:
    def test_padded_matches_unpadded(self):
        """Padding NCE (L=5) to 16 layer slots with garbage tail actions
        must not change any cost (the vmapped multi-model contract)."""
        fleet = default_fleet()
        profiles = paper_model_profiles("NCE", fleet)
        rng = np.random.default_rng(5)
        A = _random_plans(rng, 24, 5, 2)
        with jax.experimental.enable_x64():
            soft_u, cost_u, feas_u = jax_cost.jnp_soft_plan_cost(
                A, profiles, fleet, JOB
            )
            ct = jax_cost.cost_tensors(profiles, fleet, JOB, pad_to=16)
            tail = rng.integers(0, 2, (24, 11))
            out = jax_cost._soft_cost_jit(
                ct, jnp.asarray(np.concatenate([A, tail], axis=1), jnp.int32)
            )
        np.testing.assert_allclose(np.asarray(out.soft), soft_u, rtol=1e-12)
        np.testing.assert_array_equal(np.asarray(out.feasible), feas_u)

    def test_pad_to_too_small_rejected(self):
        fleet = default_fleet()
        profiles = paper_model_profiles("NCE", fleet)
        with pytest.raises(ValueError):
            jax_cost.cost_tensors(profiles, fleet, JOB, pad_to=3)


class TestSeedFromDevice:
    def setup_method(self):
        self.fleet = default_fleet()
        self.profiles = paper_model_profiles("2EMB", self.fleet)
        self.L = len(self.profiles)

    def test_fills_both_memos_and_counts_novel_once(self):
        cache = CostCache(self.profiles, self.fleet, JOB)
        a, b = (0,) * self.L, (1,) * self.L
        n = cache.seed_from_device(
            [a, b, a], [3.0, 5.0, 3.0], [True, True, True]
        )
        assert n == 2 and cache.evaluations == 2
        assert cache(a) == 3.0 and cache.soft(a) == 3.0
        # repeat insert: nothing new, accounting unchanged
        assert cache.seed_from_device([a, b], [9.9, 9.9], [True, True]) == 0
        assert cache.evaluations == 2 and cache(a) == 3.0

    def test_infeasible_gets_inf_true_cost(self):
        cache = CostCache(self.profiles, self.fleet, JOB)
        a = (0,) * self.L
        cache.seed_from_device([a], [7.5], [False])
        assert cache(a) == INFEASIBLE and cache.soft(a) == 7.5

    def test_never_overwrites_oracle_entries(self):
        cache = CostCache(self.profiles, self.fleet, JOB)
        a = (1,) * self.L
        exact = cache(a)  # NumPy-oracle evaluation
        n0 = cache.evaluations
        cache.seed_from_device([a], [exact * 1.001], [math.isfinite(exact)])
        assert cache(a) == exact and cache.evaluations == n0
        if math.isfinite(exact):
            assert cache.soft(a) == exact

    def test_best_sees_device_scored_plans(self):
        cache = CostCache(self.profiles, self.fleet, JOB)
        good, bad, infeas = (0,) * self.L, (1,) * self.L, (0, 1) * (self.L // 2)
        cache.seed_from_device(
            [good, bad, infeas], [1.0, 2.0, 0.5], [True, True, False]
        )
        plan, cost = cache.best()
        assert plan == good and cost == 1.0  # infeasible 0.5 not preferred

    def test_soft_only_mode(self):
        cache = CostCache(self.profiles, self.fleet, JOB)
        a = (0,) * self.L
        cache.seed_from_device([a], [4.0])
        assert cache.soft(a) == 4.0 and cache.evaluations == 1
