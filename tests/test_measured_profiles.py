"""Measured-profile ingestion (the paper's §4.1 profiling input path)."""

import json
import math

from repro.core import SchedulingPlan, TrainingJob, default_fleet, plan_cost
from repro.core.profiles import profiles_from_json

FLEET = default_fleet()


def test_direct_oct_measurements(tmp_path):
    rows = [
        {"kind": "embedding", "oct": [0.001, 0.0005],
         "odt_sync": [0.0002, 0.0002], "odt_act": [0.0001, 0.0001]},
        {"kind": "fc", "oct": [0.01, 0.0001],
         "odt_sync": [0.0001, 0.0001], "odt_act": [0.0001, 0.0001]},
    ]
    p = tmp_path / "prof.json"
    p.write_text(json.dumps(rows))
    profs = profiles_from_json(str(p), FLEET)
    assert len(profs) == 2
    assert profs[0].oct == (0.001, 0.0005)
    assert profs[1].odt == (0.0002, 0.0002)


def test_size_measurements_go_analytic(tmp_path):
    rows = [
        {"kind": "embedding", "flops": 1e4, "input_bytes": 1e5,
         "weight_bytes": 1e9, "output_bytes": 2e4},
        {"kind": "fc", "flops": 1e8, "input_bytes": 4e3,
         "weight_bytes": 1e7, "output_bytes": 4e3},
    ]
    p = tmp_path / "prof.json"
    p.write_text(json.dumps(rows))
    profs = profiles_from_json(str(p), FLEET)
    assert [pr.index for pr in profs] == [0, 1]
    # data-intensive layer relatively cheaper on CPU than the fc layer
    emb_rel = profs[0].oct[0] / profs[0].oct[1]
    fc_rel = profs[1].oct[0] / profs[1].oct[1]
    assert emb_rel < fc_rel


def test_measured_profiles_drive_cost_model(tmp_path):
    rows = [
        {"kind": "embedding", "oct": [1e-4, 5e-3],
         "odt_sync": [1e-5, 1e-5], "odt_act": [1e-5, 1e-5]},
        {"kind": "fc", "oct": [5e-2, 1e-5],
         "odt_sync": [1e-5, 1e-5], "odt_act": [1e-5, 1e-5]},
    ]
    p = tmp_path / "prof.json"
    p.write_text(json.dumps(rows))
    profs = profiles_from_json(str(p), FLEET)
    job = TrainingJob(throughput_limit=50_000.0)
    het, _ = plan_cost(SchedulingPlan((0, 1)), profs, FLEET, job)
    gpu, _ = plan_cost(SchedulingPlan((1, 1)), profs, FLEET, job)
    assert math.isfinite(het) and het < gpu
