"""Elastic PS fleet: resharding invariants, bounded staleness, lossless
replica recovery, and the CTR convergence pin.

Property tests (hypothesis, with the in-repo fallback shim) drive random
join/leave/kill sequences interleaved with training traffic and assert
the three invariants the design note promises:

1. **ownership partition** — after any event sequence, every bucket has
   exactly one live primary that actually hosts its rows (checked
   against the shard servers' own bucket lists, not just the client map);
2. **bounded staleness** — a pull against a migrating range never misses
   more than ``staleness_bound`` updates (0 ⇒ never stale at all);
3. **lossless recovery** — after a hard kill, the promoted replica's
   slab is bit-exact vs the lost shard's last acked state.

Plus the ISSUE's acceptance pin: a shard kill + recovery mid-CTR-training
produces the same loss trajectory as the uninterrupted run.
"""

from __future__ import annotations

import signal

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # in-repo deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.ps.elastic import BucketSpec, ElasticPSFleet
from repro.ps.transport import PSShardLost

VOCAB, DIM = 97, 4
HARD_TIMEOUT_S = 120


@pytest.fixture(autouse=True)
def hard_timeout():
    def boom(signum, frame):
        raise TimeoutError(
            f"test exceeded the {HARD_TIMEOUT_S}s hard timeout")

    old = signal.signal(signal.SIGALRM, boom)
    signal.alarm(HARD_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def _push_some(fleet, rng, n=16, lr=0.1):
    ids = rng.integers(0, VOCAB, size=n)
    fleet.push(ids, rng.normal(size=(n, DIM)).astype(np.float32), lr=lr)
    return ids


def _assert_ownership_partition(fleet):
    """Every bucket: exactly one live primary, hosted server-side; the
    buckets' rows partition the vocab."""
    stats = fleet.stats()
    live = set(stats["live_shards"])
    hosted = {s: set(rep["buckets"]) for s, rep in stats["shards"].items()}
    total_rows = 0
    for b in range(fleet.spec.num_buckets):
        p = stats["primary"][b]
        assert p in live, f"bucket {b} primary {p} is not live"
        assert b in hosted[p], f"shard {p} does not host its bucket {b}"
        k = stats["backup"][b]
        if k >= 0:
            assert k in live and k != p
            assert b in hosted[k]
        total_rows += fleet.spec.rows_in(b)
    assert total_rows == fleet.spec.vocab


class TestBucketSpec:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=1, max_value=VOCAB))
    def test_buckets_partition_vocab(self, num_buckets):
        spec = BucketSpec(VOCAB, DIM, num_buckets)
        seen = np.concatenate([spec.global_rows(b)
                               for b in range(num_buckets)])
        assert np.array_equal(np.sort(seen), np.arange(VOCAB))
        ids = np.arange(VOCAB)
        owners = spec.bucket_of(ids)
        for b in range(num_buckets):
            assert np.array_equal(ids[owners == b], spec.global_rows(b))


class TestReshardingInvariants:
    @settings(max_examples=10, deadline=None)
    @given(
        st.integers(min_value=0, max_value=10_000),
        st.lists(st.sampled_from(["join", "leave", "kill"]),
                 min_size=1, max_size=6),
    )
    def test_ownership_partition_after_any_sequence(self, seed, events):
        rng = np.random.default_rng(seed)
        fleet = ElasticPSFleet(VOCAB, DIM, num_shards=3, num_buckets=8,
                               optimizer="sgd")
        try:
            for ev in events:
                _push_some(fleet, rng)
                live = sorted(fleet.transport.live_shards)
                if ev == "join":
                    fleet.join()
                elif ev == "leave" and len(live) > 2:
                    fleet.leave(int(rng.choice(live)))
                elif ev == "kill" and len(live) > 2:
                    fleet.kill(int(rng.choice(live)))
                    fleet.recover()
                _push_some(fleet, rng)
                _assert_ownership_partition(fleet)
            # the table is still fully readable row-for-row
            assert np.asarray(fleet.to_dense()).shape == (VOCAB, DIM)
        finally:
            fleet.close()

    @settings(max_examples=10, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_state_unchanged_by_elasticity(self, seed):
        """The same push stream lands bit-identically whether or not the
        fleet reshapes mid-stream — elasticity is invisible to values."""
        def run(with_events):
            rng = np.random.default_rng(seed)
            fleet = ElasticPSFleet(VOCAB, DIM, num_shards=3, num_buckets=8,
                                   optimizer="adagrad")
            try:
                for i in range(8):
                    _push_some(fleet, rng)
                    if with_events and i == 2:
                        fleet.join()
                    if with_events and i == 5:
                        fleet.kill(0)
                        fleet.recover()
                return np.asarray(fleet.to_dense())
            finally:
                fleet.close()

        assert np.array_equal(run(True), run(False))


class TestBoundedStaleness:
    @settings(max_examples=10, deadline=None)
    @given(
        st.integers(min_value=0, max_value=5),
        st.integers(min_value=1, max_value=12),
        st.integers(min_value=0, max_value=10_000),
    )
    def test_pull_never_staler_than_bound(self, bound, n_pushes, seed):
        rng = np.random.default_rng(seed)
        fleet = ElasticPSFleet(VOCAB, DIM, num_shards=2, num_buckets=4,
                               optimizer="sgd", staleness_bound=bound)
        try:
            sid = fleet.join(rebalance=False)
            fleet.begin_migration(0, sid)
            lr = 0.5
            ids = np.arange(min(5, fleet.spec.bucket_rows))
            for i in range(n_pushes):
                fleet.push(ids, np.ones((ids.size, DIM), np.float32), lr=lr)
                assert fleet.migration_staleness(0) <= bound
                # the true value is -lr per push; the pull may miss at
                # most `bound` of the applied pushes
                seen = float(np.asarray(fleet.pull(ids[:1]))[0, 0])
                true = -lr * (i + 1)
                missed = round((seen - true) / lr)
                assert 0 <= missed <= bound, (seen, true, missed)
            fleet.finish_migration(0)
            assert fleet.migration_backlog(0) == 0
            # after the flip the destination has every update
            seen = float(np.asarray(fleet.pull(ids[:1]))[0, 0])
            assert abs(seen - (-lr * n_pushes)) < 1e-5
            assert fleet.owners()[0][0] == sid
        finally:
            fleet.close()


class TestLosslessRecovery:
    @settings(max_examples=10, deadline=None)
    @given(
        st.integers(min_value=0, max_value=10_000),
        st.sampled_from(["sgd", "adagrad", "adam"]),
    )
    def test_promoted_replica_is_bit_exact(self, seed, optimizer):
        rng = np.random.default_rng(seed)
        fleet = ElasticPSFleet(VOCAB, DIM, num_shards=3, num_buckets=6,
                               optimizer=optimizer)
        try:
            for _ in range(5):
                _push_some(fleet, rng, lr=0.05)
            before = np.asarray(fleet.to_dense())
            victim = int(rng.choice(sorted(fleet.transport.live_shards)))
            fleet.kill(victim)
            # next touch triggers recovery transparently
            after_pull = np.asarray(fleet.pull(np.arange(VOCAB)))
            after = np.asarray(fleet.to_dense())
            assert np.array_equal(before, after)
            assert np.array_equal(before, after_pull)
            _assert_ownership_partition(fleet)
        finally:
            fleet.close()

    def test_losing_both_replicas_is_unrecoverable(self):
        fleet = ElasticPSFleet(VOCAB, DIM, num_shards=2, num_buckets=4,
                               optimizer="sgd")
        fleet.kill(0)
        fleet.kill(1)
        with pytest.raises((RuntimeError, PSShardLost)):
            fleet.recover()

    def test_no_replicas_means_no_recovery(self):
        fleet = ElasticPSFleet(VOCAB, DIM, num_shards=2, num_buckets=4,
                               optimizer="sgd", replicas=0)
        try:
            fleet.kill(0)
            with pytest.raises(RuntimeError):
                fleet.recover()
        finally:
            fleet.close()


class TestCTRConvergencePin:
    def test_kill_recovery_matches_uninterrupted_trajectory(self):
        """ISSUE acceptance: shard kill + replica recovery during CTR
        training converges to the same loss trajectory as the
        uninterrupted run (bit-equal here — sync replication plus a
        deterministic PS-hosted optimizer lose nothing at all)."""
        from repro.ps.workload import CTRConfig, train_ctr_elastic

        cfg = CTRConfig(vocab=5_000, emb_dim=8, slots=8, tower=(32,),
                        batch=64)
        kw = dict(steps=40, num_shards=3, optimizer="sgd", mode="sync")
        calm = train_ctr_elastic(cfg, **kw)
        hit = train_ctr_elastic(
            cfg, **kw, events=[(10, "join", None), (20, "kill", 0)])
        assert any(e["kind"] == "recover" for e in hit["events"])
        assert hit["live_shards"] != calm["live_shards"]
        np.testing.assert_allclose(hit["losses"], calm["losses"],
                                   rtol=0.0, atol=0.0)
        assert np.mean(calm["losses"][-8:]) < np.mean(calm["losses"][:8])
