"""Unit + property tests for the HeterPS cost model (Formulas 1–7)."""

import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hard dep: deterministic fallback shim
    from _hypothesis_fallback import given, settings, st


from repro.core import (
    INFEASIBLE, SchedulingPlan, TrainingJob, build_stages, default_fleet,
    monetary_cost, paper_model_profiles, pipeline_throughput, plan_cost,
)
from repro.core.cost_model import (
    stage_comm_time, stage_compute_time, stage_exec_time, stage_throughput,
)
from repro.core.plan import ProvisioningPlan
from repro.core.profiles import PAPER_MODELS, ctrdnn_variant, profile_layers

FLEET = default_fleet()
JOB = TrainingJob()


def _stages(model="CTRDNN", plan=None):
    profs = paper_model_profiles(model, FLEET)
    plan = plan or SchedulingPlan((0,) + (1,) * (len(profs) - 1))
    return plan, profs, build_stages(plan, profs, FLEET)


class TestStageFusion:
    def test_consecutive_same_type_layers_fuse(self):
        plan = SchedulingPlan((0, 0, 1, 1, 1, 0))
        assert plan.stage_boundaries() == [(0, 2, 0), (2, 5, 1), (5, 6, 0)]

    def test_all_same_type_is_one_stage(self):
        plan = SchedulingPlan((1,) * 16)
        assert len(plan.stage_boundaries()) == 1

    def test_stage_oct_sums_layer_octs(self):
        plan, profs, stages = _stages()
        assert stages[0].oct == pytest.approx(profs[0].oct[0])
        assert stages[1].oct == pytest.approx(sum(p.oct[1] for p in profs[1:]))

    def test_interior_activation_handoff_not_counted(self):
        """Fusing layers must drop interior activation transfer (§1)."""
        profs = paper_model_profiles("CTRDNN", FLEET)
        fused = build_stages(SchedulingPlan((1,) * 16), profs, FLEET)
        split = build_stages(
            SchedulingPlan(tuple([1] * 15 + [0])), profs, FLEET
        )
        # fused single stage comm < sum of per-layer odt (activations dropped)
        assert fused[0].odt < sum(p.odt[1] for p in profs)


class TestAmdahl:
    def test_more_replicas_never_slower(self):
        _, _, stages = _stages()
        s = stages[1]
        times = [stage_exec_time(s, k, JOB.batch_size) for k in (1, 2, 4, 8, 64)]
        assert all(a >= b for a, b in zip(times, times[1:]))

    def test_sequential_fraction_is_asymptote(self):
        _, _, stages = _stages()
        s = stages[1]
        t_inf = stage_compute_time(s, 10**9, JOB.batch_size)
        expected = (s.oct / 64) * JOB.batch_size * (1 - s.alpha)
        assert t_inf == pytest.approx(expected, rel=1e-3)

    def test_exec_time_is_max_of_compute_and_comm(self):
        _, _, stages = _stages()
        for s in stages:
            for k in (1, 3, 7):
                assert stage_exec_time(s, k, 4096) == pytest.approx(
                    max(stage_compute_time(s, k, 4096),
                        stage_comm_time(s, k, 4096))
                )


class TestThroughputAndCost:
    def test_pipeline_throughput_is_min_over_stages(self):
        plan, profs, stages = _stages()
        prov = ProvisioningPlan(k=(4, 2))
        tps = [stage_throughput(s, k, JOB.batch_size)
               for s, k in zip(stages, prov.k)]
        assert pipeline_throughput(stages, prov, JOB.batch_size) == min(tps)

    def test_resource_limit_violation_is_infeasible(self):
        plan, profs, _ = _stages()
        prov = ProvisioningPlan(k=(10**6, 1))
        assert monetary_cost(plan, prov, profs, FLEET, JOB) == INFEASIBLE

    def test_throughput_violation_is_infeasible(self):
        plan, profs, _ = _stages()
        prov = ProvisioningPlan(k=(1, 1))  # 1 CPU core can't hit 200k ex/s
        assert monetary_cost(plan, prov, profs, FLEET, JOB) == INFEASIBLE

    def test_cpu_only_infeasible_for_ctrdnn(self):
        """Paper Fig. 10: CPU cannot meet the constraint for CTRDNN."""
        profs = paper_model_profiles("CTRDNN", FLEET)
        cost, _ = plan_cost(SchedulingPlan((0,) * 16), profs, FLEET, JOB)
        assert cost == INFEASIBLE

    def test_heterogeneous_beats_gpu_only(self):
        """Paper §6.2: scheduling the embedding to CPU beats GPU-only."""
        profs = paper_model_profiles("CTRDNN", FLEET)
        gpu, _ = plan_cost(SchedulingPlan((1,) * 16), profs, FLEET, JOB)
        het, _ = plan_cost(SchedulingPlan((0,) + (1,) * 15), profs, FLEET, JOB)
        assert het < gpu

    @given(st.lists(st.integers(0, 1), min_size=16, max_size=16))
    @settings(max_examples=30, deadline=None)
    def test_cost_nonnegative_or_infeasible(self, assignment):
        profs = paper_model_profiles("CTRDNN", FLEET)
        cost, prov = plan_cost(SchedulingPlan(tuple(assignment)), profs, FLEET, JOB)
        assert cost == INFEASIBLE or cost > 0
        if prov is not None:
            assert all(k >= 1 for k in prov.k)

    @given(st.sampled_from(sorted(PAPER_MODELS)))
    @settings(max_examples=8, deadline=None)
    def test_every_paper_model_has_feasible_plan(self, model):
        profs = paper_model_profiles(model, FLEET)
        cost, _ = plan_cost(
            SchedulingPlan(tuple(0 if p.kind == "embedding" else 1
                                 for p in profs)),
            profs, FLEET, JOB,
        )
        assert math.isfinite(cost)


class TestVariants:
    @pytest.mark.parametrize("n", [8, 12, 16, 20])
    def test_ctrdnn_variant_layer_counts(self, n):
        assert len(ctrdnn_variant(n)) == n

    def test_variant_profiles_build(self):
        profs = profile_layers(ctrdnn_variant(12), FLEET)
        assert len(profs) == 12 and all(len(p.oct) == 2 for p in profs)
