"""Sharding-rule tests (run against param templates; no devices needed)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.specs import SHAPES, input_specs, param_templates, supports


class FakeMesh:
    """Duck-typed mesh: only .shape / .axis_names are consulted by the
    spec builders."""

    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}
    size = 256


MESH = FakeMesh()


class TestParamSpecs:
    def _specs(self, arch):
        from repro.parallel.sharding import param_specs

        cfg = get_config(arch)
        params_t, _ = param_templates(cfg)
        return cfg, params_t, param_specs(params_t, cfg, MESH)

    def test_embed_vocab_over_model(self):
        _, _, specs = self._specs("llama3.2-1b")
        assert specs["embed"] == P("model", "data")

    def test_stacked_block_leaves_keep_repeats_unsharded(self):
        cfg, params_t, specs = self._specs("llama3.2-1b")
        w1 = specs["blocks"][0]["ffn"]["w1"]
        assert w1[0] is None  # repeats dim
        assert "model" in w1 and "data" in w1

    def test_moe_experts_over_model(self):
        _, _, specs = self._specs("qwen3-moe-30b-a3b")
        w1 = specs["blocks"][0]["ffn"]["w1"]   # (repeats, E, D, F)
        assert w1[0] is None and w1[1] == "model"

    def test_every_spec_divides_shape(self):
        """A spec must never shard a non-divisible dim (would fail at jit)."""
        for arch in ARCH_IDS:
            cfg, params_t, specs = self._specs(arch)

            def check(leaf, spec):
                for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 9):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    n = 1
                    for a in axes:
                        n *= MESH.shape[a]
                    assert dim % n == 0, (arch, leaf.shape, spec)

            jax.tree.map(check, params_t, specs,
                         is_leaf=lambda x: isinstance(x, P))


class TestInputSpecs:
    @pytest.mark.parametrize("shape", sorted(SHAPES))
    def test_llama_all_shapes_build(self, shape):
        if not supports(get_config("llama3.2-1b"), shape):
            pytest.skip("unsupported")
        step, args, specs, donate = input_specs("llama3.2-1b", shape, MESH)
        assert len(args) == len(specs)
        assert all(d < len(args) for d in donate)

    def test_long_500k_rejected_for_full_attention(self):
        with pytest.raises(ValueError):
            input_specs("chatglm3-6b", "long_500k", MESH)

    def test_long_500k_supported_for_ssm_hybrid_swa(self):
        for arch in ("rwkv6-7b", "jamba-v0.1-52b", "gemma2-2b"):
            step, args, specs, _ = input_specs(arch, "long_500k", MESH)
            assert step is not None

    def test_decode_cache_templates_sized_by_shape(self):
        step, args, specs, _ = input_specs("llama3.2-1b", "decode_32k", MESH)
        cache_t = args[2]
        k = cache_t[0]["k"]
        assert k.shape[2] == 32768  # cache length = seq_len
        assert k.shape[1] == 128    # global batch

    def test_whisper_context_in_train_batch(self):
        step, args, specs, _ = input_specs("whisper-large-v3", "train_4k", MESH)
        batch_t = args[2]
        assert "context" in batch_t
        assert batch_t["context"].shape == (256, 1500, 1280)
