"""Equivalence tests: batched cost model vs the scalar reference oracle.

`batched_plan_cost` / `batched_soft_plan_cost` / `batched_build_stages` /
`batched_provision` must agree with the scalar `plan_cost` /
`soft_plan_cost` / `build_stages` / `provision` on cost, feasibility, and
the chosen provisioning — over randomized plans, fleets, and jobs,
including infeasible and resource-limit edge cases.  The batched path is
written to follow the scalar operation sequence per plan, so agreement is
expected to be exact, but the assertions allow a relative 1e-9 to stay
robust to benign reduction-order changes.
"""

import dataclasses
import math

import numpy as np
import pytest

from repro.core import (
    SchedulingPlan,
    TrainingJob,
    batched_plan_cost,
    batched_soft_plan_cost,
    build_stages,
    default_fleet,
    make_fleet,
    paper_model_profiles,
    plan_cost,
    soft_plan_cost,
)
from repro.core.plan import batched_build_stages
from repro.core.schedulers.base import CostCache

JOB = TrainingJob()


def _random_plans(rng, n, L, T):
    A = rng.integers(0, T, (n, L))
    A[: min(T, n)] = np.arange(min(T, n))[:, None]      # homogeneous anchors
    if n > T + 1:
        A[T] = np.arange(L) % T                          # max-fragmentation plan
    return A


def _assert_close(a, b, what):
    if math.isinf(a) or math.isinf(b):
        assert a == b, f"{what}: {a} != {b}"
    else:
        assert a == pytest.approx(b, rel=1e-9), f"{what}: {a} != {b}"


def _check_equivalence(profiles, fleet, job, A):
    bc, soft = batched_soft_plan_cost(A, profiles, fleet, job)
    bc2 = batched_plan_cost(A, profiles, fleet, job)
    np.testing.assert_array_equal(bc.costs, bc2.costs)
    for i, row in enumerate(A):
        plan = SchedulingPlan(tuple(int(x) for x in row))
        cost, prov = plan_cost(plan, profiles, fleet, job)
        s = soft_plan_cost(plan, profiles, fleet, job)
        _assert_close(cost, float(bc.costs[i]), f"cost[{i}]")
        _assert_close(s, float(soft[i]), f"soft[{i}]")
        assert math.isfinite(cost) == bool(bc.feasible[i]), f"feasible[{i}]"
        bprov = bc.prov(i)
        if prov is None:
            assert bprov is None, f"prov[{i}]: scalar None, batched {bprov}"
        else:
            assert bprov is not None, f"prov[{i}]: batched None, scalar {prov}"
            assert prov.k == bprov.k, f"k[{i}]: {prov.k} != {bprov.k}"
            assert prov.ps_cores == bprov.ps_cores, f"ps[{i}]"


class TestStageBatchEquivalence:
    @pytest.mark.parametrize("model", ["CTRDNN", "MATCHNET", "2EMB", "NCE"])
    def test_matches_build_stages(self, model):
        fleet = make_fleet(3)
        profiles = paper_model_profiles(model, fleet)
        rng = np.random.default_rng(7)
        A = _random_plans(rng, 24, len(profiles), len(fleet))
        sb = batched_build_stages(A, profiles, fleet)
        for i, row in enumerate(A):
            stages = build_stages(
                SchedulingPlan(tuple(int(x) for x in row)), profiles, fleet
            )
            n = int(sb.num_stages[i])
            assert n == len(stages)
            assert not sb.mask[i, n:].any()
            for s in stages:
                j = s.index
                assert sb.rtype[i, j] == s.resource_type
                assert sb.oct[i, j] == s.oct
                assert sb.odt[i, j] == s.odt
                assert sb.alpha[i, j] == pytest.approx(s.alpha, rel=1e-12)
                assert sb.beta[i, j] == pytest.approx(s.beta, rel=1e-12)

    def test_rejects_bad_shapes(self):
        fleet = default_fleet()
        profiles = paper_model_profiles("NCE", fleet)
        with pytest.raises(ValueError):
            batched_build_stages(np.zeros(5, dtype=int), profiles, fleet)
        with pytest.raises(ValueError):
            batched_build_stages(np.zeros((2, 3), dtype=int), profiles, fleet)


class TestBatchedCostEquivalence:
    @pytest.mark.parametrize(
        "model,num_types", [("CTRDNN", 2), ("MATCHNET", 2), ("2EMB", 3), ("NCE", 4)]
    )
    def test_randomized_plans(self, model, num_types):
        fleet = default_fleet() if num_types == 2 else make_fleet(num_types)
        profiles = paper_model_profiles(model, fleet)
        rng = np.random.default_rng(hash((model, num_types)) % 2**32)
        A = _random_plans(rng, 32, len(profiles), num_types)
        _check_equivalence(profiles, fleet, JOB, A)

    def test_mostly_infeasible_job(self):
        """A throughput limit near the fleet ceiling exercises the graded
        surrogate (relaxed re-provision) on most plans."""
        fleet = default_fleet()
        profiles = paper_model_profiles("CTRDNN", fleet)
        job = dataclasses.replace(JOB, throughput_limit=2_000_000.0)
        rng = np.random.default_rng(11)
        A = _random_plans(rng, 24, len(profiles), len(fleet))
        _check_equivalence(profiles, fleet, job, A)

    def test_easy_job_all_feasible_path(self):
        fleet = default_fleet()
        profiles = paper_model_profiles("2EMB", fleet)
        job = dataclasses.replace(JOB, throughput_limit=5_000.0)
        rng = np.random.default_rng(13)
        A = _random_plans(rng, 24, len(profiles), len(fleet))
        _check_equivalence(profiles, fleet, job, A)

    def test_resource_limit_edge(self):
        """Per-type limits small enough that integer rounding decides
        feasibility (Formula 10 boundary)."""
        fleet = [
            dataclasses.replace(r, max_count=max(2, r.max_count // 80))
            for r in default_fleet()
        ]
        profiles = paper_model_profiles("NCE", fleet)
        for limit in (5_000.0, 50_000.0, 200_000.0):
            job = dataclasses.replace(JOB, throughput_limit=limit)
            rng = np.random.default_rng(int(limit))
            A = _random_plans(rng, 16, len(profiles), len(fleet))
            _check_equivalence(profiles, fleet, job, A)

    def test_varied_batch_sizes(self):
        fleet = default_fleet()
        profiles = paper_model_profiles("NCE", fleet)
        rng = np.random.default_rng(17)
        A = _random_plans(rng, 12, len(profiles), len(fleet))
        for bs in (256, 4096, 65536):
            job = dataclasses.replace(JOB, batch_size=bs)
            _check_equivalence(profiles, fleet, job, A)

    def test_single_plan_batch(self):
        fleet = default_fleet()
        profiles = paper_model_profiles("CTRDNN", fleet)
        A = np.array([[0] + [1] * (len(profiles) - 1)])
        _check_equivalence(profiles, fleet, JOB, A)


class TestCostCacheBatching:
    def setup_method(self):
        self.fleet = default_fleet()
        self.profiles = paper_model_profiles("2EMB", self.fleet)

    def test_dedup_counts_one_eval_per_novel_plan(self):
        cache = CostCache(self.profiles, self.fleet, JOB)
        L = len(self.profiles)
        a, b = (0,) * L, (1,) * L
        costs = cache.batch_call([a, b, a, b, a])
        assert cache.evaluations == 2
        assert costs.shape == (5,)
        assert costs[0] == costs[2] == costs[4]
        cache.batch_call([a, b])  # fully cached: no new evaluations
        assert cache.evaluations == 2

    def test_soft_shares_true_cost_evaluation(self):
        cache = CostCache(self.profiles, self.fleet, JOB)
        L = len(self.profiles)
        plans = [(i % 2,) * L for i in range(2)] + [
            tuple((i + j) % 2 for j in range(L)) for i in range(2)
        ]
        soft = cache.batch_soft(plans)
        n = cache.evaluations
        # soft scoring also populated the true-cost cache: no re-evaluation
        cache.batch_call(plans)
        assert cache.evaluations == n
        for p, s in zip(plans, soft):
            true = cache(p)
            if math.isfinite(true):
                assert s == true
            else:
                assert math.isfinite(s)  # graded surrogate stays finite

    def test_scalar_and_batch_entry_points_agree(self):
        cache1 = CostCache(self.profiles, self.fleet, JOB)
        cache2 = CostCache(self.profiles, self.fleet, JOB)
        L = len(self.profiles)
        rng = np.random.default_rng(3)
        A = rng.integers(0, 2, (8, L))
        batch = cache1.batch_soft(A)
        single = np.array([cache2.soft(row) for row in A])
        np.testing.assert_array_equal(batch, single)
