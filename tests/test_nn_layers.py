"""Layer-level unit & equivalence tests for the nn library."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hard dep: deterministic fallback shim
    from _hypothesis_fallback import given, settings, st


from repro.nn import attention as attn_mod
from repro.nn import mamba as mamba_mod
from repro.nn import moe as moe_mod
from repro.nn import rwkv as rwkv_mod
from repro.nn.attention import AttnSpec
from repro.nn.base import apply_rope, cross_entropy_loss, rmsnorm, softcap

KEY = jax.random.PRNGKey(0)


class TestAttention:
    def _spec(self, **kw):
        d = dict(n_heads=4, n_kv_heads=2, head_dim=32, causal=True, rope=True)
        d.update(kw)
        return AttnSpec(**d)

    def test_blockwise_equals_direct(self):
        """The flash-style scan path must equal direct attention exactly."""
        spec = self._spec()
        B, S, D = 2, 2304, 128  # > BLOCKWISE_THRESHOLD with padding ragged
        p = attn_mod.init_attention(KEY, D, spec)
        x = jax.random.normal(KEY, (B, S, D)) * 0.3
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        out_block = attn_mod.attention(p, x, spec, positions=pos)
        # force the direct path by raising the threshold
        old = attn_mod.BLOCKWISE_THRESHOLD
        try:
            attn_mod.BLOCKWISE_THRESHOLD = 10**9
            out_direct = attn_mod.attention(p, x, spec, positions=pos)
        finally:
            attn_mod.BLOCKWISE_THRESHOLD = old
        np.testing.assert_allclose(np.asarray(out_block),
                                   np.asarray(out_direct), atol=3e-5)

    def test_causality(self):
        """Future tokens must not influence earlier outputs."""
        spec = self._spec(rope=False)
        D = 128
        p = attn_mod.init_attention(KEY, D, spec)
        x1 = jax.random.normal(KEY, (1, 16, D))
        x2 = x1.at[:, -1].set(99.0)  # perturb only the last token
        pos = jnp.arange(16, dtype=jnp.int32)[None]
        o1 = attn_mod.attention(p, x1, spec, positions=pos)
        o2 = attn_mod.attention(p, x2, spec, positions=pos)
        np.testing.assert_allclose(np.asarray(o1[:, :-1]),
                                   np.asarray(o2[:, :-1]), atol=1e-6)

    def test_sliding_window_limits_receptive_field(self):
        spec = self._spec(window=4, rope=False)
        D = 128
        p = attn_mod.init_attention(KEY, D, spec)
        x1 = jax.random.normal(KEY, (1, 32, D))
        x2 = x1.at[:, 0].set(50.0)  # token 0 outside window of token 31
        pos = jnp.arange(32, dtype=jnp.int32)[None]
        o1 = attn_mod.attention(p, x1, spec, positions=pos)
        o2 = attn_mod.attention(p, x2, spec, positions=pos)
        np.testing.assert_allclose(np.asarray(o1[:, -1]), np.asarray(o2[:, -1]),
                                   atol=1e-6)

    def test_ring_buffer_decode_window(self):
        """Windowed decode with L = window must match full-cache decode."""
        spec = self._spec(window=8, rope=True)
        D = 128
        p = attn_mod.init_attention(KEY, D, spec)
        B, T = 1, 20
        xs = jax.random.normal(KEY, (B, T, 1, D)) * 0.5
        big = attn_mod.init_kv_cache(B, T, spec, dtype=jnp.float32)
        ring = attn_mod.init_kv_cache(B, 8, spec, dtype=jnp.float32)
        for i in range(T):
            o_big, big = attn_mod.decode_attention(p, xs[:, i], big, jnp.int32(i), spec)
            o_ring, ring = attn_mod.decode_attention(p, xs[:, i], ring, jnp.int32(i), spec)
            np.testing.assert_allclose(np.asarray(o_big), np.asarray(o_ring),
                                       atol=1e-5)


class TestRope:
    def test_rope_preserves_norm(self):
        x = jax.random.normal(KEY, (2, 8, 4, 64))
        pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
        y = apply_rope(x, pos)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                                   np.linalg.norm(np.asarray(y), axis=-1),
                                   rtol=1e-5)

    def test_partial_rope_leaves_tail_untouched(self):
        x = jax.random.normal(KEY, (1, 4, 2, 64))
        pos = jnp.broadcast_to(jnp.arange(4, dtype=jnp.int32), (1, 4))
        y = apply_rope(x, pos, fraction=0.5)
        np.testing.assert_allclose(np.asarray(x[..., 32:]),
                                   np.asarray(y[..., 32:]))

    def test_relative_phase(self):
        """RoPE scores depend only on relative distance."""
        q = jax.random.normal(KEY, (1, 1, 1, 64))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, 64))
        def score(pq, pk):
            qq = apply_rope(q, jnp.full((1, 1), pq, jnp.int32))
            kk = apply_rope(k, jnp.full((1, 1), pk, jnp.int32))
            return float(jnp.sum(qq * kk))
        assert score(3, 1) == pytest.approx(score(10, 8), rel=1e-4)


class TestMamba:
    def test_decode_matches_sequence(self):
        D = 64
        p = mamba_mod.init_mamba(KEY, D)
        B, S = 2, 24
        x = jax.random.normal(KEY, (B, S, D)) * 0.5
        y_seq = mamba_mod.mamba(p, x)
        cache = mamba_mod.init_mamba_cache(B, D)
        outs = []
        for i in range(S):
            y, cache = mamba_mod.decode_mamba(p, x[:, i : i + 1], cache)
            outs.append(y[:, 0])
        y_dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_seq),
                                   atol=1e-4)

    def test_state_carries_information(self):
        D = 32
        p = mamba_mod.init_mamba(KEY, D)
        cache = mamba_mod.init_mamba_cache(1, D)
        x = jax.random.normal(KEY, (1, 1, D))
        _, c1 = mamba_mod.decode_mamba(p, x, cache)
        assert float(jnp.abs(c1["h"]).max()) > 0


class TestRwkv:
    def test_decode_matches_sequence(self):
        D = 128
        p = rwkv_mod.init_time_mix(KEY, D, head_size=64)
        B, S = 1, 16
        x = jax.random.normal(KEY, (B, S, D)) * 0.5
        y_seq = rwkv_mod.time_mix(p, x, head_size=64)
        cache = rwkv_mod.init_rwkv_cache(B, D, head_size=64)
        outs = []
        for i in range(S):
            y, upd = rwkv_mod.decode_time_mix(p, x[:, i : i + 1], cache,
                                              head_size=64)
            cache = {**cache, **upd}
            outs.append(y[:, 0])
        np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                                   np.asarray(y_seq), atol=1e-4)

    def test_decay_in_unit_interval(self):
        D = 128
        p = rwkv_mod.init_time_mix(KEY, D, head_size=64)
        x = jax.random.normal(KEY, (4, D))
        from repro.nn.rwkv import _lora
        w = jnp.exp(-jnp.exp(p["decay_base"] + _lora(p["decay_lora"], x)))
        assert float(w.min()) > 0.0 and float(w.max()) < 1.0


class TestMoe:
    def test_full_capacity_equals_dense_expert_mix(self):
        """With capacity ≥ all tokens and top_k=E, MoE = gate-weighted sum
        of every expert — check against an explicit loop."""
        D, F, E = 16, 32, 4
        p = moe_mod.init_moe(KEY, D, F, E)
        x = jax.random.normal(KEY, (2, 8, D))
        y, aux = moe_mod.moe_ffn(p, x, top_k=E, capacity_factor=8.0)
        logits = (x @ p["router"]).astype(jnp.float32)
        gates = jax.nn.softmax(logits, -1)
        want = jnp.zeros_like(x)
        for e in range(E):
            pe = {"w1": p["w1"][e], "w3": p["w3"][e], "w2": p["w2"][e]}
            want += gates[..., e : e + 1] * moe_mod.dense_ffn(pe, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4)
        assert float(aux["dropped"]) == 0.0

    def test_capacity_drops_tokens(self):
        D, F, E = 8, 16, 2
        p = moe_mod.init_moe(KEY, D, F, E)
        x = jax.random.normal(KEY, (1, 64, D))
        _, aux = moe_mod.moe_ffn(p, x, top_k=1, capacity_factor=0.25)
        assert float(aux["dropped"]) > 0.0

    @given(st.integers(1, 4))
    @settings(max_examples=6, deadline=None)
    def test_aux_loss_finite(self, top_k):
        D, F, E = 8, 16, 4
        p = moe_mod.init_moe(KEY, D, F, E)
        x = jax.random.normal(KEY, (2, 16, D))
        y, aux = moe_mod.moe_ffn(p, x, top_k=top_k)
        assert np.isfinite(float(aux["aux_loss"]))
        assert np.isfinite(np.asarray(y)).all()

    def test_impl_paths_agree(self):
        """Default (auto→slot on CPU) and ref oracle produce one answer."""
        D, F, E = 16, 32, 4
        p = moe_mod.init_moe(KEY, D, F, E)
        x = jax.random.normal(KEY, (2, 24, D))
        y_auto, _ = moe_mod.moe_ffn(p, x, top_k=2)
        y_ref, _ = moe_mod.moe_ffn(p, x, top_k=2, impl="ref")
        np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_ref),
                                   atol=1e-5)


class TestMoeRouting:
    """Property tests for the routing invariants of moe_route (the
    pure-JAX reference shared by oracle and kernel paths)."""

    def _route(self, S, K, cf, seed=0):
        D, E = 8, 4
        key = jax.random.fold_in(KEY, seed)
        p = moe_mod.init_moe(key, D, 16, E)
        x = jax.random.normal(key, (2, S, D))
        C = moe_mod.moe_capacity(S, E, K, cf)
        probs, gate, eid_f, pos, keep = moe_mod.moe_route(
            p["router"], x, top_k=K, capacity=C)
        return E, C, probs, gate, eid_f, pos, keep

    @given(st.integers(4, 40), st.integers(1, 4), st.sampled_from(
        [0.25, 0.5, 1.0, 1.25, 4.0]))
    @settings(max_examples=15, deadline=None)
    def test_routing_invariants(self, S, K, cf):
        E, C, probs, gate, eid_f, pos, keep = self._route(S, K, cf,
                                                          seed=S * 16 + K)
        eid_np = np.asarray(eid_f)
        pos_np = np.asarray(pos)
        keep_np = np.asarray(keep)
        G, NK = eid_np.shape
        assert NK == S * K
        # gates: renormalized over k, each in (0, 1]
        g_np = np.asarray(gate)
        np.testing.assert_allclose(g_np.sum(-1), 1.0, atol=1e-5)
        assert (g_np > 0).all()
        for g in range(G):
            for e in range(E):
                sel = eid_np[g] == e
                # kept slots of expert e occupy distinct positions 0..<C
                kept_pos = pos_np[g][sel & keep_np[g]]
                assert len(set(kept_pos.tolist())) == len(kept_pos)
                assert (kept_pos < C).all() and (kept_pos >= 0).all()
                # occupancy == min(routed, C): first-come-first-kept
                assert len(kept_pos) == min(int(sel.sum()), C)
            # per-token: the K expert choices are distinct (top-k)
            per_tok = eid_np[g].reshape(S, K)
            for s in range(S):
                assert len(set(per_tok[s].tolist())) == K
        # drop accounting matches moe_capacity arithmetic exactly
        overflow = sum(
            max(0, int((eid_np[g] == e).sum()) - C)
            for g in range(G) for e in range(E)
        )
        assert int((~keep_np).sum()) == overflow

    @given(st.integers(1, 4))
    @settings(max_examples=4, deadline=None)
    def test_reconstruction_when_undropped(self, K):
        """combine∘dispatch on an un-dropped batch reconstructs the
        top-k gate-weighted mix: with identity experts, y == x."""
        from repro.kernels import moe as moe_k

        D, E, S = 8, 4, 12
        key = jax.random.fold_in(KEY, 7 + K)
        p = moe_mod.init_moe(key, D, 16, E)
        x = jax.random.normal(key, (2, S, D))
        C = moe_mod.moe_capacity(S, E, K, 8.0)   # capacity ≥ all tokens
        _, gate, eid_f, pos, keep = moe_mod.moe_route(p["router"], x,
                                                      top_k=K, capacity=C)
        assert bool(jnp.all(keep))
        buf = moe_k.moe_dispatch(x, eid_f, pos, keep.astype(jnp.float32),
                                 E, C, K, "slot")
        y = moe_k.moe_combine(buf, eid_f.reshape(2, S, K),
                              pos.reshape(2, S, K),
                              gate.reshape(2, S, K), "slot")
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-5)


class TestBase:
    def test_softcap_bounds(self):
        x = jnp.linspace(-1e4, 1e4, 101)
        y = softcap(x, 30.0)
        assert float(jnp.abs(y).max()) <= 30.0

    def test_rmsnorm_unit_rms(self):
        x = jax.random.normal(KEY, (4, 64)) * 7
        y = rmsnorm(x, jnp.ones((64,)))
        rms = np.sqrt((np.asarray(y) ** 2).mean(-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_cross_entropy_ignores_masked(self):
        logits = jax.random.normal(KEY, (2, 4, 10))
        labels = jnp.array([[1, 2, -1, -1], [0, -1, -1, -1]])
        l1 = cross_entropy_loss(logits, labels, vocab=10)
        labels2 = jnp.array([[1, 2, -1, -1], [0, -1, -1, -1]])
        assert np.isfinite(float(l1))
        # uniform logits → loss = log(10) on unmasked positions
        lu = cross_entropy_loss(jnp.zeros((1, 3, 10)),
                                jnp.array([[0, 1, -1]]), vocab=10)
        assert float(lu) == pytest.approx(np.log(10), rel=1e-5)
