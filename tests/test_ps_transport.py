"""Transport-backed PS: multiprocess shards bit-exact vs in-process,
failure semantics, and the spawn-fast import contract.

The in-process transport is the oracle (itself pinned against
``SparseEmbedding`` in test_ps.py); these tests pin the multiprocess
backend — real worker processes behind OS pipes — bit-for-bit against
it, and exercise the failure surface elastic recovery stands on
(``PSShardError`` vs ``PSShardLost``, partial-failure ``request_many``).

Every test runs under a hard SIGALRM timeout so a hung shard process can
never hang the suite (the CI multiproc lane relies on this).
"""

from __future__ import annotations

import signal
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.ps.server import ShardServer
from repro.ps.sharding import ShardedTable
from repro.ps.transport import (
    InProcTransport, MultiprocTransport, PSShardError, PSShardLost,
    make_transport,
)

VOCAB, DIM = 101, 8
HARD_TIMEOUT_S = 120


@pytest.fixture(autouse=True)
def hard_timeout():
    """SIGALRM per-test ceiling: a wedged shard process fails the test
    instead of wedging the runner."""
    def boom(signum, frame):
        raise TimeoutError(
            f"test exceeded the {HARD_TIMEOUT_S}s hard timeout")

    old = signal.signal(signal.SIGALRM, boom)
    signal.alarm(HARD_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def _traffic(rng, n_ops=6):
    """A deterministic mixed pull/push workload."""
    ops = []
    for i in range(n_ops):
        ids = rng.integers(0, VOCAB, size=rng.integers(3, 40))
        grads = rng.normal(size=(ids.size, DIM)).astype(np.float32)
        ops.append((ids, grads, 0.01 * (i + 1), bool(i % 2)))
    return ops


class TestMultiprocBitExact:
    @pytest.mark.parametrize("partition", ["mod", "block"])
    @pytest.mark.parametrize("shards", [1, 3])
    def test_multiproc_matches_inproc(self, shards, partition):
        rng = np.random.default_rng(0)
        ops = _traffic(rng)
        key = jax.random.PRNGKey(7)
        tables = [
            ShardedTable(VOCAB, DIM, shards, key, partition=partition,
                         transport=kind)
            for kind in ("inproc", "multiproc")
        ]
        try:
            for ids, grads, lr, dedup in ops:
                pulled = [np.asarray(t.pull(ids)) for t in tables]
                assert np.array_equal(pulled[0], pulled[1])
                for t in tables:
                    t.push(ids, grads, lr=lr, dedup=dedup)
            dense = [np.asarray(t.to_dense()) for t in tables]
            assert np.array_equal(dense[0], dense[1])
        finally:
            for t in tables:
                t.close()

    def test_hot_cache_write_through_over_multiproc(self):
        rng = np.random.default_rng(1)
        table = ShardedTable(VOCAB, DIM, 3, jax.random.PRNGKey(0),
                             transport="multiproc", hot_capacity=16)
        try:
            hot = np.arange(10, dtype=np.int64)
            table.install_hot_rows(hot)
            ids = rng.integers(0, VOCAB, size=64)
            grads = rng.normal(size=(64, DIM)).astype(np.float32)
            table.push(ids, grads, lr=0.5)
            # cached rows must equal the shard-held rows after the push
            pulled = np.asarray(table.pull(hot))          # served hot
            cold = table._fetch(hot)                      # served by shards
            assert np.array_equal(pulled, cold)
        finally:
            table.close()


class TestFailureSemantics:
    def test_bad_request_is_error_not_lost(self):
        for kind in ("inproc", "multiproc"):
            tr = make_transport(kind)
            tr.add_shard(0, dim=DIM)
            try:
                with pytest.raises(PSShardError):
                    tr.request(0, {"op": "no-such-op"})
                # the shard survived the bad request
                assert tr.request(0, {"op": "stats"})["ok"]
            finally:
                tr.close()

    def test_kill_surfaces_as_lost(self):
        for kind in ("inproc", "multiproc"):
            tr = make_transport(kind)
            tr.add_shard(0, dim=DIM)
            tr.kill_shard(0)
            assert tr.live_shards == set()
            with pytest.raises(PSShardLost):
                tr.request(0, {"op": "stats"})
            tr.close()

    def test_request_many_partial_failure_applies_to_live_shards(self):
        for kind in ("inproc", "multiproc"):
            tr = make_transport(kind)
            for s in (0, 1, 2):
                tr.add_shard(s, dim=DIM)
                tr.request(s, {"op": "create", "bucket": s,
                               "rows": np.zeros((4, DIM), np.float32)})
            tr.kill_shard(1)
            msgs = [(s, {"op": "add",
                         "buckets": np.array([s]),
                         "ids": np.array([0]),
                         "updates": np.ones((1, DIM), np.float32)})
                    for s in (0, 1, 2)]
            with pytest.raises(PSShardLost) as ei:
                tr.request_many(msgs)
            assert ei.value.shard_ids == {1}
            # the live shards applied their messages, replies consumed —
            # the channel is still in protocol sync
            for s in (0, 2):
                rows = tr.request(s, {"op": "snapshot", "bucket": s})["rows"]
                assert rows[0, 0] == 1.0
            tr.close()

    def test_timeout_surfaces_as_lost(self):
        tr = MultiprocTransport(request_timeout=1.0)
        tr.add_shard(0, dim=DIM)
        try:
            # suspend the worker so the request genuinely hangs
            import os

            pid = tr._shards[0].proc.pid
            os.kill(pid, signal.SIGSTOP)
            try:
                with pytest.raises(PSShardLost):
                    tr.request(0, {"op": "stats"})
            finally:
                try:
                    os.kill(pid, signal.SIGCONT)
                except ProcessLookupError:
                    pass
            assert 0 not in tr.live_shards
        finally:
            tr.close()

    def test_double_add_shard_rejected(self):
        tr = InProcTransport()
        tr.add_shard(0, dim=DIM)
        with pytest.raises(ValueError):
            tr.add_shard(0, dim=DIM)
        tr.close()


class TestServerProtocol:
    def test_acked_counts_per_bucket(self):
        srv = ShardServer(0, DIM)
        srv.handle({"op": "create", "bucket": 3,
                    "rows": np.zeros((5, DIM), np.float32)})
        for i in range(3):
            out = srv.handle({"op": "add", "buckets": np.array([3, 3]),
                              "ids": np.array([0, 1]),
                              "updates": np.ones((2, DIM), np.float32)})
        assert out["acked"] == {3: 3}

    def test_replica_flag_splits_counters(self):
        srv = ShardServer(0, DIM)
        srv.handle({"op": "create", "bucket": 0,
                    "rows": np.zeros((5, DIM), np.float32)})
        msg = {"op": "add", "buckets": np.array([0]), "ids": np.array([0]),
               "updates": np.ones((1, DIM), np.float32)}
        srv.handle(msg)
        srv.handle({**msg, "replica": True})
        assert srv.counters["pushes"] == 1
        assert srv.counters["replica_pushes"] == 1

    def test_snapshot_install_roundtrip_preserves_opt_state(self):
        src = ShardServer(0, DIM, optimizer="adam")
        dst = ShardServer(1, DIM, optimizer="adam")
        rng = np.random.default_rng(0)
        src.handle({"op": "create", "bucket": 0,
                    "rows": rng.normal(size=(6, DIM)).astype(np.float32)})
        grad = {"op": "grad", "buckets": np.array([0, 0]),
                "ids": np.array([1, 4]),
                "grads": rng.normal(size=(2, DIM)).astype(np.float32),
                "lr": 0.1}
        src.handle(grad)
        snap = src.handle({"op": "snapshot", "bucket": 0})
        dst.handle({"op": "install", "bucket": 0, "rows": snap["rows"],
                    "opt": snap["opt"], "acked": snap["acked"]})
        # replaying one more identical update lands bit-identically
        src.handle(grad)
        dst.handle(grad)
        a = src.handle({"op": "snapshot", "bucket": 0})
        b = dst.handle({"op": "snapshot", "bucket": 0})
        assert np.array_equal(a["rows"], b["rows"])
        assert a["acked"] == b["acked"]


class TestSpawnImportCost:
    def test_server_module_imports_without_jax(self):
        """The shard worker's import path must stay numpy-only — that is
        what keeps multiproc shard startup at milliseconds."""
        import os

        root = os.path.join(os.path.dirname(__file__), "..")
        code = ("import sys; import repro.ps.server; "
                "sys.exit(1 if 'jax' in sys.modules else 0)")
        proc = subprocess.run(
            [sys.executable, "-c", code],
            env={**os.environ, "PYTHONPATH": os.path.join(root, "src")},
            capture_output=True, timeout=60)
        assert proc.returncode == 0, proc.stderr.decode()
