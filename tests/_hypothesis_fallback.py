"""Minimal stand-in for ``hypothesis`` when it is not installed.

The test suite's property tests use a small, fixed subset of the
hypothesis API (``@given``/``@settings`` with ``st.integers``,
``st.floats``, ``st.lists``, ``st.sampled_from``).  When the real
library is available the test modules import it directly; otherwise
they fall back to this shim, which replays each property test over a
deterministic pseudo-random sample of the strategy space.  That keeps
the suite collectable and the properties exercised everywhere without
adding a hard dependency (see requirements-dev.txt for the real one).
"""

from __future__ import annotations

import functools
import inspect
import random

_DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int = 0, max_value: int = 1 << 16) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        pool = list(elements)
        return _Strategy(lambda rng: rng.choice(pool))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]

        return _Strategy(draw)


st = strategies


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    def wrap(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return wrap


def given(*strats: _Strategy):
    def wrap(fn):
        @functools.wraps(fn)
        def run(*args, **kwargs):
            n = getattr(run, "_fallback_max_examples", None) or getattr(
                fn, "_fallback_max_examples", _DEFAULT_EXAMPLES
            )
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                fn(*args, *[s.example(rng) for s in strats], **kwargs)

        # Hide the strategy-filled trailing parameters from pytest, which
        # would otherwise look for fixtures with those names.
        params = list(inspect.signature(fn).parameters.values())
        run.__signature__ = inspect.Signature(params[: len(params) - len(strats)])
        del run.__wrapped__
        return run

    return wrap
