"""Chaos suite: fault injection, retry masking, failure detection, and
crash-consistent checkpoint/restore.

The contract under test, layer by layer:

* **schedule** — ``parse_schedule``/``FaultRule`` are a deterministic
  failure oracle: same seed + schedule ⇒ the same injections at the
  same requests, so every chaos run is replayable;
* **masking** — every non-``crash`` fault (delay, dropped reply,
  duplicated reply, transient recv error) is absorbed by the transport
  retry layer + server seq-dedup and produces a **bit-exact** loss
  trajectory vs a fault-free run;
* **detection** — a hung worker surfaces as retryable
  :class:`PSShardSlow` before escalating, a dead one as
  :class:`PSShardLost` carrying op/exitcode; the heartbeat notices a
  dead shard within its deadline with no request traffic at all;
* **durability** — killing a bucket's primary *and* backup is only
  survivable through the unified checkpoint: the run restores the
  newest complete step and replays to the fault-free trajectory,
  bit-for-bit.  Checkpoint publication is atomic (staged dirs + a
  ``LATEST`` pointer), so a torn save is never selectable.

The property test (hypothesis, in-repo fallback shim) is the ISSUE's
satellite: random interleaved delay/drop/dup/kill schedules against the
elastic fleet, pinned on post-recovery pulls bit-exact vs a fault-free
oracle and on ownership remaining a partition.
"""

from __future__ import annotations

import os
import signal
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # in-repo deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.checkpoint import read_pointer
from repro.ps.elastic import ElasticPSFleet, PSUnrecoverable
from repro.ps.faults import FaultInjector, FaultRule, parse_schedule
from repro.ps.snapshot import (
    FleetCheckpointer, list_checkpoints, load_fleet_checkpoint,
    save_fleet_checkpoint, snapshot_fleet,
)
from repro.ps.transport import (
    InProcTransport, MultiprocTransport, PSShardLost, PSShardSlow,
    RetryPolicy,
)

VOCAB, DIM = 97, 4
HARD_TIMEOUT_S = 300

#: proven masking schedule: every fault kind the retry layer must absorb
MASK_SCHED = ("drop_reply,op=grad,after=10,times=2;"
              "dup_reply,op=pull,after=5,times=2;"
              "recv_error,after=20,times=2;"
              "delay,delay_s=0.001,prob=0.3")

#: correlated loss: both replicas of every bucket die inside one step.
#: ``after`` counts global transport attempts — fleet startup is ~24
#: creates, each sync step ~9 attempts (3 shards), each checkpoint
#: drain +12 — so 170 lands ~step 14, after the step-9 checkpoint.
KILL_BOTH = ("crash,op=grad,shard=0,after=170,times=1;"
             "crash,op=grad,shard=1,after=170,times=1")


@pytest.fixture(autouse=True)
def hard_timeout():
    """SIGALRM per-test ceiling: a wedged shard process fails the test
    instead of wedging the runner."""
    def boom(signum, frame):
        raise TimeoutError(
            f"test exceeded the {HARD_TIMEOUT_S}s hard timeout")

    old = signal.signal(signal.SIGALRM, boom)
    signal.alarm(HARD_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def _ctr_cfg():
    from repro.ps.workload import CTRConfig

    return CTRConfig(vocab=5_000, emb_dim=8, slots=8, tower=(32,), batch=64)


def _assert_ownership_partition(fleet):
    stats = fleet.stats()
    live = set(stats["live_shards"])
    hosted = {s: set(rep["buckets"]) for s, rep in stats["shards"].items()}
    for b in range(fleet.spec.num_buckets):
        p = stats["primary"][b]
        assert p in live, f"bucket {b} primary {p} is not live"
        assert b in hosted[p], f"shard {p} does not host its bucket {b}"
        k = stats["backup"][b]
        if k >= 0:
            assert k in live and k != p
            assert b in hosted[k]


class TestSchedule:
    def test_parse_string_round_trip(self):
        rules = parse_schedule(
            "crash,op=grad,shard=1,after=50,times=1;"
            "delay,delay_s=0.01,prob=0.2,until=90")
        assert [r.kind for r in rules] == ["crash", "delay"]
        assert rules[0].op == "grad" and rules[0].shard == 1
        assert rules[0].after == 50 and rules[0].times == 1
        assert rules[1].delay_s == 0.01 and rules[1].prob == 0.2
        assert rules[1].until == 90

    def test_parse_accepts_rules_dicts_none(self):
        assert parse_schedule(None) == []
        rules = parse_schedule([FaultRule("delay", delay_s=1.0),
                                {"kind": "crash", "shard": 0}])
        assert rules[0].delay_s == 1.0 and rules[1].shard == 0

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            parse_schedule("meteor_strike")
        with pytest.raises(ValueError):
            FaultRule("meteor_strike")

    def test_bad_token_rejected(self):
        with pytest.raises(ValueError):
            parse_schedule("delay,oops")

    def test_rule_window_and_budget(self):
        r = FaultRule("delay", op="pull", after=3, until=6, times=2)
        assert not r.matches(2, "pull", 0)      # before the window
        assert r.matches(3, "pull", 0)
        assert not r.matches(3, "grad", 0)      # op filter
        assert not r.matches(6, "pull", 0)      # window closed
        r.fired = 2
        assert not r.matches(4, "pull", 0)      # budget exhausted


def _injector_traffic(schedule, seed):
    """A fixed op sequence through a wrapped in-proc shard; returns the
    injector's fired-injection log."""
    tr = FaultInjector(InProcTransport(), schedule, seed=seed)
    tr.add_shard(0, dim=DIM)
    tr.request(0, {"op": "create", "bucket": 0,
                   "rows": np.zeros((8, DIM), np.float32)})
    try:
        for i in range(40):
            tr.request(0, {"op": "pull", "buckets": np.array([0]),
                           "ids": np.array([i % 8])})
        return list(tr.injections), dict(tr.counters)
    finally:
        tr.close()


class TestInjectorDeterminism:
    def test_same_seed_same_injections(self):
        sched = "delay,prob=0.5,delay_s=0.0;recv_error,after=10,times=2"
        a, _ = _injector_traffic(sched, seed=7)
        b, _ = _injector_traffic(sched, seed=7)
        assert a == b and len(a) > 0

    def test_seed_drives_probabilistic_rules(self):
        sched = "delay,prob=0.5,delay_s=0.0"
        a, _ = _injector_traffic(sched, seed=1)
        b, _ = _injector_traffic(sched, seed=2)
        # deterministic per seed, and a fair coin over 40+ attempts
        # cannot fire on exactly the same subset for both seeds
        assert a != b
        for fires in (a, b):
            assert 0 < len(fires) < 40


class TestRetryMasking:
    """Transport-level: each non-crash kind is absorbed with the state
    bit-identical to a fault-free application."""

    def _one_shard(self, schedule, seed=0):
        tr = FaultInjector(InProcTransport(), schedule, seed=seed)
        tr.add_shard(0, dim=DIM, optimizer="sgd")
        tr.request(0, {"op": "create", "bucket": 0,
                       "rows": np.zeros((8, DIM), np.float32)})
        return tr

    def _grad(self):
        return {"op": "grad", "buckets": np.array([0, 0]),
                "ids": np.array([1, 4]),
                "grads": np.ones((2, DIM), np.float32), "lr": 0.1}

    def test_drop_reply_applies_exactly_once(self):
        # the shard applies the grad, the reply evaporates; the retry is
        # answered from the server's seq cache — never double-applied
        tr = self._one_shard("drop_reply,op=grad,times=1")
        try:
            tr.request(0, self._grad())
            rows = tr.request(0, {"op": "snapshot", "bucket": 0})["rows"]
            assert np.allclose(rows[1], -0.1)   # one application of lr=0.1
            assert tr.counters["retries"] >= 1
            stats = tr.request(0, {"op": "stats"})
            assert stats["counters"]["dedup_replays"] >= 1
        finally:
            tr.close()

    def test_dup_reply_stale_seq_discarded(self):
        tr = self._one_shard("dup_reply,op=pull,times=1")
        try:
            out = tr.request(0, {"op": "pull", "buckets": np.array([0]),
                                 "ids": np.array([2])})
            assert np.array_equal(out["rows"], np.zeros((1, DIM)))
            assert tr.counters["stale_replies"] >= 1
        finally:
            tr.close()

    def test_recv_error_resend_is_first_delivery(self):
        tr = self._one_shard("recv_error,op=grad,times=1")
        try:
            tr.request(0, self._grad())
            rows = tr.request(0, {"op": "snapshot", "bucket": 0})["rows"]
            assert np.allclose(rows[1], -0.1)
            assert tr.counters["retries"] >= 1
            stats = tr.request(0, {"op": "stats"})
            # the request was never delivered twice
            assert stats["counters"]["dedup_replays"] == 0
        finally:
            tr.close()

    def test_crash_surfaces_as_lost_with_shard_ids(self):
        tr = self._one_shard("crash,op=grad,times=1")
        try:
            with pytest.raises(PSShardLost) as ei:
                tr.request(0, self._grad())
            assert ei.value.shard_ids == {0}
            assert 0 not in tr.live_shards
        finally:
            tr.close()

    def test_exhausted_retries_escalate(self):
        tr = FaultInjector(
            InProcTransport(retry=RetryPolicy(max_attempts=2,
                                              backoff_s=0.001)),
            "recv_error", seed=0)   # unbounded: every attempt fails
        tr.add_shard(0, dim=DIM)
        try:
            with pytest.raises(PSShardLost) as ei:
                tr.request(0, {"op": "stats"})
            assert "escalated after 2 attempt(s)" in str(ei.value)
            assert tr.counters["escalations"] == 1
        finally:
            tr.close()


class TestCTRChaosMasking:
    """Workload-level: the ISSUE's acceptance pins, against the elastic
    CTR trainer."""

    KW = dict(steps=30, num_shards=3, optimizer="adagrad", mode="sync")

    def test_masked_schedule_is_bit_exact(self):
        from repro.ps.workload import train_ctr_elastic

        cfg = _ctr_cfg()
        base = train_ctr_elastic(cfg, **self.KW)
        chaotic = train_ctr_elastic(cfg, **self.KW,
                                    fault_schedule=MASK_SCHED, fault_seed=0)
        assert chaotic["injections"], "schedule never fired"
        assert chaotic["transport_counters"]["retries"] >= 1
        np.testing.assert_array_equal(chaotic["losses"], base["losses"])

    def test_single_crash_masked_by_replica_recovery(self):
        from repro.ps.workload import train_ctr_elastic

        cfg = _ctr_cfg()
        base = train_ctr_elastic(cfg, **self.KW)
        hit = train_ctr_elastic(
            cfg, **self.KW, fault_seed=0,
            fault_schedule="crash,op=grad,shard=0,after=100,times=1")
        assert any(i["kind"] == "crash" for i in hit["injections"])
        assert any(e["kind"] == "recover" for e in hit["events"])
        np.testing.assert_array_equal(hit["losses"], base["losses"])

    def test_kill_both_replicas_without_checkpoint_is_fatal(self):
        from repro.ps.workload import train_ctr_elastic

        with pytest.raises(PSUnrecoverable):
            train_ctr_elastic(_ctr_cfg(), **self.KW,
                              fault_schedule=KILL_BOTH, fault_seed=0)

    def test_kill_both_replicas_restores_bit_exact(self, tmp_path):
        """THE tentpole pin: correlated primary+backup loss mid-training
        restores the newest unified checkpoint and replays to the
        fault-free loss trajectory, bit-for-bit."""
        from repro.ps.workload import train_ctr_elastic

        cfg = _ctr_cfg()
        base = train_ctr_elastic(cfg, **self.KW)
        d = str(tmp_path / "ckpt")
        r = train_ctr_elastic(cfg, **self.KW, fault_schedule=KILL_BOTH,
                              fault_seed=0, ckpt_dir=d, ckpt_every=5)
        assert r["restores"] >= 1
        assert sum(i["kind"] == "crash" for i in r["injections"]) == 2
        assert [s for s, _ in r["checkpoints"]] == [4, 9, 14, 19, 24, 29]
        np.testing.assert_array_equal(r["losses"], base["losses"])
        # the checkpoint dir is clean: no staging residue, LATEST valid
        assert not [e for e in os.listdir(d) if ".tmp-" in e]
        latest = read_pointer(d)
        assert latest is not None and os.path.isdir(latest)


def _small_fleet(**kw):
    return ElasticPSFleet(VOCAB, DIM, num_shards=3, num_buckets=6,
                          optimizer=kw.pop("optimizer", "adagrad"), **kw)


class TestCheckpointAtomicity:
    def _push_some(self, fleet, rng, rounds=4):
        for _ in range(rounds):
            ids = rng.integers(0, VOCAB, size=16)
            fleet.push(ids, rng.normal(size=(16, DIM)).astype(np.float32),
                       lr=0.1)

    def test_snapshot_restore_round_trip_bit_exact(self, tmp_path):
        rng = np.random.default_rng(0)
        fleet = _small_fleet()
        try:
            self._push_some(fleet, rng)
            before = np.asarray(fleet.to_dense())
            snap = snapshot_fleet(fleet)
            save_fleet_checkpoint(str(tmp_path), 7, params={"w": before},
                                  snap=snap)
            params, snap2, step, _ = load_fleet_checkpoint(
                str(tmp_path), params_template={"w": before})
            assert step == 7
            np.testing.assert_array_equal(params["w"], before)
            fresh = _small_fleet()
            try:
                fresh.restore_snapshot(snap2)
                np.testing.assert_array_equal(
                    np.asarray(fresh.to_dense()), before)
                _assert_ownership_partition(fresh)
                # the restored optimizer state keeps training identical
                ids = np.arange(8)
                g = np.ones((8, DIM), np.float32)
                fleet.push(ids, g, lr=0.1)
                fresh.push(ids, g, lr=0.1)
                np.testing.assert_array_equal(
                    np.asarray(fresh.to_dense()),
                    np.asarray(fleet.to_dense()))
            finally:
                fresh.close()
        finally:
            fleet.close()

    def test_interrupted_save_is_never_selected(self, tmp_path):
        rng = np.random.default_rng(1)
        fleet = _small_fleet()
        try:
            self._push_some(fleet, rng)
            snap = snapshot_fleet(fleet)
            dense = np.asarray(fleet.to_dense())
            save_fleet_checkpoint(str(tmp_path), 3, params={"w": dense},
                                  snap=snap)
            # a crash mid-write leaves a staging dir and no pointer flip
            orphan = tmp_path / "step-00000004.tmp-999"
            orphan.mkdir()
            (orphan / "manifest.json").write_text("{\"torn\":")
            assert [s for s, _ in list_checkpoints(str(tmp_path))] == [3]
            _, _, step, _ = load_fleet_checkpoint(
                str(tmp_path), params_template={"w": dense})
            assert step == 3
        finally:
            fleet.close()

    def test_prune_keeps_newest_and_sweeps_orphans(self, tmp_path):
        rng = np.random.default_rng(2)
        fleet = _small_fleet()
        try:
            dense = np.asarray(fleet.to_dense())
            for step in (1, 2, 3, 4):
                self._push_some(fleet, rng, rounds=1)
                save_fleet_checkpoint(
                    str(tmp_path), step, params={"w": dense},
                    snap=snapshot_fleet(fleet), keep=2)
            steps = [s for s, _ in list_checkpoints(str(tmp_path))]
            assert steps == [3, 4]
            latest = read_pointer(str(tmp_path))
            assert latest and latest.endswith("step-00000004")
        finally:
            fleet.close()

    def test_checkpointer_cadence_and_order(self, tmp_path):
        rng = np.random.default_rng(3)
        fleet = _small_fleet()
        ckpt = FleetCheckpointer(fleet, str(tmp_path), every=3, keep=0)
        try:
            dense = {"w": np.zeros((2, 2), np.float32)}
            fired = [ckpt.maybe_save(i, dense) for i in range(9)]
            ckpt.wait()
            assert fired == [False, False, True] * 3
            assert [s for s, _ in ckpt.saved] == [2, 5, 8]
            assert [s for s, _ in list_checkpoints(str(tmp_path))] \
                == [2, 5, 8]
        finally:
            ckpt.close()
            fleet.close()

    def test_restore_rejects_mismatched_geometry(self):
        fleet = _small_fleet()
        try:
            snap = snapshot_fleet(fleet)
            snap["meta"]["vocab"] = VOCAB + 1
            with pytest.raises(ValueError):
                fleet.restore_snapshot(snap)
        finally:
            fleet.close()


class TestHungVsDeadMultiproc:
    """The multiproc transport's three failure grades, against real
    worker processes."""

    def test_hung_worker_escalates_with_context(self):
        tr = MultiprocTransport(
            request_timeout=0.5, heartbeat_s=None,
            retry=RetryPolicy(max_attempts=2, backoff_s=0.01))
        tr.add_shard(0, dim=DIM)
        try:
            pid = tr._shards[0].proc.pid
            os.kill(pid, signal.SIGSTOP)
            try:
                with pytest.raises(PSShardLost) as ei:
                    tr.request(0, {"op": "stats"})
            finally:
                try:
                    os.kill(pid, signal.SIGCONT)
                except ProcessLookupError:
                    pass
            msg = str(ei.value)
            # hung (not dead): retried, then escalated with the op name
            # and the alive-at-timeout diagnosis in the chain
            assert "op='stats'" in msg and "process alive" in msg
            assert tr.counters["retries"] >= 1
            assert tr.counters["escalations"] == 1
        finally:
            tr.close()

    def test_dead_worker_reports_exitcode(self):
        tr = MultiprocTransport(heartbeat_s=None)
        tr.add_shard(0, dim=DIM)
        try:
            os.kill(tr._shards[0].proc.pid, signal.SIGKILL)
            time.sleep(0.1)
            with pytest.raises(PSShardLost) as ei:
                tr.request(0, {"op": "stats"})
            assert "exitcode=-9" in str(ei.value)
        finally:
            tr.close()

    def test_heartbeat_detects_death_without_traffic(self):
        lost = []
        tr = MultiprocTransport(heartbeat_s=0.1)
        tr.on_shard_lost = lost.append
        tr.add_shard(0, dim=DIM)
        tr.add_shard(1, dim=DIM)
        try:
            os.kill(tr._shards[0].proc.pid, signal.SIGKILL)
            deadline = time.monotonic() + 5.0
            while 0 in tr.live_shards and time.monotonic() < deadline:
                time.sleep(0.02)
            assert 0 not in tr.live_shards, "heartbeat never noticed"
            assert lost == [0]
            assert tr.counters["heartbeat_misses"] >= 1
            assert 1 in tr.live_shards    # the healthy shard is untouched
        finally:
            tr.close()

    def test_intentional_removal_never_fires_callback(self):
        lost = []
        tr = MultiprocTransport(heartbeat_s=0.05)
        tr.on_shard_lost = lost.append
        for s in (0, 1):
            tr.add_shard(s, dim=DIM)
        try:
            tr.stop_shard(0)
            tr.kill_shard(1)
            time.sleep(0.3)   # several heartbeat periods
            assert lost == []
        finally:
            tr.close()

    def test_hedged_read_wins_over_stall(self):
        tr = MultiprocTransport(request_timeout=10.0, heartbeat_s=None,
                                hedge_s=0.05)
        tr.add_shard(0, dim=DIM)
        try:
            pid = tr._shards[0].proc.pid
            os.kill(pid, signal.SIGSTOP)
            t = threading.Timer(0.3, os.kill, (pid, signal.SIGCONT))
            t.start()
            try:
                out = tr.request(0, {"op": "stats"})
            finally:
                t.cancel()
                try:
                    os.kill(pid, signal.SIGCONT)
                except ProcessLookupError:
                    pass
            assert out["ok"]
            assert tr.counters["hedges"] >= 1
            # the duplicate reply (same op answered twice) must not
            # poison the channel for the next request
            assert tr.request(0, {"op": "stats"})["ok"]
        finally:
            tr.close()


class TestClientFlushFailFast:
    """Satellite pin: a dead pusher thread fails ``flush()`` immediately
    with the pending count — not after the full timeout."""

    class _Table:
        def pull(self, ids):
            return np.zeros((np.asarray(ids).size, DIM), np.float32)

        def push(self, ids, grads, *, lr, dedup=True):
            pass

    def test_dead_pusher_raises_immediately(self):
        from repro.ps.client import _STOP, PSClient

        client = PSClient(self._Table(), iter([]), depth=2)
        try:
            # kill the pusher out from under the client, then queue work
            client._push_q.put(_STOP)
            client._pusher.join(5.0)
            assert not client._pusher.is_alive()
            client.push(np.arange(4), np.ones((4, DIM), np.float32), lr=0.1)
            t0 = time.monotonic()
            with pytest.raises(RuntimeError, match=r"1 push\(es\) pending"):
                client.flush(timeout=60.0)
            assert time.monotonic() - t0 < 5.0, "flush spun out the timeout"
        finally:
            client.close(drain=False)

    def test_failed_push_surfaces_with_cause(self):
        class _Boom(self._Table):
            def push(self, ids, grads, *, lr, dedup=True):
                raise ValueError("shard exploded")

        from repro.ps.client import PSClient

        client = PSClient(_Boom(), iter([]), depth=2)
        try:
            client.push(np.arange(4), np.ones((4, DIM), np.float32), lr=0.1)
            t0 = time.monotonic()
            with pytest.raises(RuntimeError, match="PS push failed"):
                client.flush(timeout=60.0)
            assert time.monotonic() - t0 < 5.0
        finally:
            with pytest.raises(RuntimeError):
                client.close()


class TestHealthBridge:
    def test_fleet_health_reflects_degradation(self):
        from repro.core.resources import CPU_CORE
        from repro.obs.bridge import fleet_health, snapshot_resources

        fleet = _small_fleet()
        try:
            h = fleet_health(fleet)
            assert not h["degraded"] and h["dead_shards"] == []
            fleet.kill(0)
            h = fleet_health(fleet)
            assert h["degraded"] and h["dead_shards"] == [0]
            snap = snapshot_resources(CPU_CORE, fleet=fleet)
            assert snap["ps_health"]["degraded"]
            fleet.recover()
            h = fleet_health(fleet)
            assert not h["degraded"]
            assert h["events"]["recover"] >= 1
        finally:
            fleet.close()


class TestChaosProperty:
    """Satellite: random interleaved fault schedules vs the elastic
    fleet — post-recovery pulls bit-exact vs a fault-free oracle,
    ownership stays a partition."""

    ROUNDS = 10

    def _run(self, schedule, seed):
        rng = np.random.default_rng(seed)
        transport = (FaultInjector(InProcTransport(), schedule, seed=seed)
                     if schedule is not None else None)
        fleet = ElasticPSFleet(VOCAB, DIM, num_shards=3, num_buckets=6,
                               optimizer="adagrad", transport=transport)
        try:
            for _ in range(self.ROUNDS):
                ids = rng.integers(0, VOCAB, size=16)
                fleet.push(ids,
                           rng.normal(size=(16, DIM)).astype(np.float32),
                           lr=0.1)
                fleet.pull(ids[:4])
            if schedule is not None:
                # retire the schedule: the property is about state AFTER
                # the chaos window, and fleet.stats() below is a raw
                # introspection call with no recovery path of its own
                fleet.transport.rules.clear()
            pulled = np.asarray(fleet.pull(np.arange(VOCAB)))
            _assert_ownership_partition(fleet)
            fired = (list(fleet.transport.injections)
                     if schedule is not None else [])
            return pulled, np.asarray(fleet.to_dense()), fired
        finally:
            fleet.close()

    @settings(max_examples=8, deadline=None)
    @given(
        st.integers(min_value=0, max_value=10_000),
        st.lists(st.sampled_from(["delay", "drop_reply", "dup_reply",
                                  "recv_error", "crash"]),
                 min_size=1, max_size=5),
    )
    def test_random_schedules_keep_state_bit_exact(self, seed, kinds):
        rng = np.random.default_rng(seed ^ 0xC0FFEE)
        rules, crashed = [], False
        for kind in kinds:
            if kind == "crash":
                if crashed:    # a second crash could take both replicas
                    continue
                crashed = True
            rules.append(FaultRule(
                kind, after=int(rng.integers(20, 120)), times=1,
                shard=(int(rng.integers(0, 3)) if kind == "crash"
                       else None),
                delay_s=0.0005 if kind == "delay" else 0.0))
        oracle_pull, oracle_dense, _ = self._run(None, seed)
        pull, dense, fired = self._run(rules, seed)
        np.testing.assert_array_equal(pull, oracle_pull)
        np.testing.assert_array_equal(dense, oracle_dense)
        # budget respected: each rule fires at most `times`
        for rule in rules:
            assert sum(1 for f in fired if f["kind"] == rule.kind) \
                <= sum(r.times for r in rules if r.kind == rule.kind)
