"""Pallas kernel validation: interpret-mode sweep vs the jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.flash_attention import flash_attention

KEY = jax.random.PRNGKey(0)


def _qkv(B, H, Sq, Sk, hd, dtype):
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (B, H, Sq, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, H, Sk, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, H, Sk, hd), dtype)
    return q, k, v


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,S,hd", [
        (1, 1, 128, 64), (2, 2, 256, 64), (1, 2, 384, 128), (1, 1, 128, 256),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shape_dtype_sweep_causal(self, B, H, S, hd, dtype):
        q, k, v = _qkv(B, H, S, S, hd, dtype)
        out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), atol=tol)

    @pytest.mark.parametrize("window", [32, 100, 128])
    def test_sliding_window(self, window):
        q, k, v = _qkv(1, 2, 256, 256, 64, jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)

    def test_logit_softcap(self):
        q, k, v = _qkv(1, 1, 128, 128, 64, jnp.float32)
        out = flash_attention(q, k, v, causal=True, softcap=50.0, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, softcap=50.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)

    def test_non_causal_encoder(self):
        q, k, v = _qkv(2, 1, 128, 256, 64, jnp.float32)
        out = flash_attention(q, k, v, causal=False, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)

    def test_cross_lengths(self):
        q, k, v = _qkv(1, 2, 128, 384, 64, jnp.float32)
        out = flash_attention(q, k, v, causal=False, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)

    def test_matches_model_blockwise_path(self):
        """The XLA blockwise fallback and the Pallas kernel agree."""
        from repro.nn.attention import AttnSpec, _sdpa_blockwise

        B, H, S, hd = 1, 2, 4096, 64
        q, k, v = _qkv(B, H, S, S, hd, jnp.float32)
        spec = AttnSpec(n_heads=H, n_kv_heads=H, head_dim=hd, causal=True,
                        rope=False)
        qb = jnp.moveaxis(q, 1, 2)  # (B,S,H,hd)
        kb = jnp.moveaxis(k, 1, 2)
        vb = jnp.moveaxis(v, 1, 2)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        out_xla = jnp.moveaxis(_sdpa_blockwise(qb, kb, vb, pos, pos, spec), 2, 1)
        out_pl = flash_attention(q, k, v, causal=True, interpret=True)
        np.testing.assert_allclose(np.asarray(out_xla), np.asarray(out_pl),
                                   atol=3e-5)


class TestEmbeddingBag:
    @pytest.mark.parametrize("N,bag,V,dim", [
        (8, 4, 100, 128), (16, 1, 50, 128), (4, 16, 1000, 256),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, N, bag, V, dim, dtype):
        ids = jax.random.randint(KEY, (N, bag), 0, V)
        table = jax.random.normal(KEY, (V, dim), dtype)
        out = embedding_bag(ids, table, interpret=True)
        want = ref.embedding_bag_ref(ids, table)
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), atol=tol)

    def test_duplicate_ids(self):
        ids = jnp.zeros((4, 8), jnp.int32)  # all the same row
        table = jax.random.normal(KEY, (10, 128))
        out = embedding_bag(ids, table, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(8 * table[0])[None]
                                   .repeat(4, 0), rtol=1e-5)
