"""Pallas kernel validation: interpret-mode sweep vs the jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hard dep: deterministic fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.kernels import moe as moe_k
from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.flash_attention import flash_attention
from repro.nn import moe as moe_mod

KEY = jax.random.PRNGKey(0)


def _qkv(B, H, Sq, Sk, hd, dtype):
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (B, H, Sq, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, H, Sk, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, H, Sk, hd), dtype)
    return q, k, v


class TestFlashAttention:
    @pytest.mark.parametrize("B,H,S,hd", [
        (1, 1, 128, 64), (2, 2, 256, 64), (1, 2, 384, 128), (1, 1, 128, 256),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shape_dtype_sweep_causal(self, B, H, S, hd, dtype):
        q, k, v = _qkv(B, H, S, S, hd, dtype)
        out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), atol=tol)

    @pytest.mark.parametrize("window", [32, 100, 128])
    def test_sliding_window(self, window):
        q, k, v = _qkv(1, 2, 256, 256, 64, jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)

    def test_logit_softcap(self):
        q, k, v = _qkv(1, 1, 128, 128, 64, jnp.float32)
        out = flash_attention(q, k, v, causal=True, softcap=50.0, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, softcap=50.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)

    def test_non_causal_encoder(self):
        q, k, v = _qkv(2, 1, 128, 256, 64, jnp.float32)
        out = flash_attention(q, k, v, causal=False, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)

    def test_cross_lengths(self):
        q, k, v = _qkv(1, 2, 128, 384, 64, jnp.float32)
        out = flash_attention(q, k, v, causal=False, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)

    def test_matches_model_blockwise_path(self):
        """The XLA blockwise fallback and the Pallas kernel agree."""
        from repro.nn.attention import AttnSpec, _sdpa_blockwise

        B, H, S, hd = 1, 2, 4096, 64
        q, k, v = _qkv(B, H, S, S, hd, jnp.float32)
        spec = AttnSpec(n_heads=H, n_kv_heads=H, head_dim=hd, causal=True,
                        rope=False)
        qb = jnp.moveaxis(q, 1, 2)  # (B,S,H,hd)
        kb = jnp.moveaxis(k, 1, 2)
        vb = jnp.moveaxis(v, 1, 2)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        out_xla = jnp.moveaxis(_sdpa_blockwise(qb, kb, vb, pos, pos, spec), 2, 1)
        out_pl = flash_attention(q, k, v, causal=True, interpret=True)
        np.testing.assert_allclose(np.asarray(out_xla), np.asarray(out_pl),
                                   atol=3e-5)


class TestEmbeddingBag:
    @pytest.mark.parametrize("N,bag,V,dim", [
        (8, 4, 100, 128), (16, 1, 50, 128), (4, 16, 1000, 256),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, N, bag, V, dim, dtype):
        ids = jax.random.randint(KEY, (N, bag), 0, V)
        table = jax.random.normal(KEY, (V, dim), dtype)
        out = embedding_bag(ids, table, interpret=True)
        want = ref.embedding_bag_ref(ids, table)
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32), atol=tol)

    def test_duplicate_ids(self):
        ids = jnp.zeros((4, 8), jnp.int32)  # all the same row
        table = jax.random.normal(KEY, (10, 128))
        out = embedding_bag(ids, table, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(8 * table[0])[None]
                                   .repeat(4, 0), rtol=1e-5)


def _moe_setup(G, S, D, E, K, cf, *, dtype=jnp.float32, fold=0):
    p = moe_mod.init_moe(jax.random.fold_in(KEY, fold), D, 2 * D, E)
    p = jax.tree.map(lambda a: a.astype(dtype), p)
    x = jax.random.normal(jax.random.fold_in(KEY, fold + 1), (G, S, D), dtype)
    C = moe_mod.moe_capacity(S, E, K, cf)
    return p, x, C


def _routing(p, x, K, C):
    _, gate, eid_f, pos, keep = moe_mod.moe_route(p["router"], x, top_k=K,
                                                  capacity=C)
    return gate, eid_f, pos, keep


class TestMoeDispatchCombine:
    """Fused MoE dispatch/combine vs the nn/moe.py scatter/gather oracle."""

    @pytest.mark.parametrize("impl", ["slot", "interpret"])
    @pytest.mark.parametrize("G,S,D,E,K,cf", [
        (2, 24, 16, 4, 2, 1.25),
        (1, 64, 32, 8, 2, 1.0),
        (2, 32, 16, 4, 1, 0.25),   # heavy overflow / dropped tokens
        (1, 8, 16, 4, 4, 8.0),     # full capacity, top_k = E
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_forward_equivalence(self, impl, G, S, D, E, K, cf, dtype):
        p, x, _ = _moe_setup(G, S, D, E, K, cf, dtype=dtype)
        y_ref, aux_ref = moe_mod.moe_ffn(p, x, top_k=K, capacity_factor=cf,
                                         impl="ref")
        y, aux = moe_mod.moe_ffn(p, x, top_k=K, capacity_factor=cf, impl=impl)
        tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32), atol=tol)
        assert float(aux["dropped"]) == pytest.approx(
            float(aux_ref["dropped"]), abs=1e-6)

    @pytest.mark.parametrize("impl", ["slot", "interpret"])
    @pytest.mark.parametrize("cf", [1.25, 0.25])  # incl. dropped tokens
    def test_grad_equivalence(self, impl, cf):
        """jax.grad through the kernelized moe_ffn == reference path,
        for every parameter and the input, incl. capacity overflow."""
        G, S, D, E, K = 2, 24, 16, 4, 2
        p, x, _ = _moe_setup(G, S, D, E, K, cf)

        def loss(p, x, impl):
            y, aux = moe_mod.moe_ffn(p, x, top_k=K, capacity_factor=cf,
                                     impl=impl)
            return (y ** 2).sum() + aux["aux_loss"]

        (g_ref, gx_ref) = jax.grad(loss, argnums=(0, 1))(p, x, "ref")
        (g, gx) = jax.grad(loss, argnums=(0, 1))(p, x, impl)
        for k in g_ref:
            np.testing.assert_allclose(np.asarray(g[k]), np.asarray(g_ref[k]),
                                       atol=2e-5, err_msg=k)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                                   atol=2e-5)

    def test_dispatch_combine_roundtrip_identity(self):
        """With no drops, combine(dispatch(x)) with gate weights must
        reconstruct x exactly: gates renormalize to Σ_k w = 1."""
        G, S, D, E, K, cf = 2, 16, 16, 4, 2, 8.0
        p, x, C = _moe_setup(G, S, D, E, K, cf)
        gate, eid_f, pos, keep = _routing(p, x, K, C)
        assert bool(jnp.all(keep))
        buf = moe_k.moe_dispatch(x, eid_f, pos, keep.astype(jnp.float32),
                                 E, C, K, "slot")
        w = (gate.reshape(G, S, K) * keep.reshape(G, S, K))
        y = moe_k.moe_combine(buf, eid_f.reshape(G, S, K),
                              jnp.where(keep, pos, 0).reshape(G, S, K),
                              w, "slot")
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-5)

    @given(st.integers(4, 48), st.integers(1, 3), st.sampled_from(
        [0.25, 0.5, 1.0, 1.25, 2.0]))
    @settings(max_examples=12, deadline=None)
    def test_slot_map_invariants(self, S, K, cf):
        """Kernel-path routing invariants, randomized over (S, K, cf):
        every kept (token, k) claims exactly one slot of its expert's
        slab, occupancy ≤ capacity, drops match moe_capacity arithmetic."""
        G, D, E = 2, 8, 4
        K = min(K, E)
        p, x, C = _moe_setup(G, S, D, E, K, cf, fold=S * 8 + K)
        _, eid_f, pos, keep = _routing(p, x, K, C)
        slot_nk = moe_k.slot_maps(eid_f, pos, keep, num_experts=E, capacity=C)
        nk, snk = np.asarray(eid_f), np.asarray(slot_nk)
        keep_np, pos_np = np.asarray(keep), np.asarray(pos)
        for g in range(G):
            filled = snk[g][snk[g] >= 0]
            # each kept (token,k) appears in exactly one slot, drops in none
            assert sorted(filled.tolist()) == sorted(
                np.nonzero(keep_np[g])[0].tolist())
            # a claimed slot sits in the slab of the expert that routed it
            for e in range(E):
                owners = snk[g, e][snk[g, e] >= 0]
                assert (nk[g][owners] == e).all()
                # occupancy ≤ capacity and == min(routed, C)
                routed = int((nk[g] == e).sum())
                assert len(owners) == min(routed, C) <= C
            # drop accounting: overflow per expert == dropped (token,k)s
            overflow = sum(max(0, int((nk[g] == e).sum()) - C)
                           for e in range(E))
            assert int((~keep_np[g]).sum()) == overflow
            # position-in-expert is the exclusive running count
            assert (pos_np[g] >= 0).all()

    @given(st.integers(0, 5))
    @settings(max_examples=6, deadline=None)
    def test_property_kernel_matches_ref(self, fold):
        """Randomized fwd equivalence of the full kernelized moe_ffn."""
        G, S, D, E, K, cf = 2, 20, 16, 4, 2, 1.0
        p, x, _ = _moe_setup(G, S, D, E, K, cf, fold=10 + fold)
        y_ref, _ = moe_mod.moe_ffn(p, x, top_k=K, capacity_factor=cf,
                                   impl="ref")
        y, _ = moe_mod.moe_ffn(p, x, top_k=K, capacity_factor=cf, impl="slot")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)

    def test_grad_through_interpret_kernels(self):
        """custom_vjp backward runs through the Pallas interpreter too."""
        G, S, D, E, K, cf = 1, 12, 16, 4, 2, 0.5  # with drops
        p, x, C = _moe_setup(G, S, D, E, K, cf)
        gate, eid_f, pos, keep = _routing(p, x, K, C)
        w = (gate.reshape(G, S, K) * keep.reshape(G, S, K))
        safe_pos = jnp.where(keep, pos, 0)

        def f(x, w, impl):
            buf = moe_k.moe_dispatch(x, eid_f, pos, keep.astype(jnp.float32),
                                     E, C, K, impl)
            y = moe_k.moe_combine(buf, eid_f.reshape(G, S, K),
                                  safe_pos.reshape(G, S, K), w, impl)
            return (y ** 2).sum()

        gx_s, gw_s = jax.grad(f, argnums=(0, 1))(x, w, "slot")
        gx_i, gw_i = jax.grad(f, argnums=(0, 1))(x, w, "interpret")
        np.testing.assert_allclose(np.asarray(gx_i), np.asarray(gx_s),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(gw_i), np.asarray(gw_s),
                                   atol=1e-5)


# --------------------------------------------------------------------------
# paged KV-cache decode attention (kernels/paged_attention.py)
# --------------------------------------------------------------------------

from repro.kernels import ops as kernel_ops  # noqa: E402
from repro.kernels import paged_attention as paged_k  # noqa: E402


def _paged_setup(B, KV, G, hd, ps, P, fold=0, dtype=jnp.float32):
    """Identity-allocated pool (slot b owns pages [1+bP, 1+(b+1)P))."""
    key = jax.random.fold_in(KEY, 100 + fold)
    N = 1 + B * P
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, KV, G, hd), dtype)
    kp = jax.random.normal(jax.random.fold_in(key, 2), (N, ps, KV, hd), dtype)
    vp = jax.random.normal(jax.random.fold_in(key, 3), (N, ps, KV, hd), dtype)
    table = (1 + jnp.arange(B * P, dtype=jnp.int32)).reshape(B, P)
    return q, kp, vp, table


def _paged_dense_ref(q, kp, vp, table, q_pos, *, window, softcap):
    """Straight-line oracle: densify the pages, masked grouped softmax."""
    B, KV, G, hd = q.shape
    ps, P = kp.shape[1], table.shape[1]
    k = np.asarray(kp, np.float32)[np.asarray(table)].reshape(B, P * ps, KV, hd)
    v = np.asarray(vp, np.float32)[np.asarray(table)].reshape(B, P * ps, KV, hd)
    qn = np.asarray(q, np.float32)
    pos = np.arange(P * ps)
    out = np.zeros_like(qn)
    for b in range(B):
        valid = pos <= int(q_pos[b])
        if window is not None:
            valid &= pos > int(q_pos[b]) - window
        s = np.einsum("kgd,skd->kgs", qn[b], k[b]) / np.sqrt(hd)
        if softcap:
            s = softcap * np.tanh(s / softcap)
        s = np.where(valid[None, None, :], s, -1e30)
        s -= s.max(-1, keepdims=True)
        w = np.exp(s)
        w /= w.sum(-1, keepdims=True)
        out[b] = np.einsum("kgs,skd->kgd", w, v[b])
    return out


class TestPagedDecodeAttention:
    CASES = [
        # B, KV, G, hd, ps, P, window, softcap — incl. multi-page spans
        (2, 2, 2, 64, 4, 4, None, None),
        (2, 1, 4, 32, 8, 3, 5, 30.0),
        (1, 4, 1, 16, 4, 3, None, 50.0),
        (3, 2, 4, 32, 4, 5, 7, None),
    ]

    @pytest.mark.parametrize("B,KV,G,hd,ps,P,window,sc", CASES)
    def test_gather_matches_dense_oracle(self, B, KV, G, hd, ps, P, window, sc):
        q, kp, vp, table = _paged_setup(B, KV, G, hd, ps, P)
        # positions spanning >1 page and mid-page, ragged across the batch
        q_pos = jnp.asarray([(ps * P - 1), ps + 1, 0][:B], jnp.int32)
        got = kernel_ops.paged_attention_decode(
            q, kp, vp, table, q_pos, window=window, softcap=sc, impl="gather")
        want = _paged_dense_ref(q, kp, vp, table, q_pos, window=window,
                                softcap=sc)
        np.testing.assert_allclose(np.asarray(got), want, atol=2e-5)

    @pytest.mark.parametrize("B,KV,G,hd,ps,P,window,sc", CASES)
    def test_interpret_matches_gather(self, B, KV, G, hd, ps, P, window, sc):
        """The Pallas kernel body (online softmax over scalar-prefetched
        pages) against the jnp gather formulation."""
        q, kp, vp, table = _paged_setup(B, KV, G, hd, ps, P)
        q_pos = jnp.asarray([(ps * P - 1), ps + 1, 0][:B], jnp.int32)
        got = kernel_ops.paged_attention_decode(
            q, kp, vp, table, q_pos, window=window, softcap=sc,
            impl="interpret")
        want = kernel_ops.paged_attention_decode(
            q, kp, vp, table, q_pos, window=window, softcap=sc, impl="gather")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    @given(st.integers(0, 63), st.integers(0, 4))
    @settings(max_examples=12, deadline=None)
    def test_property_any_position(self, q_pos, fold):
        """Randomized positions (incl. page boundaries) stay equivalent."""
        B, KV, G, hd, ps, P = 1, 2, 2, 16, 8, 8
        q, kp, vp, table = _paged_setup(B, KV, G, hd, ps, P, fold=fold)
        qp = jnp.asarray([q_pos], jnp.int32)
        got = kernel_ops.paged_attention_decode(
            q, kp, vp, table, qp, window=11, impl="interpret")
        want = _paged_dense_ref(q, kp, vp, table, qp, window=11, softcap=None)
        np.testing.assert_allclose(np.asarray(got), want, atol=2e-5)

    def test_write_then_read_roundtrip(self):
        """paged_write lands the row where the gather path reads it; an
        inactive slot's write is steered to the scratch page."""
        B, KV, G, hd, ps, P = 2, 2, 2, 16, 4, 3
        q, kp, vp, table = _paged_setup(B, KV, G, hd, ps, P)
        k_new = jax.random.normal(KEY, (B, KV, hd))
        v_new = jax.random.normal(jax.random.fold_in(KEY, 7), (B, KV, hd))
        q_pos = jnp.asarray([5, 2], jnp.int32)
        active = jnp.asarray([True, False])
        kp2, vp2 = paged_k.paged_write(kp, vp, k_new, v_new, table, q_pos,
                                       active)
        # active slot 0: row at (table[0, 5//ps], 5%ps)
        pid = int(table[0, 5 // ps])
        np.testing.assert_allclose(np.asarray(kp2[pid, 5 % ps]),
                                   np.asarray(k_new[0]))
        # inactive slot 1: its own pages untouched, scratch page got the row
        pid1 = int(table[1, 2 // ps])
        np.testing.assert_allclose(np.asarray(kp2[pid1, 2 % ps]),
                                   np.asarray(kp[pid1, 2 % ps]))
        np.testing.assert_allclose(np.asarray(kp2[0, 2 % ps]),
                                   np.asarray(k_new[1]))
