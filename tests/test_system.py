"""End-to-end behaviour tests for the HeterPS system."""

import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TrainingJob, build_stages, default_fleet, make_fleet
from repro.core.schedulers import HeuristicScheduler, RLScheduler
from repro.launch.train import train
from repro.models.profile import profile_arch
#: system-scale tests — excluded from the default (tier-1) run via
#: `-m "not slow"`; run them with `pytest -m slow` or `-m ""`.
pytestmark = pytest.mark.slow


class TestEndToEndTraining:
    def test_reduced_llama_trains_and_loss_decreases(self):
        s = train("llama3.2-1b", reduced=True, steps=60, batch=8, seq=64,
                  lr=1e-3, log_every=0)
        assert s["loss_decreased"], s

    def test_moe_arch_trains(self):
        s = train("olmoe-1b-7b", reduced=True, steps=20, batch=8, seq=32,
                  log_every=0)
        assert s["loss_decreased"], s

    def test_checkpoint_written(self, tmp_path):
        ck = str(tmp_path / "ck")
        train("llama3.2-1b", reduced=True, steps=3, batch=4, seq=32,
              checkpoint_dir=ck, log_every=0)
        assert os.path.exists(os.path.join(ck, "arrays.npz"))
        assert os.path.exists(os.path.join(ck, "manifest.json"))


class TestSchedulerOnAssignedArchs:
    """The paper's technique applied to the assigned architecture pool
    (DESIGN.md §Arch-applicability): every arch must be schedulable."""

    @pytest.mark.parametrize("arch", ["jamba-v0.1-52b", "rwkv6-7b",
                                      "qwen3-moe-30b-a3b", "whisper-large-v3"])
    def test_rl_schedules_arch(self, arch):
        fleet = make_fleet(3)
        job = TrainingJob(throughput_limit=2000.0, num_examples=50_000_000)
        profiles = profile_arch(arch, fleet)
        # 30 rounds: the capacity-slab MoE cost accounting (PR 4 — FFN
        # FLOPs ∝ E·C/S, the slabs the fused kernel really computes)
        # shrinks jamba's feasible set enough that a 15-round search
        # misses it at this seed; feasible plans still exist and the
        # assertions are unchanged.
        r = RLScheduler(rounds=30, seed=0).schedule(profiles, fleet, job)
        assert r.plan.num_layers == len(profiles)
        assert math.isfinite(r.cost)

    def test_rl_not_worse_than_heuristic_on_ctr_like(self):
        fleet = default_fleet()
        job = TrainingJob()
        from repro.core import paper_model_profiles

        profiles = paper_model_profiles("MATCHNET", fleet)
        rl = RLScheduler(rounds=60, seed=0).schedule(profiles, fleet, job)
        he = HeuristicScheduler().schedule(profiles, fleet, job)
        if math.isfinite(he.cost):
            assert rl.cost <= he.cost * 1.001


class TestServe:
    def test_serve_generates_valid_tokens(self):
        from repro.launch.serve import serve

        out = serve("llama3.2-1b", reduced=True, batch=2, prompt_len=8, gen=4)
        assert out["tokens_in_vocab"]
        assert out["generated_shape"] == [2, 4]


@pytest.mark.slow
class TestDryRunIntegration:
    """One real (arch × shape) lower+compile on the 16x16 production mesh,
    in a subprocess (needs the 512-device XLA flag before jax init)."""

    def test_dryrun_one_pair(self):
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        env["PYTHONPATH"] = "src"
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "llama3.2-1b", "--shape", "decode_32k"],
            env=env, capture_output=True, text=True, timeout=900,
            cwd="/root/repo",
        )
        assert "[ok" in out.stdout, out.stdout + out.stderr[-2000:]
