"""Pipeline-parallel tests.

The in-process tests run on whatever devices exist (1 CPU → 1-stage
degenerate pipeline must equal sequential).  The multi-device test spawns
a subprocess with ``--xla_force_host_platform_device_count=4`` and checks
the 4-stage pipeline's forward AND gradients against the sequential
reference — the integration proof that ppermute scheduling is correct.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

import pytest

from repro.parallel.pipeline import (
    make_stage_mesh, pipeline_apply, stack_stage_params,
)

#: system-scale tests — excluded from the default (tier-1) run via
#: `-m "not slow"`; run them with `pytest -m slow` or `-m ""`.
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])


class TestSingleDevice:
    def test_one_stage_pipeline_equals_fn(self):
        d, M, mb = 16, 4, 8
        params = stack_stage_params(
            [{"w": jax.random.normal(KEY, (d, d)) * 0.3, "b": jnp.zeros((d,))}]
        )
        xs = jax.random.normal(KEY, (M, mb, d))
        mesh = make_stage_mesh(1)
        out = pipeline_apply(_stage_fn, params, xs, mesh)
        want = jax.vmap(lambda x: _stage_fn(
            jax.tree.map(lambda a: a[0], params), x))(xs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import (
        make_stage_mesh, pipeline_apply, pipeline_loss, stack_stage_params)

    key = jax.random.PRNGKey(0)
    S, M, mb, d = 4, 8, 16, 32
    per_stage = [{"w": jax.random.normal(jax.random.fold_in(key, i), (d, d)) * 0.3,
                  "b": jnp.zeros((d,))} for i in range(S)]
    params = stack_stage_params(per_stage)
    xs = jax.random.normal(key, (M, mb, d))
    labels = jax.random.normal(key, (M, mb, d))
    stage_fn = lambda p, x: jnp.tanh(x @ p["w"] + p["b"])
    lf = lambda y, t: jnp.mean((y - t) ** 2)
    mesh = make_stage_mesh(S)

    out = pipeline_apply(stage_fn, params, xs, mesh)
    ref = xs
    for p in per_stage:
        ref = jax.vmap(lambda x: stage_fn(p, x))(ref)
    assert float(jnp.abs(out - ref).max()) < 1e-5, "forward mismatch"

    loss, grads = jax.value_and_grad(
        lambda prm: pipeline_loss(stage_fn, lf, prm, xs, labels, mesh))(params)

    def seq_loss(prm):
        h = xs
        for i in range(S):
            p = jax.tree.map(lambda a: a[i], prm)
            h = jax.vmap(lambda x: stage_fn(p, x))(h)
        return jax.vmap(lf)(h, labels).mean()

    loss2, grads2 = jax.value_and_grad(seq_loss)(params)
    assert abs(float(loss) - float(loss2)) < 1e-6, "loss mismatch"
    ge = max(float(jnp.abs(a - b).max())
             for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(grads2)))
    assert ge < 1e-5, f"grad mismatch {ge}"
    print("MULTIDEV_PIPELINE_OK")
""")


class TestMultiDevice:
    def test_four_stage_pipeline_forward_and_grads(self):
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        out = subprocess.run(
            [sys.executable, "-c", MULTIDEV_SCRIPT], env=env,
            capture_output=True, text=True, timeout=600, cwd="/root/repo",
        )
        assert "MULTIDEV_PIPELINE_OK" in out.stdout, out.stderr[-2000:]
