"""Pytree checkpointing (.npz + JSON manifest, atomic publish)."""

from repro.checkpoint.io import (
    load_checkpoint, load_extra_arrays, load_manifest, read_pointer,
    save_checkpoint, write_pointer,
)

__all__ = ["load_checkpoint", "load_extra_arrays", "load_manifest",
           "read_pointer", "save_checkpoint", "write_pointer"]
