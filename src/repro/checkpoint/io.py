"""Checkpoint save/restore for parameter/optimizer pytrees.

Flat ``.npz`` of leaves + a JSON manifest of the treedef (keypaths), so a
checkpoint round-trips exactly (shapes, dtypes, tree structure) without
pickle.  Works with host or sharded arrays (gathers to host on save).

Crash consistency: with ``atomic=True`` (default) the checkpoint is
staged into a ``<path>.tmp-<pid>`` sibling and published with a single
``os.replace`` — a crash mid-write leaves a ``.tmp-`` orphan, never a
half-written checkpoint a reader could mistake for a complete one.  The
same write-temp-then-rename discipline backs :func:`write_pointer`, the
``LATEST``-style pointer file a checkpoint *directory* uses to name its
newest complete step (readers resolve the pointer, so an interrupted
save can never be selected).

``extra_arrays`` rides arbitrary named numpy arrays (e.g. the PS fleet's
per-bucket slabs + optimizer state from :mod:`repro.ps.snapshot`)
alongside the template-checked params/opt pytrees — they round-trip via
:func:`load_extra_arrays` without needing a template.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


#: extra_arrays keys get this prefix inside arrays.npz so they can never
#: collide with a params/opt keypath
_EXTRA = "extra//"


def save_checkpoint(path: str, *, params, opt_state=None, step: int = 0,
                    metadata: dict | None = None,
                    extra_arrays: dict[str, np.ndarray] | None = None,
                    atomic: bool = True) -> int:
    """Write one checkpoint directory; returns the payload bytes written.

    With ``atomic`` the directory appears at ``path`` fully-written or
    not at all (staged under ``<path>.tmp-<pid>`` then ``os.replace``\\ d
    into place, clobbering any previous checkpoint at ``path``).
    """
    payload = {"params": params}
    if opt_state is not None:
        payload["opt"] = opt_state
    flat = _flatten(payload)
    for k, v in (extra_arrays or {}).items():
        flat[_EXTRA + k] = np.asarray(v)
    stage = f"{path}.tmp-{os.getpid()}" if atomic else path
    if atomic and os.path.exists(stage):
        shutil.rmtree(stage)
    os.makedirs(stage, exist_ok=True)
    np.savez(os.path.join(stage, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "metadata": metadata or {},
    }
    with open(os.path.join(stage, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if atomic:
        if os.path.exists(path):
            shutil.rmtree(path)
        os.replace(stage, path)
    return sum(v.nbytes for v in flat.values())


def load_manifest(path: str) -> dict:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def load_checkpoint(path: str, *, params_template, opt_template=None
                    ) -> tuple[Any, Any, int]:
    """Restore into the structure of the given templates (shape-checked)."""
    manifest = load_manifest(path)
    data = np.load(os.path.join(path, "arrays.npz"))
    payload = {"params": params_template}
    if opt_template is not None:
        payload["opt"] = opt_template
    leaves_with_path = jax.tree_util.tree_flatten_with_path(payload)
    out_leaves = []
    for path_keys, leaf in leaves_with_path[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys
        )
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint {arr.shape} != template {leaf.shape}")
        out_leaves.append(arr.astype(leaf.dtype))
    restored = jax.tree_util.tree_unflatten(leaves_with_path[1], out_leaves)
    opt = restored.get("opt") if opt_template is not None else None
    return restored["params"], opt, manifest["step"]


def load_extra_arrays(path: str) -> dict[str, np.ndarray]:
    """The ``extra_arrays`` companion payload, prefix stripped."""
    data = np.load(os.path.join(path, "arrays.npz"))
    return {k[len(_EXTRA):]: data[k] for k in data.files
            if k.startswith(_EXTRA)}


def write_pointer(root: str, target: str, *, name: str = "LATEST") -> None:
    """Atomically point ``root/name`` at a checkpoint directory name
    (relative to ``root``).  Readers that resolve through the pointer
    can never observe a partially-written step."""
    os.makedirs(root, exist_ok=True)
    tmp = os.path.join(root, f".{name}.tmp-{os.getpid()}")
    with open(tmp, "w") as f:
        f.write(target + "\n")
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(root, name))


def read_pointer(root: str, *, name: str = "LATEST") -> str | None:
    """Resolve ``root/name`` to an absolute checkpoint path (None if the
    pointer or its target does not exist yet)."""
    ptr = os.path.join(root, name)
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        target = f.read().strip()
    path = os.path.join(root, target)
    return path if target and os.path.isdir(path) else None
