"""Checkpoint save/restore for parameter/optimizer pytrees.

Flat ``.npz`` of leaves + a JSON manifest of the treedef (keypaths), so a
checkpoint round-trips exactly (shapes, dtypes, tree structure) without
pickle.  Works with host or sharded arrays (gathers to host on save).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(path: str, *, params, opt_state=None, step: int = 0,
                    metadata: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    payload = {"params": params}
    if opt_state is not None:
        payload["opt"] = opt_state
    flat = _flatten(payload)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "metadata": metadata or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


def load_checkpoint(path: str, *, params_template, opt_template=None
                    ) -> tuple[Any, Any, int]:
    """Restore into the structure of the given templates (shape-checked)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    payload = {"params": params_template}
    if opt_template is not None:
        payload["opt"] = opt_template
    leaves_with_path = jax.tree_util.tree_flatten_with_path(payload)
    out_leaves = []
    for path_keys, leaf in leaves_with_path[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys
        )
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint {arr.shape} != template {leaf.shape}")
        out_leaves.append(arr.astype(leaf.dtype))
    restored = jax.tree_util.tree_unflatten(leaves_with_path[1], out_leaves)
    opt = restored.get("opt") if opt_template is not None else None
    return restored["params"], opt, manifest["step"]
