"""Heterogeneous resource-type fleet definitions (HeterPS §3, §6).

A :class:`ResourceType` is one *kind* of computing resource the scheduler
may place a layer on — one CPU core, one V100 card, one XPU chip, one TPU
v5e chip.  The paper prices resources per hour (0.04 USD/core-hr CPU,
2.42 USD/hr V100) and simulates additional GPU types by scaling the price;
we keep the same fleet for the scheduling experiments and add a TPU-like
tier used by the analytic profiles of the assigned architectures.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

GB = 1024**3
TFLOPS = 1e12


@dataclasses.dataclass(frozen=True)
class ResourceType:
    """One type of computing resource (paper's ``Type t``).

    Attributes:
      name: human-readable identifier.
      price: USD per hour for one unit (paper §6: CPU core 0.04, V100 2.42).
      flops: peak dense FLOP/s of one unit.
      mem_bw: memory bandwidth in bytes/s of one unit.
      net_bw: network/interconnect bandwidth in bytes/s of one unit.
      ingest_bw: bandwidth at which *input training data* reaches the unit
        (host RAM for CPU workers; PCIe for GPU workers).  This is what
        makes embedding/data-intensive layers expensive on accelerators —
        the paper's data-intensive vs compute-intensive distinction.
      sparse_eff: efficiency multiplier for sparse/gather-heavy work
        (CPUs handle irregular access relatively better than their peak
        FLOPs suggest; accelerators are de-rated).
      max_count: ``N_{t,limit}`` — maximum number of units available
        (Formula 10).
    """

    name: str
    price: float
    flops: float
    mem_bw: float
    net_bw: float
    ingest_bw: float
    sparse_eff: float
    max_count: int

    @property
    def price_per_sec(self) -> float:
        return self.price / 3600.0


# --- the paper's experimental fleet (§6: Intel Gold 6271C cores + V100) ---

CPU_CORE = ResourceType(
    name="cpu",
    price=0.04,
    flops=0.05 * TFLOPS,          # one core w/ AVX-512, fp32
    mem_bw=8 * GB,                # per-core share of socket bandwidth
    net_bw=12.5 * GB,             # 100 Gbps InfiniBand
    ingest_bw=8 * GB,             # data already in host RAM
    sparse_eff=0.5,
    max_count=10 * 48,            # 10 CPU servers x 48 cores (paper §6)
)

V100 = ResourceType(
    name="v100",
    price=2.42,
    flops=112 * TFLOPS,           # tensor-core fp16
    mem_bw=900 * GB,
    net_bw=12.5 * GB,
    ingest_bw=12 * GB,            # PCIe 3.0 x16 effective
    sparse_eff=0.05,
    max_count=4 * 8,              # 4 GPU servers x 8 V100 (paper §6)
)

# TPU v5e-like tier used for the assigned-architecture profiles
# (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI — roofline constants).
TPU_V5E = ResourceType(
    name="tpu_v5e",
    price=1.20,
    flops=197 * TFLOPS,
    mem_bw=819 * GB,
    net_bw=50 * GB,
    ingest_bw=12 * GB,
    sparse_eff=0.05,
    max_count=512,
)


def default_fleet() -> list[ResourceType]:
    """The paper's two-type fleet: CPU cores + V100 cards."""
    return [CPU_CORE, V100]


def make_fleet(num_types: int, *, seedless: bool = True) -> list[ResourceType]:
    """A fleet with ``num_types`` resource types.

    The paper simulates many GPU types by taking "the V100 GPU with
    different prices" (§6.2).  We do the same deterministically: type
    ``j`` is a V100 variant whose price and throughput are scaled so that
    price/performance varies across types (otherwise every plan would pick
    the single cheapest type and the scheduling problem degenerates).
    """
    fleet = [CPU_CORE]
    for j in range(num_types - 1):
        # spread performance over [0.55x, 1.45x] and price super-linearly so
        # faster variants have worse price/perf (cloud-realistic).
        perf = 0.55 + 0.9 * (j / max(1, num_types - 2)) if num_types > 2 else 1.0
        price = 2.42 * perf**1.35
        fleet.append(
            dataclasses.replace(
                V100,
                name=f"gpu{j}",
                price=round(price, 4),
                flops=V100.flops * perf,
                mem_bw=V100.mem_bw * perf,
                max_count=V100.max_count,
            )
        )
    return fleet


def fleet_names(fleet: Sequence[ResourceType]) -> list[str]:
    return [r.name for r in fleet]
