"""Per-layer profiles — the scheduler's view of a DNN (HeterPS Fig. 3).

The paper profiles each layer on a single unit of each resource type with
a small batch ``B_o`` to obtain ``OCT`` (original computation time) and
``ODT`` (original data-communication time).  We provide:

* :class:`LayerProfile` — one layer's features + per-type OCT/ODT, exactly
  the five LSTM input features of Fig. 3 (index, layer type, input size,
  weight size, comm time);
* analytic profiling (:func:`analytic_oct` / :func:`profile_layers`) that
  derives OCT/ODT from layer FLOPs/bytes and the resource roofline —
  used both for the paper's CTR models and for the 10 assigned
  architectures (``profile_arch`` in ``repro.models.profile``);
* the paper's four experimental models (MATCHNET/CTRDNN/2EMB/NCE,
  Appendix Figs. 13–16) as layer graphs.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.resources import ResourceType

# Layer kinds understood by the profiler / LSTM one-hot (Fig. 3 "type").
LAYER_KINDS = (
    "embedding",     # sparse lookup — data-intensive
    "fc",            # fully-connected — compute-intensive
    "attention",
    "moe",
    "ssm",           # mamba / rwkv mixing
    "norm",
    "match",         # cosine/dot match head (MATCHNET)
    "nce",           # sampled-softmax loss head (NCE)
    "conv",
    "cross_attention",
)

#: small profiling batch size ``B_o`` (paper §4.1)
B_O = 64


@dataclasses.dataclass(frozen=True)
class LayerProfile:
    """Profile of one layer.

    ``flops``/``weight_bytes``/``input_bytes``/``output_bytes`` are *per
    example*; ``oct``/``odt`` are seconds for a batch of ``B_o`` examples
    on one unit of each resource type (paper's OCT/ODT), index-aligned
    with the fleet.  ``alpha``/``beta`` are the Amdahl parallel fractions
    of computation and communication (Formulas 1–2).
    """

    index: int
    kind: str
    flops: float
    input_bytes: float
    weight_bytes: float
    output_bytes: float
    oct: tuple[float, ...]
    odt_sync: tuple[float, ...]   # gradient/parameter sync per B_o window
    odt_act: tuple[float, ...]    # activation hand-off per B_o window
    alpha: float = 0.95
    beta: float = 0.90

    @property
    def odt(self) -> tuple[float, ...]:
        return tuple(s + a for s, a in zip(self.odt_sync, self.odt_act))

    def comm_time(self, t: int) -> float:
        return self.odt[t]


def analytic_oct(
    kind: str,
    flops: float,
    input_bytes: float,
    output_bytes: float,
    weight_bytes: float,
    res: ResourceType,
) -> float:
    """Seconds to compute one layer for ``B_o`` examples on one unit.

    Roofline-style: compute time + memory time + input-ingest time.  For
    data-intensive kinds (embedding lookups) the FLOPs are negligible but
    the *ingest* term dominates — and is far worse on accelerators that
    must pull sparse inputs across PCIe.  This reproduces the paper's
    data-intensive vs compute-intensive split without physical profiling.
    """
    sparse = kind in ("embedding", "nce")
    eff_flops = res.flops * (res.sparse_eff if sparse else 1.0)
    compute = B_O * flops / eff_flops
    # Dense layers stream their full weights each step; sparse lookups only
    # touch the gathered rows (~= the layer's output bytes per example).
    weight_traffic = B_O * output_bytes if sparse else weight_bytes
    memory = (B_O * input_bytes + weight_traffic) / res.mem_bw
    ingest = B_O * input_bytes / res.ingest_bw if kind == "embedding" else 0.0
    return compute + memory + ingest


#: global batch size the weight-gradient sync is amortized over when
#: profiling (sync happens once per *training batch*, not per example;
#: the paper §6.2 notes exactly this small-batch profiling distortion for
#: its CPU runs — we amortize at the job batch size to avoid it).
TRAIN_BATCH_FOR_PROFILING = 4096


def analytic_odt(
    kind: str,
    output_bytes: float,
    weight_bytes: float,
    res: ResourceType,
    *,
    train_batch: int = TRAIN_BATCH_FOR_PROFILING,
) -> tuple[float, float]:
    """(sync, activation) communication seconds for ``B_o`` examples.

    * sync — gradient/parameter synchronization.  Dense layers allreduce /
      PS-push+pull their full weights once per *training batch* (amortized
      to the ``B_o`` window).  Sparse layers (embedding/nce) exchange only
      the touched rows — per example, the PS-for-sparse path of §3.
    * activation — hand-off of the layer output to the next stage.
    """
    if kind in ("embedding", "nce"):
        sync = 2.0 * B_O * output_bytes
    else:
        sync = 2.0 * weight_bytes * (B_O / train_batch)
    return sync / res.net_bw, B_O * output_bytes / res.net_bw


def profile_layers(
    specs: Sequence[tuple[str, float, float, float, float]],
    fleet: Sequence[ResourceType],
    *,
    alpha: float = 0.95,
    beta: float = 0.90,
) -> list[LayerProfile]:
    """Build :class:`LayerProfile`s from ``(kind, flops, in_b, w_b, out_b)``."""
    out = []
    for i, (kind, flops, in_b, w_b, out_b) in enumerate(specs):
        oct_ = tuple(analytic_oct(kind, flops, in_b, out_b, w_b, r) for r in fleet)
        pairs = [analytic_odt(kind, out_b, w_b, r) for r in fleet]
        out.append(
            LayerProfile(
                index=i, kind=kind, flops=flops, input_bytes=in_b,
                weight_bytes=w_b, output_bytes=out_b, oct=oct_,
                odt_sync=tuple(p[0] for p in pairs),
                odt_act=tuple(p[1] for p in pairs),
                alpha=alpha, beta=beta,
            )
        )
    return out


# ---------------------------------------------------------------------------
# The paper's four experimental models (Appendix Figs. 13–16).
#
# The appendix gives the structures only as figures; we reconstruct
# representative CTR-style layer stacks with the stated layer counts:
# MATCHNET (16 layers), CTRDNN (16), 2EMB (10), NCE (5).  Sizes follow the
# paper's setting — huge sparse inputs (≈10 TB-scale feature logs → large
# per-example sparse bytes) and modest dense towers.
# ---------------------------------------------------------------------------

_F = 4  # bytes per float32


def _fc(d_in: int, d_out: int) -> tuple[str, float, float, float, float]:
    return ("fc", 2.0 * d_in * d_out, d_in * _F, d_in * d_out * _F, d_out * _F)


def _norm(d: int) -> tuple[str, float, float, float, float]:
    return ("norm", 8.0 * d, d * _F, 2 * d * _F, d * _F)


def _emb(n_slots: int, dim: int, vocab: float) -> tuple[str, float, float, float, float]:
    # n_slots sparse feature slots, each a lookup+sum into `dim`; input is
    # the raw sparse ids/values (data-intensive part).
    return (
        "embedding",
        2.0 * n_slots * dim,
        n_slots * 64 * _F,          # sparse ids+values per example
        vocab * dim * _F,           # the (huge) table
        n_slots * dim * _F,
    )


def ctrdnn_layers() -> list[tuple[str, float, float, float, float]]:
    """CTRDNN (16 layers): embedding → deep FC tower → sigmoid head."""
    d = 1024
    ls = [_emb(400, 16, 1e7)]
    ls += [_fc(400 * 16, d)]
    for _ in range(6):
        ls += [_fc(d, d), _norm(d)]
    ls += [_fc(d, 1), ("fc", 2.0, _F, 2 * _F, _F)]
    assert len(ls) == 16, len(ls)
    return ls


def matchnet_layers() -> list[tuple[str, float, float, float, float]]:
    """MATCHNET (16 layers): two embedding towers + match head.

    More heterogeneous than CTRDNN (the paper: "MATCHNET is more complex
    … because of the diverse types of layers").
    """
    d = 1024
    ls = [
        _emb(300, 32, 2e7), _fc(300 * 32, d), _norm(d), _fc(d, d),   # query tower
        _emb(500, 32, 5e7), _fc(500 * 32, d), _norm(d), _fc(d, d),   # doc tower
        _fc(d, d), _norm(d), _fc(d, d), _norm(d),
        ("match", 2.0 * d, 2 * d * _F, 0.0, _F),
        _fc(2 * d, d), _fc(d, 256), _fc(256, 1),
    ]
    assert len(ls) == 16, len(ls)
    return ls


def twoemb_layers() -> list[tuple[str, float, float, float, float]]:
    """2EMB (10 layers): two embeddings feeding one shared FC tower."""
    d = 384
    ls = [
        _emb(200, 16, 8e6), _emb(200, 16, 8e6),
        _fc(400 * 16, d), _norm(d), _fc(d, d), _norm(d),
        _fc(d, d), _norm(d), _fc(d, 128), _fc(128, 1),
    ]
    assert len(ls) == 10, len(ls)
    return ls


def nce_layers() -> list[tuple[str, float, float, float, float]]:
    """NCE (5 layers): embedding + small tower + sampled-softmax head."""
    d = 256
    ls = [
        _emb(100, 64, 3e7), _fc(100 * 64, d), _fc(d, d),
        _norm(d),
        ("nce", 2.0 * d * 50, d * _F, 3e6 * d * _F, 50 * _F),
    ]
    assert len(ls) == 5, len(ls)
    return ls


PAPER_MODELS = {
    "CTRDNN": ctrdnn_layers,
    "MATCHNET": matchnet_layers,
    "2EMB": twoemb_layers,
    "NCE": nce_layers,
}


def paper_model_profiles(
    name: str, fleet: Sequence[ResourceType]
) -> list[LayerProfile]:
    return profile_layers(PAPER_MODELS[name](), fleet)


def profiles_from_json(path: str, fleet: Sequence[ResourceType]
                       ) -> list[LayerProfile]:
    """Load *measured* per-layer profiles (the paper's §4.1 profiling
    path: OCT/ODT measured on a single unit with a small batch).

    JSON schema: a list of layer objects, either
      {"kind", "oct": [s per type], "odt_sync": […], "odt_act": […]}
    (direct measurements, index-aligned with ``fleet``), or
      {"kind", "flops", "input_bytes", "weight_bytes", "output_bytes"}
    (size measurements → analytic OCT/ODT).  ``alpha``/``beta`` optional.
    """
    import json

    with open(path) as f:
        rows = json.load(f)
    out: list[LayerProfile] = []
    for i, r in enumerate(rows):
        kw = dict(alpha=r.get("alpha", 0.95), beta=r.get("beta", 0.90))
        if "oct" in r:
            if not (len(r["oct"]) == len(fleet)):
                raise ValueError(f"layer {i}: {len(r['oct'])} octs for "
                                 f"{len(fleet)} resource types")
            out.append(LayerProfile(
                index=i, kind=r["kind"],
                flops=r.get("flops", 0.0),
                input_bytes=r.get("input_bytes", 0.0),
                weight_bytes=r.get("weight_bytes", 0.0),
                output_bytes=r.get("output_bytes", 0.0),
                oct=tuple(r["oct"]),
                odt_sync=tuple(r.get("odt_sync", [0.0] * len(fleet))),
                odt_act=tuple(r.get("odt_act", [0.0] * len(fleet))),
                **kw,
            ))
        else:
            out.extend(profile_layers(
                [(r["kind"], r["flops"], r["input_bytes"],
                  r["weight_bytes"], r["output_bytes"])], fleet, **kw,
            ))
            object.__setattr__(out[-1], "index", i)
    return out


def ctrdnn_variant(num_layers: int) -> list[tuple[str, float, float, float, float]]:
    """CTRDNN with FC layers added/removed (paper §6.2, Table 2: 8/12/16/20)."""
    base = ctrdnn_layers()
    if num_layers == 16:
        return base
    if num_layers < 16:
        # drop (fc, norm) pairs from the middle
        drop = 16 - num_layers
        return base[:2] + base[2 + drop:]
    d = 512
    extra = []
    while len(extra) < num_layers - 16:
        extra.append(_fc(d, d))
        if len(extra) < num_layers - 16:
            extra.append(_norm(d))
    return base[:-2] + extra + base[-2:]
