"""HeterPS core: cost model, scheduling plans, provisioning, RL scheduler."""

from repro.core.cost_model import (
    INFEASIBLE,
    BatchedCost,
    TrainingJob,
    batched_plan_cost,
    batched_soft_plan_cost,
    monetary_cost,
    pipeline_throughput,
    plan_cost,
    soft_plan_cost,
)
from repro.core.plan import (
    ProvisioningPlan,
    SchedulingPlan,
    Stage,
    StageBatch,
    batched_build_stages,
    build_stages,
)
from repro.core.profiles import (
    B_O,
    LAYER_KINDS,
    LayerProfile,
    PAPER_MODELS,
    paper_model_profiles,
    profile_layers,
)
from repro.core.provision import (
    BatchedProvisioning,
    batched_provision,
    provision,
    provision_sta_ratio,
)
from repro.core.resources import (
    CPU_CORE,
    TPU_V5E,
    V100,
    ResourceType,
    default_fleet,
    make_fleet,
)

__all__ = [
    "INFEASIBLE", "TrainingJob", "monetary_cost", "pipeline_throughput",
    "plan_cost", "soft_plan_cost", "ProvisioningPlan", "SchedulingPlan",
    "Stage", "build_stages", "B_O", "LAYER_KINDS", "LayerProfile",
    "PAPER_MODELS", "paper_model_profiles", "profile_layers", "provision",
    "provision_sta_ratio", "CPU_CORE", "TPU_V5E", "V100", "ResourceType",
    "default_fleet", "make_fleet",
    # batched evaluation path
    "BatchedCost", "StageBatch", "BatchedProvisioning",
    "batched_plan_cost", "batched_soft_plan_cost", "batched_build_stages",
    "batched_provision",
]
