"""Provisioning for load balance (HeterPS §5.1, Formulas 11–13).

Given a scheduling plan's stages, choose replica counts ``k_i`` so that
(a) every stage sustains the same throughput (no pipeline straggler),
(b) the throughput constraint holds (Formula 13 lower-bounds ``k_1``),
(c) monetary cost is minimized — a Newton iteration on the continuous
relaxation of ``k_1`` (the paper uses Newton's method on ``k_1``), then
integer rounding with a local feasibility search.

Also provides the two static baselines of §6.1: ``StaRatio`` (GPU:CPU
cores 1:6, AIBox default) and ``StaPSRatio`` (1:6 + 6 PS cores per GPU,
BytePS-style).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.cost_model import (
    TrainingJob,
    stage_throughput,
)
from repro.core.plan import ProvisioningPlan, Stage, StageBatch
from repro.core.profiles import B_O
from repro.core.resources import ResourceType


def required_k(stage: Stage, throughput: float, batch_size: int) -> float:
    """Smallest continuous ``k`` giving ``stage`` at least ``throughput``.

    Inverts Formulas 1–4: both the compute and the comm term must fit in
    ``B/throughput`` seconds.  Returns ``inf`` when the sequential
    (non-parallelizable) fraction alone exceeds the budget — no number of
    replicas can reach that throughput (Amdahl ceiling).
    """
    budget = 1.0 / throughput  # seconds per example
    ks = []
    for time_per_ex, frac in ((stage.oct / B_O, stage.alpha), (stage.odt / B_O, stage.beta)):
        if time_per_ex <= 0.0:
            ks.append(0.0)
            continue
        slack = budget / time_per_ex - (1.0 - frac)
        if slack <= 0.0:
            return float("inf")
        ks.append(frac / slack)
    return max(max(ks), 1.0)


def _balanced_k(
    stages: Sequence[Stage], throughput: float, batch_size: int
) -> list[float] | None:
    """Formula 12 generalized: per-stage continuous ``k_i`` at equal throughput."""
    ks = []
    for s in stages:
        k = required_k(s, throughput, batch_size)
        if not math.isfinite(k):
            return None
        ks.append(k)
    return ks


def _ps_cores(stages: Sequence[Stage], k: Sequence[float]) -> int:
    """CPU cores added for parameter servers (§5.1: "based on historical
    profiling results") — the paper's default server ratio is ~1 PS core
    per 6 accelerator units."""
    n_accel = sum(kk for s, kk in zip(stages, k) if s.resource_type != 0)
    return int(math.ceil(n_accel / 6.0)) if n_accel > 0 else 0


def _cost_at_throughput(
    stages: Sequence[Stage],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
    throughput: float,
) -> tuple[float, list[float] | None]:
    """Continuous-relaxation cost at a target throughput (load-balanced)."""
    ks = _balanced_k(stages, throughput, job.batch_size)
    if ks is None:
        return float("inf"), None
    rate = sum(
        k * fleet[s.resource_type].price_per_sec for s, k in zip(stages, ks)
    )
    rate += _ps_cores(stages, ks) * fleet[0].price_per_sec
    et = job.num_epochs * job.num_examples / throughput
    return et * rate, ks


def provision(
    stages: Sequence[Stage],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
    *,
    newton_iters: int = 25,
) -> ProvisioningPlan | None:
    """Generate a provisioning plan for ``stages`` (§5.1).

    Newton's method on the continuous throughput target ``τ`` (equivalent
    to the paper's iteration on ``k_1`` — ``τ`` and ``k_1`` are related
    1:1 by Formula 12/13; optimizing τ directly avoids singling out
    stage 1): minimize ``cost(τ)`` for ``τ ≥ throughput_limit``, then
    round to integers and locally repair feasibility.

    Returns ``None`` when no feasible provisioning exists (resource
    limits, Formula 10).
    """
    tau_min = job.throughput_limit
    c0, ks0 = _cost_at_throughput(stages, fleet, job, tau_min)
    if ks0 is None:
        return None

    # Newton on f(τ) = d cost/d τ, seeking interior minima; cost(τ) is
    # usually increasing past the constraint (paper §5.1 observes this),
    # in which case Newton stays pinned at τ_min.
    tau, best_tau, best_cost = tau_min, tau_min, c0
    h = max(tau_min * 1e-4, 1e-9)
    for _ in range(newton_iters):
        cm, _ = _cost_at_throughput(stages, fleet, job, max(tau - h, tau_min))
        cp, _ = _cost_at_throughput(stages, fleet, job, tau + h)
        cc, _ = _cost_at_throughput(stages, fleet, job, tau)
        if not (math.isfinite(cm) and math.isfinite(cp) and math.isfinite(cc)):
            break
        g = (cp - cm) / (2 * h)
        hess = (cp - 2 * cc + cm) / (h * h)
        if hess <= 0.0 or not math.isfinite(hess):
            step = -math.copysign(0.1 * tau, g)
        else:
            step = -g / hess
        new_tau = max(tau_min, tau + step)
        c_new, _ = _cost_at_throughput(stages, fleet, job, new_tau)
        if math.isfinite(c_new) and c_new < best_cost:
            best_cost, best_tau = c_new, new_tau
        if abs(new_tau - tau) < 1e-6 * tau_min:
            tau = new_tau
            break
        tau = new_tau

    _, ks = _cost_at_throughput(stages, fleet, job, best_tau)
    if ks is None:
        return None
    k_int = [int(math.ceil(k)) for k in ks]

    # Feasibility: per-type limits (Formula 10).
    counts: dict[int, int] = {}
    for s, k in zip(stages, k_int):
        counts[s.resource_type] = counts.get(s.resource_type, 0) + k
    ps = _ps_cores(stages, k_int)
    counts[0] = counts.get(0, 0) + ps
    for t, n in counts.items():
        if n > fleet[t].max_count:
            return None
    # Throughput check with the integer k (ceil only raises throughput,
    # so this should hold; guard against degenerate stages anyway).
    tp = min(
        stage_throughput(s, k, job.batch_size) for s, k in zip(stages, k_int)
    )
    if tp < job.throughput_limit:
        return None
    return ProvisioningPlan(k=tuple(k_int), ps_cores=ps)


# --- batched provisioning (vectorized over N plans) --------------------------
#
# The scalar `provision` above is the reference oracle; the functions below
# run the same algorithm — continuous balanced-k inversion of Formulas 1–4,
# Newton iteration on the throughput target τ, integer rounding, limit and
# throughput checks — for N plans at once with NumPy.  Per-plan reductions
# over the stage axis are written as explicit left folds so each plan's
# arithmetic is the same operation sequence as the scalar path (see
# DESIGN.md, "Batched provisioning").


@dataclasses.dataclass(frozen=True)
class BatchedProvisioning:
    """Integer provisioning for a :class:`StageBatch` (invalid slots k=0)."""

    k: np.ndarray         # (N, S) int replica counts
    ps_cores: np.ndarray  # (N,) int
    feasible: np.ndarray  # (N,) bool — limits + throughput constraint hold


@dataclasses.dataclass(frozen=True)
class _ProvisionCtx:
    """Loop-invariant arrays for one batched provisioning run."""

    tc: np.ndarray           # (N, S) per-example compute time  (oct / B_o)
    tm: np.ndarray           # (N, S) per-example comm time     (odt / B_o)
    alpha: np.ndarray        # (N, S)
    beta: np.ndarray         # (N, S)
    na: np.ndarray           # (N, S) 1 - alpha
    nb: np.ndarray           # (N, S) 1 - beta
    mask: np.ndarray         # (N, S)
    stage_price: np.ndarray  # (N, S) price/s per stage (0 in invalid slots)
    accel: np.ndarray        # (N, S) 1.0 where the stage is on an accelerator
    cpu_price: float
    et_num: float            # num_epochs * num_examples


def _provision_ctx(
    sb: StageBatch, fleet: Sequence[ResourceType], job: TrainingJob
) -> _ProvisionCtx:
    price = np.array([r.price_per_sec for r in fleet])
    return _ProvisionCtx(
        tc=sb.oct / B_O, tm=sb.odt / B_O,
        alpha=sb.alpha, beta=sb.beta,
        na=1.0 - sb.alpha, nb=1.0 - sb.beta,
        mask=sb.mask,
        stage_price=np.where(sb.mask, price[sb.rtype], 0.0),
        accel=np.where(sb.mask & (sb.rtype != 0), 1.0, 0.0),
        cpu_price=float(price[0]),
        et_num=float(job.num_epochs * job.num_examples),
    )


def _batched_required_k(ctx: _ProvisionCtx, throughput: np.ndarray) -> np.ndarray:
    """Vectorized :func:`required_k`: (N, S) continuous k at per-plan τ.

    Invalid stage slots (zero oct/odt) come out as the clamp value 1.0;
    callers must mask them out.  A valid slot past its Amdahl ceiling is
    ``inf`` — no replica count reaches the target throughput.
    """
    budget = 1.0 / throughput[:, None]                   # (N, 1) s/example
    out = np.full_like(ctx.tc, 1.0)
    for time_per_ex, frac, nfrac in (
        (ctx.tc, ctx.alpha, ctx.na), (ctx.tm, ctx.beta, ctx.nb)
    ):
        slack = budget / time_per_ex - nfrac
        k = np.where(slack > 0.0, frac / slack, np.inf)
        k = np.where(time_per_ex <= 0.0, 0.0, k)
        out = np.maximum(out, k)
    return out


def _batched_cost_at_throughput(
    ctx: _ProvisionCtx, throughput: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized `_cost_at_throughput`: per-plan continuous cost + ks.

    Returns ``(cost (N,), ks (N, S))`` with ``cost = inf`` where any stage
    hits its Amdahl ceiling (the scalar path's ``(inf, None)``).
    ``cumsum`` is a sequential in-order fold over the stage axis, so the
    sums match the scalar left-fold ``sum()`` bit-for-bit (invalid slots
    contribute exactly 0.0, which is a no-op on any finite partial sum).
    """
    ks = _batched_required_k(ctx, throughput)
    ksm = np.where(ctx.mask, ks, 0.0)
    ok = np.isfinite(ksm).all(axis=1)
    rate = (ksm * ctx.stage_price).cumsum(axis=1)[:, -1]
    accel = (ksm * ctx.accel).cumsum(axis=1)[:, -1]
    ps = np.where(accel > 0.0, np.ceil(accel / 6.0), 0.0)
    rate = rate + ps * ctx.cpu_price
    cost = np.where(ok, (ctx.et_num / throughput) * rate, np.inf)
    return cost, ksm


def _batched_int_throughput(
    sb: StageBatch, k: np.ndarray, batch_size: int
) -> np.ndarray:
    """Pipeline throughput (Formula 5) under integer replica counts."""
    k_eff = np.maximum(k, 1).astype(np.float64)
    ct = (sb.oct / B_O) * batch_size * (1.0 - sb.alpha + sb.alpha / k_eff)
    dt = (sb.odt / B_O) * batch_size * (1.0 - sb.beta + sb.beta / k_eff)
    ex = np.maximum(ct, dt)
    with np.errstate(divide="ignore"):
        tp_s = np.where(sb.mask & (ex > 0.0), batch_size / np.where(ex > 0.0, ex, 1.0), np.inf)
    return tp_s.min(axis=1)


def _batched_type_counts(
    sb: StageBatch, k: np.ndarray, ps: np.ndarray, num_types: int
) -> np.ndarray:
    """(N, T) total units per resource type (Formula 7 / type_counts)."""
    counts = np.zeros((sb.batch, num_types))
    np.add.at(counts, (np.arange(sb.batch)[:, None], sb.rtype), k.astype(np.float64))
    counts[:, 0] += ps
    return counts


def batched_provision(
    sb: StageBatch,
    fleet: Sequence[ResourceType],
    job: TrainingJob,
    *,
    tau_min: np.ndarray | None = None,
    newton_iters: int = 25,
) -> BatchedProvisioning:
    """Vectorized :func:`provision` over a :class:`StageBatch`.

    ``tau_min`` optionally overrides the throughput target per plan (the
    graded-surrogate path relaxes it per plan); defaults to the job's
    ``throughput_limit`` everywhere.
    """
    N = sb.batch
    if tau_min is None:
        tau_min = np.full(N, float(job.throughput_limit))
    else:
        tau_min = np.asarray(tau_min, dtype=np.float64)

    ctx = _provision_ctx(sb, fleet, job)
    with np.errstate(all="ignore"):
        c0, _ = _batched_cost_at_throughput(ctx, tau_min)
        alive = np.isfinite(c0)

        tau = tau_min.copy()
        best_tau = tau_min.copy()
        best_cost = c0.copy()
        cc = c0  # cost at the current tau; carried across iterations
        h = np.maximum(tau_min * 1e-4, 1e-9)
        active = alive.copy()
        for _ in range(newton_iters):
            if not active.any():
                break
            cm, _ = _batched_cost_at_throughput(ctx, np.maximum(tau - h, tau_min))
            cp, _ = _batched_cost_at_throughput(ctx, tau + h)
            active &= np.isfinite(cm) & np.isfinite(cp) & np.isfinite(cc)
            g = (cp - cm) / (2 * h)
            hess = (cp - 2 * cc + cm) / (h * h)
            step = np.where(
                (hess <= 0.0) | ~np.isfinite(hess),
                -np.copysign(0.1 * tau, g),
                -g / hess,
            )
            new_tau = np.where(active, np.maximum(tau_min, tau + step), tau)
            c_new, _ = _batched_cost_at_throughput(ctx, new_tau)
            better = active & np.isfinite(c_new) & (c_new < best_cost)
            best_cost = np.where(better, c_new, best_cost)
            best_tau = np.where(better, new_tau, best_tau)
            converged = np.abs(new_tau - tau) < 1e-6 * tau_min
            tau = new_tau
            cc = c_new  # next iteration's cost-at-tau, already evaluated
            active &= ~converged

        _, ks = _batched_cost_at_throughput(ctx, best_tau)
    k_int = np.where(
        alive[:, None] & sb.mask, np.ceil(np.where(alive[:, None], ks, 0.0)), 0.0
    ).astype(np.int64)

    # Feasibility: per-type limits (Formula 10) + throughput under integer k.
    accel = (np.where(sb.rtype != 0, k_int, 0)).sum(axis=1)
    ps = np.where(accel > 0, np.ceil(accel / 6.0), 0.0).astype(np.int64)
    counts = _batched_type_counts(sb, k_int, ps, len(fleet))
    max_counts = np.array([r.max_count for r in fleet])
    limit_ok = (counts <= max_counts[None, :]).all(axis=1)
    tp = _batched_int_throughput(sb, k_int, job.batch_size)
    feasible = alive & limit_ok & (tp >= tau_min)
    return BatchedProvisioning(k=k_int, ps_cores=ps, feasible=feasible)


def provision_sta_ratio(
    stages: Sequence[Stage],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
    *,
    with_ps: bool = False,
) -> ProvisioningPlan | None:
    """StaRatio / StaPSRatio: per-stage minimum k to meet the throughput
    limit *independently* (no load balancing), CPU stages sized at 6 cores
    per accelerator unit (AIBox's 1:6 in-server ratio), plus 6 PS cores
    per accelerator for StaPSRatio."""
    n_accel = 0.0
    k_int: list[int] = []
    for s in stages:
        k = required_k(s, job.throughput_limit, job.batch_size)
        if not math.isfinite(k):
            return None
        k_int.append(int(math.ceil(k)))
        if s.resource_type != 0:
            n_accel += k_int[-1]
    # force the static CPU:GPU ratio on CPU stages
    if n_accel:
        for i, s in enumerate(stages):
            if s.resource_type == 0:
                k_int[i] = max(k_int[i], int(math.ceil(6.0 * n_accel)))
    ps = int(math.ceil(6.0 * n_accel)) if with_ps and n_accel else 0
    counts: dict[int, int] = {}
    for s, k in zip(stages, k_int):
        counts[s.resource_type] = counts.get(s.resource_type, 0) + k
    counts[0] = counts.get(0, 0) + ps
    for t, n in counts.items():
        if n > fleet[t].max_count:
            return None
    return ProvisioningPlan(k=tuple(k_int), ps_cores=ps)
