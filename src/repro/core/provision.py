"""Provisioning for load balance (HeterPS §5.1, Formulas 11–13).

Given a scheduling plan's stages, choose replica counts ``k_i`` so that
(a) every stage sustains the same throughput (no pipeline straggler),
(b) the throughput constraint holds (Formula 13 lower-bounds ``k_1``),
(c) monetary cost is minimized — a Newton iteration on the continuous
relaxation of ``k_1`` (the paper uses Newton's method on ``k_1``), then
integer rounding with a local feasibility search.

Also provides the two static baselines of §6.1: ``StaRatio`` (GPU:CPU
cores 1:6, AIBox default) and ``StaPSRatio`` (1:6 + 6 PS cores per GPU,
BytePS-style).
"""

from __future__ import annotations

import math
from typing import Sequence

from repro.core.cost_model import (
    TrainingJob,
    stage_throughput,
)
from repro.core.plan import ProvisioningPlan, Stage
from repro.core.profiles import B_O
from repro.core.resources import ResourceType


def required_k(stage: Stage, throughput: float, batch_size: int) -> float:
    """Smallest continuous ``k`` giving ``stage`` at least ``throughput``.

    Inverts Formulas 1–4: both the compute and the comm term must fit in
    ``B/throughput`` seconds.  Returns ``inf`` when the sequential
    (non-parallelizable) fraction alone exceeds the budget — no number of
    replicas can reach that throughput (Amdahl ceiling).
    """
    budget = 1.0 / throughput  # seconds per example
    ks = []
    for time_per_ex, frac in ((stage.oct / B_O, stage.alpha), (stage.odt / B_O, stage.beta)):
        if time_per_ex <= 0.0:
            ks.append(0.0)
            continue
        slack = budget / time_per_ex - (1.0 - frac)
        if slack <= 0.0:
            return float("inf")
        ks.append(frac / slack)
    return max(max(ks), 1.0)


def _balanced_k(
    stages: Sequence[Stage], throughput: float, batch_size: int
) -> list[float] | None:
    """Formula 12 generalized: per-stage continuous ``k_i`` at equal throughput."""
    ks = []
    for s in stages:
        k = required_k(s, throughput, batch_size)
        if not math.isfinite(k):
            return None
        ks.append(k)
    return ks


def _ps_cores(stages: Sequence[Stage], k: Sequence[float]) -> int:
    """CPU cores added for parameter servers (§5.1: "based on historical
    profiling results") — the paper's default server ratio is ~1 PS core
    per 6 accelerator units."""
    n_accel = sum(kk for s, kk in zip(stages, k) if s.resource_type != 0)
    return int(math.ceil(n_accel / 6.0)) if n_accel > 0 else 0


def _cost_at_throughput(
    stages: Sequence[Stage],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
    throughput: float,
) -> tuple[float, list[float] | None]:
    """Continuous-relaxation cost at a target throughput (load-balanced)."""
    ks = _balanced_k(stages, throughput, job.batch_size)
    if ks is None:
        return float("inf"), None
    rate = sum(
        k * fleet[s.resource_type].price_per_sec for s, k in zip(stages, ks)
    )
    rate += _ps_cores(stages, ks) * fleet[0].price_per_sec
    et = job.num_epochs * job.num_examples / throughput
    return et * rate, ks


def provision(
    stages: Sequence[Stage],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
    *,
    newton_iters: int = 25,
) -> ProvisioningPlan | None:
    """Generate a provisioning plan for ``stages`` (§5.1).

    Newton's method on the continuous throughput target ``τ`` (equivalent
    to the paper's iteration on ``k_1`` — ``τ`` and ``k_1`` are related
    1:1 by Formula 12/13; optimizing τ directly avoids singling out
    stage 1): minimize ``cost(τ)`` for ``τ ≥ throughput_limit``, then
    round to integers and locally repair feasibility.

    Returns ``None`` when no feasible provisioning exists (resource
    limits, Formula 10).
    """
    tau_min = job.throughput_limit
    c0, ks0 = _cost_at_throughput(stages, fleet, job, tau_min)
    if ks0 is None:
        return None

    # Newton on f(τ) = d cost/d τ, seeking interior minima; cost(τ) is
    # usually increasing past the constraint (paper §5.1 observes this),
    # in which case Newton stays pinned at τ_min.
    tau, best_tau, best_cost = tau_min, tau_min, c0
    h = max(tau_min * 1e-4, 1e-9)
    for _ in range(newton_iters):
        cm, _ = _cost_at_throughput(stages, fleet, job, max(tau - h, tau_min))
        cp, _ = _cost_at_throughput(stages, fleet, job, tau + h)
        cc, _ = _cost_at_throughput(stages, fleet, job, tau)
        if not (math.isfinite(cm) and math.isfinite(cp) and math.isfinite(cc)):
            break
        g = (cp - cm) / (2 * h)
        hess = (cp - 2 * cc + cm) / (h * h)
        if hess <= 0.0 or not math.isfinite(hess):
            step = -math.copysign(0.1 * tau, g)
        else:
            step = -g / hess
        new_tau = max(tau_min, tau + step)
        c_new, _ = _cost_at_throughput(stages, fleet, job, new_tau)
        if math.isfinite(c_new) and c_new < best_cost:
            best_cost, best_tau = c_new, new_tau
        if abs(new_tau - tau) < 1e-6 * tau_min:
            tau = new_tau
            break
        tau = new_tau

    _, ks = _cost_at_throughput(stages, fleet, job, best_tau)
    if ks is None:
        return None
    k_int = [int(math.ceil(k)) for k in ks]

    # Feasibility: per-type limits (Formula 10).
    counts: dict[int, int] = {}
    for s, k in zip(stages, k_int):
        counts[s.resource_type] = counts.get(s.resource_type, 0) + k
    ps = _ps_cores(stages, k_int)
    counts[0] = counts.get(0, 0) + ps
    for t, n in counts.items():
        if n > fleet[t].max_count:
            return None
    # Throughput check with the integer k (ceil only raises throughput,
    # so this should hold; guard against degenerate stages anyway).
    tp = min(
        stage_throughput(s, k, job.batch_size) for s, k in zip(stages, k_int)
    )
    if tp < job.throughput_limit:
        return None
    return ProvisioningPlan(k=tuple(k_int), ps_cores=ps)


# --- static baselines (§6.1) -------------------------------------------------


def provision_sta_ratio(
    stages: Sequence[Stage],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
    *,
    with_ps: bool = False,
) -> ProvisioningPlan | None:
    """StaRatio / StaPSRatio: per-stage minimum k to meet the throughput
    limit *independently* (no load balancing), CPU stages sized at 6 cores
    per accelerator unit (AIBox's 1:6 in-server ratio), plus 6 PS cores
    per accelerator for StaPSRatio."""
    n_accel = 0.0
    k_int: list[int] = []
    for s in stages:
        k = required_k(s, job.throughput_limit, job.batch_size)
        if not math.isfinite(k):
            return None
        k_int.append(int(math.ceil(k)))
        if s.resource_type != 0:
            n_accel += k_int[-1]
    # force the static CPU:GPU ratio on CPU stages
    if n_accel:
        for i, s in enumerate(stages):
            if s.resource_type == 0:
                k_int[i] = max(k_int[i], int(math.ceil(6.0 * n_accel)))
    ps = int(math.ceil(6.0 * n_accel)) if with_ps and n_accel else 0
    counts: dict[int, int] = {}
    for s, k in zip(stages, k_int):
        counts[s.resource_type] = counts.get(s.resource_type, 0) + k
    counts[0] = counts.get(0, 0) + ps
    for t, n in counts.items():
        if n > fleet[t].max_count:
            return None
    return ProvisioningPlan(k=tuple(k_int), ps_cores=ps)
