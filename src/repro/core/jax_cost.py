"""JAX-native soft cost model — Formulas 1–7 + graded surrogate, on device.

This is the fused RL search's reward function: a pure-``jnp`` port of the
NumPy batched path (``plan.batched_build_stages`` →
``provision.batched_provision`` → ``cost_model.batched_soft_plan_cost``)
that can be traced into a single jitted program together with policy
sampling and the REINFORCE update (see ``schedulers/rl.py``).  The NumPy
implementation remains the reference oracle; equivalence over randomized
plans/fleets/jobs is pinned in ``tests/test_jax_cost.py``.

Design constraints that shape the port:

* **Static shapes.** Stage counts vary per plan, so every per-stage array
  is padded to ``S = L`` (a plan can have at most one stage per layer)
  with a validity mask, instead of NumPy's per-batch ``max(num_stages)``.
* **Layer padding.** All tensors carry a per-layer validity mask so
  several models can be padded to a common ``L_max`` and the whole search
  ``vmap``-ed across them (``RLScheduler.schedule_many``).  Padded layers
  contribute nothing: no stage boundaries, zero OCT/ODT.
* **No early exits.** NumPy's Newton loop retires converged plans and the
  graded surrogate re-provisions only the infeasible subset; under ``jit``
  we run fixed-trip loops with masked updates and compute the relaxed
  provisioning for every plan, selecting with ``where`` — same results,
  branch-free.
* **Precision.** All arrays are built from float64 NumPy inputs and take
  whatever precision JAX canonicalizes to: float64 under
  ``jax.experimental.enable_x64()`` (the fused scheduler runs its cost
  side there — agreement with the oracle is then ~1e-9 relative), float32
  otherwise (agreement to ~1e-3 on log-cost; documented in DESIGN.md).
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import TrainingJob
from repro.core.profiles import B_O, LayerProfile
from repro.core.resources import ResourceType

#: fixed trip count of the Newton iteration — matches the NumPy default
NEWTON_ITERS = 25


class CostTensors(NamedTuple):
    """Device-resident constants for one job: per-layer profile tables,
    fleet prices/limits, and job scalars.  A NamedTuple so it is a pytree:
    close over it in a jitted search, pass it through ``lax.scan``, or
    stack ``M`` of them and ``vmap`` across models."""

    oct: jax.Array        # (L, T) per-layer OCT per resource type
    sync: jax.Array       # (L, T) per-layer gradient/param sync ODT
    act: jax.Array        # (L, T) per-layer activation hand-off ODT
    alpha: jax.Array      # (L,) Amdahl compute fraction
    beta: jax.Array       # (L,) Amdahl comm fraction
    lmask: jax.Array      # (L,) bool — False on padded layer slots
    price: jax.Array      # (T,) price per second
    maxc: jax.Array       # (T,) per-type unit limits (Formula 10)
    batch: jax.Array      # () global batch size B
    et_num: jax.Array     # () num_epochs * num_examples
    tau_limit: jax.Array  # () throughput_limit (Formula 10)

    @property
    def num_layers_padded(self) -> int:
        return self.oct.shape[0]

    @property
    def num_types(self) -> int:
        return self.oct.shape[1]


def cost_tensors(
    profiles: Sequence[LayerProfile],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
    *,
    pad_to: int | None = None,
) -> CostTensors:
    """Build :class:`CostTensors`, optionally padding the layer axis.

    Arrays are assembled in float64 NumPy and handed to JAX's dtype
    canonicalization (float64 iff x64 is enabled at call time).
    """
    L = len(profiles)
    P = pad_to if pad_to is not None else L
    if P < L:
        raise ValueError(f"pad_to={P} < {L} layers")
    T = len(fleet)

    def lay(get):
        a = np.zeros((P, T))
        for i, p in enumerate(profiles):
            a[i] = get(p)
        return a

    alpha = np.zeros(P)
    beta = np.zeros(P)
    for i, p in enumerate(profiles):
        alpha[i], beta[i] = p.alpha, p.beta
    return CostTensors(
        oct=jnp.asarray(lay(lambda p: p.oct)),
        sync=jnp.asarray(lay(lambda p: p.odt_sync)),
        act=jnp.asarray(lay(lambda p: p.odt_act)),
        alpha=jnp.asarray(alpha),
        beta=jnp.asarray(beta),
        lmask=jnp.asarray(np.arange(P) < L),
        price=jnp.asarray(np.array([r.price_per_sec for r in fleet])),
        maxc=jnp.asarray(np.array([float(r.max_count) for r in fleet])),
        batch=jnp.asarray(float(job.batch_size)),
        et_num=jnp.asarray(float(job.num_epochs * job.num_examples)),
        tau_limit=jnp.asarray(float(job.throughput_limit)),
    )


class _Stages(NamedTuple):
    """Per-stage arrays for N plans, padded to S = L (cf. plan.StageBatch)."""

    rtype: jax.Array   # (N, S) int resource type (0 in invalid slots)
    oct: jax.Array     # (N, S)
    odt: jax.Array     # (N, S)
    alpha: jax.Array   # (N, S)
    beta: jax.Array    # (N, S)
    mask: jax.Array    # (N, S) bool


def build_stages(ct: CostTensors, actions: jax.Array) -> _Stages:
    """Fuse consecutive same-type layers into stages (plan.build_stages).

    ``actions`` is ``(N, L)`` int; padded layer slots (``ct.lmask`` False)
    never open a stage and contribute zero OCT/ODT.
    """
    N, L = actions.shape
    lm = ct.lmask
    lmf = lm.astype(ct.oct.dtype)
    n_layers = jnp.sum(lm)

    lay = jnp.arange(L)
    oct_l = ct.oct[lay, actions] * lmf          # (N, L)
    sync_l = ct.sync[lay, actions] * lmf
    act_l = ct.act[lay, actions] * lmf

    change = jnp.concatenate(
        [jnp.ones((N, 1), bool), actions[:, 1:] != actions[:, :-1]], axis=1
    ) & lm
    sid = jnp.cumsum(change, axis=1) - 1        # (N, L) stage id per layer
    # last layer of a stage: the next layer opens a new stage, or it is the
    # last *valid* layer (padded slots have change=False, so the real last
    # layer needs the explicit test)
    nxt = jnp.concatenate([change[:, 1:], jnp.zeros((N, 1), bool)], axis=1)
    is_last = (nxt | (lay[None, :] == n_layers - 1)) & lm

    onehot = (sid[:, :, None] == jnp.arange(L)[None, None, :]).astype(
        ct.oct.dtype
    )                                           # (N, L, S)

    def seg(v):
        return jnp.einsum("nl,nls->ns", v, onehot)

    oct_s = seg(oct_l)
    odt_s = seg(sync_l) + seg(jnp.where(is_last, act_l, 0.0))
    w = jnp.maximum(oct_s, 1e-30)
    alpha_s = seg(ct.alpha[None, :] * oct_l) / w
    beta_s = seg(ct.beta[None, :] * oct_l) / w
    # the stage's type is its first layer's action (change marks exactly one
    # layer per stage)
    rtype = jnp.einsum(
        "nl,nls->ns", actions * change, onehot.astype(actions.dtype)
    )
    smask = jnp.arange(L)[None, :] < (sid[:, -1] + 1)[:, None]
    return _Stages(
        rtype=rtype, oct=oct_s, odt=odt_s, alpha=alpha_s, beta=beta_s,
        mask=smask,
    )


def _required_k(st: _Stages, tau: jax.Array) -> jax.Array:
    """Vectorized ``provision.required_k``: (N, S) continuous k at per-plan
    target throughput ``tau`` (inf past a stage's Amdahl ceiling)."""
    budget = 1.0 / tau[:, None]
    out = jnp.full_like(st.oct, 1.0)
    for time_per_ex, frac in (
        (st.oct / B_O, st.alpha), (st.odt / B_O, st.beta)
    ):
        slack = budget / time_per_ex - (1.0 - frac)
        k = jnp.where(slack > 0.0, frac / slack, jnp.inf)
        k = jnp.where(time_per_ex <= 0.0, 0.0, k)
        out = jnp.maximum(out, k)
    return out


def _cost_at_tau(
    ct: CostTensors, st: _Stages, tau: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Continuous-relaxation cost at per-plan ``tau`` → (cost (N,), ks (N, S)).

    inf where a stage hits its Amdahl ceiling.  ``cumsum``-based folds
    mirror the NumPy path's left-to-right stage accumulation.
    """
    ks = _required_k(st, tau)
    ksm = jnp.where(st.mask, ks, 0.0)
    ok = jnp.all(jnp.isfinite(ksm), axis=1)
    stage_price = jnp.where(st.mask, ct.price[st.rtype], 0.0)
    accel_ind = jnp.where(st.mask & (st.rtype != 0), 1.0, 0.0)
    rate = jnp.cumsum(ksm * stage_price, axis=1)[:, -1]
    accel = jnp.cumsum(ksm * accel_ind, axis=1)[:, -1]
    ps = jnp.where(accel > 0.0, jnp.ceil(accel / 6.0), 0.0)
    rate = rate + ps * ct.price[0]
    cost = jnp.where(ok, (ct.et_num / tau) * rate, jnp.inf)
    return cost, ksm


def _int_throughput(
    ct: CostTensors, st: _Stages, k: jax.Array
) -> jax.Array:
    """Pipeline throughput (Formula 5) under integer replica counts."""
    k_eff = jnp.maximum(k, 1).astype(st.oct.dtype)
    cts = (st.oct / B_O) * ct.batch * (1.0 - st.alpha + st.alpha / k_eff)
    dts = (st.odt / B_O) * ct.batch * (1.0 - st.beta + st.beta / k_eff)
    ex = jnp.maximum(cts, dts)
    tp_s = jnp.where(
        st.mask & (ex > 0.0),
        ct.batch / jnp.where(ex > 0.0, ex, 1.0),
        jnp.inf,
    )
    return jnp.min(tp_s, axis=1)


def _type_counts(
    ct: CostTensors, st: _Stages, k: jax.Array, ps: jax.Array
) -> jax.Array:
    """(N, T) total units per resource type, PS cores on type 0."""
    onehot_t = (
        st.rtype[:, :, None] == jnp.arange(ct.num_types)[None, None, :]
    ).astype(k.dtype)
    counts = jnp.einsum("ns,nst->nt", k, onehot_t)
    return counts.at[:, 0].add(ps)


class _Provisioning(NamedTuple):
    k: jax.Array         # (N, S) integer replica counts (0 in invalid slots)
    ps: jax.Array        # (N,) PS cores
    feasible: jax.Array  # (N,) bool


def provision(
    ct: CostTensors, st: _Stages, tau_min: jax.Array
) -> _Provisioning:
    """Vectorized ``provision.batched_provision``: Newton on the throughput
    target τ (fixed ``NEWTON_ITERS`` trips, masked updates), integer
    rounding, Formula-10 limit + throughput checks."""
    c0, _ = _cost_at_tau(ct, st, tau_min)
    alive = jnp.isfinite(c0)
    h = jnp.maximum(tau_min * 1e-4, 1e-9)

    def body(_, carry):
        tau, best_tau, best_cost, cc, active = carry
        cm, _ = _cost_at_tau(ct, st, jnp.maximum(tau - h, tau_min))
        cp, _ = _cost_at_tau(ct, st, tau + h)
        active = active & jnp.isfinite(cm) & jnp.isfinite(cp) & jnp.isfinite(cc)
        g = (cp - cm) / (2 * h)
        hess = (cp - 2 * cc + cm) / (h * h)
        step = jnp.where(
            (hess <= 0.0) | ~jnp.isfinite(hess),
            -jnp.copysign(0.1 * tau, g),
            -g / hess,
        )
        new_tau = jnp.where(active, jnp.maximum(tau_min, tau + step), tau)
        c_new, _ = _cost_at_tau(ct, st, new_tau)
        better = active & jnp.isfinite(c_new) & (c_new < best_cost)
        best_cost = jnp.where(better, c_new, best_cost)
        best_tau = jnp.where(better, new_tau, best_tau)
        active = active & ~(jnp.abs(new_tau - tau) < 1e-6 * tau_min)
        return new_tau, best_tau, best_cost, c_new, active

    _, best_tau, _, _, _ = jax.lax.fori_loop(
        0, NEWTON_ITERS, body, (tau_min, tau_min, c0, c0, alive)
    )
    _, ks = _cost_at_tau(ct, st, best_tau)
    k_int = jnp.where(
        alive[:, None] & st.mask,
        jnp.ceil(jnp.where(alive[:, None], ks, 0.0)),
        0.0,
    )
    accel = jnp.sum(jnp.where(st.rtype != 0, k_int, 0.0), axis=1)
    ps = jnp.where(accel > 0.0, jnp.ceil(accel / 6.0), 0.0)
    counts = _type_counts(ct, st, k_int, ps)
    limit_ok = jnp.all(counts <= ct.maxc[None, :], axis=1)
    tp = _int_throughput(ct, st, k_int)
    return _Provisioning(
        k=k_int, ps=ps, feasible=alive & limit_ok & (tp >= tau_min)
    )


def _monetary(
    ct: CostTensors, st: _Stages, k: jax.Array, ps: jax.Array
) -> jax.Array:
    """Formulas 5–7 for integer provisioning, no constraint checks."""
    tp = _int_throughput(ct, st, k)
    et = ct.et_num / tp
    counts = _type_counts(ct, st, k, ps)
    rate = jnp.cumsum(counts * ct.price[None, :], axis=1)[:, -1]
    return et * rate


class SoftCost(NamedTuple):
    """Per-plan results of :func:`soft_cost` — the device analogue of
    ``(batched_plan_cost.costs, soft)`` plus the feasibility mask that lets
    the host reconstruct exact true costs (feasible ⇒ cost == soft;
    infeasible ⇒ cost == inf)."""

    soft: jax.Array      # (N,) graded surrogate (finite unless degenerate)
    cost: jax.Array      # (N,) true cost, inf where infeasible
    feasible: jax.Array  # (N,) bool


def soft_cost(ct: CostTensors, actions: jax.Array) -> SoftCost:
    """Vectorized ``cost_model.batched_soft_plan_cost`` in pure jnp.

    Unlike the NumPy path, the relaxed re-provisioning runs for every plan
    (no dynamic subsetting under jit) and ``where`` selects; feasible
    plans' relaxed branch is computed-and-discarded.
    """
    st = build_stages(ct, actions)
    bp = provision(ct, st, jnp.broadcast_to(ct.tau_limit, actions.shape[:1]))
    cost = jnp.where(bp.feasible, _monetary(ct, st, bp.k, bp.ps), jnp.inf)

    # graded surrogate for the infeasible subset: max achievable pipeline
    # throughput with every stage at its type's limit, re-provision at a
    # relaxed target, scale by squared constraint violation
    k_cap = jnp.where(st.mask, ct.maxc[st.rtype], 0.0)
    tp_max = _int_throughput(ct, st, k_cap)
    relaxed = jnp.minimum(tp_max * 0.5, ct.tau_limit)
    bp_r = provision(ct, st, relaxed)
    base = _monetary(ct, st, bp_r.k, bp_r.ps)
    violation = jnp.maximum(ct.tau_limit / jnp.maximum(tp_max, 1e-9), 1.0)
    graded = base * 10.0 * violation**2
    soft_infeas = jnp.where(bp_r.feasible & (tp_max > 0), graded, 1e15)
    return SoftCost(
        soft=jnp.where(bp.feasible, cost, soft_infeas),
        cost=cost,
        feasible=bp.feasible,
    )


@jax.jit
def _soft_cost_jit(ct: CostTensors, actions: jax.Array) -> SoftCost:
    return soft_cost(ct, actions)


def jnp_soft_plan_cost(
    assignments: np.ndarray,
    profiles: Sequence[LayerProfile],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-convenience wrapper: (soft, cost, feasible) NumPy arrays for an
    (N, L) assignment batch — the equivalence-test entry point."""
    ct = cost_tensors(profiles, fleet, job)
    out = _soft_cost_jit(ct, jnp.asarray(np.asarray(assignments), jnp.int32))
    return (
        np.asarray(out.soft, dtype=np.float64),
        np.asarray(out.cost, dtype=np.float64),
        np.asarray(out.feasible),
    )
