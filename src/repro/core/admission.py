"""Deadline-aware admission control for the continuous-batching serve path.

HeterPS's scheduler minimizes cost *subject to throughput constraints* —
but a serve loop that admits FIFO until the page pool blocks has no
constraint at all under overload: a traffic burst collapses TTFT for
every request instead of protecting goodput.  This module is the
admission half of that constraint:

* a four-way **outcome taxonomy** every request terminates in —
  :data:`COMPLETED` / :data:`REJECTED` / :data:`TIMED_OUT` /
  :data:`PREEMPTED` — so nothing can hang silently;
* :class:`AdmissionPolicy` — a bounded admission queue plus a
  measured-rate deadline feasibility test: using EMA estimates of
  prefill seconds and per-output-token decode seconds (TPOT), a request
  is rejected at arrival when even the *optimistic* service estimate
  (current backlog drained at the measured best rate) cannot meet its
  TTFT or total deadline.  The knobs an external controller tunes
  (``max_concurrency``, ``queue_bound``) live here — see
  ``repro.core.replan.AdmissionActuator`` for the AIMD loop that closes
  them against measured SLO windows.

The admission math (documented in DESIGN.md "Overload robustness"):
with measured TPOT ``τ`` seconds/token and effective decode concurrency
``c``, the batch drains ``c/τ`` tokens per second, so a request behind a
backlog of ``B`` scheduled tokens waits an estimated ``B·τ/c`` seconds
before its prefill (EMA ``ρ`` seconds) can produce the first token:

    TTFT_est  = (now − arrival) + B·τ/c + ρ
    total_est = TTFT_est + gen·τ

Both estimates are *optimistic* (they assume the measured steady-state
rate with no further arrivals), so a rejection is a proof sketch: the
deadline cannot be met even under best-case service.  Unmeasured rates
(``τ == 0``, a cold loop) admit everything — there is no basis to
reject yet.
"""

from __future__ import annotations

#: terminal request outcomes — every request the serve loop sees ends in
#: exactly one of these (the "zero hung requests" contract)
COMPLETED = "completed"    #: finished its full generation
REJECTED = "rejected"      #: never admitted (oversize / queue / deadline)
TIMED_OUT = "timed_out"    #: deadline passed while queued or mid-decode
PREEMPTED = "preempted"    #: evicted mid-flight and never resumed

OUTCOMES = (COMPLETED, REJECTED, TIMED_OUT, PREEMPTED)


class AdmissionPolicy:
    """Bounded admission queue + measured-rate deadline feasibility.

    The serve loop consults :meth:`admit_check` when a request *arrives*
    (joins the admission queue) and feeds measurements back through
    :meth:`observe_prefill` / :meth:`observe_tpot` as requests prefill
    and complete.  ``max_concurrency`` caps live decode slots and
    ``queue_bound`` caps the admission queue depth (``None`` =
    unbounded); both are plain attributes so a controller thread (the
    AIMD actuator) can retune them while the loop runs — single
    attribute reads/writes, safe under the GIL.
    """

    def __init__(self, *, slots: int, queue_bound: int | None = None,
                 max_concurrency: int | None = None,
                 prefill_s: float = 0.0, tpot_s: float = 0.0,
                 ema: float = 0.3):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        self.slots = int(slots)
        self.max_concurrency = (int(max_concurrency)
                                if max_concurrency is not None else slots)
        self.queue_bound = (int(queue_bound) if queue_bound is not None
                            else None)
        #: EMA measured rates; 0.0 = not yet measured (admit everything)
        self.prefill_s = float(prefill_s)
        self.tpot_s = float(tpot_s)
        self.ema = float(ema)
        self.admitted = 0
        self.rejections: dict[str, int] = {}

    # -- measurement feedback ---------------------------------------------

    def _ema(self, old: float, new: float) -> float:
        return new if old <= 0.0 else (1 - self.ema) * old + self.ema * new

    def observe_prefill(self, seconds: float) -> None:
        if seconds > 0:
            self.prefill_s = self._ema(self.prefill_s, float(seconds))

    def observe_tpot(self, seconds: float) -> None:
        if seconds > 0:
            self.tpot_s = self._ema(self.tpot_s, float(seconds))

    # -- estimates --------------------------------------------------------

    @property
    def concurrency(self) -> int:
        """Effective decode concurrency the estimate assumes."""
        return max(1, min(self.slots, int(self.max_concurrency)))

    def estimate_ttft(self, *, now: float, arrival: float,
                      backlog_tokens: float) -> float:
        """Optimistic arrival→first-token estimate behind ``backlog``
        scheduled tokens (0.0 when rates are unmeasured)."""
        if self.tpot_s <= 0.0:
            return 0.0
        wait = backlog_tokens * self.tpot_s / self.concurrency
        return (now - arrival) + wait + self.prefill_s

    # -- the admission decision -------------------------------------------

    def admit_check(self, *, now: float, arrival: float, gen: int,
                    ttft_deadline: float | None = None,
                    total_deadline: float | None = None,
                    backlog_tokens: float = 0.0,
                    queue_len: int = 0) -> str | None:
        """``None`` to admit, else a typed reject reason.

        ``backlog_tokens`` is the sum of scheduled output tokens ahead of
        this request (in-flight remainders + queued generations);
        ``queue_len`` the current admission-queue depth.  Deadlines are
        absolute offsets from ``arrival``.
        """
        if self.queue_bound is not None and queue_len >= self.queue_bound:
            return self._reject("queue_full")
        if self.tpot_s > 0.0:
            ttft_est = self.estimate_ttft(now=now, arrival=arrival,
                                          backlog_tokens=backlog_tokens)
            if ttft_deadline is not None and ttft_est > ttft_deadline:
                return self._reject("ttft_deadline")
            if (total_deadline is not None
                    and ttft_est + gen * self.tpot_s > total_deadline):
                return self._reject("total_deadline")
        self.admitted += 1
        return None

    def _reject(self, reason: str) -> str:
        self.rejections[reason] = self.rejections.get(reason, 0) + 1
        return reason

    def report(self) -> dict:
        return {
            "max_concurrency": self.max_concurrency,
            "queue_bound": self.queue_bound,
            "prefill_s": self.prefill_s,
            "tpot_s": self.tpot_s,
            "admitted": self.admitted,
            "rejections": dict(self.rejections),
        }
