"""HeterPS cost model — Formulas 1–7 (§4.1).

Estimates per-stage computation/communication time, pipeline throughput,
end-to-end execution time, and monetary cost for a (scheduling plan,
provisioning plan) pair.

Note on Formula 1/2 scaling: the paper writes ``CT_i = OCT_i/B_o *
(1-α+α/k)`` and then ``Throughput_i = B/ET_i``.  Dimensional consistency
requires CT to be the time of a *full batch* ``B``, i.e. ``CT_i =
(OCT_i/B_o)·B·(1-α+α/k)`` — ``OCT_i/B_o`` is the profiled per-example
time.  We implement that reading (a noted erratum in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.plan import (
    ProvisioningPlan,
    SchedulingPlan,
    Stage,
    StageBatch,
    batched_build_stages,
    build_stages,
    type_counts,
)
from repro.core.profiles import B_O, LayerProfile
from repro.core.resources import ResourceType

#: cost returned for infeasible plans (constraint violations, Formula 10)
INFEASIBLE = float("inf")


@dataclasses.dataclass(frozen=True)
class TrainingJob:
    """The workload the plans are evaluated against.

    Attributes:
      batch_size: global batch size ``B``.
      num_examples: ``M`` examples per epoch.
      num_epochs: ``L`` epochs.
      throughput_limit: minimum examples/s (Formula 10).
    """

    batch_size: int = 4096
    num_examples: int = 4_000_000_000   # ads-scale feature logs (~10 TB, §1)
    num_epochs: int = 1
    throughput_limit: float = 200_000.0  # examples/s


def stage_compute_time(stage: Stage, k: int, batch_size: int) -> float:
    """Formula 1 (batch-scaled): ``CT_i``."""
    k = max(1, int(k))
    return (stage.oct / B_O) * batch_size * (1.0 - stage.alpha + stage.alpha / k)


def stage_comm_time(stage: Stage, k: int, batch_size: int) -> float:
    """Formula 2 (batch-scaled): ``DT_i``."""
    k = max(1, int(k))
    return (stage.odt / B_O) * batch_size * (1.0 - stage.beta + stage.beta / k)


def stage_exec_time(stage: Stage, k: int, batch_size: int) -> float:
    """Formula 3: computation/communication overlap → max of the two."""
    return max(
        stage_compute_time(stage, k, batch_size),
        stage_comm_time(stage, k, batch_size),
    )


def stage_throughput(stage: Stage, k: int, batch_size: int) -> float:
    """Formula 4: examples/s of stage ``i``."""
    return batch_size / stage_exec_time(stage, k, batch_size)


def pipeline_throughput(
    stages: Sequence[Stage], prov: ProvisioningPlan, batch_size: int
) -> float:
    """Formula 5: the pipeline is limited by its slowest stage."""
    return min(stage_throughput(s, k, batch_size) for s, k in zip(stages, prov.k))


def execution_time(
    stages: Sequence[Stage], prov: ProvisioningPlan, job: TrainingJob
) -> float:
    """Formula 6: ``ET = L · M / Throughput``."""
    tp = pipeline_throughput(stages, prov, job.batch_size)
    return job.num_epochs * job.num_examples / tp


def monetary_cost(
    plan: SchedulingPlan,
    prov: ProvisioningPlan,
    profiles: Sequence[LayerProfile],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
    *,
    check_limits: bool = True,
    stages: Sequence[Stage] | None = None,
) -> float:
    """Formula 7 with the Formula-10 constraints.

    Returns :data:`INFEASIBLE` when the throughput constraint or a
    per-type resource limit is violated.  ``stages`` lets callers share
    already-built stages.
    """
    if stages is None:
        stages = build_stages(plan, profiles, fleet)
    if len(prov.k) != len(stages):
        raise ValueError(f"{len(prov.k)} k's for {len(stages)} stages")
    counts = type_counts(plan, prov, len(fleet))
    if check_limits:
        for t, (n, res) in enumerate(zip(counts, fleet)):
            if n > res.max_count:
                return INFEASIBLE
        if pipeline_throughput(stages, prov, job.batch_size) < job.throughput_limit:
            return INFEASIBLE
    et = execution_time(stages, prov, job)
    rate = sum(n * res.price_per_sec for n, res in zip(counts, fleet))
    return et * rate


def plan_cost(
    plan: SchedulingPlan,
    profiles: Sequence[LayerProfile],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
    *,
    stages: Sequence[Stage] | None = None,
) -> tuple[float, ProvisioningPlan | None]:
    """Cost of a scheduling plan = cost under its best provisioning (§5).

    This is the reward the RL scheduler optimizes (Algorithm 1, Line 5):
    the provisioning module is invoked inside the cost evaluation.
    ``stages`` lets callers that already built the plan's stages share
    them instead of re-deriving.
    """
    from repro.core.provision import provision  # cycle-free late import

    if stages is None:
        stages = build_stages(plan, profiles, fleet)
    prov = provision(stages, fleet, job)
    if prov is None:
        return INFEASIBLE, None
    return (
        monetary_cost(plan, prov, profiles, fleet, job, stages=stages),
        prov,
    )


def soft_plan_cost(
    plan: SchedulingPlan,
    profiles: Sequence[LayerProfile],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
    *,
    stages: Sequence[Stage] | None = None,
    cost: float | None = None,
) -> float:
    """Graded surrogate for search rewards (beyond-paper refinement).

    A flat penalty for infeasible plans gives REINFORCE/GA/BO zero
    gradient when *every* sampled plan violates the constraint (common
    early in training for deep models where one bad stage placement hits
    the Amdahl ceiling).  Instead, re-evaluate the plan at its *achievable*
    throughput and scale the cost by the squared constraint-violation
    ratio — infeasible plans are ordered by how infeasible they are.
    Feasible plans return their true cost.

    ``stages``/``cost`` let callers that already evaluated the plan (e.g.
    ``CostCache``) share that work instead of re-running ``build_stages``
    and the full provisioning search.
    """
    import dataclasses as _dc

    from repro.core.provision import provision

    if stages is None:
        stages = build_stages(plan, profiles, fleet)
    if cost is None:
        cost, _ = plan_cost(plan, profiles, fleet, job, stages=stages)
    if math.isfinite(cost):
        return cost
    tp_max = min(
        stage_throughput(s, fleet[s.resource_type].max_count, job.batch_size)
        for s in stages
    )
    if tp_max <= 0:
        return 1e15
    relaxed = _dc.replace(job, throughput_limit=min(tp_max * 0.5,
                                                    job.throughput_limit))
    prov = provision(stages, fleet, relaxed)
    if prov is None:
        return 1e15
    base = monetary_cost(plan, prov, profiles, fleet, relaxed,
                         check_limits=False, stages=stages)
    violation = max(job.throughput_limit / max(tp_max, 1e-9), 1.0)
    return base * 10.0 * violation**2


# --- batched evaluation (Formulas 1–7 over N plans at once) ------------------
#
# The scalar functions above remain the reference oracle; the batched path
# below evaluates an (N, L) assignment batch with NumPy array ops and a
# vectorized provisioning search (see provision.batched_provision).  Each
# plan's arithmetic follows the same operation sequence as the scalar path,
# so results agree bit-for-bit (tested in tests/test_batched_cost.py).


#: plans per vectorized slice — around this size the working set of (N, S)
#: temporaries stays cache-resident; larger batches are internally chunked
#: (throughput falls off a cliff once the Newton loop spills to DRAM)
EVAL_CHUNK = 512


@dataclasses.dataclass(frozen=True)
class BatchedCost:
    """Result of :func:`batched_plan_cost` for N plans.

    ``costs[i]`` is the true monetary cost (:data:`INFEASIBLE` when no
    feasible provisioning exists); ``prov(i)`` materializes plan ``i``'s
    chosen provisioning as a scalar :class:`ProvisioningPlan`.
    """

    costs: np.ndarray       # (N,)
    k: np.ndarray           # (N, S) int replica counts (0 past num_stages)
    ps_cores: np.ndarray    # (N,) int
    num_stages: np.ndarray  # (N,) int
    feasible: np.ndarray    # (N,) bool

    def prov(self, i: int) -> ProvisioningPlan | None:
        if not self.feasible[i]:
            return None
        n = int(self.num_stages[i])
        return ProvisioningPlan(
            k=tuple(int(x) for x in self.k[i, :n]),
            ps_cores=int(self.ps_cores[i]),
        )


def _concat_batched(parts: list[BatchedCost]) -> BatchedCost:
    """Stack chunked results; pad ``k`` to the widest stage count."""
    S = max(p.k.shape[1] for p in parts)
    ks = []
    for p in parts:
        pad = S - p.k.shape[1]
        ks.append(np.pad(p.k, ((0, 0), (0, pad))) if pad else p.k)
    return BatchedCost(
        costs=np.concatenate([p.costs for p in parts]),
        k=np.concatenate(ks),
        ps_cores=np.concatenate([p.ps_cores for p in parts]),
        num_stages=np.concatenate([p.num_stages for p in parts]),
        feasible=np.concatenate([p.feasible for p in parts]),
    )


def _batched_monetary_cost(
    sb: StageBatch,
    k: np.ndarray,
    ps: np.ndarray,
    fleet: Sequence[ResourceType],
    job: TrainingJob,
) -> np.ndarray:
    """Formulas 5–7 for integer provisioning, no constraint checks."""
    from repro.core.provision import (
        _batched_int_throughput,
        _batched_type_counts,
    )

    tp = _batched_int_throughput(sb, k, job.batch_size)
    et = float(job.num_epochs * job.num_examples) / tp
    counts = _batched_type_counts(sb, k, ps, len(fleet))
    # left fold in fleet order == the scalar sum() over types
    rate = np.zeros(sb.batch)
    for t, res in enumerate(fleet):
        rate = rate + counts[:, t] * res.price_per_sec
    return et * rate


def batched_plan_cost(
    assignments: np.ndarray,
    profiles: Sequence[LayerProfile],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
) -> BatchedCost:
    """Vectorized :func:`plan_cost` over an ``(N, L)`` assignment batch."""
    from repro.core.provision import batched_provision

    assignments = np.asarray(assignments, dtype=np.int64)
    if len(assignments) > EVAL_CHUNK:
        return _concat_batched([
            batched_plan_cost(assignments[i:i + EVAL_CHUNK], profiles, fleet, job)
            for i in range(0, len(assignments), EVAL_CHUNK)
        ])
    sb = batched_build_stages(assignments, profiles, fleet)
    bp = batched_provision(sb, fleet, job)
    cost = np.where(
        bp.feasible,
        _batched_monetary_cost(sb, bp.k, bp.ps_cores, fleet, job),
        INFEASIBLE,
    )
    return BatchedCost(
        costs=cost, k=bp.k, ps_cores=bp.ps_cores,
        num_stages=sb.num_stages, feasible=bp.feasible,
    )


def batched_soft_plan_cost(
    assignments: np.ndarray,
    profiles: Sequence[LayerProfile],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
) -> tuple[BatchedCost, np.ndarray]:
    """Vectorized (:func:`plan_cost`, :func:`soft_plan_cost`) in one pass.

    Returns the true-cost batch plus the graded surrogate vector; the
    stage arrays and true-cost provisioning are computed once and shared
    (the batched analogue of the ``CostCache.soft`` single-evaluation
    path).  Only the infeasible subset pays for the relaxed re-provision.
    """
    from repro.core.provision import _batched_int_throughput, batched_provision

    assignments = np.asarray(assignments, dtype=np.int64)
    if len(assignments) > EVAL_CHUNK:
        parts = [
            batched_soft_plan_cost(assignments[i:i + EVAL_CHUNK], profiles, fleet, job)
            for i in range(0, len(assignments), EVAL_CHUNK)
        ]
        return (
            _concat_batched([bc for bc, _ in parts]),
            np.concatenate([s for _, s in parts]),
        )
    sb = batched_build_stages(assignments, profiles, fleet)
    bp = batched_provision(sb, fleet, job)
    cost = np.where(
        bp.feasible,
        _batched_monetary_cost(sb, bp.k, bp.ps_cores, fleet, job),
        INFEASIBLE,
    )
    soft = cost.copy()
    bad = ~np.isfinite(cost)
    if bad.any():
        idx = np.flatnonzero(bad)
        sub = sb.take(idx)
        # max achievable pipeline throughput: every stage at its type's limit
        max_counts = np.array([r.max_count for r in fleet])
        tp_max = _batched_int_throughput(
            sub, np.where(sub.mask, max_counts[sub.rtype], 0), job.batch_size
        )
        relaxed = np.minimum(tp_max * 0.5, float(job.throughput_limit))
        bp_r = batched_provision(sub, fleet, job, tau_min=relaxed)
        base = _batched_monetary_cost(sub, bp_r.k, bp_r.ps_cores, fleet, job)
        violation = np.maximum(
            float(job.throughput_limit) / np.maximum(tp_max, 1e-9), 1.0
        )
        graded = base * 10.0 * violation**2
        soft[idx] = np.where(bp_r.feasible & (tp_max > 0), graded, 1e15)
    return (
        BatchedCost(
            costs=cost, k=bp.k, ps_cores=bp.ps_cores,
            num_stages=sb.num_stages, feasible=bp.feasible,
        ),
        soft,
    )
