"""HeterPS cost model — Formulas 1–7 (§4.1).

Estimates per-stage computation/communication time, pipeline throughput,
end-to-end execution time, and monetary cost for a (scheduling plan,
provisioning plan) pair.

Note on Formula 1/2 scaling: the paper writes ``CT_i = OCT_i/B_o *
(1-α+α/k)`` and then ``Throughput_i = B/ET_i``.  Dimensional consistency
requires CT to be the time of a *full batch* ``B``, i.e. ``CT_i =
(OCT_i/B_o)·B·(1-α+α/k)`` — ``OCT_i/B_o`` is the profiled per-example
time.  We implement that reading (a noted erratum in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.plan import (
    ProvisioningPlan,
    SchedulingPlan,
    Stage,
    build_stages,
    type_counts,
)
from repro.core.profiles import B_O, LayerProfile
from repro.core.resources import ResourceType

#: cost returned for infeasible plans (constraint violations, Formula 10)
INFEASIBLE = float("inf")


@dataclasses.dataclass(frozen=True)
class TrainingJob:
    """The workload the plans are evaluated against.

    Attributes:
      batch_size: global batch size ``B``.
      num_examples: ``M`` examples per epoch.
      num_epochs: ``L`` epochs.
      throughput_limit: minimum examples/s (Formula 10).
    """

    batch_size: int = 4096
    num_examples: int = 4_000_000_000   # ads-scale feature logs (~10 TB, §1)
    num_epochs: int = 1
    throughput_limit: float = 200_000.0  # examples/s


def stage_compute_time(stage: Stage, k: int, batch_size: int) -> float:
    """Formula 1 (batch-scaled): ``CT_i``."""
    k = max(1, int(k))
    return (stage.oct / B_O) * batch_size * (1.0 - stage.alpha + stage.alpha / k)


def stage_comm_time(stage: Stage, k: int, batch_size: int) -> float:
    """Formula 2 (batch-scaled): ``DT_i``."""
    k = max(1, int(k))
    return (stage.odt / B_O) * batch_size * (1.0 - stage.beta + stage.beta / k)


def stage_exec_time(stage: Stage, k: int, batch_size: int) -> float:
    """Formula 3: computation/communication overlap → max of the two."""
    return max(
        stage_compute_time(stage, k, batch_size),
        stage_comm_time(stage, k, batch_size),
    )


def stage_throughput(stage: Stage, k: int, batch_size: int) -> float:
    """Formula 4: examples/s of stage ``i``."""
    return batch_size / stage_exec_time(stage, k, batch_size)


def pipeline_throughput(
    stages: Sequence[Stage], prov: ProvisioningPlan, batch_size: int
) -> float:
    """Formula 5: the pipeline is limited by its slowest stage."""
    return min(stage_throughput(s, k, batch_size) for s, k in zip(stages, prov.k))


def execution_time(
    stages: Sequence[Stage], prov: ProvisioningPlan, job: TrainingJob
) -> float:
    """Formula 6: ``ET = L · M / Throughput``."""
    tp = pipeline_throughput(stages, prov, job.batch_size)
    return job.num_epochs * job.num_examples / tp


def monetary_cost(
    plan: SchedulingPlan,
    prov: ProvisioningPlan,
    profiles: Sequence[LayerProfile],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
    *,
    check_limits: bool = True,
) -> float:
    """Formula 7 with the Formula-10 constraints.

    Returns :data:`INFEASIBLE` when the throughput constraint or a
    per-type resource limit is violated.
    """
    stages = build_stages(plan, profiles, fleet)
    if len(prov.k) != len(stages):
        raise ValueError(f"{len(prov.k)} k's for {len(stages)} stages")
    counts = type_counts(plan, prov, len(fleet))
    if check_limits:
        for t, (n, res) in enumerate(zip(counts, fleet)):
            if n > res.max_count:
                return INFEASIBLE
        if pipeline_throughput(stages, prov, job.batch_size) < job.throughput_limit:
            return INFEASIBLE
    et = execution_time(stages, prov, job)
    rate = sum(n * res.price_per_sec for n, res in zip(counts, fleet))
    return et * rate


def plan_cost(
    plan: SchedulingPlan,
    profiles: Sequence[LayerProfile],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
) -> tuple[float, ProvisioningPlan | None]:
    """Cost of a scheduling plan = cost under its best provisioning (§5).

    This is the reward the RL scheduler optimizes (Algorithm 1, Line 5):
    the provisioning module is invoked inside the cost evaluation.
    """
    from repro.core.provision import provision  # cycle-free late import

    stages = build_stages(plan, profiles, fleet)
    prov = provision(stages, fleet, job)
    if prov is None:
        return INFEASIBLE, None
    return (
        monetary_cost(plan, prov, profiles, fleet, job),
        prov,
    )


def soft_plan_cost(
    plan: SchedulingPlan,
    profiles: Sequence[LayerProfile],
    fleet: Sequence[ResourceType],
    job: TrainingJob,
) -> float:
    """Graded surrogate for search rewards (beyond-paper refinement).

    A flat penalty for infeasible plans gives REINFORCE/GA/BO zero
    gradient when *every* sampled plan violates the constraint (common
    early in training for deep models where one bad stage placement hits
    the Amdahl ceiling).  Instead, re-evaluate the plan at its *achievable*
    throughput and scale the cost by the squared constraint-violation
    ratio — infeasible plans are ordered by how infeasible they are.
    Feasible plans return their true cost.
    """
    import dataclasses as _dc

    from repro.core.provision import provision

    cost, _ = plan_cost(plan, profiles, fleet, job)
    if math.isfinite(cost):
        return cost
    stages = build_stages(plan, profiles, fleet)
    tp_max = min(
        stage_throughput(s, fleet[s.resource_type].max_count, job.batch_size)
        for s in stages
    )
    if tp_max <= 0:
        return 1e15
    relaxed = _dc.replace(job, throughput_limit=min(tp_max * 0.5,
                                                    job.throughput_limit))
    stages_r = build_stages(plan, profiles, fleet)
    prov = provision(stages_r, fleet, relaxed)
    if prov is None:
        return 1e15
    base = monetary_cost(plan, prov, profiles, fleet, relaxed,
                         check_limits=False)
    violation = max(job.throughput_limit / max(tp_max, 1e-9), 1.0)
    return base * 10.0 * violation**2
