"""Scheduling & provisioning plans (HeterPS §4.2, §5.1).

A *scheduling plan* assigns each layer to one resource type (the paper's
``Schedule(l, t)`` 0/1 matrix — we store the equivalent dense vector of
type indices).  Consecutive layers on the same type fuse into a *stage*;
a *provisioning plan* assigns each stage its replica count ``k_i``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.profiles import LayerProfile
from repro.core.resources import ResourceType


@dataclasses.dataclass(frozen=True)
class SchedulingPlan:
    """``assignment[l] = t`` — Layer ``l`` runs on resource Type ``t``."""

    assignment: tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "assignment", tuple(int(a) for a in self.assignment))

    @property
    def num_layers(self) -> int:
        return len(self.assignment)

    def stage_boundaries(self) -> list[tuple[int, int, int]]:
        """Fuse consecutive same-type layers: list of (start, end, type)."""
        out: list[tuple[int, int, int]] = []
        start = 0
        for i in range(1, len(self.assignment) + 1):
            if i == len(self.assignment) or self.assignment[i] != self.assignment[start]:
                out.append((start, i, self.assignment[start]))
                start = i
        return out


@dataclasses.dataclass(frozen=True)
class Stage:
    """One pipeline stage: fused consecutive layers on one resource type.

    ``oct``/``odt`` are the stage's aggregate original computation /
    communication times for a ``B_o`` batch on ONE unit of its type
    (paper §4.1): computation sums over the fused layers; communication is
    the boundary activation hand-off plus the per-layer parameter sync.
    """

    index: int
    layer_range: tuple[int, int]
    resource_type: int
    oct: float
    odt: float
    alpha: float
    beta: float


@dataclasses.dataclass(frozen=True)
class ProvisioningPlan:
    """``k[i]`` replicas for stage ``i`` (+ optional PS cores, §5.1)."""

    k: tuple[int, ...]
    ps_cores: int = 0


def build_stages(
    plan: SchedulingPlan,
    profiles: Sequence[LayerProfile],
    fleet: Sequence[ResourceType],
) -> list[Stage]:
    """Fuse layers into stages and aggregate OCT/ODT (paper §4.1)."""
    assert len(profiles) == plan.num_layers
    stages = []
    bounds = plan.stage_boundaries()
    for si, (s, e, t) in enumerate(bounds):
        layers = profiles[s:e]
        oct_ = sum(p.oct[t] for p in layers)
        # Communication = per-layer parameter/gradient sync for every fused
        # layer, plus the activation hand-off to the next stage for the
        # LAST layer only — interior activations stay on-device inside a
        # stage (this is why fusing consecutive layers "reduces the time
        # to transfer data", paper §1).
        odt_ = sum(p.odt_sync[t] for p in layers)
        odt_ += layers[-1].odt_act[t]
        # Amdahl fractions: OCT-weighted average over fused layers.
        w = max(oct_, 1e-30)
        alpha = sum(p.alpha * p.oct[t] for p in layers) / w
        beta = sum(p.beta * p.oct[t] for p in layers) / max(
            sum(p.oct[t] for p in layers), 1e-30
        )
        stages.append(
            Stage(
                index=si, layer_range=(s, e), resource_type=t,
                oct=oct_, odt=odt_, alpha=alpha, beta=beta,
            )
        )
    return stages


@dataclasses.dataclass(frozen=True)
class StageBatch:
    """Stage-level arrays for ``N`` plans at once (batched ``build_stages``).

    All per-stage arrays are ``(N, S)`` where ``S`` is the maximum stage
    count in the batch; slots at or past a plan's ``num_stages[n]`` are
    invalid (``mask`` False, zero oct/odt, type 0).  Per-plan reductions
    over the stage axis must exclude invalid slots.
    """

    rtype: np.ndarray       # (N, S) int — resource type per stage
    oct: np.ndarray         # (N, S) — aggregate OCT per stage
    odt: np.ndarray         # (N, S) — aggregate ODT per stage
    alpha: np.ndarray       # (N, S) — OCT-weighted Amdahl compute fraction
    beta: np.ndarray        # (N, S) — OCT-weighted Amdahl comm fraction
    mask: np.ndarray        # (N, S) bool — valid stage slots
    num_stages: np.ndarray  # (N,) int

    @property
    def batch(self) -> int:
        return self.oct.shape[0]

    @property
    def max_stages(self) -> int:
        return self.oct.shape[1]

    def take(self, idx: np.ndarray) -> "StageBatch":
        """Row subset (used to rescue only the infeasible plans)."""
        return StageBatch(
            rtype=self.rtype[idx], oct=self.oct[idx], odt=self.odt[idx],
            alpha=self.alpha[idx], beta=self.beta[idx], mask=self.mask[idx],
            num_stages=self.num_stages[idx],
        )


def batched_build_stages(
    assignments: np.ndarray,
    profiles: Sequence[LayerProfile],
    fleet: Sequence[ResourceType],
) -> StageBatch:
    """Vectorized :func:`build_stages` over an ``(N, L)`` assignment batch.

    Stage aggregation uses ``np.bincount`` segment sums, which accumulate
    in flat-index (= layer) order — the same left-fold order as the scalar
    ``sum()`` — so per-stage aggregates match the scalar path bit-for-bit.
    """
    A = np.asarray(assignments, dtype=np.int64)
    if A.ndim != 2:
        raise ValueError(f"assignments must be (N, L), got shape {A.shape}")
    N, L = A.shape
    if L != len(profiles):
        raise ValueError(f"{L} layers assigned, {len(profiles)} profiled")

    OCT = np.array([p.oct for p in profiles])        # (L, T)
    SYNC = np.array([p.odt_sync for p in profiles])  # (L, T)
    ACT = np.array([p.odt_act for p in profiles])    # (L, T)
    AL = np.array([p.alpha for p in profiles])       # (L,)
    BE = np.array([p.beta for p in profiles])        # (L,)

    lay = np.arange(L)
    oct_l = OCT[lay, A]                              # (N, L)
    sync_l = SYNC[lay, A]
    act_l = ACT[lay, A]

    change = np.ones((N, L), dtype=bool)
    change[:, 1:] = A[:, 1:] != A[:, :-1]
    sid = np.cumsum(change, axis=1) - 1              # (N, L) stage id per layer
    num_stages = sid[:, -1] + 1
    S = int(num_stages.max())
    flat = (np.arange(N)[:, None] * S + sid).ravel()

    def seg(v: np.ndarray) -> np.ndarray:
        return np.bincount(flat, weights=v.ravel(), minlength=N * S).reshape(N, S)

    oct_s = seg(oct_l)
    # activation hand-off counts only for the last layer of each stage
    is_last = np.ones((N, L), dtype=bool)
    is_last[:, :-1] = change[:, 1:]
    odt_s = seg(sync_l) + seg(np.where(is_last, act_l, 0.0))
    w = np.maximum(oct_s, 1e-30)
    alpha_s = seg(AL[None, :] * oct_l) / w
    beta_s = seg(BE[None, :] * oct_l) / w
    rtype = np.zeros((N, S), dtype=np.int64)
    rtype[np.arange(N)[:, None], sid] = A
    mask = np.arange(S)[None, :] < num_stages[:, None]
    return StageBatch(
        rtype=rtype, oct=oct_s, odt=odt_s, alpha=alpha_s, beta=beta_s,
        mask=mask, num_stages=num_stages,
    )


def type_counts(
    plan: SchedulingPlan, prov: ProvisioningPlan, num_types: int
) -> list[int]:
    """``k_t`` — total units of each type across stages (Formula 7)."""
    counts = [0] * num_types
    for (s, e, t), k in zip(plan.stage_boundaries(), prov.k):
        counts[t] += k
    # PS cores are CPU cores (type 0) in the paper's architecture.
    counts[0] += prov.ps_cores
    return counts
