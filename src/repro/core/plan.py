"""Scheduling & provisioning plans (HeterPS §4.2, §5.1).

A *scheduling plan* assigns each layer to one resource type (the paper's
``Schedule(l, t)`` 0/1 matrix — we store the equivalent dense vector of
type indices).  Consecutive layers on the same type fuse into a *stage*;
a *provisioning plan* assigns each stage its replica count ``k_i``.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.profiles import LayerProfile
from repro.core.resources import ResourceType


@dataclasses.dataclass(frozen=True)
class SchedulingPlan:
    """``assignment[l] = t`` — Layer ``l`` runs on resource Type ``t``."""

    assignment: tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "assignment", tuple(int(a) for a in self.assignment))

    @property
    def num_layers(self) -> int:
        return len(self.assignment)

    def stage_boundaries(self) -> list[tuple[int, int, int]]:
        """Fuse consecutive same-type layers: list of (start, end, type)."""
        out: list[tuple[int, int, int]] = []
        start = 0
        for i in range(1, len(self.assignment) + 1):
            if i == len(self.assignment) or self.assignment[i] != self.assignment[start]:
                out.append((start, i, self.assignment[start]))
                start = i
        return out


@dataclasses.dataclass(frozen=True)
class Stage:
    """One pipeline stage: fused consecutive layers on one resource type.

    ``oct``/``odt`` are the stage's aggregate original computation /
    communication times for a ``B_o`` batch on ONE unit of its type
    (paper §4.1): computation sums over the fused layers; communication is
    the boundary activation hand-off plus the per-layer parameter sync.
    """

    index: int
    layer_range: tuple[int, int]
    resource_type: int
    oct: float
    odt: float
    alpha: float
    beta: float


@dataclasses.dataclass(frozen=True)
class ProvisioningPlan:
    """``k[i]`` replicas for stage ``i`` (+ optional PS cores, §5.1)."""

    k: tuple[int, ...]
    ps_cores: int = 0


def build_stages(
    plan: SchedulingPlan,
    profiles: Sequence[LayerProfile],
    fleet: Sequence[ResourceType],
) -> list[Stage]:
    """Fuse layers into stages and aggregate OCT/ODT (paper §4.1)."""
    assert len(profiles) == plan.num_layers
    stages = []
    bounds = plan.stage_boundaries()
    for si, (s, e, t) in enumerate(bounds):
        layers = profiles[s:e]
        oct_ = sum(p.oct[t] for p in layers)
        # Communication = per-layer parameter/gradient sync for every fused
        # layer, plus the activation hand-off to the next stage for the
        # LAST layer only — interior activations stay on-device inside a
        # stage (this is why fusing consecutive layers "reduces the time
        # to transfer data", paper §1).
        odt_ = sum(p.odt_sync[t] for p in layers)
        odt_ += layers[-1].odt_act[t]
        # Amdahl fractions: OCT-weighted average over fused layers.
        w = max(oct_, 1e-30)
        alpha = sum(p.alpha * p.oct[t] for p in layers) / w
        beta = sum(p.beta * p.oct[t] for p in layers) / max(
            sum(p.oct[t] for p in layers), 1e-30
        )
        stages.append(
            Stage(
                index=si, layer_range=(s, e), resource_type=t,
                oct=oct_, odt=odt_, alpha=alpha, beta=beta,
            )
        )
    return stages


def type_counts(
    plan: SchedulingPlan, prov: ProvisioningPlan, num_types: int
) -> list[int]:
    """``k_t`` — total units of each type across stages (Formula 7)."""
    counts = [0] * num_types
    for (s, e, t), k in zip(plan.stage_boundaries(), prov.k):
        counts[t] += k
    # PS cores are CPU cores (type 0) in the paper's architecture.
    counts[0] += prov.ps_cores
    return counts
