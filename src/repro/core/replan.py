"""Reactive re-planning: the telemetry→scheduler feedback loop.

HeterPS plans once, offline, against analytic profiles — but the fleet
the plan runs on drifts: a PS shard dies, ingest bandwidth collapses,
serve SLOs blow out.  This module closes the circle the obs spine
(PR 7/8) opened: :class:`ReplanController` windows successive
:func:`repro.obs.bridge.snapshot_resources` snapshots into **interval**
rates (:func:`repro.obs.bridge.snapshot_delta` — the registries are
cumulative, so lifetime averages would dilute any mid-run drift),
detects drift against the assumptions the incumbent plan was made
under, and when triggered re-runs the fused RL search
(``scheduler.schedule_many`` with the incumbent as a warm-start anchor)
over profiles **rebuilt from the live fleet** — ``LayerProfile`` bakes
bandwidths in at build time, so measurements only reach the cost model
through :func:`repro.core.profiles.profile_layers` on a re-anchored
``ResourceType`` plus :func:`repro.obs.bridge.apply_measured_odt` on
the sparse layers.

Stability is structural, not tuned:

* **warm start** — the incumbent is an oracle-scored anchor inside the
  search's cost cache, so the candidate is never worse than the plan it
  might replace (under the live profiles both are scored on);
* **switch margin** — the candidate is applied only if its predicted
  cost beats the incumbent's live-profile cost by more than
  ``switch_margin`` (re-planning has a real cost: weight migration,
  cache warmup);
* **hysteresis** — noisy signals (bandwidth drift, SLO p99, queue
  growth) must persist for ``hysteresis_windows`` consecutive windows;
  discrete fleet events (kill/recover) and a *rising edge* of
  ``ps_health.degraded`` fire immediately — a persistently-degraded
  fleet does not re-fire every window;
* **cooldown** — after any replan consideration (applied or not) the
  detector is re-anchored to the window that triggered it and drift
  checks pause for ``cooldown_windows`` windows, so one sustained shift
  produces exactly one replan, not a flap.

The first completed window is a **calibration**: in-process measured
bandwidths differ from the nominal fleet constants by orders of
magnitude, so the controller re-anchors its assumptions (and, with
``calibrate=True``, re-plans once against measured reality) before any
drift detection — otherwise the very first window would always
"drift".  Calibration is reported separately from drift replans.
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Callable, Sequence

from repro.core.cost_model import TrainingJob, plan_cost
from repro.core.plan import SchedulingPlan
from repro.core.profiles import LayerProfile, profile_layers
from repro.core.resources import ResourceType
from repro.obs.bridge import (
    SnapshotDelta,
    apply_measured_odt,
    snapshot_delta,
)

#: layer kinds whose ODT terms come from measured PS traffic
_SPARSE_KINDS = ("embedding", "nce")


@dataclasses.dataclass
class ReplanConfig:
    """Knobs of the reactive loop (defaults favour stability)."""

    #: wall-clock window span for the background loop / time-driven ticks
    window_s: float = 5.0
    #: step-driven mode: complete a window every N ``observe()`` calls
    #: (0 = time-driven via ``window_s``)
    window_steps: int = 0
    #: relative deviation of windowed bandwidth vs the anchored
    #: assumption that counts as drift (0.5 = ±50%)
    bw_tolerance: float = 0.5
    #: windows with less than this much in-flight PS time don't get a
    #: bandwidth verdict (a handful of RPCs is noise, not a rate)
    min_traffic_s: float = 1e-4
    #: serve SLOs — p99 above these (with completions in the window)
    #: counts as drift; 0 disables the check
    ttft_slo_s: float = 0.0
    tpot_slo_s: float = 0.0
    #: queue-depth growth per window that counts as drift; 0 disables
    queue_growth: float = 0.0
    #: consecutive windows a noisy signal must persist before firing
    hysteresis_windows: int = 2
    #: windows to sit out after a replan consideration
    cooldown_windows: int = 3
    #: candidate must beat the incumbent's live cost by this fraction
    switch_margin: float = 0.05
    #: re-plan once on the calibration window (first window with PS
    #: traffic) so the incumbent reflects measured, not nominal, rates
    calibrate: bool = True
    #: minimum window examples before measured ODT is grafted onto the
    #: sparse layers (below this the per-example rates are noise)
    min_examples: int = 1


@dataclasses.dataclass
class Incumbent:
    """The currently-applied plan plus the context it was scored in."""

    assignment: tuple[int, ...]
    cost: float
    profiles: list[LayerProfile]
    fleet: list[ResourceType]

    @property
    def plan(self) -> SchedulingPlan:
        return SchedulingPlan(self.assignment)


class DriftDetector:
    """Classifies one :class:`SnapshotDelta` against anchored assumptions.

    Two signal classes: *edge* signals (fleet lifecycle events, the
    rising edge of ``degraded``) fire on the window they appear in;
    *noisy* signals (bandwidth deviation, SLO p99, queue growth) keep a
    per-reason streak and fire only after ``hysteresis_windows``
    consecutive positive windows.  :meth:`reanchor` resets the bandwidth
    assumptions (and streaks) to a new baseline — called after every
    replan consideration so the same shift cannot re-trigger.
    """

    def __init__(self, config: ReplanConfig, *, ingest_bw: float,
                 net_bw: float):
        self.cfg = config
        self.assumed_ingest = ingest_bw
        self.assumed_net = net_bw
        self._streak: dict[str, int] = {}
        self._was_degraded = False

    def reanchor(self, *, ingest_bw: float | None = None,
                 net_bw: float | None = None) -> None:
        if ingest_bw is not None and ingest_bw > 0:
            self.assumed_ingest = ingest_bw
        if net_bw is not None and net_bw > 0:
            self.assumed_net = net_bw
        self._streak.clear()

    @staticmethod
    def _deviates(measured: float, assumed: float, tol: float) -> bool:
        if measured <= 0 or assumed <= 0:
            return False
        return abs(measured - assumed) / assumed > tol

    def check(self, delta: SnapshotDelta) -> list[str]:
        """Reasons this window counts as drift (empty = steady state)."""
        cfg = self.cfg
        reasons: list[str] = []
        if delta.fleet_events > 0:
            reasons.append("fleet_events")
        if delta.ps_degraded and not self._was_degraded:
            reasons.append("ps_degraded")
        self._was_degraded = delta.ps_degraded

        noisy: list[str] = []
        if (delta.pull_seconds + delta.push_seconds) >= cfg.min_traffic_s:
            if self._deviates(delta.ingest_bw, self.assumed_ingest,
                              cfg.bw_tolerance):
                noisy.append("ingest_bw")
            if self._deviates(delta.net_bw, self.assumed_net,
                              cfg.bw_tolerance):
                noisy.append("net_bw")
        for key, slo in (("ttft", cfg.ttft_slo_s), ("tpot", cfg.tpot_slo_s)):
            snap = getattr(delta, key)
            completed = getattr(delta, f"{key}_completed")
            if slo > 0 and snap and completed > 0 and snap["p99"] > slo:
                noisy.append(f"{key}_slo")
        if cfg.queue_growth > 0 and delta.queue_growth > cfg.queue_growth:
            noisy.append("queue_growth")

        for r in noisy:
            self._streak[r] = self._streak.get(r, 0) + 1
            if self._streak[r] >= cfg.hysteresis_windows:
                reasons.append(r)
        for r in list(self._streak):
            if r not in noisy:
                del self._streak[r]
        return reasons


class AdmissionActuator:
    """AIMD tuning of an :class:`~repro.core.admission.AdmissionPolicy`
    from windowed serve telemetry — the actuation half of the ROADMAP's
    "admission-control policy the scheduler itself tunes".

    Fed one :class:`~repro.obs.bridge.SnapshotDelta` per controller
    window (:meth:`tune`), it classifies the window:

    * **breach** — admitted-request TTFT p99 above ``ttft_slo_s`` (with
      completions in the window, so an idle window can't breach) or any
      in-window deadline timeout.  Response is multiplicative decrease
      of ``queue_bound`` — the primary lever: decode chunks are fixed-
      shape jitted over *all* slots, so TPOT is ~flat in concurrency and
      admitted TTFT is dominated by queued wait, which the queue bound
      caps directly.  After ``concurrency_after`` *consecutive* breach
      windows the queue bound alone is judged insufficient and
      ``max_concurrency`` is also decreased.
    * **healthy** — no breach and the window saw progress (completions
      or deadline-met tokens).  Response is additive increase of both
      knobs back toward their ceilings, reclaiming capacity the next
      burst can use.

    Idle windows (no breach, no progress) leave the knobs alone.  The
    policy's knobs are plain attributes read by the serve loop each
    admission pass, so retuning from the controller thread is a
    single-attribute write — safe under the GIL, effective on the very
    next admission decision.
    """

    def __init__(self, policy, *, ttft_slo_s: float = 0.0,
                 decrease: float = 0.5, increase: int = 1,
                 min_queue_bound: int = 1,
                 max_queue_bound: int | None = None,
                 min_concurrency: int = 1, concurrency_after: int = 2):
        if not 0.0 < decrease < 1.0:
            raise ValueError(f"decrease must be in (0, 1), got {decrease}")
        self.policy = policy
        self.ttft_slo_s = float(ttft_slo_s)
        self.decrease = float(decrease)
        self.increase = int(increase)
        self.min_queue_bound = int(min_queue_bound)
        # an unbounded policy needs a finite ceiling to climb back to
        self.max_queue_bound = (int(max_queue_bound)
                                if max_queue_bound is not None
                                else (policy.queue_bound
                                      if policy.queue_bound is not None
                                      else 8 * policy.slots))
        self.min_concurrency = int(min_concurrency)
        self.concurrency_after = int(concurrency_after)
        self._breach_streak = 0
        self.breaches = 0
        self.decisions: list[dict] = []

    def tune(self, delta) -> dict | None:
        """Apply one window of telemetry; returns the decision applied
        (``None`` for an idle window)."""
        p = self.policy
        ttft_breach = (self.ttft_slo_s > 0.0 and delta.ttft is not None
                       and delta.ttft_completed > 0
                       and delta.ttft["p99"] > self.ttft_slo_s)
        breach = ttft_breach or delta.timed_out > 0
        progressed = delta.completed > 0 or delta.good_tokens > 0
        if not breach and not progressed:
            return None
        qb = p.queue_bound if p.queue_bound is not None \
            else self.max_queue_bound
        mc = p.max_concurrency
        if breach:
            self.breaches += 1
            self._breach_streak += 1
            p.queue_bound = max(self.min_queue_bound,
                                int(qb * self.decrease))
            if self._breach_streak >= self.concurrency_after:
                p.max_concurrency = max(self.min_concurrency,
                                        int(mc * self.decrease))
            action = "decrease"
        else:
            self._breach_streak = 0
            p.queue_bound = min(self.max_queue_bound, qb + self.increase)
            p.max_concurrency = min(p.slots, mc + self.increase)
            action = "increase"
        decision = {
            "action": action,
            "ttft_breach": ttft_breach,
            "timed_out": float(delta.timed_out),
            "queue_bound": (qb, p.queue_bound),
            "max_concurrency": (mc, p.max_concurrency),
            "breach_streak": self._breach_streak,
        }
        self.decisions.append(decision)
        return decision

    def report(self) -> dict:
        return {
            "ttft_slo_s": self.ttft_slo_s,
            "breaches": self.breaches,
            "queue_bound": self.policy.queue_bound,
            "max_concurrency": self.policy.max_concurrency,
            "decisions": list(self.decisions),
        }


class ReplanController:
    """Windows live snapshots, detects drift, re-plans with hysteresis.

    ``layer_specs`` are the raw ``(kind, flops, in_b, w_b, out_b)``
    tuples (``core/profiles.py``) — the controller must rebuild profiles
    per replan because ``LayerProfile`` bakes fleet bandwidths in at
    build time.  ``snapshot_fn`` returns a
    :func:`~repro.obs.bridge.snapshot_resources`-shaped dict; the fleet
    resource at ``base_index`` is the one re-anchored to measured PS
    bandwidths (the CPU/PS side — accelerator constants stay nominal).

    Drive it either way:

    * **step-driven** — call :meth:`observe` once per training step
      (``window_steps > 0`` completes a window every N steps); the
      training loop stays single-threaded and deterministic;
    * **time-driven** — :meth:`start` spawns a daemon thread ticking
      every ``window_s`` seconds (the serve path, where there is no
      step loop to piggyback on).
    """

    def __init__(
        self,
        layer_specs: Sequence[tuple],
        fleet: Sequence[ResourceType],
        job: TrainingJob,
        scheduler,
        *,
        snapshot_fn: Callable[[], dict],
        config: ReplanConfig | None = None,
        base_index: int = 0,
        clock: Callable[[], float] = time.monotonic,
        initial: Sequence[int] | None = None,
        admission: AdmissionActuator | None = None,
    ):
        self.layer_specs = list(layer_specs)
        self.fleet = list(fleet)
        self.job = job
        self.scheduler = scheduler
        self.snapshot_fn = snapshot_fn
        self.cfg = config if config is not None else ReplanConfig()
        self.base_index = base_index
        self.clock = clock
        self.admission = admission

        profiles = profile_layers(self.layer_specs, self.fleet)
        if initial is not None:
            assignment = tuple(int(a) for a in initial)
            cost, _ = plan_cost(SchedulingPlan(assignment), profiles,
                                self.fleet, job)
        else:
            res = self._run_search(profiles, self.fleet, warm=())
            assignment, cost = tuple(res.plan.assignment), res.cost
        self.incumbent = Incumbent(assignment, cost, profiles, self.fleet)

        base = self.fleet[base_index]
        self.detector = DriftDetector(self.cfg, ingest_bw=base.ingest_bw,
                                      net_bw=base.net_bw)

        self._lock = threading.Lock()
        self._prev: dict | None = None
        self._prev_t = 0.0
        self._prev_examples = 0.0
        self._examples = 0.0
        self._steps_since = 0
        self._last_window_t = self.clock()
        self._calibrated = False
        self._cooldown = 0
        self.windows = 0
        self.calibrations = 0
        self.considered = 0
        self.applied = 0
        self.decisions: list[dict] = []
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # --- search plumbing ------------------------------------------------
    def _run_search(self, profiles, fleet, warm):
        """One scheduler invocation, warm-seeded when supported."""
        many = getattr(self.scheduler, "schedule_many", None)
        if many is not None:
            try:
                return many([(profiles, fleet, self.job)],
                            warm_starts=[warm])[0]
            except TypeError:  # scheduler without the warm-start seam
                return many([(profiles, fleet, self.job)])[0]
        return self.scheduler.schedule(profiles, fleet, self.job)

    # --- driving --------------------------------------------------------
    def observe(self, num_examples: float = 0.0,
                snapshot: dict | None = None) -> dict | None:
        """Step-driven entry: account examples, complete a window when
        due (every ``window_steps`` calls, or ``window_s`` seconds when
        ``window_steps == 0``).  Returns the decision dict when a window
        completed with a replan consideration, else ``None``."""
        with self._lock:
            self._examples += num_examples
            self._steps_since += 1
            if self.cfg.window_steps > 0:
                if self._steps_since < self.cfg.window_steps:
                    return None
            elif (self.clock() - self._last_window_t) < self.cfg.window_s:
                return None
            return self._tick_locked(snapshot)

    def tick(self, snapshot: dict | None = None) -> dict | None:
        """Complete a window now (the background loop's entry)."""
        with self._lock:
            return self._tick_locked(snapshot)

    def _tick_locked(self, snapshot: dict | None) -> dict | None:
        snap = snapshot if snapshot is not None else self.snapshot_fn()
        now = self.clock()
        self._steps_since = 0
        self._last_window_t = now
        if self._prev is None:  # first snapshot opens the first window
            self._prev, self._prev_t = snap, now
            self._prev_examples = self._examples
            return None
        delta = snapshot_delta(self._prev, snap, max(now - self._prev_t,
                                                     1e-12))
        window_examples = self._examples - self._prev_examples
        self._prev, self._prev_t = snap, now
        self._prev_examples = self._examples
        self.windows += 1

        if self.admission is not None:
            # admission actuation is per-window and independent of the
            # (hysteresis/cooldown-gated) replan path: overload must be
            # answered on the window it appears in, not two windows later
            self.admission.tune(delta)

        if not self._calibrated:
            if not delta.has_ps_traffic:
                return None  # nothing measured yet; stay uncalibrated
            self._calibrated = True
            self.detector.reanchor(ingest_bw=delta.ingest_bw,
                                   net_bw=delta.net_bw)
            if self.cfg.calibrate:
                return self._replan(delta, window_examples,
                                    kind="calibrate", reasons=["calibrate"])
            return None

        reasons = self.detector.check(delta)
        if self._cooldown > 0:
            self._cooldown -= 1
            return None
        if not reasons:
            return None
        self._cooldown = self.cfg.cooldown_windows
        return self._replan(delta, window_examples, kind="drift",
                            reasons=reasons)

    # --- the replan itself ----------------------------------------------
    def _live_context(self, delta: SnapshotDelta, window_examples: float):
        """(profiles, fleet) rebuilt from this window's measurements."""
        live_fleet = list(self.fleet)
        if delta.has_ps_traffic:
            live_fleet[self.base_index] = delta.resource(
                self.fleet[self.base_index])
        live_profiles = profile_layers(self.layer_specs, live_fleet)
        if delta.has_ps_traffic and window_examples >= self.cfg.min_examples:
            sync, act = delta.embedding_odt(window_examples)
            live_profiles = [
                apply_measured_odt(p, sync, act)
                if p.kind in _SPARSE_KINDS else p
                for p in live_profiles
            ]
        return live_profiles, live_fleet

    def _replan(self, delta: SnapshotDelta, window_examples: float, *,
                kind: str, reasons: list[str]) -> dict:
        live_profiles, live_fleet = self._live_context(delta,
                                                       window_examples)
        inc_cost, _ = plan_cost(self.incumbent.plan, live_profiles,
                                live_fleet, self.job)
        result = self._run_search(live_profiles, live_fleet,
                                  warm=(self.incumbent.assignment,))
        cand = tuple(result.plan.assignment)
        # apply only past the switch margin (or when the incumbent has
        # become outright infeasible under live conditions)
        better = result.feasible and (
            not math.isfinite(inc_cost)
            or result.cost < inc_cost * (1.0 - self.cfg.switch_margin)
        )
        applied = better and cand != self.incumbent.assignment
        decision = {
            "window": self.windows,
            "kind": kind,
            "reasons": list(reasons),
            "incumbent_cost": inc_cost,
            "candidate_cost": result.cost,
            "applied": applied,
            "from": self.incumbent.assignment,
            "to": cand,
        }
        if applied:
            self.incumbent = Incumbent(cand, result.cost, live_profiles,
                                       live_fleet)
        else:
            # keep the plan but re-score it against measured reality, so
            # the next margin test compares like with like
            self.incumbent = Incumbent(self.incumbent.assignment, inc_cost,
                                       live_profiles, live_fleet)
        # either way the window's rates become the new baseline: the
        # *same* shift must not re-trigger after cooldown
        self.detector.reanchor(ingest_bw=delta.ingest_bw,
                               net_bw=delta.net_bw)
        if kind == "calibrate":
            self.calibrations += 1
        else:
            self.considered += 1
            if applied:
                self.applied += 1
        self.decisions.append(decision)
        return decision

    # --- background loop -------------------------------------------------
    def start(self, interval_s: float | None = None) -> None:
        """Spawn the daemon tick loop (serve path)."""
        if self._thread is not None:
            return
        period = interval_s if interval_s is not None else self.cfg.window_s
        self._stop.clear()

        def loop():
            while not self._stop.wait(period):
                try:
                    self.tick()
                except Exception:  # never take the serving loop down
                    pass

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="replan-controller")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    # --- reporting -------------------------------------------------------
    def report(self) -> dict:
        out = {
            "windows": self.windows,
            "calibrations": self.calibrations,
            "considered": self.considered,
            "applied": self.applied,
            "cooldown": self._cooldown,
            "decisions": list(self.decisions),
            "incumbent": {
                "assignment": list(self.incumbent.assignment),
                "cost": self.incumbent.cost,
            },
        }
        if self.admission is not None:
            out["admission"] = self.admission.report()
        return out


def ctr_replan_factory(config: ReplanConfig | None = None, *,
                       scheduler=None, fleet=None, job=None,
                       layer_specs=None, base_index: int = 0):
    """``ps_fleet -> ReplanController`` factory for the CTR-over-PS
    workload — the shape :func:`repro.ps.workload.train_ctr_elastic`'s
    ``replan=`` parameter takes (and what ``launch/train.py --replan``
    builds from its flags).

    Defaults: the paper's CTR-DNN layer specs scheduled over
    ``default_fleet()`` with a small-budget fused :class:`RLScheduler`
    (re-planning runs *inside* the training loop; a 40-round warm-started
    search is enough because the incumbent anchor already bounds the
    result).  Snapshots come from
    :func:`~repro.obs.bridge.snapshot_resources` on the PS fleet's
    telemetry plus its live health.
    """

    def build(ps_fleet) -> ReplanController:
        from repro.core.profiles import ctrdnn_layers
        from repro.core.resources import default_fleet
        from repro.obs.bridge import snapshot_resources

        rfleet = list(fleet) if fleet is not None else default_fleet()
        specs = (list(layer_specs) if layer_specs is not None
                 else ctrdnn_layers())
        j = job if job is not None else TrainingJob()
        sched = scheduler
        if sched is None:
            from repro.core.schedulers.rl import RLScheduler

            sched = RLScheduler(rounds=40, plans_per_round=16,
                                early_stop_rounds=15, chunk_rounds=10)

        def snap() -> dict:
            return snapshot_resources(rfleet[base_index],
                                      telemetry=ps_fleet.telemetry,
                                      fleet=ps_fleet)

        return ReplanController(specs, rfleet, j, sched, snapshot_fn=snap,
                                config=config, base_index=base_index)

    return build
