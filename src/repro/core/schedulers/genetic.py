"""Genetic-algorithm scheduling baseline (HeterPS §6.2, [3])."""

from __future__ import annotations

import random

from repro.core.schedulers.base import CostCache, Scheduler


class GeneticScheduler(Scheduler):
    name = "Genetic"

    def __init__(
        self,
        population: int = 32,
        generations: int = 40,
        mutation_rate: float = 0.08,
        elite: int = 2,
        seed: int = 0,
    ):
        self.population = population
        self.generations = generations
        self.mutation_rate = mutation_rate
        self.elite = elite
        self.seed = seed

    def _search(self, profiles, fleet, job):
        T, L = len(fleet), len(profiles)
        rng = random.Random(self.seed)
        cache = CostCache(profiles, fleet, job)

        pop = [tuple(rng.randrange(T) for _ in range(L)) for _ in range(self.population)]
        # seed with the homogeneous plans (guaranteed-structure anchors)
        pop[: min(T, len(pop))] = [(t,) * L for t in range(min(T, len(pop)))]

        def fitness(ind):
            return cache.soft(ind)  # graded infeasibility (see CostCache)

        for _ in range(self.generations):
            cache.batch_soft(pop)  # score the generation in one pass
            scored = sorted(pop, key=fitness)
            nxt = scored[: self.elite]
            while len(nxt) < self.population:
                # tournament selection
                a = min(rng.sample(scored, 3), key=fitness)
                b = min(rng.sample(scored, 3), key=fitness)
                # one-point crossover
                cut = rng.randrange(1, L) if L > 1 else 0
                child = a[:cut] + b[cut:]
                # mutation
                child = tuple(
                    rng.randrange(T) if rng.random() < self.mutation_rate else g
                    for g in child
                )
                nxt.append(child)
            pop = nxt

        from repro.core.plan import SchedulingPlan

        best, _ = cache.best()
        return SchedulingPlan(best), cache.evaluations, {}
