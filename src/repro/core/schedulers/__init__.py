"""Scheduling methods: HeterPS RL-LSTM + the paper's §6.2 baselines."""

from repro.core.schedulers.base import ScheduleResult, Scheduler
from repro.core.schedulers.bayesian import BayesianScheduler
from repro.core.schedulers.genetic import GeneticScheduler
from repro.core.schedulers.rl import RLScheduler
from repro.core.schedulers.static import (
    BruteForceScheduler,
    CPUOnlyScheduler,
    GPUOnlyScheduler,
    GreedyScheduler,
    HeuristicScheduler,
)

ALL_SCHEDULERS = {
    "RL-LSTM": lambda **kw: RLScheduler(cell="lstm", **kw),
    "RL-RNN": lambda **kw: RLScheduler(cell="rnn", **kw),
    "BO": BayesianScheduler,
    "Genetic": GeneticScheduler,
    "Greedy": GreedyScheduler,
    "CPU": CPUOnlyScheduler,
    "GPU": GPUOnlyScheduler,
    "Heuristic": HeuristicScheduler,
    "BF": BruteForceScheduler,
}

__all__ = [
    "Scheduler", "ScheduleResult", "RLScheduler", "BayesianScheduler",
    "GeneticScheduler", "BruteForceScheduler", "CPUOnlyScheduler",
    "GPUOnlyScheduler", "GreedyScheduler", "HeuristicScheduler",
    "ALL_SCHEDULERS",
]
