"""Bayesian-optimization scheduling baseline (HeterPS §6.2, [10]).

A GP surrogate with a Hamming-distance RBF kernel over the discrete plan
space; expected-improvement acquisition maximized over a random candidate
pool.  The paper notes BO "may add much randomness to the scheduling
process" — visible here as seed-to-seed cost variance.
"""

from __future__ import annotations

import math
import random

import numpy as np

from repro.core.schedulers.base import CostCache, Scheduler


def _hamming_kernel(X: np.ndarray, Y: np.ndarray, ell: float) -> np.ndarray:
    # X: (n, L), Y: (m, L) integer plans
    d = (X[:, None, :] != Y[None, :, :]).mean(-1)
    return np.exp(-d / ell)


class BayesianScheduler(Scheduler):
    name = "BO"

    def __init__(
        self,
        num_iters: int = 48,
        init_random: int = 12,
        candidates: int = 256,
        ell: float = 0.3,
        noise: float = 1e-6,
        seed: int = 0,
    ):
        self.num_iters = num_iters
        self.init_random = init_random
        self.candidates = candidates
        self.ell = ell
        self.noise = noise
        self.seed = seed

    def _search(self, profiles, fleet, job):
        T, L = len(fleet), len(profiles)
        rng = random.Random(self.seed)
        cache = CostCache(profiles, fleet, job)

        X: list[tuple[int, ...]] = []
        y: list[float] = []

        def observe(plan):
            c = cache.soft(plan)  # graded infeasibility (see CostCache)
            X.append(plan)
            y.append(math.log10(c + 1.0))  # log costs: GP-friendlier scale

        init = [tuple(rng.randrange(T) for _ in range(L))
                for _ in range(self.init_random)]
        cache.batch_soft(init)  # score the whole warm-up set in one pass
        for plan in init:
            observe(plan)

        for _ in range(self.num_iters - self.init_random):
            Xa = np.array(X, dtype=np.int64)
            ya = np.array(y)
            mu0, sd0 = ya.mean(), ya.std() + 1e-9
            yn = (ya - mu0) / sd0
            K = _hamming_kernel(Xa, Xa, self.ell) + self.noise * np.eye(len(X))
            Lc = np.linalg.cholesky(K)
            alpha = np.linalg.solve(Lc.T, np.linalg.solve(Lc, yn))

            cands = np.array(
                [[rng.randrange(T) for _ in range(L)] for _ in range(self.candidates)],
                dtype=np.int64,
            )
            Ks = _hamming_kernel(cands, Xa, self.ell)           # (c, n)
            mu = Ks @ alpha
            v = np.linalg.solve(Lc, Ks.T)                        # (n, c)
            var = np.clip(1.0 - (v**2).sum(0), 1e-12, None)
            sd = np.sqrt(var)
            best = yn.min()
            z = (best - mu) / sd
            # expected improvement (minimization)
            ei = sd * (z * _ncdf(z) + _npdf(z))
            pick = tuple(int(g) for g in cands[int(np.argmax(ei))])
            observe(pick)

        from repro.core.plan import SchedulingPlan

        best_plan, _ = cache.best()
        return SchedulingPlan(best_plan), cache.evaluations, {}


def _npdf(z):
    return np.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)


def _ncdf(z):
    from math import erf
    return 0.5 * (1.0 + np.vectorize(erf)(z / math.sqrt(2)))
