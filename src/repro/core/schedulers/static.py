"""Static / heuristic baselines (HeterPS §6.2): CPU, GPU, Heuristic, BF, Greedy."""

from __future__ import annotations

import itertools
import math
from typing import Sequence

import numpy as np

from repro.core.cost_model import TrainingJob
from repro.core.plan import SchedulingPlan
from repro.core.profiles import LayerProfile
from repro.core.resources import ResourceType
from repro.core.schedulers.base import CostCache, Scheduler


class CPUOnlyScheduler(Scheduler):
    """All layers on CPU (type 0)."""

    name = "CPU"

    def _search(self, profiles, fleet, job):
        return SchedulingPlan((0,) * len(profiles)), 1, {}


class GPUOnlyScheduler(Scheduler):
    """All layers on one accelerator type (the cheapest feasible one)."""

    name = "GPU"

    def _search(self, profiles, fleet, job):
        cache = CostCache(profiles, fleet, job)
        plans = [(t,) * len(profiles) for t in range(1, len(fleet))]
        costs = cache.batch_call(plans)
        best_t = 1
        if np.isfinite(costs).any():
            best_t = 1 + int(np.argmin(costs))
        return SchedulingPlan((best_t,) * len(profiles)), cache.evaluations, {}


class HeuristicScheduler(Scheduler):
    """AIBox/BytePS-style static rule (§1, [61]): the first (embedding,
    data-intensive) layer goes to CPUs, every other layer to GPUs."""

    name = "Heuristic"

    def _search(self, profiles, fleet, job):
        assignment = [0 if p.kind in ("embedding",) or p.index == 0 else 1
                      for p in profiles]
        return SchedulingPlan(tuple(assignment)), 1, {}


class BruteForceScheduler(Scheduler):
    """Exhaustive enumeration of all ``T^L`` plans — optimal but exponential
    (paper Table 2).  ``max_evals`` aborts overlong searches; the search is
    exact whenever ``T**L <= max_evals``."""

    name = "BF"

    def __init__(self, max_evals: int = 2_000_000, chunk: int = 4096):
        self.max_evals = max_evals
        self.chunk = chunk

    def _search(self, profiles, fleet, job):
        T, L = len(fleet), len(profiles)
        cache = CostCache(profiles, fleet, job)
        n = 0
        batch: list[tuple[int, ...]] = []
        for assignment in itertools.product(range(T), repeat=L):
            batch.append(assignment)
            n += 1
            if len(batch) >= self.chunk:
                cache.batch_call(batch)
                batch.clear()
            if n >= self.max_evals:
                break
        if batch:
            cache.batch_call(batch)
        best, _ = cache.best()
        return SchedulingPlan(best), cache.evaluations, {"exhaustive": T**L <= self.max_evals}


class GreedyScheduler(Scheduler):
    """Sequential greedy (§2.2 [51]): scan layers in order; for each layer
    pick the type minimizing the cost of the partial plan (suffix filled
    with the per-layer locally-cheapest type).  Falls into local optima —
    the paper's criticism."""

    name = "Greedy"

    def _search(self, profiles, fleet, job):
        T, L = len(fleet), len(profiles)
        cache = CostCache(profiles, fleet, job)

        # local (single-layer standalone) preference used to fill the suffix
        def local_best(p: LayerProfile) -> int:
            # cheapest type by single-unit cost rate for this layer alone
            return min(
                range(T),
                key=lambda t: (p.oct[t] + p.odt[t]) * fleet[t].price_per_sec
                * max(1.0, 1.0),
            )

        suffix = [local_best(p) for p in profiles]
        chosen: list[int] = []
        for l in range(L):
            cands = [tuple(chosen) + (t,) + tuple(suffix[l + 1:])
                     for t in range(T)]
            costs = cache.batch_call(cands)  # all T candidates in one pass
            if np.isfinite(costs).any():
                best_t = int(np.argmin(costs))
            else:
                best_t = suffix[l]
            chosen.append(best_t)
        plan = tuple(chosen)
        if not math.isfinite(cache(plan)):
            best, _ = cache.best()
            plan = best
        return SchedulingPlan(plan), cache.evaluations, {}
