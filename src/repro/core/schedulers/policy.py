"""Policy networks for RL scheduling (HeterPS §5.2, Fig. 3) — pure JAX.

The LSTM reads one layer per step.  Step ``l``'s input is the layer's five
features (Fig. 3: one-hot index, one-hot layer type, input size, weight
size, communication time) concatenated with the one-hot of the previous
action — this gives the autoregressive conditioning
``P(a_l | a_{(l-1):1}; θ)`` of Formula 14.  The per-step output is a
``T``-way softmax over resource types.

An Elman RNN cell with the same interface implements the paper's RL-RNN
baseline (which "suffers from the vanishing gradients problem", §6.2).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.profiles import LAYER_KINDS, LayerProfile

MAX_LAYERS = 64  # one-hot index capacity (paper models have <= 20 layers)


def layer_features(profiles: Sequence[LayerProfile]) -> np.ndarray:
    """(L, F) feature matrix — the five Fig.-3 features per layer."""
    L = len(profiles)
    kind_ix = {k: i for i, k in enumerate(LAYER_KINDS)}
    feats = np.zeros((L, MAX_LAYERS + len(LAYER_KINDS) + 3), dtype=np.float32)
    for i, p in enumerate(profiles):
        feats[i, min(i, MAX_LAYERS - 1)] = 1.0                       # index
        feats[i, MAX_LAYERS + kind_ix.get(p.kind, 0)] = 1.0          # type
        base = MAX_LAYERS + len(LAYER_KINDS)
        feats[i, base + 0] = math.log1p(p.input_bytes) / 20.0        # input size
        feats[i, base + 1] = math.log1p(p.weight_bytes) / 20.0       # weight size
        feats[i, base + 2] = math.log1p(1e6 * float(np.mean(p.odt))) / 20.0  # comm
    return feats


def init_lstm(key, in_dim: int, hidden: int, num_types: int):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(hidden)
    return {
        "wx": jax.random.uniform(k1, (in_dim, 4 * hidden), minval=-s, maxval=s),
        "wh": jax.random.uniform(k2, (hidden, 4 * hidden), minval=-s, maxval=s),
        "b": jnp.zeros((4 * hidden,)),
        "wo": jax.random.uniform(k3, (hidden, num_types), minval=-s, maxval=s),
        "bo": jnp.zeros((num_types,)),
        "h0": jnp.zeros((hidden,)),
        "c0": jnp.zeros((hidden,)),
    }


def init_rnn(key, in_dim: int, hidden: int, num_types: int):
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(hidden)
    return {
        "wx": jax.random.uniform(k1, (in_dim, hidden), minval=-s, maxval=s),
        "wh": jax.random.uniform(k2, (hidden, hidden), minval=-s, maxval=s),
        "b": jnp.zeros((hidden,)),
        "wo": jax.random.uniform(k3, (hidden, num_types), minval=-s, maxval=s),
        "bo": jnp.zeros((num_types,)),
        "h0": jnp.zeros((hidden,)),
    }


def _lstm_step(params, carry, x):
    h, c = carry
    z = x @ params["wx"] + h @ params["wh"] + params["b"]
    i, f, g, o = jnp.split(z, 4)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f + 1.0), jax.nn.sigmoid(o)
    c = f * c + i * jnp.tanh(g)
    h = o * jnp.tanh(c)
    return (h, c), h


def _rnn_step(params, carry, x):
    (h,) = carry
    h = jnp.tanh(x @ params["wx"] + h @ params["wh"] + params["b"])
    return (h,), h


def _initial_carry(params, cell: str):
    if cell == "lstm":
        return (params["h0"], params["c0"])
    return (params["h0"],)


@partial(jax.jit, static_argnames=("cell", "num_types"))
def sample_plan(params, feats, key, *, cell: str, num_types: int, temperature=1.0):
    """Sample one plan autoregressively; returns (actions, sum log-prob)."""
    step = _lstm_step if cell == "lstm" else _rnn_step

    def body(carry, inp):
        state, prev_a, k = carry
        x = jnp.concatenate([inp, jax.nn.one_hot(prev_a, num_types)])
        state, h = step(params, state, x)
        logits = (h @ params["wo"] + params["bo"]) / temperature
        k, ks = jax.random.split(k)
        a = jax.random.categorical(ks, logits)
        logp = jax.nn.log_softmax(logits)[a]
        return (state, a, k), (a, logp)

    carry = (_initial_carry(params, cell), jnp.int32(0), key)
    _, (actions, logps) = jax.lax.scan(body, carry, feats)
    return actions, logps.sum()


@partial(jax.jit, static_argnames=("cell", "num_types"))
def greedy_plan(params, feats, *, cell: str, num_types: int):
    """Argmax decode — the final scheduling decision (§5.2)."""
    step = _lstm_step if cell == "lstm" else _rnn_step

    def body(carry, inp):
        state, prev_a = carry
        x = jnp.concatenate([inp, jax.nn.one_hot(prev_a, num_types)])
        state, h = step(params, state, x)
        a = jnp.argmax(h @ params["wo"] + params["bo"]).astype(jnp.int32)
        return (state, a), a

    carry = (_initial_carry(params, cell), jnp.int32(0))
    _, actions = jax.lax.scan(body, carry, feats)
    return actions


def plan_logp(params, feats, actions, *, cell: str, num_types: int):
    """Teacher-forced Σ_l log P(a_l | a_{(l-1):1}; θ) (Formula 14)."""
    step = _lstm_step if cell == "lstm" else _rnn_step

    def body(carry, inp):
        state, prev_a = carry
        x, a = inp
        xin = jnp.concatenate([x, jax.nn.one_hot(prev_a, num_types)])
        state, h = step(params, state, xin)
        logits = h @ params["wo"] + params["bo"]
        return (state, a), jax.nn.log_softmax(logits)[a]

    carry = (_initial_carry(params, cell), jnp.int32(0))
    _, logps = jax.lax.scan(body, carry, (feats, actions))
    return logps.sum()


@partial(jax.jit, static_argnames=("cell", "num_types"))
def sample_batch(params, feats, keys, *, cell: str, num_types: int, temperature=1.0):
    return jax.vmap(
        lambda k: sample_plan(
            params, feats, k, cell=cell, num_types=num_types, temperature=temperature
        )
    )(keys)


@partial(jax.jit, static_argnames=("cell", "num_types"))
def reinforce_grad(params, feats, actions_batch, advantages, *, cell, num_types):
    """∇θ of the REINFORCE surrogate (Formula 15): mean over the batch of
    ``advantage · log P(plan)`` — gradient *ascent* direction on reward."""

    def surrogate(p):
        logps = jax.vmap(
            lambda a: plan_logp(p, feats, a, cell=cell, num_types=num_types)
        )(actions_batch)
        return jnp.mean(advantages * logps)

    return jax.grad(surrogate)(params)
