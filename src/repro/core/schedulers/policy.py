"""Policy networks for RL scheduling (HeterPS §5.2, Fig. 3) — pure JAX.

The LSTM reads one layer per step.  Step ``l``'s input is the layer's five
features (Fig. 3: one-hot index, one-hot layer type, input size, weight
size, communication time) concatenated with the one-hot of the previous
action — this gives the autoregressive conditioning
``P(a_l | a_{(l-1):1}; θ)`` of Formula 14.  The per-step output is a
``T``-way softmax over resource types.

An Elman RNN cell with the same interface implements the paper's RL-RNN
baseline (which "suffers from the vanishing gradients problem", §6.2).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.profiles import LAYER_KINDS, LayerProfile

MAX_LAYERS = 64  # one-hot index capacity (paper models have <= 20 layers)


def layer_features(
    profiles: Sequence[LayerProfile],
    *,
    pad_to: int | None = None,
    return_mask: bool = False,
) -> np.ndarray | tuple[np.ndarray, np.ndarray]:
    """(L, F) feature matrix — the five Fig.-3 features per layer.

    ``pad_to`` appends all-zero rows up to a common layer count so several
    models can share one vmapped search; ``return_mask`` additionally
    returns the (pad_to,) bool validity mask those searches need to zero
    padded steps out of log-probs (see ``plan_logp``).

    Models deeper than :data:`MAX_LAYERS` are rejected: the index one-hot
    would silently alias every layer past slot ``MAX_LAYERS - 1`` onto one
    column, destroying the autoregressive position signal.  Widen
    ``MAX_LAYERS`` for deeper models.
    """
    L = len(profiles)
    if L > MAX_LAYERS:
        raise ValueError(
            f"{L} layers exceed the policy's index one-hot capacity "
            f"MAX_LAYERS={MAX_LAYERS}; layers {MAX_LAYERS}..{L - 1} would "
            f"alias onto one slot — raise policy.MAX_LAYERS"
        )
    P = pad_to if pad_to is not None else L
    if P < L:
        raise ValueError(f"pad_to={P} < {L} layers")
    kind_ix = {k: i for i, k in enumerate(LAYER_KINDS)}
    feats = np.zeros((P, MAX_LAYERS + len(LAYER_KINDS) + 3), dtype=np.float32)
    for i, p in enumerate(profiles):
        feats[i, i] = 1.0                                            # index
        feats[i, MAX_LAYERS + kind_ix.get(p.kind, 0)] = 1.0          # type
        base = MAX_LAYERS + len(LAYER_KINDS)
        feats[i, base + 0] = math.log1p(p.input_bytes) / 20.0        # input size
        feats[i, base + 1] = math.log1p(p.weight_bytes) / 20.0       # weight size
        feats[i, base + 2] = math.log1p(1e6 * float(np.mean(p.odt))) / 20.0  # comm
    if return_mask:
        return feats, np.arange(P) < L
    return feats


def init_lstm(key, in_dim: int, hidden: int, num_types: int):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(hidden)
    return {
        "wx": jax.random.uniform(k1, (in_dim, 4 * hidden), minval=-s, maxval=s),
        "wh": jax.random.uniform(k2, (hidden, 4 * hidden), minval=-s, maxval=s),
        "b": jnp.zeros((4 * hidden,)),
        "wo": jax.random.uniform(k3, (hidden, num_types), minval=-s, maxval=s),
        "bo": jnp.zeros((num_types,)),
        "h0": jnp.zeros((hidden,)),
        "c0": jnp.zeros((hidden,)),
    }


def init_rnn(key, in_dim: int, hidden: int, num_types: int):
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(hidden)
    return {
        "wx": jax.random.uniform(k1, (in_dim, hidden), minval=-s, maxval=s),
        "wh": jax.random.uniform(k2, (hidden, hidden), minval=-s, maxval=s),
        "b": jnp.zeros((hidden,)),
        "wo": jax.random.uniform(k3, (hidden, num_types), minval=-s, maxval=s),
        "bo": jnp.zeros((num_types,)),
        "h0": jnp.zeros((hidden,)),
    }


def _lstm_step(params, carry, zx):
    """``zx`` is the step's input contribution ``x @ wx``, precomputed
    outside the scan (see :func:`_input_proj`)."""
    h, c = carry
    z = zx + h @ params["wh"] + params["b"]
    i, f, g, o = jnp.split(z, 4)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f + 1.0), jax.nn.sigmoid(o)
    c = f * c + i * jnp.tanh(g)
    h = o * jnp.tanh(c)
    return (h, c), h


def _rnn_step(params, carry, zx):
    (h,) = carry
    h = jnp.tanh(zx + h @ params["wh"] + params["b"])
    return (h,), h


def _input_proj(params, feats):
    """Hoist the input matmul out of the recurrence.

    Step ``l``'s input is ``concat(feats[l], one_hot(prev_a))``; its
    contribution to the pre-activation is ``feats[l] @ wx_f + wx_a[prev_a]``
    where ``wx_f``/``wx_a`` split ``wx``'s rows.  The feature half is the
    same for every step of every sampled plan, so it is computed once as
    one (L, 4H) matmul; the action half is a single row gather inside the
    scan (the one-hot picks exactly one row).  Returns ``(xf, wx_a)``.
    """
    F = feats.shape[1]
    return feats @ params["wx"][:F], params["wx"][F:]


def _initial_carry(params, cell: str):
    if cell == "lstm":
        return (params["h0"], params["c0"])
    return (params["h0"],)


def _step_mask(feats, mask):
    """(L,) float validity weights for padded layer rows (1.0 = real).

    Explicit ``feats.dtype`` keeps policy math in float32 even when the
    caller traces under ``jax.experimental.enable_x64()`` (the fused
    search runs its cost side in f64 but the policy side must stay f32 to
    match the unfused per-round path).
    """
    if mask is None:
        return jnp.ones(feats.shape[0], dtype=feats.dtype)
    return mask.astype(feats.dtype)


@partial(jax.jit, static_argnames=("cell", "num_types"))
def sample_plan(params, feats, key, *, cell: str, num_types: int,
                temperature=1.0, mask=None):
    """Sample one plan autoregressively; returns (actions, sum log-prob).

    ``temperature`` flattens the *sampling* distribution only; the
    returned log-prob is the plan's log-probability under the untempered
    policy — the quantity Formula 15's gradient differentiates (it equals
    the sampling log-prob when ``temperature == 1``).  This lets the fused
    search take the REINFORCE gradient by ``jax.vjp`` straight through
    this pass instead of re-running a teacher-forced one.

    ``mask`` (optional, (L,) bool) marks real layer rows; padded rows still
    sample an action (keeping the RNG stream independent of padding) but
    contribute zero log-prob.
    """
    step = _lstm_step if cell == "lstm" else _rnn_step
    xf, wx_a = _input_proj(params, feats)

    def body(carry, inp):
        state, prev_a, k = carry
        zf, m = inp
        state, h = step(params, state, zf + wx_a[prev_a])
        logits = h @ params["wo"] + params["bo"]
        k, ks = jax.random.split(k)
        # int32-explicit: under x64 tracing, categorical would return int64
        # and break the scan carry's dtype against the int32 initial action
        a = jax.random.categorical(ks, logits / temperature).astype(jnp.int32)
        logp = jax.nn.log_softmax(logits)[a] * m
        return (state, a, k), (a, logp)

    carry = (_initial_carry(params, cell), jnp.int32(0), key)
    _, (actions, logps) = jax.lax.scan(
        body, carry, (xf, _step_mask(feats, mask))
    )
    return actions, logps.sum()


@partial(jax.jit, static_argnames=("cell", "num_types"))
def greedy_plan(params, feats, *, cell: str, num_types: int):
    """Argmax decode — the final scheduling decision (§5.2).

    Callers with padded feature rows truncate the decoded actions to the
    real layer count (padding sits at the end, so real steps are
    unaffected by it).
    """
    step = _lstm_step if cell == "lstm" else _rnn_step
    xf, wx_a = _input_proj(params, feats)

    def body(carry, zf):
        state, prev_a = carry
        state, h = step(params, state, zf + wx_a[prev_a])
        a = jnp.argmax(h @ params["wo"] + params["bo"]).astype(jnp.int32)
        return (state, a), a

    carry = (_initial_carry(params, cell), jnp.int32(0))
    _, actions = jax.lax.scan(body, carry, xf)
    return actions


def plan_logp(params, feats, actions, *, cell: str, num_types: int, mask=None):
    """Teacher-forced Σ_l log P(a_l | a_{(l-1):1}; θ) (Formula 14).

    Padded rows (``mask`` False) are zero-weighted out of the sum.  Uses
    the same hoisted input projection as :func:`sample_plan`, so the two
    produce bit-identical log-probs for the same action sequence.
    """
    step = _lstm_step if cell == "lstm" else _rnn_step
    xf, wx_a = _input_proj(params, feats)

    def body(carry, inp):
        state, prev_a = carry
        zf, a, m = inp
        state, h = step(params, state, zf + wx_a[prev_a])
        logits = h @ params["wo"] + params["bo"]
        return (state, a), jax.nn.log_softmax(logits)[a] * m

    carry = (_initial_carry(params, cell), jnp.int32(0))
    _, logps = jax.lax.scan(
        body, carry, (xf, actions, _step_mask(feats, mask))
    )
    return logps.sum()


@partial(jax.jit, static_argnames=("cell", "num_types"))
def sample_batch(params, feats, keys, *, cell: str, num_types: int,
                 temperature=1.0, mask=None):
    return jax.vmap(
        lambda k: sample_plan(
            params, feats, k, cell=cell, num_types=num_types,
            temperature=temperature, mask=mask,
        )
    )(keys)


@partial(jax.jit, static_argnames=("cell", "num_types"))
def reinforce_grad(params, feats, actions_batch, advantages, *, cell,
                   num_types, mask=None):
    """∇θ of the REINFORCE surrogate (Formula 15): mean over the batch of
    ``advantage · log P(plan)`` — gradient *ascent* direction on reward."""

    def surrogate(p):
        logps = jax.vmap(
            lambda a: plan_logp(p, feats, a, cell=cell, num_types=num_types,
                                mask=mask)
        )(actions_batch)
        return jnp.mean(advantages * logps)

    return jax.grad(surrogate)(params)
