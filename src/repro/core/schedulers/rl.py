"""Reinforcement-learning scheduler (HeterPS §5.2, Algorithm 1).

REINFORCE (Williams) over the LSTM policy of ``policy.py``:

* each round samples ``N`` scheduling plans from the current policy;
* each plan's reward is the (negated, log-scaled) monetary cost from the
  cost model, with the provisioning module invoked inside the evaluation
  (Algorithm 1 Line 5 — ``R_n ← Cost(SP)``);
* a moving-average baseline ``b ← (1-γ)·b + γ/N·ΣR_n`` reduces variance
  (Formula 15, Line 8);
* parameters update by gradient ascent (Formula 16) — we use Adam rather
  than plain SGD for round-count economy (noted deviation; plain SGD is
  available via ``optimizer="sgd"``).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedulers import policy as pol
from repro.core.schedulers.base import CostCache, Scheduler


def _adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    m, v, t = state
    t = t + 1
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mh = jax.tree.map(lambda a: a / (1 - b1**t), m)
    vh = jax.tree.map(lambda a: a / (1 - b2**t), v)
    # ASCENT: reward gradients point uphill
    new = jax.tree.map(lambda p, a, b: p + lr * a / (jnp.sqrt(b) + eps), params, mh, vh)
    return new, (m, v, t)


class RLScheduler(Scheduler):
    """``cell="lstm"`` is HeterPS; ``cell="rnn"`` is the RL-RNN baseline."""

    def __init__(
        self,
        cell: str = "lstm",
        hidden: int = 64,
        rounds: int = 150,
        plans_per_round: int = 32,
        lr: float = 0.03,
        gamma: float = 0.3,
        temperature: float = 2.0,
        optimizer: str = "adam",
        seed: int = 0,
        early_stop_rounds: int = 50,
    ):
        assert cell in ("lstm", "rnn")
        self.cell = cell
        self.name = "RL-LSTM" if cell == "lstm" else "RL-RNN"
        self.hidden = hidden
        self.rounds = rounds
        self.plans_per_round = plans_per_round
        self.lr = lr
        self.gamma = gamma
        self.temperature = temperature
        self.optimizer = optimizer
        self.seed = seed
        self.early_stop_rounds = early_stop_rounds

    def _search(self, profiles, fleet, job):
        T, L = len(fleet), len(profiles)
        feats = jnp.asarray(pol.layer_features(profiles))
        in_dim = feats.shape[1] + T
        key = jax.random.PRNGKey(self.seed)
        key, kinit = jax.random.split(key)
        init = pol.init_lstm if self.cell == "lstm" else pol.init_rnn
        params = init(kinit, in_dim, self.hidden, T)
        opt_state = (
            jax.tree.map(jnp.zeros_like, params),
            jax.tree.map(jnp.zeros_like, params),
            0,
        )

        cache = CostCache(profiles, fleet, job)
        # Warm-start anchors (beyond-paper, DESIGN.md): the homogeneous
        # plans (Algorithm 1 "may also generate a homogeneous scheduling
        # plan") and the AIBox heuristic (data-intensive layers → type 0).
        # The final plan is best-of(search ∪ anchors), so RL never returns
        # worse than the static heuristics it subsumes.
        anchors = [(t,) * L for t in range(T)]
        if T > 1:
            anchors.append(tuple(
                0 if p.kind in ("embedding", "nce") else 1 for p in profiles
            ))
        cache.batch_call(anchors)
        b = 0.0  # moving-average baseline (Algorithm 1, Line 1)
        b_init = False
        best_cost, best_since = float("inf"), 0
        history = []

        for rnd in range(self.rounds):
            key, ks = jax.random.split(key)
            keys = jax.random.split(ks, self.plans_per_round)
            actions, _ = pol.sample_batch(
                params, feats, keys, cell=self.cell, num_types=T,
                temperature=self.temperature,
            )
            actions = np.asarray(actions)
            # graded surrogate: infeasible plans get finite costs ordered
            # by violation — keeps the REINFORCE signal alive even when a
            # whole round samples infeasible plans (see soft_plan_cost);
            # the whole round is scored in one vectorized pass
            costs = cache.batch_soft(actions)
            # reward: negative log-cost — scale-free across models/fleets
            rewards = -np.log10(costs + 1e-12)

            if not b_init:
                b, b_init = float(rewards.mean()), True
            adv = jnp.asarray(rewards - b, dtype=jnp.float32)
            grads = pol.reinforce_grad(
                params, feats, jnp.asarray(actions), adv,
                cell=self.cell, num_types=T,
            )
            if self.optimizer == "adam":
                params, opt_state = _adam_update(params, grads, opt_state, self.lr)
            else:
                params = jax.tree.map(lambda p, g: p + self.lr * g, params, grads)
            # Line 8: moving-average baseline update
            b = (1 - self.gamma) * b + self.gamma * float(rewards.mean())

            round_best = float(np.min(costs))
            history.append(round_best)
            if round_best < best_cost - 1e-12:
                best_cost, best_since = round_best, 0
            else:
                best_since += 1
            if best_since >= self.early_stop_rounds:
                break

        # Final decision: argmax decode (§5.2) — but never return something
        # worse than the best plan seen during the search.
        greedy = tuple(
            int(a)
            for a in np.asarray(
                pol.greedy_plan(params, feats, cell=self.cell, num_types=T)
            )
        )
        greedy_cost = cache(greedy)
        best_seen, best_seen_cost = cache.best()
        plan = greedy if greedy_cost <= best_seen_cost else best_seen

        from repro.core.plan import SchedulingPlan

        return (
            SchedulingPlan(plan),
            cache.evaluations,
            {"rounds": rnd + 1, "history": history, "greedy_cost": greedy_cost},
        )
