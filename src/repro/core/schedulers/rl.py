"""Reinforcement-learning scheduler (HeterPS §5.2, Algorithm 1).

REINFORCE (Williams) over the LSTM policy of ``policy.py``:

* each round samples ``N`` scheduling plans from the current policy;
* each plan's reward is the (negated, log-scaled) monetary cost from the
  cost model, with the provisioning module invoked inside the evaluation
  (Algorithm 1 Line 5 — ``R_n ← Cost(SP)``);
* a moving-average baseline ``b ← (1-γ)·b + γ/N·ΣR_n`` reduces variance
  (Formula 15, Line 8);
* parameters update by gradient ascent (Formula 16) — we use Adam rather
  than plain SGD for round-count economy (noted deviation; plain SGD is
  available via ``optimizer="sgd"``).

Two implementations of the search loop:

* **fused** (default): sample → soft-cost reward (``jax_cost``) →
  baseline/advantage → ``reinforce_grad`` → optimizer step is ONE jitted
  program, ``lax.scan``-ned over chunks of rounds; the host only harvests
  per-round history, back-fills the :class:`CostCache` memo
  (``seed_from_device``) and checks early stopping *between* chunks.
  ``schedule_many`` additionally ``vmap``s the whole search across several
  models (layer features padded to a common length, see DESIGN.md).
  Runs its cost side under ``jax.experimental.enable_x64()`` so rewards
  agree with the NumPy oracle to ~1e-9 while policy math stays float32.
* **unfused** (``fused=False``): the original per-round Python loop — one
  device round-trip per round, NumPy ``batched_soft_plan_cost`` scoring.
  Kept as the oracle the fused path is equivalence-tested against and as
  the baseline for the ``bench_table3`` speedup gate.
"""

from __future__ import annotations

import math
import time
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jax_cost
from repro.core.cost_model import plan_cost
from repro.core.plan import SchedulingPlan
from repro.core.schedulers import policy as pol
from repro.core.schedulers.base import CostCache, ScheduleResult, Scheduler


def _adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    m, v, t = state
    t = t + 1  # python int in the unfused loop, traced int32 in the scan
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    # float32-explicit bias corrections: identical math whether t is a
    # python int or a traced scalar, and no f64 promotion under x64
    c1 = 1.0 - jnp.float32(b1) ** t
    c2 = 1.0 - jnp.float32(b2) ** t
    mh = jax.tree.map(lambda a: a / c1, m)
    vh = jax.tree.map(lambda a: a / c2, v)
    # ASCENT: reward gradients point uphill
    new = jax.tree.map(lambda p, a, b: p + lr * a / (jnp.sqrt(b) + eps), params, mh, vh)
    return new, (m, v, t)


# --- fused search kernel -----------------------------------------------------

_STATIC = ("cell", "num_types", "optimizer", "plans", "early_stop")


@partial(jax.jit, static_argnames=("c",))
def _round_keys(key, c: int):
    """The unfused loop's per-round key stream, batched: replays
    ``key, ks = split(key)`` ``c`` times in one dispatch."""

    def body(k, _):
        k, ks = jax.random.split(k)
        return k, ks

    return jax.lax.scan(body, key, None, length=c)


def _chunk_scan(carry, rks, feats, mask, ct, lr, gamma, temperature,
                *, cell, num_types, optimizer, plans, early_stop):
    """``C = len(rks)`` fused REINFORCE rounds for one model.

    One round = sample ``plans`` plans → soft cost on device → advantage →
    REINFORCE gradient → optimizer step.  Stacks per-round (params,
    actions, soft, feasible, stop) so the host can harvest history and
    back-fill the cost cache; the early-stop bookkeeping (best cost /
    rounds-since-improvement) lives in the scan carry, so ``stop`` is a
    device-computed flag the host only *reads* between chunks — once
    every member of a vmapped group has flagged, the remaining chunks
    are skipped entirely.
    """

    def body(c, _ks):
        params, opt, b, binit, best, since = c
        keys = jax.random.split(_ks, plans)

        # one forward pass both samples the plans and records the vjp of
        # their (untempered) log-probs — the REINFORCE gradient is then a
        # single backward with the advantages as cotangent, with no
        # teacher-forced re-evaluation (Formula 15: ∇ mean(adv · log P))
        def fwd(p):
            actions, logps = pol.sample_batch(
                p, feats, keys, cell=cell, num_types=num_types,
                temperature=temperature, mask=mask,
            )
            return logps, actions

        logps, vjp_fn, actions = jax.vjp(fwd, params, has_aux=True)
        sc = jax_cost.soft_cost(ct, actions)
        rewards = -jnp.log10(sc.soft + 1e-12)
        rmean = jnp.mean(rewards)
        b = jnp.where(binit, b, rmean)              # Line 1: b ← first mean
        binit = jnp.ones_like(binit)
        adv = (rewards - b).astype(jnp.float32)
        (grads,) = vjp_fn(adv / plans)              # d mean(adv·logp) / dθ
        if optimizer == "adam":
            params, opt = _adam_update(params, grads, opt, lr)
        else:
            params = jax.tree.map(lambda p, g: p + lr * g, params, grads)
        b = (1 - gamma) * b + gamma * rmean         # Line 8
        # early-stop counter on device (same math the host loop used to
        # replay: strict improvement beyond 1e-12 resets the clock)
        round_best = jnp.min(sc.soft)
        improved = round_best < best - 1e-12
        since = jnp.where(improved, 0, since + 1)
        best = jnp.where(improved, round_best, best)
        stop = since >= early_stop
        return (params, opt, b, binit, best, since), (
            params, actions, sc.soft, sc.feasible, stop)

    return jax.lax.scan(body, carry, rks)


_chunk_single = partial(jax.jit, static_argnames=_STATIC)(_chunk_scan)


@partial(jax.jit, static_argnames=_STATIC)
def _chunk_multi(carry, rks, feats, mask, ct, lr, gamma, temperature,
                 *, cell, num_types, optimizer, plans, early_stop):
    """vmap of :func:`_chunk_scan` across models; the round-key stream is
    shared (each model sees the same keys a solo run with this seed would)."""
    f = partial(_chunk_scan, cell=cell, num_types=num_types,
                optimizer=optimizer, plans=plans, early_stop=early_stop)
    return jax.vmap(f, in_axes=(0, None, 0, 0, 0, None, None, None))(
        carry, rks, feats, mask, ct, lr, gamma, temperature
    )


class RLScheduler(Scheduler):
    """``cell="lstm"`` is HeterPS; ``cell="rnn"`` is the RL-RNN baseline."""

    def __init__(
        self,
        cell: str = "lstm",
        hidden: int = 64,
        rounds: int = 150,
        plans_per_round: int = 32,
        lr: float = 0.03,
        gamma: float = 0.3,
        temperature: float = 2.0,
        optimizer: str = "adam",
        seed: int = 0,
        early_stop_rounds: int = 50,
        fused: bool = True,
        chunk_rounds: int = 25,
    ):
        assert cell in ("lstm", "rnn")
        self.cell = cell
        self.name = "RL-LSTM" if cell == "lstm" else "RL-RNN"
        self.hidden = hidden
        self.rounds = rounds
        self.plans_per_round = plans_per_round
        self.lr = lr
        self.gamma = gamma
        self.temperature = temperature
        self.optimizer = optimizer
        self.seed = seed
        self.early_stop_rounds = early_stop_rounds
        self.fused = fused
        self.chunk_rounds = chunk_rounds

    # -- shared pieces --------------------------------------------------------

    def _anchored_cache(self, profiles, fleet, job, warm=()) -> CostCache:
        """Cache pre-seeded with the warm-start anchors (beyond-paper,
        DESIGN.md): the homogeneous plans (Algorithm 1 "may also generate
        a homogeneous scheduling plan") and the AIBox heuristic
        (data-intensive layers → type 0).  ``warm`` adds caller-supplied
        assignment vectors — e.g. the re-planner's incumbent plan — to the
        anchor set (malformed entries are ignored).  Anchors are
        oracle-scored here and the final plan is best-of(search ∪
        anchors), so RL never returns worse than the static heuristics it
        subsumes, nor worse than any warm start it was seeded with."""
        T, L = len(fleet), len(profiles)
        cache = CostCache(profiles, fleet, job)
        anchors = [(t,) * L for t in range(T)]
        if T > 1:
            anchors.append(tuple(
                0 if p.kind in ("embedding", "nce") else 1 for p in profiles
            ))
        for w in warm:
            a = tuple(int(x) for x in w)
            if len(a) == L and all(0 <= x < T for x in a):
                anchors.append(a)
        cache.batch_call(anchors)
        return cache

    def _select_plan(self, cache, params, feats, num_layers, T):
        """Final decision: argmax decode (§5.2) — but never return
        something worse than the best plan seen during the search.

        The winner is re-verified against the NumPy oracle before being
        returned: fused-search memo entries are device-scored, and on an
        exact constraint boundary f64 op-reordering can flip feasibility
        between XLA and NumPy.  A disagreement pins the oracle verdict
        into the cache and re-selects, so the anchor guarantee (anchors
        are always oracle-scored) survives.
        """

        ga = pol.greedy_plan(params, feats, cell=self.cell, num_types=T)
        greedy = tuple(int(a) for a in np.asarray(ga)[:num_layers])
        greedy_cost = cache(greedy)
        while True:
            best_seen, best_seen_cost = cache.best()
            plan = greedy if greedy_cost <= best_seen_cost else best_seen
            if not cache.device_seeded:
                break  # every entry is oracle-written: nothing to verify
            oracle_cost, _ = plan_cost(
                SchedulingPlan(plan), cache.profiles, cache.fleet, cache.job
            )
            if math.isfinite(oracle_cost) or not math.isfinite(
                min(greedy_cost, best_seen_cost)
            ):
                break  # oracle agrees, or nothing feasible exists anyway
            cache.pin_true(plan, oracle_cost)
            if plan == greedy:
                greedy_cost = oracle_cost
        return plan, greedy_cost

    # -- search entry points --------------------------------------------------

    def _search(self, profiles, fleet, job):
        if self.fused:
            return self._fused_search([(profiles, fleet, job)])[0]
        return self._search_unfused(profiles, fleet, job)

    def schedule_many(
        self, specs: Sequence[tuple], warm_starts: Sequence | None = None
    ) -> list[ScheduleResult]:
        """Schedule several ``(profiles, fleet, job)`` workloads in one
        vmapped fused search per fleet-size group.

        Models are grouped by resource-type count (vmap needs uniform
        tensor shapes; padding the *type* axis would distort sampling),
        layer features are padded to the group's max layer count with a
        mask, and the entire chunked search runs as one program per group.
        Per-model results are identical in structure to ``schedule()``'s.
        With ``fused=False`` this degrades to a sequential loop.

        ``warm_starts[i]``, when given, is a sequence of assignment
        vectors seeded as oracle-scored anchors for ``specs[i]`` — the
        reactive re-planner passes its incumbent plan here, so the search
        result is structurally never worse than the plan it might replace.
        """

        warms = ([() for _ in specs] if warm_starts is None
                 else [tuple(w) if w else () for w in warm_starts])
        assert len(warms) == len(specs)
        results: dict[int, ScheduleResult] = {}
        if not self.fused:
            for i, (p, f, j) in enumerate(specs):
                t0 = time.perf_counter()
                plan, evals, extra = self._search_unfused(
                    p, f, j, warm=warms[i])
                wall = time.perf_counter() - t0
                cost, prov = plan_cost(plan, p, f, j)
                results[i] = ScheduleResult(
                    plan=plan, prov=prov, cost=cost, wall_time_s=wall,
                    evaluations=evals, extra=extra,
                )
            return [results[i] for i in range(len(specs))]
        groups: dict[int, list[int]] = {}
        for i, (_, fleet, _) in enumerate(specs):
            groups.setdefault(len(fleet), []).append(i)
        for idxs in groups.values():
            t0 = time.perf_counter()
            outs = self._fused_search([specs[i] for i in idxs],
                                      warm_starts=[warms[i] for i in idxs])
            wall = time.perf_counter() - t0
            for i, (plan, evals, extra) in zip(idxs, outs):
                profiles, fleet, job = specs[i]
                cost, prov = plan_cost(plan, profiles, fleet, job)
                results[i] = ScheduleResult(
                    plan=plan, prov=prov, cost=cost, wall_time_s=wall,
                    evaluations=evals, extra=extra,
                )
        return [results[i] for i in range(len(specs))]

    # -- fused implementation -------------------------------------------------

    def _fused_search(self, specs, warm_starts=None):
        """Chunked-scan REINFORCE for one or more same-fleet-size models.

        Returns ``[(plan, evaluations, extra), ...]`` aligned with
        ``specs``.  See the module docstring and DESIGN.md for the
        host/device split.
        """
        M = len(specs)
        T = len(specs[0][1])
        assert all(len(f) == T for _, f, _ in specs), "group by fleet size"
        Lmax = max(len(p) for p, _, _ in specs)
        num_layers = [len(p) for p, _, _ in specs]
        warms = warm_starts if warm_starts is not None else [()] * M
        caches = [self._anchored_cache(p, f, j, warm=w)
                  for (p, f, j), w in zip(specs, warms)]

        # policy init in float32, OUTSIDE the x64 context (matches unfused)
        key = jax.random.PRNGKey(self.seed)
        key, kinit = jax.random.split(key)
        fm = [pol.layer_features(p, pad_to=Lmax, return_mask=True)
              for p, _, _ in specs]
        feats_np = np.stack([f for f, _ in fm])
        mask_np = np.stack([m for _, m in fm])
        in_dim = feats_np.shape[2] + T
        init = pol.init_lstm if self.cell == "lstm" else pol.init_rnn
        params1 = init(kinit, in_dim, self.hidden, T)

        C = max(1, min(self.chunk_rounds, self.rounds))
        histories = [[] for _ in range(M)]
        stopped = [False] * M
        greedy_params = [None] * M  # per-model params at its final round
        chunk_times: list[float] = []

        with jax.experimental.enable_x64():
            feats = jnp.asarray(feats_np)   # float32 (explicit in builder)
            mask = jnp.asarray(mask_np)
            cts = [jax_cost.cost_tensors(p, f, j, pad_to=Lmax)
                   for p, f, j in specs]
            if M == 1:
                ct, feats_a, mask_a = cts[0], feats[0], mask[0]
                stack = lambda x: x  # noqa: E731
                chunk_fn = _chunk_single
            else:
                ct = jax.tree.map(lambda *xs: jnp.stack(xs), *cts)
                feats_a, mask_a = feats, mask
                stack = lambda x: jnp.stack([x] * M)  # noqa: E731
                chunk_fn = _chunk_multi
            params = jax.tree.map(stack, params1)
            opt_state = (
                jax.tree.map(jnp.zeros_like, params),
                jax.tree.map(jnp.zeros_like, params),
                stack(jnp.int32(0)),
            )
            b = stack(jnp.zeros(()))
            binit = stack(jnp.zeros((), bool))
            # device-side early-stop state: best soft cost so far + rounds
            # since the last improvement (the scan emits the stop flag)
            best = stack(jnp.full((), jnp.inf))
            since = stack(jnp.int32(0))
            carry = (params, opt_state, b, binit, best, since)

            rounds_done = 0
            # every chunk runs the full static length C — a shorter final
            # chunk would jit-compile a second program shape, which costs
            # far more than the <=C-1 discarded device rounds; callers
            # that care (bench_table3) pick chunk_rounds dividing rounds
            while rounds_done < self.rounds and not all(stopped):
                key, rks = _round_keys(key, C)
                t0 = time.perf_counter()
                carry, (pstack, acts, softs, feas, stops) = chunk_fn(
                    carry, rks, feats_a, mask_a, ct,
                    self.lr, self.gamma, self.temperature,
                    cell=self.cell, num_types=T, optimizer=self.optimizer,
                    plans=self.plans_per_round,
                    early_stop=self.early_stop_rounds,
                )
                jax.block_until_ready(softs)
                acts_h = np.asarray(acts)
                softs_h = np.asarray(softs)
                feas_h = np.asarray(feas)
                stops_h = np.asarray(stops)
                if M == 1:  # normalize to a leading model axis
                    acts_h, softs_h, feas_h, stops_h = (
                        acts_h[None], softs_h[None], feas_h[None],
                        stops_h[None])

                last_round = min(rounds_done + C, self.rounds) - 1
                for m in range(M):
                    if stopped[m]:
                        continue
                    final_c = last_round - rounds_done
                    for c in range(C):
                        r = rounds_done + c
                        if r >= self.rounds:
                            break
                        caches[m].seed_from_device(
                            acts_h[m, c, :, : num_layers[m]],
                            softs_h[m, c], feas_h[m, c],
                        )
                        histories[m].append(float(softs_h[m, c].min()))
                        # device-computed stop flag: once every group
                        # member has flagged, the while-loop skips the
                        # remaining chunks for this group entirely
                        if stops_h[m, c]:
                            stopped[m], final_c = True, c
                            break
                    # params after this model's final executed round — the
                    # exact parameters the unfused loop would greedy-decode
                    greedy_params[m] = jax.tree.map(
                        (lambda x, mm=m, cc=final_c: x[mm, cc]) if M > 1
                        else (lambda x, cc=final_c: x[cc]),
                        pstack,
                    )
                rounds_done += C
                # per-chunk time includes the host harvest above, so the
                # reported rounds_per_s is end-to-end, not device-only
                chunk_times.append(time.perf_counter() - t0)

        steady = chunk_times[1:]
        compile_s = max(0.0, chunk_times[0] - (min(steady) if steady else 0.0))
        rounds_per_s = (
            (len(steady) * C) / sum(steady) if sum(steady) > 0 else None
        )

        out = []
        for m in range(M):
            plan, greedy_cost = self._select_plan(
                caches[m], greedy_params[m], feats[m] if M > 1 else feats[0],
                num_layers[m], T,
            )

            out.append((
                SchedulingPlan(plan),
                caches[m].evaluations,
                {
                    "rounds": len(histories[m]),
                    "history": histories[m],
                    "greedy_cost": greedy_cost,
                    "fused": True,
                    "vmapped_models": M,
                    "compile_s": compile_s,
                    "rounds_per_s": rounds_per_s,
                },
            ))
        return out

    # -- unfused (per-round NumPy-scored) implementation ----------------------

    def _search_unfused(self, profiles, fleet, job, warm=()):
        T = len(fleet)
        feats = jnp.asarray(pol.layer_features(profiles))
        in_dim = feats.shape[1] + T
        key = jax.random.PRNGKey(self.seed)
        key, kinit = jax.random.split(key)
        init = pol.init_lstm if self.cell == "lstm" else pol.init_rnn
        params = init(kinit, in_dim, self.hidden, T)
        opt_state = (
            jax.tree.map(jnp.zeros_like, params),
            jax.tree.map(jnp.zeros_like, params),
            0,
        )

        cache = self._anchored_cache(profiles, fleet, job, warm=warm)
        b = 0.0  # moving-average baseline (Algorithm 1, Line 1)
        b_init = False
        best_cost, best_since = float("inf"), 0
        history = []

        t_loop = time.perf_counter()
        for rnd in range(self.rounds):
            key, ks = jax.random.split(key)
            keys = jax.random.split(ks, self.plans_per_round)
            actions, _ = pol.sample_batch(
                params, feats, keys, cell=self.cell, num_types=T,
                temperature=self.temperature,
            )
            actions = np.asarray(actions)
            # graded surrogate: infeasible plans get finite costs ordered
            # by violation — keeps the REINFORCE signal alive even when a
            # whole round samples infeasible plans (see soft_plan_cost);
            # the whole round is scored in one vectorized pass
            costs = cache.batch_soft(actions)
            # reward: negative log-cost — scale-free across models/fleets
            rewards = -np.log10(costs + 1e-12)

            if not b_init:
                b, b_init = float(rewards.mean()), True
            adv = jnp.asarray(rewards - b, dtype=jnp.float32)
            grads = pol.reinforce_grad(
                params, feats, jnp.asarray(actions), adv,
                cell=self.cell, num_types=T,
            )
            if self.optimizer == "adam":
                params, opt_state = _adam_update(params, grads, opt_state, self.lr)
            else:
                params = jax.tree.map(lambda p, g: p + self.lr * g, params, grads)
            # Line 8: moving-average baseline update
            b = (1 - self.gamma) * b + self.gamma * float(rewards.mean())

            round_best = float(np.min(costs))
            history.append(round_best)
            if round_best < best_cost - 1e-12:
                best_cost, best_since = round_best, 0
            else:
                best_since += 1
            if best_since >= self.early_stop_rounds:
                break
        t_loop = time.perf_counter() - t_loop

        plan, greedy_cost = self._select_plan(
            cache, params, feats, len(profiles), T
        )

        return (
            SchedulingPlan(plan),
            cache.evaluations,
            {"rounds": rnd + 1, "history": history, "greedy_cost": greedy_cost,
             "fused": False,
             # round-loop throughput only (no anchors/greedy/final eval),
             # directly comparable to the fused path's rounds_per_s
             "rounds_per_s": (rnd + 1) / t_loop if t_loop > 0 else None},
        )
