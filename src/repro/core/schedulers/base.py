"""Scheduler interface + shared evaluation (HeterPS §5.2, §6.2)."""

from __future__ import annotations

import abc
import dataclasses
import math
import time
from typing import Sequence

from repro.core.cost_model import INFEASIBLE, TrainingJob, plan_cost
from repro.core.plan import ProvisioningPlan, SchedulingPlan
from repro.core.profiles import LayerProfile
from repro.core.resources import ResourceType


@dataclasses.dataclass
class ScheduleResult:
    plan: SchedulingPlan
    prov: ProvisioningPlan | None
    cost: float
    wall_time_s: float
    evaluations: int
    extra: dict = dataclasses.field(default_factory=dict)

    @property
    def feasible(self) -> bool:
        return math.isfinite(self.cost)


class Scheduler(abc.ABC):
    """Maps (layer profiles, fleet, job) → a scheduling plan."""

    name: str = "base"

    @abc.abstractmethod
    def _search(
        self,
        profiles: Sequence[LayerProfile],
        fleet: Sequence[ResourceType],
        job: TrainingJob,
    ) -> tuple[SchedulingPlan, int, dict]:
        """Return (best plan, #cost evaluations, extra info)."""

    def schedule(
        self,
        profiles: Sequence[LayerProfile],
        fleet: Sequence[ResourceType],
        job: TrainingJob,
    ) -> ScheduleResult:
        t0 = time.perf_counter()
        plan, evals, extra = self._search(profiles, fleet, job)
        wall = time.perf_counter() - t0
        cost, prov = plan_cost(plan, profiles, fleet, job)
        return ScheduleResult(
            plan=plan, prov=prov, cost=cost, wall_time_s=wall,
            evaluations=evals, extra=extra,
        )


class CostCache:
    """Memoizes ``plan_cost`` across a search (plans repeat a lot in GA/RL).

    ``soft()`` returns the graded surrogate (finite for infeasible plans,
    ordered by violation) used as search reward; ``__call__`` returns the
    true cost (``inf`` when infeasible) used for final plan selection.
    """

    def __init__(self, profiles, fleet, job):
        self.profiles, self.fleet, self.job = profiles, fleet, job
        self._cache: dict[tuple[int, ...], float] = {}
        self._soft: dict[tuple[int, ...], float] = {}
        self.evaluations = 0

    def __call__(self, assignment: Sequence[int]) -> float:
        key = tuple(int(a) for a in assignment)
        if key not in self._cache:
            self.evaluations += 1
            cost, _ = plan_cost(
                SchedulingPlan(key), self.profiles, self.fleet, self.job
            )
            self._cache[key] = cost
        return self._cache[key]

    def soft(self, assignment: Sequence[int]) -> float:
        from repro.core.cost_model import soft_plan_cost

        key = tuple(int(a) for a in assignment)
        if key not in self._soft:
            cost = self(key)
            self._soft[key] = (
                cost if math.isfinite(cost) else soft_plan_cost(
                    SchedulingPlan(key), self.profiles, self.fleet, self.job
                )
            )
        return self._soft[key]

    def best(self) -> tuple[tuple[int, ...], float]:
        feas = {k: v for k, v in self._cache.items() if math.isfinite(v)}
        if not feas:
            k = min(self._cache, key=self._cache.get)
            return k, self._cache[k]
        k = min(feas, key=feas.get)
        return k, feas[k]


def penalized(cost: float, penalty: float) -> float:
    """Finite stand-in for infeasible plans (RL/GA need finite rewards)."""
    return penalty if cost == INFEASIBLE or not math.isfinite(cost) else cost
