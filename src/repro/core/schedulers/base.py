"""Scheduler interface + shared evaluation (HeterPS §5.2, §6.2)."""

from __future__ import annotations

import abc
import dataclasses
import math
import time
from typing import Sequence

import numpy as np

from repro.core.cost_model import (
    INFEASIBLE,
    TrainingJob,
    batched_plan_cost,
    batched_soft_plan_cost,
    plan_cost,
)
from repro.core.plan import ProvisioningPlan, SchedulingPlan
from repro.core.profiles import LayerProfile
from repro.core.resources import ResourceType


@dataclasses.dataclass
class ScheduleResult:
    plan: SchedulingPlan
    prov: ProvisioningPlan | None
    cost: float
    wall_time_s: float
    evaluations: int
    extra: dict = dataclasses.field(default_factory=dict)

    @property
    def feasible(self) -> bool:
        return math.isfinite(self.cost)


class Scheduler(abc.ABC):
    """Maps (layer profiles, fleet, job) → a scheduling plan."""

    name: str = "base"

    @abc.abstractmethod
    def _search(
        self,
        profiles: Sequence[LayerProfile],
        fleet: Sequence[ResourceType],
        job: TrainingJob,
    ) -> tuple[SchedulingPlan, int, dict]:
        """Return (best plan, #cost evaluations, extra info)."""

    def schedule(
        self,
        profiles: Sequence[LayerProfile],
        fleet: Sequence[ResourceType],
        job: TrainingJob,
    ) -> ScheduleResult:
        t0 = time.perf_counter()
        plan, evals, extra = self._search(profiles, fleet, job)
        wall = time.perf_counter() - t0
        cost, prov = plan_cost(plan, profiles, fleet, job)
        return ScheduleResult(
            plan=plan, prov=prov, cost=cost, wall_time_s=wall,
            evaluations=evals, extra=extra,
        )


class CostCache:
    """Memoizes ``plan_cost`` across a search (plans repeat a lot in GA/RL).

    ``soft()``/``batch_soft()`` return the graded surrogate (finite for
    infeasible plans, ordered by violation) used as search reward;
    ``__call__``/``batch_call()`` return the true cost (``inf`` when
    infeasible) used for final plan selection.  Scoring goes through the
    batched cost model (``batched_plan_cost``/``batched_soft_plan_cost``):
    each batch is deduplicated, novel plans are evaluated in one
    vectorized pass, and the true cost + surrogate come out of a single
    shared evaluation (no double provisioning for infeasible plans).
    """

    def __init__(self, profiles, fleet, job):
        self.profiles, self.fleet, self.job = profiles, fleet, job
        self._cache: dict[tuple[int, ...], float] = {}
        self._soft: dict[tuple[int, ...], float] = {}
        self.evaluations = 0
        #: True once seed_from_device wrote device-scored entries — they
        #: match the NumPy oracle only to float tolerance, so final-plan
        #: selection re-verifies the winner when this is set
        self.device_seeded = False

    @staticmethod
    def _keys(assignments) -> list[tuple[int, ...]]:
        return [tuple(int(a) for a in row) for row in assignments]

    def batch_call(self, assignments) -> np.ndarray:
        """True costs for a batch of assignment vectors (dedup + memo)."""
        keys = self._keys(assignments)
        novel = [k for k in dict.fromkeys(keys) if k not in self._cache]
        if novel:
            bc = batched_plan_cost(
                np.asarray(novel, dtype=np.int64),
                self.profiles, self.fleet, self.job,
            )
            self.evaluations += len(novel)
            for k, c in zip(novel, bc.costs):
                self._cache[k] = float(c)
        return np.array([self._cache[k] for k in keys])

    def batch_soft(self, assignments) -> np.ndarray:
        """Graded surrogate costs for a batch (dedup + memo, single pass)."""
        keys = self._keys(assignments)
        need: list[tuple[int, ...]] = []
        for k in dict.fromkeys(keys):
            if k in self._soft:
                continue
            cached = self._cache.get(k)
            if cached is not None and math.isfinite(cached):
                self._soft[k] = cached  # feasible → surrogate == true cost
            else:
                need.append(k)
        if need:
            bc, soft = batched_soft_plan_cost(
                np.asarray(need, dtype=np.int64),
                self.profiles, self.fleet, self.job,
            )
            for k, c, s in zip(need, bc.costs, soft):
                if k not in self._cache:
                    self.evaluations += 1
                    self._cache[k] = float(c)
                self._soft[k] = float(s)
        return np.array([self._soft[k] for k in keys])

    def seed_from_device(
        self, assignments, soft_costs, feasible=None
    ) -> int:
        """Bulk-insert already-computed surrogate costs (fused RL search).

        The fused search scores whole chunks of rounds on device
        (``jax_cost.soft_cost``) and back-fills the memo table once per
        chunk — this is that entry point.  ``soft_costs[i]`` is the graded
        surrogate for ``assignments[i]``; ``feasible[i]``, when given,
        lets the true-cost cache be filled too (feasible ⇒ true == soft,
        infeasible ⇒ true == inf), so ``best()`` sees device-scored plans.

        ``evaluations`` accounting stays exact: each *novel* plan counts
        once, plans already scored (by either path) count zero, and
        existing entries are never overwritten — a plan first evaluated by
        the NumPy oracle keeps its oracle-exact value.  Returns the number
        of novel plans inserted.
        """
        soft = np.asarray(soft_costs, dtype=np.float64)
        novel = 0
        for key, s, f in zip(
            self._keys(assignments),
            soft,
            np.asarray(feasible) if feasible is not None else soft,
        ):
            if key in self._soft:
                continue
            cached = self._cache.get(key)
            if cached is not None:
                # true cost known exactly (e.g. anchors): reuse it for the
                # surrogate when feasible, keep the device value otherwise
                self._soft[key] = cached if math.isfinite(cached) else float(s)
                continue
            novel += 1
            self.evaluations += 1
            self._soft[key] = float(s)
            if feasible is not None:
                self._cache[key] = float(s) if f else INFEASIBLE
                self.device_seeded = True
        return novel

    def __call__(self, assignment: Sequence[int]) -> float:
        key = tuple(int(a) for a in assignment)
        if key not in self._cache:
            self.batch_call([key])
        return self._cache[key]

    def soft(self, assignment: Sequence[int]) -> float:
        key = tuple(int(a) for a in assignment)
        if key not in self._soft:
            self.batch_soft([key])
        return self._soft[key]

    def pin_true(self, assignment: Sequence[int], cost: float) -> None:
        """Overwrite a memo entry with an oracle-computed true cost.

        Unlike :meth:`seed_from_device`, this *does* overwrite: it exists
        for the final-selection path to correct a device-scored entry
        whose feasibility the NumPy oracle disagrees with (possible only
        on exact constraint boundaries, where f64 op-reordering flips a
        comparison).  Does not touch ``evaluations``.
        """
        key = tuple(int(a) for a in assignment)
        self._cache[key] = float(cost)
        if math.isfinite(cost):
            self._soft[key] = float(cost)

    def best(self) -> tuple[tuple[int, ...], float]:
        feas = {k: v for k, v in self._cache.items() if math.isfinite(v)}
        if not feas:
            k = min(self._cache, key=self._cache.get)
            return k, self._cache[k]
        k = min(feas, key=feas.get)
        return k, feas[k]


def penalized(cost: float, penalty: float) -> float:
    """Finite stand-in for infeasible plans (RL/GA need finite rewards)."""
    return penalty if cost == INFEASIBLE or not math.isfinite(cost) else cost
