"""Block-pattern model definitions for the assigned architectures."""

from repro.models.config import ArchConfig, EncoderConfig, LayerSpec
from repro.models.decoder import (
    decode_loop,
    decode_step,
    forward,
    init_cache,
    init_model,
    loss_fn,
    prefill,
)

__all__ = [
    "ArchConfig", "EncoderConfig", "LayerSpec", "decode_loop", "decode_step",
    "forward", "init_cache", "init_model", "loss_fn", "prefill",
]
