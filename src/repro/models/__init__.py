"""Block-pattern model definitions for the assigned architectures."""

from repro.models.config import ArchConfig, EncoderConfig, LayerSpec
from repro.models.decoder import (
    decode_step,
    forward,
    init_cache,
    init_model,
    loss_fn,
)

__all__ = [
    "ArchConfig", "EncoderConfig", "LayerSpec", "decode_step", "forward",
    "init_cache", "init_model", "loss_fn",
]
