"""Unified block-pattern model covering all 10 assigned architectures.

One implementation handles dense / MoE / SSM / hybrid / enc-dec / VLM via
the :class:`~repro.models.config.ArchConfig` pattern.  Repeated pattern
groups are stacked on a leading ``repeats`` axis and executed with
``jax.lax.scan`` (+ ``jax.checkpoint`` remat), keeping HLO size O(pattern)
and activation memory O(depth × layer-input).

Entry points:
  * :func:`init_model`  — parameter pytree
  * :func:`forward`     — full-sequence logits (train / prefill / encoder)
  * :func:`loss_fn`     — token cross-entropy (+ MoE aux loss)
  * :func:`init_cache`  — decode cache (KV / SSM state / RWKV state)
  * :func:`decode_step` — one-token serve step against the cache
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import paged_attention as paged_k
from repro.models.config import ArchConfig, LayerSpec
from repro.parallel import act
from repro.nn import attention as attn_mod
from repro.nn import mamba as mamba_mod
from repro.nn import moe as moe_mod
from repro.nn import rwkv as rwkv_mod
from repro.nn.attention import AttnSpec
from repro.nn.base import (
    cross_entropy_loss,
    layernorm,
    layernorm_init,
    rmsnorm,
    rmsnorm_init,
    softcap,
)

MOE_AUX_COEF = 0.01


def _attn_spec(cfg: ArchConfig, spec: LayerSpec, *, causal=True) -> AttnSpec:
    return AttnSpec(
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        causal=causal, window=spec.window, logit_softcap=spec.logit_softcap,
        rope=spec.rope and cfg.pos_embed == "rope",
        rope_theta=cfg.rope_theta, rope_fraction=spec.rope_fraction,
        qk_norm=spec.qk_norm,
    )


def _norm_init(cfg: ArchConfig, d: int):
    return rmsnorm_init(d) if cfg.norm == "rms" else layernorm_init(d)


def _norm(cfg: ArchConfig, p, x):
    return rmsnorm(x, p) if cfg.norm == "rms" else layernorm(x, p)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _init_layer(key, cfg: ArchConfig, spec: LayerSpec):
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    p: dict[str, Any] = {"norm1": _norm_init(cfg, d)}
    aspec = _attn_spec(cfg, spec)
    if spec.mixer in ("attn", "cross_attn"):
        p["mixer"] = attn_mod.init_attention(keys[0], d, aspec)
    elif spec.mixer == "attn+cross":
        p["mixer"] = attn_mod.init_attention(keys[0], d, aspec)
        p["norm_cross"] = _norm_init(cfg, d)
        p["cross"] = attn_mod.init_attention(keys[1], d, aspec)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba_mod.init_mamba(
            keys[0], d, d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv,
            expand=cfg.mamba_expand,
        )
    elif spec.mixer == "rwkv":
        p["mixer"] = rwkv_mod.init_time_mix(keys[0], d, head_size=cfg.rwkv_head_size)
    else:
        raise ValueError(spec.mixer)

    if spec.ffn != "none":
        p["norm2"] = _norm_init(cfg, d)
    if spec.ffn == "dense":
        p["ffn"] = moe_mod.init_dense_ffn(keys[2], d, cfg.d_ff)
    elif spec.ffn == "moe":
        p["ffn"] = moe_mod.init_moe(keys[2], d, cfg.moe_d_ff or cfg.d_ff,
                                    cfg.moe_experts)
    elif spec.ffn == "channel_mix":
        p["ffn"] = rwkv_mod.init_channel_mix(keys[2], d, cfg.d_ff)
    if spec.post_norm:
        p["norm_post1"] = _norm_init(cfg, d)
        if spec.ffn != "none":
            p["norm_post2"] = _norm_init(cfg, d)
    return p


def init_model(cfg: ArchConfig, key, *, dtype=jnp.float32):
    cfg.validate()
    keys = jax.random.split(key, 8)
    d, vp = cfg.d_model, cfg.padded_vocab
    params: dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (vp, d)) * (1.0 / math.sqrt(d)),
        "final_norm": _norm_init(cfg, d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(keys[1], (d, vp)) * (1.0 / math.sqrt(d))
    if cfg.pos_embed == "learned":
        params["pos"] = jax.random.normal(keys[2], (cfg.max_position, d)) * 0.02

    # stacked pattern blocks: tuple over pattern index, leaves (repeats, …)
    blocks = []
    for j, spec in enumerate(cfg.pattern):
        ks = jax.random.split(jax.random.fold_in(keys[3], j), cfg.repeats)
        blocks.append(jax.vmap(lambda k: _init_layer(k, cfg, spec))(ks))
    params["blocks"] = tuple(blocks)

    if cfg.encoder is not None:
        enc_spec = LayerSpec(mixer="attn", ffn="dense", rope=False)
        ks = jax.random.split(keys[4], cfg.encoder.num_layers)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: _init_layer(k, cfg, enc_spec))(ks),
            "final_norm": _norm_init(cfg, d),
            "pos": jax.random.normal(keys[5], (cfg.encoder.frames, d)) * 0.02,
        }
    params = jax.tree.map(lambda x: x.astype(dtype), params)
    return params


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------


def _cast(p, dtype):
    """Cast float params to the compute dtype (norms etc. recompute in f32
    internally); non-float leaves pass through."""
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        p,
    )


def _ffn_block(cfg, spec: LayerSpec, p, x, *, mode: str = "seq", cache=None):
    """norm2 → ffn → (post-norm) → residual — shared by the train,
    prefill and decode layer bodies.  ``mode``: "seq" (train/forward),
    "prefill" (also emits the rwkv channel-mix shift state), "decode"
    (steps the channel-mix against ``cache``).  Returns
    (x, moe_aux, cache_update)."""
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn == "none":
        return x, aux, {}
    upd: dict[str, Any] = {}
    h = _norm(cfg, p["norm2"], x)
    if spec.ffn == "dense":
        y = moe_mod.dense_ffn(p["ffn"], h)
    elif spec.ffn == "moe":
        y, moe_aux = moe_mod.moe_ffn(p["ffn"], h, top_k=cfg.moe_top_k,
                                     capacity_factor=cfg.moe_capacity_factor,
                                     impl=cfg.moe_impl)
        aux = aux + moe_aux["aux_loss"]
    elif spec.ffn == "channel_mix":
        if mode == "decode":
            y, upd = rwkv_mod.decode_channel_mix(p["ffn"], h, cache)
        else:
            y = rwkv_mod.channel_mix_seq(p["ffn"], h)
            if mode == "prefill":
                upd = {"cm_shift": h[:, -1].astype(jnp.float32)}
    else:
        raise ValueError(spec.ffn)
    if spec.post_norm:
        y = _norm(cfg, p["norm_post2"], y)
    return x + y, aux, upd


def _apply_layer(cfg, spec: LayerSpec, p, x, *, positions, cross_kv=None,
                 causal=True):
    """One layer forward. Returns (x, moe_aux)."""
    p = act.gather_params(_cast(p, x.dtype), cfg)
    aux = jnp.zeros((), jnp.float32)
    h = _norm(cfg, p["norm1"], x)
    aspec = _attn_spec(cfg, spec, causal=causal)
    if spec.mixer == "attn":
        y = attn_mod.attention(p["mixer"], h, aspec, positions=positions)
    elif spec.mixer == "cross_attn":
        kv_pos = jnp.broadcast_to(
            jnp.arange(cross_kv.shape[1], dtype=jnp.int32), cross_kv.shape[:2]
        )
        y = attn_mod.attention(
            p["mixer"], h, aspec, positions=positions,
            kv_x=cross_kv.astype(h.dtype), kv_positions=kv_pos,
        )
    elif spec.mixer == "attn+cross":
        y = attn_mod.attention(p["mixer"], h, aspec, positions=positions)
        if spec.post_norm:
            y = _norm(cfg, p["norm_post1"], y)
        x = x + y
        h = _norm(cfg, p["norm_cross"], x)
        kv_pos = jnp.broadcast_to(
            jnp.arange(cross_kv.shape[1], dtype=jnp.int32), cross_kv.shape[:2]
        )
        y = attn_mod.attention(
            p["cross"], h, aspec, positions=positions,
            kv_x=cross_kv.astype(h.dtype), kv_positions=kv_pos,
        )
    elif spec.mixer == "mamba":
        y = mamba_mod.mamba(p["mixer"], h, d_state=cfg.mamba_d_state,
                            d_conv=cfg.mamba_d_conv)
    elif spec.mixer == "rwkv":
        y = rwkv_mod.time_mix(p["mixer"], h, head_size=cfg.rwkv_head_size)
    else:
        raise ValueError(spec.mixer)
    if spec.post_norm and spec.mixer != "attn+cross":
        y = _norm(cfg, p["norm_post1"], y)
    x = x + y
    x, ffn_aux, _ = _ffn_block(cfg, spec, p, x, mode="seq")
    return x, aux + ffn_aux


def _run_blocks(params, cfg: ArchConfig, x, *, positions, cross_kv=None,
                remat=True):
    """Scan the stacked pattern blocks over ``repeats``."""

    def group(carry, block_slice):
        x, aux = carry
        for j, spec in enumerate(cfg.pattern):
            def layer(p, x, positions, cross_kv, *, _spec=spec):
                return _apply_layer(cfg, _spec, p, x, positions=positions,
                                    cross_kv=cross_kv)

            # per-LAYER remat: backward recomputes one layer at a time, so
            # wide mixer internals (Mamba scan states, MoE buffers) never
            # coexist across the whole pattern group.
            if remat:
                layer = jax.checkpoint(layer)
            x, a = layer(block_slice[j], x, positions, cross_kv)
            x = act.shard_batch_act(x)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(group, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    return x, aux


def _encode(params, cfg: ArchConfig, context, *, remat=True):
    """Whisper-style bidirectional encoder over stub frame embeddings."""
    enc = params["encoder"]
    x = context + enc["pos"][None, : context.shape[1]].astype(context.dtype)
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2]
    )
    spec = LayerSpec(mixer="attn", ffn="dense", rope=False)

    def layer(carry, p):
        y, _ = _apply_layer(cfg, spec, p, carry, positions=positions,
                            causal=False)
        return act.shard_batch_act(y), None

    body = jax.checkpoint(layer) if remat else layer
    x, _ = jax.lax.scan(body, x, enc["blocks"])
    return _norm(cfg, enc["final_norm"], x)


def _hidden(params, cfg: ArchConfig, tokens, *, context=None,
            compute_dtype=jnp.bfloat16, remat=True):
    """Backbone forward up to the final norm. Returns (x (B,S,D), moe_aux)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    x = act.shard_batch_act(x)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos_embed == "learned":
        x = x + params["pos"][:S][None].astype(compute_dtype)

    cross_kv = None
    if cfg.encoder is not None:
        cross_kv = _encode(params, cfg, context.astype(compute_dtype), remat=remat)
    elif cfg.cross_kv_len:
        cross_kv = context.astype(compute_dtype)

    x, aux = _run_blocks(params, cfg, x, positions=positions,
                         cross_kv=cross_kv, remat=remat)
    return _norm(cfg, params["final_norm"], x), aux


def forward(params, cfg: ArchConfig, tokens, *, context=None,
            compute_dtype=jnp.bfloat16, remat=True):
    """tokens: (B, S) int32; context: stub frontend embeddings (B, N, D)
    for audio/vlm archs.  Returns (logits (B, S, padded_vocab), moe_aux)."""
    x, aux = _hidden(params, cfg, tokens, context=context,
                     compute_dtype=compute_dtype, remat=remat)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(compute_dtype)
    logits = act.shard_logits(logits)
    if cfg.final_softcap:
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, aux


#: sequence-chunk length for the loss head: logits materialize one
#: (B, LOSS_CHUNK, vocab) tile at a time (§Perf cycle 3 — the full
#: (B, S, 256k) f32 logits dominated gemma2's HBM bytes)
LOSS_CHUNK = 512


def loss_fn(params, cfg: ArchConfig, batch, *, compute_dtype=jnp.bfloat16,
            remat=True):
    x, aux = _hidden(
        params, cfg, batch["tokens"], context=batch.get("context"),
        compute_dtype=compute_dtype, remat=remat,
    )
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(compute_dtype)
    labels = batch["labels"]
    B, S, _ = x.shape
    C = LOSS_CHUNK if (S % LOSS_CHUNK == 0 and S > LOSS_CHUNK) else S
    nc = S // C

    @jax.checkpoint
    def chunk(carry, inp):
        nll_sum, n = carry
        x_c, y_c = inp                                   # (B,C,D), (B,C)
        logits = x_c @ head
        logits = act.shard_logits(logits)
        if cfg.final_softcap:
            logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
        logits = logits.astype(jnp.float32)
        mask = y_c >= 0
        safe = jnp.maximum(y_c, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll_sum = nll_sum + (((logz - gold) * mask).sum())
        return (nll_sum, n + mask.sum()), None

    xs = (
        jnp.moveaxis(x.reshape(B, nc, C, -1), 1, 0),
        jnp.moveaxis(labels.reshape(B, nc, C), 1, 0),
    )
    (nll, n), _ = jax.lax.scan(
        chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), xs
    )
    loss = nll / jnp.maximum(n, 1)
    if cfg.has_moe:
        loss = loss + MOE_AUX_COEF * aux / cfg.num_layers
    return loss


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------


def _layer_cache(cfg: ArchConfig, spec: LayerSpec, batch: int, cache_len: int,
                 dtype, *, paged_pool: tuple[int, int] | None = None):
    kv = dict(
        n_kv=cfg.n_kv_heads, hd=cfg.head_dim
    )
    c: dict[str, Any] = {}
    if spec.mixer in ("attn", "attn+cross"):
        if paged_pool is not None:
            num_pages, page_size = paged_pool
            c.update(attn_mod.init_paged_kv_cache(
                num_pages, page_size, _attn_spec(cfg, spec), dtype))
        else:
            L = cache_len if spec.window is None else min(cache_len, spec.window)
            c["k"] = jnp.zeros((batch, L, kv["n_kv"], kv["hd"]), dtype)
            c["v"] = jnp.zeros((batch, L, kv["n_kv"], kv["hd"]), dtype)
            c["pos"] = jnp.full((batch, L), -1, jnp.int32)
    if spec.mixer in ("cross_attn", "attn+cross"):
        c["ck"] = jnp.zeros((batch, cfg.cross_kv_len, kv["n_kv"], kv["hd"]), dtype)
        c["cv"] = jnp.zeros((batch, cfg.cross_kv_len, kv["n_kv"], kv["hd"]), dtype)
    if spec.mixer == "mamba":
        c.update(mamba_mod.init_mamba_cache(
            batch, cfg.d_model, d_state=cfg.mamba_d_state,
            d_conv=cfg.mamba_d_conv, expand=cfg.mamba_expand, dtype=dtype,
        ))
    if spec.mixer == "rwkv":
        c.update(rwkv_mod.init_rwkv_cache(batch, cfg.d_model,
                                          head_size=cfg.rwkv_head_size))
    return c


def init_cache(cfg: ArchConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16, *, global_cap: int | None = None,
               page_size: int = 16, num_pages: int | None = None):
    """Decode cache pytree, stacked (repeats, …) per pattern position.

    ``global_cap`` bounds full-attention layers' KV length (used for
    gemma2's global layers at ``long_500k`` — see DESIGN.md).

    With ``cfg.kv_impl == "paged"`` the attention layers share a page
    pool instead of per-sequence ring buffers and the result is a dict
    ``{"layers", "page_table", "length", "active"}``: ``page_table``
    (batch, cache_len/page_size) maps each slot's logical pages to
    physical pool pages (identity-allocated here when ``num_pages``
    covers every slot — the continuous-batching serve loop overrides it
    from a host :class:`~repro.kernels.PagePool`), ``length`` carries
    per-sequence positions (ragged decode), and ``active`` masks live
    slots.  ``num_pages`` below full coverage *oversubscribes* the pool
    (admission control happens on the host)."""
    paged = cfg.kv_impl == "paged"
    pages_per_seq = -(-cache_len // page_size)
    if paged and num_pages is None:
        num_pages = 1 + batch * pages_per_seq
    pool = (num_pages, page_size) if paged else None
    caches = []
    for spec in cfg.pattern:
        L = cache_len
        if global_cap is not None and spec.mixer == "attn" and spec.window is None:
            L = min(L, global_cap)
        one = _layer_cache(cfg, spec, batch, L, dtype, paged_pool=pool)
        caches.append(
            jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (cfg.repeats,) + x.shape),
                one,
            )
        )
    if not paged:
        return tuple(caches)
    if num_pages >= 1 + batch * pages_per_seq:
        # identity allocation: slot b owns pages [1 + b·P, 1 + (b+1)·P)
        table = 1 + jnp.arange(batch * pages_per_seq,
                               dtype=jnp.int32).reshape(batch, pages_per_seq)
    else:
        table = jnp.zeros((batch, pages_per_seq), jnp.int32)  # host-assigned
    return {
        "layers": tuple(caches),
        "page_table": table,
        "length": jnp.zeros((batch,), jnp.int32),
        "active": jnp.ones((batch,), bool),
    }


def _decode_layer(cfg, spec: LayerSpec, p, x, cache, index, *, paged=None):
    """One decode layer.  ``paged = (page_table, q_pos, active)`` routes
    the self-attention through the shared page pool (ragged per-sequence
    positions); ``None`` keeps the dense ring-buffer path (scalar
    ``index``)."""
    p = act.gather_params(_cast(p, x.dtype), cfg)
    aspec = _attn_spec(cfg, spec)
    h = _norm(cfg, p["norm1"], x)
    if spec.mixer == "attn":
        if paged is not None:
            pt, q_pos, active = paged
            y, upd = attn_mod.paged_decode_attention(
                p["mixer"], h, cache, pt, q_pos, aspec, active=active)
            cache = {**cache, **upd}
        else:
            y, cache = attn_mod.decode_attention(p["mixer"], h, cache, index,
                                                 aspec)
    elif spec.mixer == "cross_attn":
        y, _ = attn_mod.decode_attention(
            p["mixer"], h, {"k": cache["ck"], "v": cache["cv"]}, index, aspec,
            cross=True,
        )
    elif spec.mixer == "attn+cross":
        if paged is not None:
            pt, q_pos, active = paged
            y, self_c = attn_mod.paged_decode_attention(
                p["mixer"], h, {k: cache[k] for k in ("kp", "vp")}, pt, q_pos,
                aspec, active=active)
            cross_index = q_pos
        else:
            y, self_c = attn_mod.decode_attention(
                p["mixer"], h, {k: cache[k] for k in ("k", "v", "pos")},
                index, aspec)
            cross_index = index
        x = x + y
        h = _norm(cfg, p["norm_cross"], x)
        y, _ = attn_mod.decode_attention(
            p["cross"], h, {"k": cache["ck"], "v": cache["cv"]}, cross_index,
            aspec, cross=True,
        )
        cache = {**cache, **self_c}
    elif spec.mixer == "mamba":
        y, cache = mamba_mod.decode_mamba(p["mixer"], h, cache,
                                          d_state=cfg.mamba_d_state,
                                          d_conv=cfg.mamba_d_conv)
    elif spec.mixer == "rwkv":
        y, tm = rwkv_mod.decode_time_mix(p["mixer"], h, cache,
                                         head_size=cfg.rwkv_head_size)
        cache = {**cache, **tm}
    if spec.post_norm and spec.mixer != "attn+cross":
        y = _norm(cfg, p["norm_post1"], y)
    x = x + y
    x, _, upd = _ffn_block(cfg, spec, p, x, mode="decode", cache=cache)
    if upd:
        cache = {**cache, **upd}
    return x, cache


def decode_step(params, cfg: ArchConfig, token, cache, index, *,
                compute_dtype=jnp.bfloat16):
    """One serve step: token (B, 1) int32 at position ``index`` (scalar),
    against ``cache``.  Returns (logits (B, 1, padded_vocab), new_cache).

    For a paged cache (``cfg.kv_impl == "paged"``) ``index`` is ignored:
    per-sequence positions come from ``cache["length"]`` (ragged across
    the batch) and only ``cache["active"]`` slots advance — inactive
    slots compute but write the pool's scratch page."""
    paged = isinstance(cache, dict)
    B = token.shape[0]
    x = params["embed"][token].astype(compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    if cfg.pos_embed == "learned":
        if paged:
            x = x + params["pos"][cache["length"]][:, None].astype(compute_dtype)
        else:
            x = x + params["pos"][index][None, None].astype(compute_dtype)

    layers = cache["layers"] if paged else cache
    pctx = (cache["page_table"], cache["length"], cache["active"]) \
        if paged else None
    # Decode unrolls the repeats (python loop): one-token HLO per layer is
    # tiny, and unrolling lets every layer's cache keep its sharding —
    # SPMD handles per-iteration dynamic-slice resharding of scanned cache
    # stacks poorly (involuntary full rematerialization).
    new_stacks = []
    for r in range(cfg.repeats):
        p_r = jax.tree.map(lambda a: a[r], params["blocks"])
        c_r = jax.tree.map(lambda a: a[r], layers)
        new_c = []
        for j, spec in enumerate(cfg.pattern):
            x, cj = _decode_layer(cfg, spec, p_r[j], x, c_r[j], index,
                                  paged=pctx)
            x = act.shard_batch_act(x)
            new_c.append(cj)
        new_stacks.append(tuple(new_c))
    new_layers = jax.tree.map(lambda *xs: jnp.stack(xs), *new_stacks)
    x = _norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(compute_dtype)
    if cfg.final_softcap:
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if paged:
        new_cache = {
            **cache,
            "layers": new_layers,
            "length": cache["length"] + cache["active"].astype(jnp.int32),
        }
    else:
        new_cache = new_layers
    return logits, new_cache


# --------------------------------------------------------------------------
# batched prefill + fused decode loop (the serve hot path)
# --------------------------------------------------------------------------


def _dense_prefill_write(cache, k, v, positions, lengths):
    """Fill a dense ring buffer from a prefilled sequence in one scatter.
    Padded positions (≥ length) keep ``pos = -1`` so decode never attends
    them.  When S exceeds the ring length only the last L tokens are kept
    (uniform lengths assumed in that regime — the windowed ring is what
    makes it correct for every sequence at the same position)."""
    L = cache["k"].shape[1]
    B, S = k.shape[:2]
    if S > L:
        k, v, positions = k[:, -L:], v[:, -L:], positions[:, -L:]
    slots = positions % L
    b_ix = jnp.arange(B, dtype=jnp.int32)[:, None]
    pos = jnp.where(positions < lengths[:, None], positions, -1)
    return {
        "k": cache["k"].at[b_ix, slots].set(k.astype(cache["k"].dtype)),
        "v": cache["v"].at[b_ix, slots].set(v.astype(cache["v"].dtype)),
        "pos": cache["pos"].at[b_ix, slots].set(pos),
    }


def _prefill_layer(cfg, spec: LayerSpec, p, x, cache, positions, lengths,
                   paged):
    """One prefill layer: forward + fill this layer's decode cache."""
    p = act.gather_params(_cast(p, x.dtype), cfg)
    aspec = _attn_spec(cfg, spec)
    h = _norm(cfg, p["norm1"], x)
    if spec.mixer in ("attn", "attn+cross"):
        y, k, v = attn_mod.prefill_attention(p["mixer"], h, aspec,
                                             positions=positions,
                                             lengths=lengths)
        if paged is not None:
            kp, vp = paged_k.paged_write_prefill(
                cache["kp"], cache["vp"], k, v, paged, lengths)
            cache = {**cache, "kp": kp, "vp": vp}
        else:
            cache = {**cache,
                     **_dense_prefill_write(cache, k, v, positions, lengths)}
        if spec.mixer == "attn+cross":
            if spec.post_norm:
                y = _norm(cfg, p["norm_post1"], y)
            x = x + y
            h = _norm(cfg, p["norm_cross"], x)
            y = attn_mod.attention_with_kv(
                p["cross"], h, cache["ck"], cache["cv"], aspec,
                positions=positions)
    elif spec.mixer == "cross_attn":
        y = attn_mod.attention_with_kv(p["mixer"], h, cache["ck"],
                                       cache["cv"], aspec,
                                       positions=positions)
    elif spec.mixer == "mamba":
        y, st = mamba_mod.mamba(p["mixer"], h, d_state=cfg.mamba_d_state,
                                d_conv=cfg.mamba_d_conv, return_state=True)
        cache = {**cache, **st}
    elif spec.mixer == "rwkv":
        y, st = rwkv_mod.time_mix(p["mixer"], h,
                                  head_size=cfg.rwkv_head_size,
                                  return_state=True)
        cache = {**cache, **st}
    else:
        raise ValueError(spec.mixer)
    if spec.post_norm and spec.mixer != "attn+cross":
        y = _norm(cfg, p["norm_post1"], y)
    x = x + y
    x, _, upd = _ffn_block(cfg, spec, p, x, mode="prefill")
    if upd:
        cache = {**cache, **upd}
    return x, cache


def prefill(params, cfg: ArchConfig, tokens, cache, *, lengths=None,
            compute_dtype=jnp.bfloat16):
    """Batched prefill: ONE forward pass that fills the decode cache.

    tokens: (B, S) int32, right-padded when ``lengths (B,)`` is given —
    sample the first generated token from ``logits[b, lengths[b]-1]``.
    Returns (logits (B, S, padded_vocab), cache).

    Attention layers mask padded keys exactly; recurrent mixers (mamba /
    rwkv) fold the whole padded window into their state, so ragged
    ``lengths`` is only safe for attention-family archs — prefill
    recurrent archs at their exact prompt length (the continuous-batching
    serve loop admits per-sequence, unpadded)."""
    paged = isinstance(cache, dict)
    B, S = tokens.shape
    x = params["embed"][tokens].astype(compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos_embed == "learned":
        x = x + params["pos"][:S][None].astype(compute_dtype)
    x = act.shard_batch_act(x)
    lens = (jnp.full((B,), S, jnp.int32) if lengths is None
            else jnp.asarray(lengths, jnp.int32))

    layers = cache["layers"] if paged else cache
    table = cache["page_table"] if paged else None
    new_stacks = []
    for r in range(cfg.repeats):
        p_r = jax.tree.map(lambda a: a[r], params["blocks"])
        c_r = jax.tree.map(lambda a: a[r], layers)
        new_c = []
        for j, spec in enumerate(cfg.pattern):
            x, cj = _prefill_layer(cfg, spec, p_r[j], x, c_r[j], positions,
                                   lens, table)
            x = act.shard_batch_act(x)
            new_c.append(cj)
        new_stacks.append(tuple(new_c))
    new_layers = jax.tree.map(lambda *xs: jnp.stack(xs), *new_stacks)
    x = _norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(compute_dtype)
    if cfg.final_softcap:
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if paged:
        new_cache = {**cache, "layers": new_layers,
                     "length": jnp.where(cache["active"], lens, 0)}
    else:
        new_cache = new_layers
    return logits, new_cache


def sample_logits(logits, key, *, temperature: float = 1.0,
                  top_k: int = 0, top_p: float = 1.0):
    """Sample next tokens from ``logits (..., V)`` → int32 ``(...)``.

    Standard filtered-softmax sampling: logits are divided by
    ``temperature``, truncated to the ``top_k`` highest (0 = off) and to
    the smallest prefix whose probability mass reaches ``top_p``
    (1.0 = off; the argmax token is always kept), then drawn via
    ``jax.random.categorical``.  Filters compose (top-k first, then
    top-p over what survives).  ``temperature``/``top_k``/``top_p`` are
    static — bake them into the jitted caller."""
    V = logits.shape[-1]
    lg = logits.astype(jnp.float32) / jnp.float32(max(temperature, 1e-6))
    if top_k and 0 < top_k < V:
        kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    if top_p < 1.0:
        desc = jnp.sort(lg, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(desc, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep tokens whose preceding cumulative mass is < top_p (the
        # first is always kept: its preceding mass is 0)
        keep = (cum - probs) < top_p
        thresh = jnp.min(jnp.where(keep, desc, jnp.inf), axis=-1,
                         keepdims=True)
        lg = jnp.where(lg < thresh, -jnp.inf, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)


def decode_loop(params, cfg: ArchConfig, token, cache, index, steps: int, *,
                compute_dtype=jnp.bfloat16, key=None,
                temperature: float = 1.0, top_k: int = 0,
                top_p: float = 1.0):
    """``steps`` decode iterations as one ``lax.scan`` program —
    generated tokens accumulate ON DEVICE and transfer once, instead of a
    jit dispatch + host sync per token.

    token: (B, 1) int32 — the first token to feed (it is also the first
    token emitted, matching the serve convention that the argmax of the
    prefill logits is the first generated token).  ``index`` is the
    scalar start position for a dense cache (ignored by paged caches).

    ``key=None`` (default) decodes greedily — bit-identical to the
    pre-sampling loop.  With a PRNG key, each step draws from
    :func:`sample_logits` under ``temperature``/``top_k``/``top_p``
    (static args), splitting the key per step — fixed key ⇒ fixed
    tokens.  Returns (tokens (B, steps), next_token (B, 1), cache)."""
    V = cfg.vocab
    greedy = key is None

    def body(carry, _):
        tok, cache, idx, k = carry
        logits, cache = decode_step(params, cfg, tok, cache, idx,
                                    compute_dtype=compute_dtype)
        if greedy:
            ntok = jnp.argmax(logits[:, :, :V], axis=-1).astype(jnp.int32)
        else:
            k, sub = jax.random.split(k)
            ntok = sample_logits(logits[:, -1, :V], sub,
                                 temperature=temperature, top_k=top_k,
                                 top_p=top_p)[:, None]
        return (ntok, cache, idx + 1, k), tok[:, 0]

    k0 = jax.random.PRNGKey(0) if greedy else key
    (ntok, cache, _, _), toks = jax.lax.scan(
        body, (token, cache, jnp.asarray(index, jnp.int32), k0), None,
        length=steps)
    return jnp.moveaxis(toks, 0, 1), ntok, cache


def slot_cache(cache, slot: int):
    """One batch slot's view of a paged cache (B=1), for per-admission
    prefill: pool arrays (``kp``/``vp``) are shared and pass through
    whole; per-slot state (recurrent mixers, cross k/v) is sliced."""
    def per_layer(d):
        return {k: (v if k in ("kp", "vp") else v[:, slot:slot + 1])
                for k, v in d.items()}

    return {
        "layers": tuple(per_layer(d) for d in cache["layers"]),
        "page_table": cache["page_table"][slot:slot + 1],
        "length": cache["length"][slot:slot + 1],
        "active": jnp.ones((1,), bool),
    }


def merge_slot_cache(cache, sub, slot: int):
    """Merge a ``slot_cache`` view updated by :func:`prefill` back into
    the full paged cache (pool arrays replace; per-slot state scatters)."""
    def per_layer(d, s):
        return {k: (s[k] if k in ("kp", "vp")
                    else d[k].at[:, slot:slot + 1].set(s[k]))
                for k in d}

    return {
        **cache,
        "layers": tuple(per_layer(d, s)
                        for d, s in zip(cache["layers"], sub["layers"])),
        "length": cache["length"].at[slot].set(sub["length"][0]),
    }
