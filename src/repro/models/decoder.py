"""Unified block-pattern model covering all 10 assigned architectures.

One implementation handles dense / MoE / SSM / hybrid / enc-dec / VLM via
the :class:`~repro.models.config.ArchConfig` pattern.  Repeated pattern
groups are stacked on a leading ``repeats`` axis and executed with
``jax.lax.scan`` (+ ``jax.checkpoint`` remat), keeping HLO size O(pattern)
and activation memory O(depth × layer-input).

Entry points:
  * :func:`init_model`  — parameter pytree
  * :func:`forward`     — full-sequence logits (train / prefill / encoder)
  * :func:`loss_fn`     — token cross-entropy (+ MoE aux loss)
  * :func:`init_cache`  — decode cache (KV / SSM state / RWKV state)
  * :func:`decode_step` — one-token serve step against the cache
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, LayerSpec
from repro.parallel import act
from repro.nn import attention as attn_mod
from repro.nn import mamba as mamba_mod
from repro.nn import moe as moe_mod
from repro.nn import rwkv as rwkv_mod
from repro.nn.attention import AttnSpec
from repro.nn.base import (
    cross_entropy_loss,
    layernorm,
    layernorm_init,
    rmsnorm,
    rmsnorm_init,
    softcap,
)

MOE_AUX_COEF = 0.01


def _attn_spec(cfg: ArchConfig, spec: LayerSpec, *, causal=True) -> AttnSpec:
    return AttnSpec(
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        causal=causal, window=spec.window, logit_softcap=spec.logit_softcap,
        rope=spec.rope and cfg.pos_embed == "rope",
        rope_theta=cfg.rope_theta, rope_fraction=spec.rope_fraction,
        qk_norm=spec.qk_norm,
    )


def _norm_init(cfg: ArchConfig, d: int):
    return rmsnorm_init(d) if cfg.norm == "rms" else layernorm_init(d)


def _norm(cfg: ArchConfig, p, x):
    return rmsnorm(x, p) if cfg.norm == "rms" else layernorm(x, p)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------


def _init_layer(key, cfg: ArchConfig, spec: LayerSpec):
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    p: dict[str, Any] = {"norm1": _norm_init(cfg, d)}
    aspec = _attn_spec(cfg, spec)
    if spec.mixer in ("attn", "cross_attn"):
        p["mixer"] = attn_mod.init_attention(keys[0], d, aspec)
    elif spec.mixer == "attn+cross":
        p["mixer"] = attn_mod.init_attention(keys[0], d, aspec)
        p["norm_cross"] = _norm_init(cfg, d)
        p["cross"] = attn_mod.init_attention(keys[1], d, aspec)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba_mod.init_mamba(
            keys[0], d, d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv,
            expand=cfg.mamba_expand,
        )
    elif spec.mixer == "rwkv":
        p["mixer"] = rwkv_mod.init_time_mix(keys[0], d, head_size=cfg.rwkv_head_size)
    else:
        raise ValueError(spec.mixer)

    if spec.ffn != "none":
        p["norm2"] = _norm_init(cfg, d)
    if spec.ffn == "dense":
        p["ffn"] = moe_mod.init_dense_ffn(keys[2], d, cfg.d_ff)
    elif spec.ffn == "moe":
        p["ffn"] = moe_mod.init_moe(keys[2], d, cfg.moe_d_ff or cfg.d_ff,
                                    cfg.moe_experts)
    elif spec.ffn == "channel_mix":
        p["ffn"] = rwkv_mod.init_channel_mix(keys[2], d, cfg.d_ff)
    if spec.post_norm:
        p["norm_post1"] = _norm_init(cfg, d)
        if spec.ffn != "none":
            p["norm_post2"] = _norm_init(cfg, d)
    return p


def init_model(cfg: ArchConfig, key, *, dtype=jnp.float32):
    cfg.validate()
    keys = jax.random.split(key, 8)
    d, vp = cfg.d_model, cfg.padded_vocab
    params: dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (vp, d)) * (1.0 / math.sqrt(d)),
        "final_norm": _norm_init(cfg, d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(keys[1], (d, vp)) * (1.0 / math.sqrt(d))
    if cfg.pos_embed == "learned":
        params["pos"] = jax.random.normal(keys[2], (cfg.max_position, d)) * 0.02

    # stacked pattern blocks: tuple over pattern index, leaves (repeats, …)
    blocks = []
    for j, spec in enumerate(cfg.pattern):
        ks = jax.random.split(jax.random.fold_in(keys[3], j), cfg.repeats)
        blocks.append(jax.vmap(lambda k: _init_layer(k, cfg, spec))(ks))
    params["blocks"] = tuple(blocks)

    if cfg.encoder is not None:
        enc_spec = LayerSpec(mixer="attn", ffn="dense", rope=False)
        ks = jax.random.split(keys[4], cfg.encoder.num_layers)
        params["encoder"] = {
            "blocks": jax.vmap(lambda k: _init_layer(k, cfg, enc_spec))(ks),
            "final_norm": _norm_init(cfg, d),
            "pos": jax.random.normal(keys[5], (cfg.encoder.frames, d)) * 0.02,
        }
    params = jax.tree.map(lambda x: x.astype(dtype), params)
    return params


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------


def _cast(p, dtype):
    """Cast float params to the compute dtype (norms etc. recompute in f32
    internally); non-float leaves pass through."""
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        p,
    )


def _apply_layer(cfg, spec: LayerSpec, p, x, *, positions, cross_kv=None,
                 causal=True):
    """One layer forward. Returns (x, moe_aux)."""
    p = act.gather_params(_cast(p, x.dtype), cfg)
    aux = jnp.zeros((), jnp.float32)
    h = _norm(cfg, p["norm1"], x)
    aspec = _attn_spec(cfg, spec, causal=causal)
    if spec.mixer == "attn":
        y = attn_mod.attention(p["mixer"], h, aspec, positions=positions)
    elif spec.mixer == "cross_attn":
        kv_pos = jnp.broadcast_to(
            jnp.arange(cross_kv.shape[1], dtype=jnp.int32), cross_kv.shape[:2]
        )
        y = attn_mod.attention(
            p["mixer"], h, aspec, positions=positions,
            kv_x=cross_kv.astype(h.dtype), kv_positions=kv_pos,
        )
    elif spec.mixer == "attn+cross":
        y = attn_mod.attention(p["mixer"], h, aspec, positions=positions)
        if spec.post_norm:
            y = _norm(cfg, p["norm_post1"], y)
        x = x + y
        h = _norm(cfg, p["norm_cross"], x)
        kv_pos = jnp.broadcast_to(
            jnp.arange(cross_kv.shape[1], dtype=jnp.int32), cross_kv.shape[:2]
        )
        y = attn_mod.attention(
            p["cross"], h, aspec, positions=positions,
            kv_x=cross_kv.astype(h.dtype), kv_positions=kv_pos,
        )
    elif spec.mixer == "mamba":
        y = mamba_mod.mamba(p["mixer"], h, d_state=cfg.mamba_d_state,
                            d_conv=cfg.mamba_d_conv)
    elif spec.mixer == "rwkv":
        y = rwkv_mod.time_mix(p["mixer"], h, head_size=cfg.rwkv_head_size)
    else:
        raise ValueError(spec.mixer)
    if spec.post_norm and spec.mixer != "attn+cross":
        y = _norm(cfg, p["norm_post1"], y)
    x = x + y

    if spec.ffn == "none":
        return x, aux
    h = _norm(cfg, p["norm2"], x)
    if spec.ffn == "dense":
        y = moe_mod.dense_ffn(p["ffn"], h)
    elif spec.ffn == "moe":
        y, moe_aux = moe_mod.moe_ffn(p["ffn"], h, top_k=cfg.moe_top_k,
                                     capacity_factor=cfg.moe_capacity_factor,
                                     impl=cfg.moe_impl)
        aux = aux + moe_aux["aux_loss"]
    elif spec.ffn == "channel_mix":
        y = rwkv_mod.channel_mix_seq(p["ffn"], h)
    else:
        raise ValueError(spec.ffn)
    if spec.post_norm:
        y = _norm(cfg, p["norm_post2"], y)
    return x + y, aux


def _run_blocks(params, cfg: ArchConfig, x, *, positions, cross_kv=None,
                remat=True):
    """Scan the stacked pattern blocks over ``repeats``."""

    def group(carry, block_slice):
        x, aux = carry
        for j, spec in enumerate(cfg.pattern):
            def layer(p, x, positions, cross_kv, *, _spec=spec):
                return _apply_layer(cfg, _spec, p, x, positions=positions,
                                    cross_kv=cross_kv)

            # per-LAYER remat: backward recomputes one layer at a time, so
            # wide mixer internals (Mamba scan states, MoE buffers) never
            # coexist across the whole pattern group.
            if remat:
                layer = jax.checkpoint(layer)
            x, a = layer(block_slice[j], x, positions, cross_kv)
            x = act.shard_batch_act(x)
            aux = aux + a
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(group, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    return x, aux


def _encode(params, cfg: ArchConfig, context, *, remat=True):
    """Whisper-style bidirectional encoder over stub frame embeddings."""
    enc = params["encoder"]
    x = context + enc["pos"][None, : context.shape[1]].astype(context.dtype)
    positions = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2]
    )
    spec = LayerSpec(mixer="attn", ffn="dense", rope=False)

    def layer(carry, p):
        y, _ = _apply_layer(cfg, spec, p, carry, positions=positions,
                            causal=False)
        return act.shard_batch_act(y), None

    body = jax.checkpoint(layer) if remat else layer
    x, _ = jax.lax.scan(body, x, enc["blocks"])
    return _norm(cfg, enc["final_norm"], x)


def _hidden(params, cfg: ArchConfig, tokens, *, context=None,
            compute_dtype=jnp.bfloat16, remat=True):
    """Backbone forward up to the final norm. Returns (x (B,S,D), moe_aux)."""
    B, S = tokens.shape
    x = params["embed"][tokens].astype(compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    x = act.shard_batch_act(x)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos_embed == "learned":
        x = x + params["pos"][:S][None].astype(compute_dtype)

    cross_kv = None
    if cfg.encoder is not None:
        cross_kv = _encode(params, cfg, context.astype(compute_dtype), remat=remat)
    elif cfg.cross_kv_len:
        cross_kv = context.astype(compute_dtype)

    x, aux = _run_blocks(params, cfg, x, positions=positions,
                         cross_kv=cross_kv, remat=remat)
    return _norm(cfg, params["final_norm"], x), aux


def forward(params, cfg: ArchConfig, tokens, *, context=None,
            compute_dtype=jnp.bfloat16, remat=True):
    """tokens: (B, S) int32; context: stub frontend embeddings (B, N, D)
    for audio/vlm archs.  Returns (logits (B, S, padded_vocab), moe_aux)."""
    x, aux = _hidden(params, cfg, tokens, context=context,
                     compute_dtype=compute_dtype, remat=remat)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(compute_dtype)
    logits = act.shard_logits(logits)
    if cfg.final_softcap:
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, aux


#: sequence-chunk length for the loss head: logits materialize one
#: (B, LOSS_CHUNK, vocab) tile at a time (§Perf cycle 3 — the full
#: (B, S, 256k) f32 logits dominated gemma2's HBM bytes)
LOSS_CHUNK = 512


def loss_fn(params, cfg: ArchConfig, batch, *, compute_dtype=jnp.bfloat16,
            remat=True):
    x, aux = _hidden(
        params, cfg, batch["tokens"], context=batch.get("context"),
        compute_dtype=compute_dtype, remat=remat,
    )
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(compute_dtype)
    labels = batch["labels"]
    B, S, _ = x.shape
    C = LOSS_CHUNK if (S % LOSS_CHUNK == 0 and S > LOSS_CHUNK) else S
    nc = S // C

    @jax.checkpoint
    def chunk(carry, inp):
        nll_sum, n = carry
        x_c, y_c = inp                                   # (B,C,D), (B,C)
        logits = x_c @ head
        logits = act.shard_logits(logits)
        if cfg.final_softcap:
            logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
        logits = logits.astype(jnp.float32)
        mask = y_c >= 0
        safe = jnp.maximum(y_c, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        nll_sum = nll_sum + (((logz - gold) * mask).sum())
        return (nll_sum, n + mask.sum()), None

    xs = (
        jnp.moveaxis(x.reshape(B, nc, C, -1), 1, 0),
        jnp.moveaxis(labels.reshape(B, nc, C), 1, 0),
    )
    (nll, n), _ = jax.lax.scan(
        chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), xs
    )
    loss = nll / jnp.maximum(n, 1)
    if cfg.has_moe:
        loss = loss + MOE_AUX_COEF * aux / cfg.num_layers
    return loss


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------


def _layer_cache(cfg: ArchConfig, spec: LayerSpec, batch: int, cache_len: int,
                 dtype):
    kv = dict(
        n_kv=cfg.n_kv_heads, hd=cfg.head_dim
    )
    c: dict[str, Any] = {}
    if spec.mixer in ("attn", "attn+cross"):
        L = cache_len if spec.window is None else min(cache_len, spec.window)
        c["k"] = jnp.zeros((batch, L, kv["n_kv"], kv["hd"]), dtype)
        c["v"] = jnp.zeros((batch, L, kv["n_kv"], kv["hd"]), dtype)
        c["pos"] = jnp.full((batch, L), -1, jnp.int32)
    if spec.mixer in ("cross_attn", "attn+cross"):
        c["ck"] = jnp.zeros((batch, cfg.cross_kv_len, kv["n_kv"], kv["hd"]), dtype)
        c["cv"] = jnp.zeros((batch, cfg.cross_kv_len, kv["n_kv"], kv["hd"]), dtype)
    if spec.mixer == "mamba":
        c.update(mamba_mod.init_mamba_cache(
            batch, cfg.d_model, d_state=cfg.mamba_d_state,
            d_conv=cfg.mamba_d_conv, expand=cfg.mamba_expand, dtype=dtype,
        ))
    if spec.mixer == "rwkv":
        c.update(rwkv_mod.init_rwkv_cache(batch, cfg.d_model,
                                          head_size=cfg.rwkv_head_size))
    return c


def init_cache(cfg: ArchConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16, *, global_cap: int | None = None):
    """Decode cache pytree, stacked (repeats, …) per pattern position.

    ``global_cap`` bounds full-attention layers' KV length (used for
    gemma2's global layers at ``long_500k`` — see DESIGN.md)."""
    caches = []
    for spec in cfg.pattern:
        L = cache_len
        if global_cap is not None and spec.mixer == "attn" and spec.window is None:
            L = min(L, global_cap)
        one = _layer_cache(cfg, spec, batch, L, dtype)
        caches.append(
            jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (cfg.repeats,) + x.shape),
                one,
            )
        )
    return tuple(caches)


def _decode_layer(cfg, spec: LayerSpec, p, x, cache, index):
    p = act.gather_params(_cast(p, x.dtype), cfg)
    aspec = _attn_spec(cfg, spec)
    h = _norm(cfg, p["norm1"], x)
    if spec.mixer == "attn":
        y, cache = attn_mod.decode_attention(p["mixer"], h, cache, index, aspec)
    elif spec.mixer == "cross_attn":
        y, _ = attn_mod.decode_attention(
            p["mixer"], h, {"k": cache["ck"], "v": cache["cv"]}, index, aspec,
            cross=True,
        )
    elif spec.mixer == "attn+cross":
        y, self_c = attn_mod.decode_attention(
            p["mixer"], h, {k: cache[k] for k in ("k", "v", "pos")}, index, aspec
        )
        x = x + y
        h = _norm(cfg, p["norm_cross"], x)
        y, _ = attn_mod.decode_attention(
            p["cross"], h, {"k": cache["ck"], "v": cache["cv"]}, index, aspec,
            cross=True,
        )
        cache = {**cache, **self_c}
    elif spec.mixer == "mamba":
        y, cache = mamba_mod.decode_mamba(p["mixer"], h, cache,
                                          d_state=cfg.mamba_d_state,
                                          d_conv=cfg.mamba_d_conv)
    elif spec.mixer == "rwkv":
        y, tm = rwkv_mod.decode_time_mix(p["mixer"], h, cache,
                                         head_size=cfg.rwkv_head_size)
        cache = {**cache, **tm}
    if spec.post_norm and spec.mixer != "attn+cross":
        y = _norm(cfg, p["norm_post1"], y)
    x = x + y
    if spec.ffn == "none":
        return x, cache
    h = _norm(cfg, p["norm2"], x)
    if spec.ffn == "dense":
        y = moe_mod.dense_ffn(p["ffn"], h)
    elif spec.ffn == "moe":
        y, _ = moe_mod.moe_ffn(p["ffn"], h, top_k=cfg.moe_top_k,
                               capacity_factor=cfg.moe_capacity_factor,
                               impl=cfg.moe_impl)
    elif spec.ffn == "channel_mix":
        y, cm = rwkv_mod.decode_channel_mix(p["ffn"], h, cache)
        cache = {**cache, **cm}
    if spec.post_norm:
        y = _norm(cfg, p["norm_post2"], y)
    return x + y, cache


def decode_step(params, cfg: ArchConfig, token, cache, index, *,
                compute_dtype=jnp.bfloat16):
    """One serve step: token (B, 1) int32 at position ``index`` (scalar),
    against ``cache``.  Returns (logits (B, 1, padded_vocab), new_cache)."""
    B = token.shape[0]
    x = params["embed"][token].astype(compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    if cfg.pos_embed == "learned":
        x = x + params["pos"][index][None, None].astype(compute_dtype)

    # Decode unrolls the repeats (python loop): one-token HLO per layer is
    # tiny, and unrolling lets every layer's cache keep its sharding —
    # SPMD handles per-iteration dynamic-slice resharding of scanned cache
    # stacks poorly (involuntary full rematerialization).
    new_stacks = []
    for r in range(cfg.repeats):
        p_r = jax.tree.map(lambda a: a[r], params["blocks"])
        c_r = jax.tree.map(lambda a: a[r], cache)
        new_c = []
        for j, spec in enumerate(cfg.pattern):
            x, cj = _decode_layer(cfg, spec, p_r[j], x, c_r[j], index)
            x = act.shard_batch_act(x)
            new_c.append(cj)
        new_stacks.append(tuple(new_c))
    new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_stacks)
    x = _norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(compute_dtype)
    if cfg.final_softcap:
        logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, new_cache
