"""Architecture configuration — one schema covering all 10 assigned archs.

A model is a *pattern* of :class:`LayerSpec`s repeated ``repeats`` times
(total layers = ``len(pattern) × repeats``).  Params of the repeated
pattern are stacked on a leading ``repeats`` axis and iterated with
``jax.lax.scan`` so HLO size (and 512-device compile time) is
O(len(pattern)), not O(depth).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Mixer = Literal["attn", "cross_attn", "attn+cross", "mamba", "rwkv"]
Ffn = Literal["dense", "moe", "channel_mix", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: Mixer = "attn"
    ffn: Ffn = "dense"
    window: int | None = None           # sliding-window attention (local)
    logit_softcap: float | None = None  # Gemma-2 attn soft-cap
    rope: bool = True
    rope_fraction: float = 1.0          # ChatGLM partial rotary
    qk_norm: bool = False               # Qwen3/OLMoE per-head q/k RMSNorm
    post_norm: bool = False             # Gemma-2 extra post-norms


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style bidirectional encoder over stub frame embeddings."""

    num_layers: int
    frames: int                         # encoder sequence length (stub input)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    source: str                         # paper / model-card citation
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: tuple[LayerSpec, ...]
    repeats: int
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0                   # per-expert FFN width
    moe_capacity_factor: float = 1.25   # GShard per-group expert capacity
    #: MoE dispatch/combine data path: "auto" → fused Pallas kernels on
    #: TPU, jnp slot formulation elsewhere; "ref" pins the pure-JAX
    #: scatter/gather oracle; "interpret"/"slot"/"pallas" force a path
    #: (see repro/kernels/moe.py)
    moe_impl: str = "auto"
    #: decode KV-cache layout: "dense" = per-sequence ring buffers (the
    #: reference oracle); "paged" = shared page pool + per-sequence page
    #: tables (kernels/paged_attention.py) — within the paged path the
    #: kernel impl resolves via kernels/ops.py impl="auto" (Pallas on
    #: TPU, jnp gather-over-pages elsewhere)
    kv_impl: str = "dense"
    # positions
    rope_theta: float = 10000.0
    pos_embed: Literal["rope", "learned", "none"] = "rope"
    max_position: int = 0               # for learned positions
    # output head
    final_softcap: float | None = None
    tie_embeddings: bool = False
    embed_scale: bool = False           # Gemma: embeddings × sqrt(d_model)
    norm: Literal["rms", "ln"] = "rms"
    # Mamba (hybrid)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # RWKV
    rwkv_head_size: int = 64
    # frontends (stub carve-out: audio conv / ViT are NOT implemented; the
    # launcher provides precomputed embeddings of this length)
    encoder: EncoderConfig | None = None
    cross_kv_len: int = 0               # image patches / audio frames
    # which input shapes this arch supports (long_500k needs sub-quadratic)
    supports_long_context: bool = False
    #: grad-accumulation microbatch (global examples); tuned down for the
    #: widest archs (§Perf) — activation liveness scales with this
    train_microbatch: int = 32

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so embedding/lm_head shard
        evenly on a 16-wide model axis (whisper's 51866 needs it)."""
        return -(-self.vocab // 256) * 256

    @property
    def has_moe(self) -> bool:
        return any(s.ffn == "moe" for s in self.pattern)

    def validate(self) -> None:
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, self.name
        if self.has_moe:
            assert self.moe_experts > 0 and self.moe_top_k > 0, self.name
        for s in self.pattern:
            if s.mixer in ("cross_attn", "attn+cross"):
                assert self.cross_kv_len > 0, self.name
        if self.pos_embed == "learned":
            assert self.max_position > 0, self.name
