"""Analytic layer profiles of the assigned architectures for the
HeterPS scheduler (§Arch-applicability, DESIGN.md §5).

Converts an :class:`ArchConfig` into the per-layer
(kind, flops, input_bytes, weight_bytes, output_bytes) sequence the
cost model profiles — embedding and LM head included — so the RL
scheduler can plan any of the 10 archs over a heterogeneous fleet.
FLOPs are per token at the given training context length.
"""

from __future__ import annotations

from repro.configs import get_config
from repro.core.profiles import LayerProfile, profile_layers
from repro.models.config import ArchConfig
from repro.nn.moe import moe_capacity

_F = 4  # fp32 bytes


def _effective_kv_len(window: int | None, kv_len: int, cache_len: int,
                      page_size: int | None) -> int:
    """KV positions one decode token actually reads from one layer's
    cache: the whole (window-capped) ring when dense, or only the pages
    overlapping the live span ``[max(0, t-window+1), t]`` when paged."""
    if page_size is None:
        return min(cache_len, window or cache_len)
    t = max(kv_len - 1, 0)
    first = 0 if window is None else max(0, t - window + 1)
    return (t // page_size - first // page_size + 1) * page_size


def kv_read_bytes_per_token(cfg: ArchConfig, kv_len: int, *,
                            cache_len: int, page_size: int | None = None,
                            bytes_per_el: int = 4) -> float:
    """Per-decoded-token KV-cache read traffic summed over the
    self-attention layers.

    Dense ring buffers (``page_size=None``) read their whole allocation
    every token — ``cache_len`` (window-capped) regardless of how many
    tokens the sequence actually holds.  The paged path reads only the
    pages overlapping the live span ``[max(0, t-window+1), t]`` at
    ``t = kv_len - 1`` — *used* pages, not ``max_len`` (this is the
    accounting the cost model should charge a decode workload)."""
    total = 0.0
    row = 2 * cfg.n_kv_heads * cfg.head_dim * bytes_per_el   # k + v
    for i in range(cfg.num_layers):
        spec = cfg.pattern[i % len(cfg.pattern)]
        if spec.mixer not in ("attn", "attn+cross"):
            continue
        total += _effective_kv_len(spec.window, kv_len, cache_len,
                                   page_size) * row
    return total


def _layer_rows(cfg: ArchConfig, *, seq: int,
                decode_kv: tuple | None = None) -> list[tuple]:
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    rows: list[tuple] = []
    # input embedding — the data-intensive sparse lookup
    rows.append(("embedding", 2.0 * d, 64.0, cfg.padded_vocab * d * _F, d * _F))
    for i in range(cfg.num_layers):
        spec = cfg.pattern[i % len(cfg.pattern)]
        flops = 0.0
        w_bytes = 0.0
        in_bytes = d * _F
        if spec.mixer in ("attn", "cross_attn", "attn+cross"):
            proj = 2.0 * d * (H + 2 * KV) * hd + 2.0 * H * hd * d
            ctx = min(seq, spec.window or seq)
            score = 4.0 * ctx * H * hd
            n_attn = 2 if spec.mixer == "attn+cross" else 1
            flops += n_attn * (proj + score)
            w_bytes += n_attn * (2 * d * (H + 2 * KV) * hd) * _F
            kind = "cross_attention" if spec.mixer != "attn" else "attention"
            if decode_kv is not None and spec.mixer != "cross_attn":
                # decode profiling: charge the true per-token KV read —
                # used pages for the paged cache, the whole ring for dense
                kv_len, cache_len, page_size = decode_kv
                eff = _effective_kv_len(spec.window, kv_len, cache_len,
                                        page_size)
                in_bytes += 2.0 * eff * KV * hd * _F
        elif spec.mixer == "mamba":
            din = cfg.mamba_expand * d
            flops += 2.0 * d * 2 * din + 2.0 * din * d + 9.0 * din * cfg.mamba_d_state
            w_bytes += (d * 2 * din + din * d + din * 4) * _F
            kind = "ssm"
        else:  # rwkv
            flops += 2.0 * 5 * d * d + 4.0 * d * cfg.rwkv_head_size
            w_bytes += 5 * d * d * _F
            kind = "ssm"
        if spec.ffn == "dense":
            flops += 6.0 * d * cfg.d_ff
            w_bytes += 3 * d * cfg.d_ff * _F
        elif spec.ffn == "moe":
            fe = cfg.moe_d_ff or cfg.d_ff
            # The fused dispatch/combine path computes the expert SwiGLU
            # over the full (E, C) capacity slabs (empty slots included —
            # that's what makes the einsum dense/MXU-shaped), so per-token
            # FFN FLOPs scale with E·C/S ≈ K·cf rounded up to slab
            # alignment, not bare top-k.
            E, K = cfg.moe_experts, cfg.moe_top_k
            C = moe_capacity(seq, E, K, cfg.moe_capacity_factor)
            slots_per_tok = E * C / seq
            flops += 6.0 * d * fe * slots_per_tok + 2.0 * d * E
            w_bytes += 3 * d * fe * E * _F
            # dispatch writes one activation row per slot and combine
            # reads K gate-weighted rows back per token — the kernels'
            # true per-token HBM activation traffic (the K-repeated
            # source buffer of the old scatter path no longer exists;
            # combine's own write is the layer output, already counted
            # in output_bytes)
            in_bytes += (slots_per_tok + K) * d * _F
        elif spec.ffn == "channel_mix":
            flops += 2.0 * d * cfg.d_ff + 2.0 * cfg.d_ff * d + 2.0 * d * d
            w_bytes += (2 * d * cfg.d_ff + d * d) * _F
        rows.append((kind, flops, in_bytes, w_bytes, d * _F))
    # LM head — compute-dense matmul over the (padded) vocab
    rows.append(("fc", 2.0 * d * cfg.padded_vocab, d * _F,
                 d * cfg.padded_vocab * _F, 32.0))
    return rows


def profile_arch(arch, fleet, *, seq: int = 4096,
                 decode_kv_len: int | None = None,
                 kv_cache_len: int | None = None,
                 kv_page_size: int | None = None) -> list[LayerProfile]:
    """``decode_kv_len`` switches the attention rows to decode-mode KV
    accounting: each token reads the cache — the whole ``kv_cache_len``
    ring when ``kv_page_size`` is None (dense), or only the used pages of
    a ``kv_page_size``-paged pool at sequence length ``decode_kv_len``."""
    cfg = get_config(arch) if isinstance(arch, str) else arch
    decode_kv = None
    if decode_kv_len is not None:
        decode_kv = (decode_kv_len, kv_cache_len or seq, kv_page_size)
    return profile_layers(_layer_rows(cfg, seq=seq, decode_kv=decode_kv),
                          fleet)
