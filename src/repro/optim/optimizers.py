"""AdamW / SGD + gradient clipping — tree-based, shardable.

Optimizer state mirrors the parameter pytree leaf-for-leaf, so whatever
sharding the parameters carry propagates to the moments (ZeRO-style
sharding of optimizer state falls out of the param sharding rules).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class OptState:
    mu: Any
    nu: Any
    count: jax.Array

jax.tree_util.register_pytree_node(
    OptState,
    lambda s: ((s.mu, s.nu, s.count), None),
    lambda aux, children: OptState(*children),
)


def adamw_init(params) -> OptState:
    z = jax.tree.map(jnp.zeros_like, params)
    return OptState(mu=z, nu=jax.tree.map(jnp.zeros_like, params),
                    count=jnp.zeros((), jnp.int32))


def clip_by_global_norm(grads, max_norm: float):
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


def adamw_update(params, grads, state: OptState, *, lr: float,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.0):
    count = state.count + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                      state.nu, grads)
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, m, v):
        step = (m / c1) / (jnp.sqrt(v / c2) + eps)
        if weight_decay:
            step = step + weight_decay * p
        return (p - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(mu=mu, nu=nu, count=count)


def sgd_update(params, grads, *, lr: float, ):
    return jax.tree.map(lambda p, g: (p - lr * g).astype(p.dtype), params, grads)
