"""Optimizers, pure JAX (no optax)."""

from repro.optim.optimizers import (
    OptState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    sgd_update,
)

__all__ = [
    "OptState", "adamw_init", "adamw_update", "clip_by_global_norm",
    "sgd_update",
]
