"""Data pipeline — HeterPS data-management module (§3), training-data side.

The paper stores training data in an HDFS cluster, prefetches batches
into CPU-worker memory, and spills to SSD when RAM is tight.  Here:

* :class:`SyntheticTokenDataset` — deterministic synthetic LM batches
  (seeded per-step PRNG; reproducible across restarts and host counts);
* :class:`PrefetchLoader` — background-thread prefetch with a bounded
  queue (the paper's prefetch-and-cache behaviour);
* :func:`shard_batch` — places a host batch onto the mesh with the batch
  axis sharded over ``("pod", "data")``.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticTokenDataset:
    """Deterministic synthetic next-token-prediction batches.

    Step ``i`` is a pure function of (seed, i) — restart-safe, and every
    host can generate its own shard without coordination.

    Tokens are drawn from a Zipfian unigram distribution
    (``p(t) ∝ 1/(t+1)^a``, the natural-language shape), not uniform:
    with i.i.d. *uniform* tokens the cross-entropy floor is ``log V`` and
    the only achievable descent is flattening the initial logit variance
    — a signal small enough that batch noise buries it for some archs
    (the OLMoE plateau, see DESIGN.md §MoE kernels).  A skewed marginal
    gives training real, quickly-learnable headroom
    (``H(zipf) ≪ log V``) so "loss decreases" measures optimization, not
    luck.  ``zipf_a=0`` restores uniform sampling.
    """

    def __init__(self, vocab: int, batch_size: int, seq_len: int, *,
                 seed: int = 0, context_len: int = 0, d_model: int = 0,
                 zipf_a: float = 1.2):
        self.vocab = vocab
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.seed = seed
        self.context_len = context_len
        self.d_model = d_model
        if zipf_a:
            w = 1.0 / np.arange(1, vocab + 1, dtype=np.float64) ** zipf_a
            self._probs = w / w.sum()
        else:
            self._probs = None

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        shape = (self.batch_size, self.seq_len + 1)
        if self._probs is None:
            toks = rng.integers(0, self.vocab, shape, dtype=np.int32)
        else:
            toks = rng.choice(self.vocab, size=shape,
                              p=self._probs).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.context_len:
            out["context"] = rng.standard_normal(
                (self.batch_size, self.context_len, self.d_model)
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


#: queue marker ending the stream — lets a consumer blocked in ``get()``
#: observe producer shutdown instead of hanging forever
_SENTINEL = object()


class PrefetchLoader:
    """Background prefetch with a bounded queue (HeterPS prefetches input
    data into worker memory ahead of the consuming stage).

    Shutdown contract: the worker only ever blocks in *timed* puts, so it
    observes ``close()`` promptly even when the queue is full; on exit
    (dataset exhausted or ``close()``) it always enqueues a sentinel, so a
    consumer blocked in ``__next__`` wakes up and gets ``StopIteration``
    rather than hanging on an empty queue.
    """

    def __init__(self, dataset, depth: int = 2, put_timeout: float = 0.05):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._done = False
        self._put_timeout = put_timeout

        def worker():
            try:
                for b in dataset:
                    placed = False
                    while not self._stop.is_set():
                        try:
                            self._q.put(b, timeout=self._put_timeout)
                            placed = True
                            break
                        except queue.Full:
                            continue
                    if not placed:
                        return  # close() requested while queue stayed full
            finally:
                # Always terminate the stream.  If close() was requested and
                # the queue is full, make room by dropping buffered batches
                # (the consumer is gone).  Without close() we must not drop
                # data — a slow consumer may still drain — so back off
                # exponentially instead of spinning while we wait for room.
                wait = self._put_timeout
                while True:
                    try:
                        self._q.put(_SENTINEL, timeout=wait)
                        return
                    except queue.Full:
                        if self._stop.is_set():
                            try:
                                self._q.get_nowait()
                            except queue.Empty:
                                pass
                        else:
                            wait = min(wait * 2, 1.0)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        if self._done:
            raise StopIteration
        item = self._q.get()
        if item is _SENTINEL:
            self._done = True
            raise StopIteration
        return item

    def close(self, timeout: float = 5.0):
        """Stop the worker; safe to call repeatedly / with a blocked consumer."""
        self._stop.set()
        self._t.join(timeout)


def shard_batch(batch: dict, mesh, batch_axes=("pod", "data")) -> dict:
    """Device-put a host batch with the batch dim sharded over the data
    axes of the mesh (replicated on the model axis)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    def put(x):
        spec = P(axes, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return {k: put(jnp.asarray(v)) for k, v in batch.items()}
