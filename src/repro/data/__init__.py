"""Data pipeline + parameter-tiering (HeterPS data-management module)."""

from repro.data.cache import AccessMonitor, Tier, TierThresholds
from repro.data.pipeline import PrefetchLoader, SyntheticTokenDataset, shard_batch

__all__ = [
    "AccessMonitor", "Tier", "TierThresholds", "PrefetchLoader",
    "SyntheticTokenDataset", "shard_batch",
]
