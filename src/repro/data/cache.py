"""Hot/cold parameter tiering — HeterPS data-management module (§3).

The paper: "there is a monitor that counts the access frequency of each
parameter.  If the access frequency is high, the monitor marks the
parameters as hot parameters, and the data management module dynamically
adjusts it to the high-speed storage devices … otherwise it puts it to
SSDs or normal hard disks."

TPU adaptation (DESIGN.md §2): tiers are device HBM vs host memory
(``memory_kind="pinned_host"`` on TPU runtimes) vs disk checkpoint.  The
monitor is pure policy — it consumes access counts (for embedding tables:
row-level touch counts from the data pipeline) and emits placement
decisions; the launcher applies them as shardings/memory-kinds.  On the
CPU dry-run runtime the decisions are exercised by tests, not by a real
HBM. Gradients of the same access pattern age the counts (EMA) so the
working set can drift with the data distribution.
"""

from __future__ import annotations

import dataclasses
import enum
import threading

import numpy as np


class Tier(enum.Enum):
    DEVICE = "device"       # HBM — hot
    HOST = "pinned_host"    # host RAM — warm
    DISK = "disk"           # SSD / checkpoint — cold


@dataclasses.dataclass
class TierThresholds:
    hot_fraction: float = 0.1    # top-x% of access mass → DEVICE
    warm_fraction: float = 0.5   # next slice → HOST
    ema: float = 0.9             # access-count decay per epoch


class AccessMonitor:
    """Counts row-level accesses of a (sharded) embedding table and
    assigns storage tiers by access mass.

    Thread-safe: the PS client records accesses from its puller thread
    while the tier placer reads/ages the counts on the main thread, and
    numpy releases the GIL on large-array ops — a lock keeps the counts
    coherent.
    """

    def __init__(self, num_rows: int, thresholds: TierThresholds | None = None):
        self.counts = np.zeros((num_rows,), np.float64)
        self.thresholds = thresholds or TierThresholds()
        self._lock = threading.Lock()

    def record(self, row_ids: np.ndarray) -> None:
        ids, cnt = np.unique(np.asarray(row_ids).ravel(), return_counts=True)
        if ids.size == 0:
            return
        # `ids` is sorted, so the extremes are the range check.  A silent
        # wrap/clip here would credit the wrong rows and skew placement.
        num_rows = self.counts.shape[0]
        if ids[0] < 0 or ids[-1] >= num_rows:
            raise ValueError(
                f"row ids out of range: got ids in [{ids[0]}, {ids[-1]}] for "
                f"a table with {num_rows} rows (expected 0 <= id < {num_rows})"
            )
        with self._lock:
            self.counts[ids] += cnt

    def age(self) -> None:
        with self._lock:
            self.counts *= self.thresholds.ema

    def snapshot_counts(self) -> np.ndarray:
        """Locked copy of the access counts — hand it to :meth:`placement`
        so a decision and any count-ordered post-processing (e.g. the tier
        placer's hottest-first cache fill) see the same state."""
        with self._lock:
            return self.counts.copy()

    def placement(self, counts: np.ndarray | None = None) -> np.ndarray:
        """Tier per row (np array of Tier) — hot rows by cumulative access
        mass, ties broken toward DEVICE.  ``counts`` defaults to a fresh
        :meth:`snapshot_counts`."""
        t = self.thresholds
        if self.counts.size == 0:
            return np.empty((0,), dtype=object)
        if counts is None:
            counts = self.snapshot_counts()
        order = np.argsort(-counts, kind="stable")
        mass = np.cumsum(counts[order])
        total = mass[-1] if mass[-1] > 0 else 1.0
        # classify by cumulative mass *before* the row: a row starts hot if
        # the hot budget isn't already filled when we reach it (so the
        # single hottest row is always DEVICE).
        frac_before = (mass - counts[order]) / total
        tiers = np.full(counts.shape, Tier.DISK, dtype=object)
        accessed = counts[order] > 0
        hot = order[(frac_before < t.hot_fraction) & accessed]
        warm = order[(frac_before >= t.hot_fraction)
                     & (frac_before < t.warm_fraction) & accessed]
        tiers[hot] = Tier.DEVICE
        tiers[warm] = Tier.HOST
        return tiers

    def stats(self) -> dict:
        p = self.placement()
        return {
            "device_rows": int((p == Tier.DEVICE).sum()),
            "host_rows": int((p == Tier.HOST).sum()),
            "disk_rows": int((p == Tier.DISK).sum()),
            "total_accesses": float(self.counts.sum()),
        }
