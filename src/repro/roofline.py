"""Roofline terms from compiled dry-run artifacts (TPU v5e targets).

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

``cost_analysis()`` of an SPMD-partitioned executable reports *per-device*
FLOPs/bytes, so terms are already per-chip.  Collective bytes are parsed
from the compiled HLO (operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute; async ``-start`` forms
counted once).
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12       # bf16 per chip (TPU v5e)
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
# tuple-typed async starts: "= (f32[..], f32[..]) all-gather-start(...)"
_COLL_TUPLE_RE = re.compile(
    r"=\s+\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Per-device collective bytes by op kind (output/operand sizes)."""
    by_kind: dict[str, int] = {}
    counts: dict[str, int] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if m:
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            if m.group(4) and "-done(" in line:
                continue
            b = _shape_bytes(dtype, dims)
        else:
            m = _COLL_TUPLE_RE.search(line)
            if not m:
                continue
            kind = m.group(2)
            # async start tuple carries (operand, result[, scratch]): count
            # the result element (largest) once.
            b = max(
                (_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(m.group(1))),
                default=0,
            )
        by_kind[kind] = by_kind.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    return {
        "bytes_by_kind": by_kind,
        "counts": counts,
        "total_bytes": sum(by_kind.values()),
    }


def roofline_terms(*, flops: float, hbm_bytes: float,
                   collective_bytes: float) -> dict:
    compute = flops / PEAK_FLOPS
    memory = hbm_bytes / HBM_BW
    collective = collective_bytes / LINK_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom.replace("_s", "")
    total = max(compute, memory, collective)
    terms["bound_fraction"] = {
        k.replace("_s", ""): (v / total if total else 0.0)
        for k, v in list(terms.items())
        if isinstance(v, float)
    }
    return terms


def model_flops(n_params_active: float, tokens: float) -> float:
    """MODEL_FLOPS = 6·N·D (per step), N = active params, D = tokens."""
    return 6.0 * n_params_active * tokens
