"""Jit-ready step functions: train / prefill / one-token serve."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import decoder as dec
from repro.models.config import ArchConfig
from repro.optim import adamw_init, adamw_update, clip_by_global_norm


#: default microbatch size (global examples per grad-accumulation step);
#: bounds live activation memory to O(layers × microbatch × seq × d_model)
DEFAULT_MICROBATCH = 32


def make_train_step(cfg: ArchConfig, *, lr: float = 3e-4,
                    weight_decay: float = 0.1, clip: float = 1.0,
                    compute_dtype=jnp.bfloat16, remat: bool = True,
                    microbatch: int | None = DEFAULT_MICROBATCH):
    """Train step with gradient-accumulation microbatching: the batch is
    split into microbatches scanned sequentially (grads accumulate in the
    FSDP-sharded param layout), so per-layer checkpointed activations
    exist for one microbatch at a time — the same microbatching HeterPS
    uses for its pipeline stages (§3)."""

    def grads_of(params, mb):
        return jax.value_and_grad(dec.loss_fn)(
            params, cfg, mb, compute_dtype=compute_dtype, remat=remat
        )

    def train_step(params, opt_state, batch):
        B = batch["tokens"].shape[0]
        m = microbatch or B
        n_micro = max(1, B // m) if B % (m or 1) == 0 else 1
        if n_micro > 1:
            split = jax.tree.map(
                lambda x: x.reshape((n_micro, B // n_micro) + x.shape[1:]),
                batch,
            )

            def micro(carry, mb):
                gacc, lacc = carry
                loss, g = grads_of(params, mb)
                gacc = jax.tree.map(jnp.add, gacc, g)
                return (gacc, lacc + loss), None

            zeros = jax.tree.map(jnp.zeros_like, params)
            (grads, loss), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), split
            )
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
        else:
            loss, grads = grads_of(params, batch)
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = adamw_update(
            params, grads, opt_state, lr=lr, weight_decay=weight_decay
        )
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ArchConfig, *, compute_dtype=jnp.bfloat16):
    def prefill_step(params, batch):
        logits, _ = dec.forward(
            params, cfg, batch["tokens"], context=batch.get("context"),
            compute_dtype=compute_dtype, remat=False,
        )
        return logits

    return prefill_step


def make_serve_step(cfg: ArchConfig, *, compute_dtype=jnp.bfloat16):
    def serve_step(params, token, cache, index):
        return dec.decode_step(
            params, cfg, token, cache, index, compute_dtype=compute_dtype
        )

    return serve_step


def init_train_state(cfg: ArchConfig, key, *, dtype=jnp.float32):
    params = dec.init_model(cfg, key, dtype=dtype)
    return params, adamw_init(params)
