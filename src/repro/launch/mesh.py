"""Production mesh construction (defined as functions — importing this
module never touches jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; ``multi_pod`` adds the 2-pod axis.

    Axes: ``data`` (batch / FSDP), ``model`` (tensor / expert / vocab),
    ``pod`` (pure data parallelism across pods, over DCI).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_smoke_mesh():
    """1×1 mesh over the single CPU device (smoke tests / examples)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
