"""Production mesh construction (defined as functions — importing this
module never touches jax device state)."""

from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """`jax.make_mesh` with Auto axis types where the jax version has them
    (jax.sharding.AxisType arrived after 0.4.x; older versions are
    Auto-only and take no ``axis_types`` argument)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; ``multi_pod`` adds the 2-pod axis.

    Axes: ``data`` (batch / FSDP), ``model`` (tensor / expert / vocab),
    ``pod`` (pure data parallelism across pods, over DCI).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_smoke_mesh():
    """1×1 mesh over the single CPU device (smoke tests / examples)."""
    return make_mesh_compat((1, 1), ("data", "model"))
