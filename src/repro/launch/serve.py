"""Serving launcher: batched prefill + continuous-batching KV-cache decode.

Two entry points:

* :func:`serve` — fixed-batch generation: ONE forward pass prefills the
  whole prompt into the decode cache, then a jitted ``lax.scan`` decode
  loop generates tokens in chunks that are harvested on device (a single
  host transfer per chunk, not a jit dispatch + ``np.asarray`` sync per
  token).  ``kv_impl="paged"`` swaps the dense ring buffers for the
  shared page pool of ``kernels/paged_attention.py``.

* :func:`serve_continuous` — continuous batching over variable-length
  requests: sequences are admitted into batch slots against a host
  :class:`~repro.kernels.PagePool` (per-admission exact-length prefill),
  decoded together in jitted multi-token chunks, and evicted when done so
  their pages recycle into the pool for the next request.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \\
      --batch 4 --prompt-len 32 --gen 16 [--kv-impl paged] [--continuous]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import ARCH_IDS, get_config
from repro.kernels.paged_attention import PagePool
from repro.models import decoder as dec
from repro.models.profile import kv_read_bytes_per_token
from repro.obs import trace as obs_trace


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, cache_len: int = 128,
          seed: int = 0, compute_dtype=jnp.float32, kv_impl: str = "dense",
          page_size: int = 16, decode_chunk: int | None = None,
          temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
          sample_seed: int | None = None) -> dict:
    """Fixed-batch serve: batched prefill + chunked on-device decode.

    ``temperature=0`` (default) decodes greedily.  Any positive
    temperature samples every token (including the first, drawn from the
    prefill logits) through ``models.decoder.sample_logits`` with
    ``top_k``/``top_p`` truncation; the PRNG key derives from
    ``sample_seed`` (default: ``seed``), so a fixed seed reproduces the
    same tokens exactly."""
    cfg = get_config(arch, reduced=reduced)
    if cfg.kv_impl != kv_impl:
        cfg = dataclasses.replace(cfg, kv_impl=kv_impl)
    if kv_impl == "paged" and prompt_len + gen > cache_len:
        # the page pool does not ring-wrap: positions past capacity would
        # be silently dropped (the dense ring keeps a sliding window)
        raise ValueError(
            f"paged serve needs prompt_len+gen <= cache_len "
            f"({prompt_len}+{gen} > {cache_len})")
    key = jax.random.PRNGKey(seed)
    params = dec.init_model(cfg, key)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)

    cache = dec.init_cache(cfg, batch, cache_len, dtype=compute_dtype,
                           page_size=page_size)
    prefill_jit = jax.jit(
        lambda p, t, c: dec.prefill(p, cfg, t, c, compute_dtype=compute_dtype)
    )
    # prefill: ONE forward fills the cache (vs stepping the prompt
    # token-by-token through the decode path)
    t0 = time.perf_counter()
    with obs_trace.span("serve.prefill", "serve", batch=batch,
                        prompt_len=prompt_len):
        logits, cache = prefill_jit(params, prompts, cache)
        jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0

    sampling = temperature > 0.0
    if sampling:
        skey = jax.random.PRNGKey(seed if sample_seed is None
                                  else sample_seed)
        kfirst, kloop = jax.random.split(skey)
        first = jax.jit(lambda lg, k: dec.sample_logits(
            lg, k, temperature=temperature, top_k=top_k, top_p=top_p))
        tok = first(logits[:, -1, : cfg.vocab], kfirst)[:, None]
    else:
        tok = jnp.argmax(logits[:, -1:, : cfg.vocab],
                         axis=-1).astype(jnp.int32)
    chunk = min(decode_chunk or gen, gen)
    if sampling:
        loop_jit = jax.jit(
            lambda p, t, c, i, k: dec.decode_loop(
                p, cfg, t, c, i, chunk, compute_dtype=compute_dtype,
                key=k, temperature=temperature, top_k=top_k, top_p=top_p)
        )
        chunk_key = lambda n: jax.random.fold_in(kloop, n)  # noqa: E731
    else:
        loop_jit = jax.jit(
            lambda p, t, c, i, k: dec.decode_loop(
                p, cfg, t, c, i, chunk, compute_dtype=compute_dtype)
        )
        chunk_key = lambda n: jnp.zeros((2,), jnp.uint32)  # noqa: E731
    # warm the scan program (functional: the discarded chunk leaves tok /
    # cache untouched) so decode_s measures steady-state throughput
    t0 = time.perf_counter()
    jax.block_until_ready(
        loop_jit(params, tok, cache, jnp.int32(prompt_len), chunk_key(0))[0])
    compile_s = time.perf_counter() - t0
    outs = []
    t0 = time.perf_counter()
    done, idx, n_chunk = 0, prompt_len, 0
    while done < gen:
        with obs_trace.span("serve.decode_chunk", "serve", chunk=chunk,
                            n_chunk=n_chunk):
            toks, tok, cache = loop_jit(params, tok, cache, jnp.int32(idx),
                                        chunk_key(n_chunk))
            outs.append(np.asarray(toks))   # one transfer per chunk
        done += chunk
        idx += chunk
        n_chunk += 1
    decode_s = time.perf_counter() - t0
    obs.REGISTRY.counter("serve.tokens").inc(batch * gen)
    out = np.concatenate(outs, axis=1)[:, :gen]

    el = np.dtype(compute_dtype).itemsize
    return {
        "arch": cfg.name, "batch": batch, "generated_shape": list(out.shape),
        "tokens": out.tolist(),
        "tokens_in_vocab": bool((out >= 0).all() and (out < cfg.vocab).all()),
        "prefill_s": prefill_s, "decode_s": decode_s,
        "decode_compile_s": compile_s,
        "sampling": ({"temperature": temperature, "top_k": top_k,
                      "top_p": top_p,
                      "sample_seed": seed if sample_seed is None
                      else sample_seed}
                     if sampling else None),
        "decode_tok_per_s": batch * gen / max(decode_s, 1e-9),
        "kv_impl": kv_impl,
        "kv_bytes_per_token": kv_read_bytes_per_token(
            cfg, prompt_len + gen, cache_len=cache_len,
            page_size=page_size if kv_impl == "paged" else None,
            bytes_per_el=el),
    }


def _default_requests(n: int = 12) -> list[tuple[int, int]]:
    """Deterministic skewed mix of (prompt_len, gen_len) requests."""
    return [(8 + (7 * i) % 25, 6 + (5 * i) % 15) for i in range(n)]


def serve_continuous(arch: str, *, reduced: bool = True,
                     requests: list[tuple[int, int]] | None = None,
                     slots: int = 4, page_size: int = 16,
                     num_pages: int | None = None,
                     max_seq_len: int | None = None, decode_chunk: int = 8,
                     seed: int = 0, compute_dtype=jnp.float32,
                     arrival_s: list[float] | None = None) -> dict:
    """Continuous-batching serve over variable-length requests.

    Each request ``(prompt_len, gen_len)`` is admitted into a free batch
    slot when the :class:`PagePool` can reserve its pages (prompt + gen +
    one decode chunk of slack), prefilled at its EXACT length (one
    forward, no padding — correct for recurrent mixers too; prefill
    recompiles once per distinct prompt length), then decoded with every
    other live slot in jitted ``decode_chunk``-token chunks harvested on
    device.  Finished sequences are evicted and their pages recycle.

    ``num_pages`` below full slot coverage oversubscribes the pool:
    admission blocks until evictions free enough pages.

    ``arrival_s`` (optional, one offset per request, seconds from loop
    start, non-decreasing) turns the FIFO queue into an open-loop arrival
    process: a request becomes admissible only once its arrival time has
    passed, which is what makes the per-request latency split meaningful
    — TTFT (arrival → prefill done, first output token exists) and TPOT
    (decode seconds per output token) come back in the result and land in
    the ``serve.ttft_s`` / ``serve.tpot_s`` histograms the obs bridge and
    ``benchmarks/bench_slo.py`` read.  Without it every request arrives
    at t=0 (closed-loop, TTFT includes queueing as before).
    """
    cfg = dataclasses.replace(get_config(arch, reduced=reduced),
                              kv_impl="paged")
    key = jax.random.PRNGKey(seed)
    params = dec.init_model(cfg, key)
    if requests is None:
        requests = _default_requests()
    if max_seq_len is None:
        max_seq_len = max(p + g for p, g in requests) + decode_chunk
    pages_per_seq = -(-max_seq_len // page_size)
    if num_pages is None:
        num_pages = 1 + slots * pages_per_seq
    pool = PagePool(num_pages, page_size, slots, pages_per_seq)
    cache = dec.init_cache(cfg, slots, pages_per_seq * page_size,
                           dtype=compute_dtype, page_size=page_size,
                           num_pages=num_pages)
    cache["page_table"] = jnp.asarray(pool.table)

    prefill_jit = jax.jit(
        lambda p, t, c: dec.prefill(p, cfg, t, c, compute_dtype=compute_dtype)
    )
    loop_jit = jax.jit(
        lambda p, t, c: dec.decode_loop(p, cfg, t, c, 0, decode_chunk,
                                        compute_dtype=compute_dtype)
    )

    if arrival_s is not None and len(arrival_s) != len(requests):
        raise ValueError(
            f"arrival_s has {len(arrival_s)} entries for "
            f"{len(requests)} requests")
    queue = deque(enumerate(requests))
    slot_req: list[list | None] = [None] * slots   # [rid, gen_remaining]
    cur_tok = np.zeros((slots, 1), np.int32)
    lengths = np.zeros(slots, np.int32)
    active = np.zeros(slots, bool)
    outputs: list[list[int]] = [[] for _ in requests]
    el = np.dtype(compute_dtype).itemsize
    dense_equiv_len = pages_per_seq * page_size
    kv_spans: list[tuple[int, int]] = []   # (start_len, n_tokens) per slot
    toks_done = 0
    prefills = 0
    peak_pages = 0
    reg = obs.REGISTRY
    reg.gauge("serve.pool_pages_total").set(num_pages - 1)
    first_tok_t: list[float | None] = [None] * len(requests)
    ttft_s: list[float | None] = [None] * len(requests)
    tpot_s: list[float | None] = [None] * len(requests)

    def _gauges():
        reg.gauge("serve.queue_depth").set(len(queue))
        reg.gauge("serve.pool_pages_used").set(
            (num_pages - 1) - pool.free_pages)

    def admit():
        nonlocal cache, prefills
        for s in range(slots):
            if slot_req[s] is not None or not queue:
                continue
            rid, (plen, g) = queue[0]
            if arrival_s is not None and time.perf_counter() - t0 < arrival_s[rid]:
                break                       # FIFO: head hasn't arrived yet
            need = plen + g + decode_chunk
            if not pool.can_admit(need):
                if pool.pages_for(need) > pool.pages_per_seq:
                    raise RuntimeError(
                        f"request {rid} needs {pool.pages_for(need)} pages "
                        f"> pages_per_seq={pool.pages_per_seq} (raise "
                        f"max_seq_len)")
                if not any(active):
                    raise RuntimeError(
                        f"request {rid} needs {pool.pages_for(need)} pages; "
                        f"pool has {num_pages - 1} total")
                break                       # wait for an eviction
            queue.popleft()
            pool.admit(s, need)
            cache = {**cache, "page_table": jnp.asarray(pool.table)}
            prompt = jax.random.randint(jax.random.fold_in(key, 1000 + rid),
                                        (1, plen), 0, cfg.vocab)
            sub = dec.slot_cache(cache, s)
            sub = {**sub, "length": jnp.zeros((1,), jnp.int32)}
            with obs_trace.span("serve.prefill", "serve", rid=rid, slot=s,
                                prompt_len=plen):
                lg, sub = prefill_jit(params, prompt, sub)
                cur_tok[s, 0] = int(np.argmax(np.asarray(
                    lg[0, plen - 1, : cfg.vocab])))
            prefills += 1
            cache = dec.merge_slot_cache(cache, sub, s)
            # the np.asarray above synced the prefill: the first output
            # token exists NOW — that's the TTFT edge
            done_t = time.perf_counter()
            first_tok_t[rid] = done_t
            arrive = t0 + (arrival_s[rid] if arrival_s is not None else 0.0)
            ttft_s[rid] = done_t - arrive
            reg.histogram("serve.ttft_s").record(max(ttft_s[rid], 0.0))
            reg.counter("serve.admissions").inc()
            lengths[s] = plen
            active[s] = True
            slot_req[s] = [rid, g]
        _gauges()

    t0 = time.perf_counter()
    admit()
    while any(active) or queue:
        if not any(active):
            # open-loop idle gap: sleep until the head request arrives
            rid_next = queue[0][0]
            wait = t0 + arrival_s[rid_next] - time.perf_counter()
            if wait > 0:
                time.sleep(wait)
            admit()
            continue
        peak_pages = max(peak_pages, (num_pages - 1) - pool.free_pages)
        with obs_trace.span("serve.decode_chunk", "serve",
                            live=int(active.sum()), chunk=decode_chunk):
            cache = {**cache,
                     "page_table": jnp.asarray(pool.table),
                     "active": jnp.asarray(active),
                     "length": jnp.asarray(lengths)}
            toks, ntok, cache = loop_jit(params, jnp.asarray(cur_tok), cache)
            toks_h = np.asarray(toks)       # one transfer per chunk
        cur_tok = np.array(ntok)            # writable: admit() refills slots
        harvest_t = time.perf_counter()
        for s in range(slots):
            if slot_req[s] is None:
                continue
            rid, rem = slot_req[s]
            take = min(rem, decode_chunk)
            outputs[rid].extend(int(t) for t in toks_h[s, :take])
            # byte accounting happens after the timer stops — only the
            # (start_length, tokens) span is recorded in the hot loop
            kv_spans.append((int(lengths[s]), take))
            toks_done += take
            reg.counter("serve.tokens").inc(take)
            lengths[s] += decode_chunk      # mirrors the device increment
            slot_req[s][1] = rem - decode_chunk
            if slot_req[s][1] <= 0:
                pool.evict(s)               # pages recycle into the pool
                slot_req[s] = None
                active[s] = False
                lengths[s] = 0
                reg.counter("serve.evictions").inc()
                g = requests[rid][1]
                tpot_s[rid] = ((harvest_t - first_tok_t[rid])
                               / max(1, g))
                reg.histogram("serve.tpot_s").record(max(tpot_s[rid], 0.0))
                obs_trace.instant("serve.finish", "serve", rid=rid,
                                  gen=g)
        admit()
    wall = time.perf_counter() - t0
    _gauges()

    kv_bytes = sum(
        kv_read_bytes_per_token(cfg, start + i + 1,
                                cache_len=dense_equiv_len,
                                page_size=page_size, bytes_per_el=el)
        for start, n in kv_spans for i in range(n)
    )
    dense_bpt = kv_read_bytes_per_token(cfg, dense_equiv_len,
                                        cache_len=dense_equiv_len,
                                        page_size=None, bytes_per_el=el)
    ok = all(
        len(o) == g and all(0 <= t < cfg.vocab for t in o)
        for (_, g), o in zip(requests, outputs)
    )
    return {
        "arch": cfg.name, "requests": len(requests), "slots": slots,
        "page_size": page_size, "num_pages": num_pages,
        "generated": [len(o) for o in outputs],
        "tokens": outputs,
        "tokens_in_vocab": ok,
        "decode_tok_per_s": toks_done / max(wall, 1e-9),
        "prefills": prefills, "wall_s": wall,
        "kv_bytes_per_token_paged": kv_bytes / max(toks_done, 1),
        "kv_bytes_per_token_dense": dense_bpt,
        "peak_pages_in_use": peak_pages,
        "pool_conserved": pool.free_pages == num_pages - 1,
        "ttft_s": ttft_s, "tpot_s": tpot_s,
        "arrival_s": arrival_s,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv-impl", choices=("dense", "paged"), default="dense")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching loop over a skewed request "
                         "mix (always paged, greedy)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy decode)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest logits (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off)")
    ap.add_argument("--sample-seed", type=int, default=None,
                    help="PRNG seed for sampling (default: --seed's value; "
                         "fixed seed => reproducible tokens)")
    ap.add_argument("--obs-dir", default=None,
                    help="enable observability and write trace.json + "
                         "metrics.jsonl to this directory")
    ap.add_argument("--replan", action="store_true",
                    help="run the reactive re-planning controller on a "
                         "background thread while --continuous serves: "
                         "windows the serve SLO signals (TTFT/TPOT p99, "
                         "queue growth), re-plans on sustained violation "
                         "(enables the metric registry)")
    ap.add_argument("--replan-window-s", type=float, default=1.0,
                    help="telemetry window span in seconds")
    ap.add_argument("--ttft-slo", type=float, default=0.0,
                    help="TTFT p99 SLO in seconds (0 = no SLO trigger)")
    ap.add_argument("--tpot-slo", type=float, default=0.0,
                    help="TPOT p99 SLO in seconds (0 = no SLO trigger)")
    args = ap.parse_args()
    if args.obs_dir:
        obs.configure(run_dir=args.obs_dir)
    controller = None
    if args.replan and args.continuous:
        from repro.core.cost_model import TrainingJob
        from repro.core.profiles import ctrdnn_layers
        from repro.core.replan import ReplanConfig, ReplanController
        from repro.core.resources import default_fleet
        from repro.core.schedulers.rl import RLScheduler
        from repro.obs.bridge import snapshot_resources

        obs.REGISTRY.enabled = True   # the detector reads serve histograms
        rfleet = default_fleet()
        controller = ReplanController(
            ctrdnn_layers(), rfleet, TrainingJob(),
            RLScheduler(rounds=40, plans_per_round=16,
                        early_stop_rounds=15, chunk_rounds=10),
            snapshot_fn=lambda: snapshot_resources(rfleet[0]),
            config=ReplanConfig(window_s=args.replan_window_s,
                                ttft_slo_s=args.ttft_slo,
                                tpot_slo_s=args.tpot_slo))
        controller.start()
    if args.continuous:
        out = serve_continuous(args.arch, reduced=args.reduced,
                               slots=args.batch)
    else:
        out = serve(args.arch, reduced=args.reduced, batch=args.batch,
                    prompt_len=args.prompt_len, gen=args.gen,
                    kv_impl=args.kv_impl, temperature=args.temperature,
                    top_k=args.top_k, top_p=args.top_p,
                    sample_seed=args.sample_seed)
    if controller is not None:
        controller.stop()
        out["replan"] = controller.report()
    if args.obs_dir:
        out["obs"] = obs.flush()
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
