"""Serving launcher: batched prefill + continuous-batching KV-cache decode.

Two entry points:

* :func:`serve` — fixed-batch generation: ONE forward pass prefills the
  whole prompt into the decode cache, then a jitted ``lax.scan`` decode
  loop generates tokens in chunks that are harvested on device (a single
  host transfer per chunk, not a jit dispatch + ``np.asarray`` sync per
  token).  ``kv_impl="paged"`` swaps the dense ring buffers for the
  shared page pool of ``kernels/paged_attention.py``.

* :func:`serve_continuous` — continuous batching over variable-length
  requests: sequences are admitted into batch slots against a host
  :class:`~repro.kernels.PagePool` (per-admission exact-length prefill),
  decoded together in jitted multi-token chunks, and evicted when done so
  their pages recycle into the pool for the next request.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \\
      --batch 4 --prompt-len 32 --gen 16 [--kv-impl paged] [--continuous]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import ARCH_IDS, get_config
from repro.core.admission import (AdmissionPolicy, COMPLETED, OUTCOMES,
                                  PREEMPTED, REJECTED, TIMED_OUT)
from repro.kernels.paged_attention import PagePool
from repro.models import decoder as dec
from repro.models.profile import kv_read_bytes_per_token
from repro.obs import trace as obs_trace


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, cache_len: int = 128,
          seed: int = 0, compute_dtype=jnp.float32, kv_impl: str = "dense",
          page_size: int = 16, decode_chunk: int | None = None,
          temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
          sample_seed: int | None = None) -> dict:
    """Fixed-batch serve: batched prefill + chunked on-device decode.

    ``temperature=0`` (default) decodes greedily.  Any positive
    temperature samples every token (including the first, drawn from the
    prefill logits) through ``models.decoder.sample_logits`` with
    ``top_k``/``top_p`` truncation; the PRNG key derives from
    ``sample_seed`` (default: ``seed``), so a fixed seed reproduces the
    same tokens exactly."""
    cfg = get_config(arch, reduced=reduced)
    if cfg.kv_impl != kv_impl:
        cfg = dataclasses.replace(cfg, kv_impl=kv_impl)
    if kv_impl == "paged" and prompt_len + gen > cache_len:
        # the page pool does not ring-wrap: positions past capacity would
        # be silently dropped (the dense ring keeps a sliding window)
        raise ValueError(
            f"paged serve needs prompt_len+gen <= cache_len "
            f"({prompt_len}+{gen} > {cache_len})")
    key = jax.random.PRNGKey(seed)
    params = dec.init_model(cfg, key)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)

    cache = dec.init_cache(cfg, batch, cache_len, dtype=compute_dtype,
                           page_size=page_size)
    prefill_jit = jax.jit(
        lambda p, t, c: dec.prefill(p, cfg, t, c, compute_dtype=compute_dtype)
    )
    # prefill: ONE forward fills the cache (vs stepping the prompt
    # token-by-token through the decode path)
    t0 = time.perf_counter()
    with obs_trace.span("serve.prefill", "serve", batch=batch,
                        prompt_len=prompt_len):
        logits, cache = prefill_jit(params, prompts, cache)
        jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0

    sampling = temperature > 0.0
    if sampling:
        skey = jax.random.PRNGKey(seed if sample_seed is None
                                  else sample_seed)
        kfirst, kloop = jax.random.split(skey)
        first = jax.jit(lambda lg, k: dec.sample_logits(
            lg, k, temperature=temperature, top_k=top_k, top_p=top_p))
        tok = first(logits[:, -1, : cfg.vocab], kfirst)[:, None]
    else:
        tok = jnp.argmax(logits[:, -1:, : cfg.vocab],
                         axis=-1).astype(jnp.int32)
    chunk = min(decode_chunk or gen, gen)
    if sampling:
        loop_jit = jax.jit(
            lambda p, t, c, i, k: dec.decode_loop(
                p, cfg, t, c, i, chunk, compute_dtype=compute_dtype,
                key=k, temperature=temperature, top_k=top_k, top_p=top_p)
        )
        chunk_key = lambda n: jax.random.fold_in(kloop, n)  # noqa: E731
    else:
        loop_jit = jax.jit(
            lambda p, t, c, i, k: dec.decode_loop(
                p, cfg, t, c, i, chunk, compute_dtype=compute_dtype)
        )
        chunk_key = lambda n: jnp.zeros((2,), jnp.uint32)  # noqa: E731
    # warm the scan program (functional: the discarded chunk leaves tok /
    # cache untouched) so decode_s measures steady-state throughput
    t0 = time.perf_counter()
    jax.block_until_ready(
        loop_jit(params, tok, cache, jnp.int32(prompt_len), chunk_key(0))[0])
    compile_s = time.perf_counter() - t0
    outs = []
    t0 = time.perf_counter()
    done, idx, n_chunk = 0, prompt_len, 0
    while done < gen:
        with obs_trace.span("serve.decode_chunk", "serve", chunk=chunk,
                            n_chunk=n_chunk):
            toks, tok, cache = loop_jit(params, tok, cache, jnp.int32(idx),
                                        chunk_key(n_chunk))
            outs.append(np.asarray(toks))   # one transfer per chunk
        done += chunk
        idx += chunk
        n_chunk += 1
    decode_s = time.perf_counter() - t0
    obs.REGISTRY.counter("serve.tokens").inc(batch * gen)
    out = np.concatenate(outs, axis=1)[:, :gen]

    el = np.dtype(compute_dtype).itemsize
    return {
        "arch": cfg.name, "batch": batch, "generated_shape": list(out.shape),
        "tokens": out.tolist(),
        "tokens_in_vocab": bool((out >= 0).all() and (out < cfg.vocab).all()),
        "prefill_s": prefill_s, "decode_s": decode_s,
        "decode_compile_s": compile_s,
        "sampling": ({"temperature": temperature, "top_k": top_k,
                      "top_p": top_p,
                      "sample_seed": seed if sample_seed is None
                      else sample_seed}
                     if sampling else None),
        "decode_tok_per_s": batch * gen / max(decode_s, 1e-9),
        "kv_impl": kv_impl,
        "kv_bytes_per_token": kv_read_bytes_per_token(
            cfg, prompt_len + gen, cache_len=cache_len,
            page_size=page_size if kv_impl == "paged" else None,
            bytes_per_el=el),
    }


def _default_requests(n: int = 12) -> list[tuple[int, int]]:
    """Deterministic skewed mix of (prompt_len, gen_len) requests."""
    return [(8 + (7 * i) % 25, 6 + (5 * i) % 15) for i in range(n)]


def serve_continuous(arch: str, *, reduced: bool = True,
                     requests: list[tuple[int, int]] | None = None,
                     slots: int = 4, page_size: int = 16,
                     num_pages: int | None = None,
                     max_seq_len: int | None = None, decode_chunk: int = 8,
                     seed: int = 0, compute_dtype=jnp.float32,
                     arrival_s: list[float] | None = None,
                     deadlines=None,
                     admission: AdmissionPolicy | None = None,
                     preemption: bool = False, max_preemptions: int = 1,
                     watchdog_s: float | None = None,
                     max_wall_s: float | None = None,
                     clock=None) -> dict:
    """Continuous-batching serve over variable-length requests.

    Each request ``(prompt_len, gen_len)`` is admitted into a free batch
    slot when the :class:`PagePool` can reserve its pages (prompt + gen +
    one decode chunk of slack), prefilled at its EXACT length (one
    forward, no padding — correct for recurrent mixers too; prefill
    recompiles once per distinct prompt length), then decoded with every
    other live slot in jitted ``decode_chunk``-token chunks harvested on
    device.  Finished sequences are evicted and their pages recycle.

    ``num_pages`` below full slot coverage oversubscribes the pool:
    admission blocks until evictions free enough pages.

    ``arrival_s`` (optional, one offset per request, seconds from loop
    start, non-decreasing) turns the FIFO queue into an open-loop arrival
    process: a request becomes admissible only once its arrival time has
    passed, which is what makes the per-request latency split meaningful
    — TTFT (arrival → prefill done, first output token exists) and TPOT
    (decode seconds per output token) come back in the result and land in
    the ``serve.ttft_s`` / ``serve.tpot_s`` histograms the obs bridge and
    ``benchmarks/bench_slo.py`` read.  Without it every request arrives
    at t=0 (closed-loop, TTFT includes queueing as before).

    **Overload robustness** (see DESIGN.md "Overload robustness"):

    * every request terminates in exactly one typed outcome —
      ``completed`` / ``rejected`` / ``timed_out`` / ``preempted``
      (``result["outcomes"]``; nothing can hang, including requests
      whose page need exceeds the pool, which are *rejected at arrival*
      instead of waiting forever on an eviction that cannot help);
    * ``deadlines`` — one ``(ttft_deadline_s, total_deadline_s)`` pair
      (applied to all requests) or one pair per request, offsets from
      each request's arrival (``None`` entries disable that deadline).
      The ``admission`` policy (default: an untuned
      :class:`~repro.core.admission.AdmissionPolicy`) rejects arrivals
      that provably cannot meet their deadline under measured
      prefill/TPOT rates, bounds the admission queue, and caps decode
      concurrency; queued requests whose deadline passes are reaped as
      ``timed_out``, and in-flight requests past their total deadline
      are evicted mid-decode with their partial output;
    * ``preemption=True`` — when the arrived head is blocked on pool
      pages, a victim slot with strictly more remaining work is
      preempted (pages released via :meth:`PagePool.preempt`, generated
      tokens kept host-side) and later resumed by prefilling
      prompt + generated-so-far; the resumed token stream is bit-exact
      vs an un-preempted run (pinned in tests/test_admission.py);
    * ``watchdog_s`` — decode chunks slower than this emit a
      ``serve.stall`` obs instant and trigger a shed pass over the
      queue; ``max_wall_s`` hard-stops the loop (in-flight →
      ``preempted``, queued → ``rejected``) so a wedged run still ends
      with typed outcomes;
    * ``clock`` — injectable time source (default
      ``time.perf_counter``); a virtual clock makes deadline/arrival
      behaviour deterministic in tests (idle waits then spin on the
      clock instead of sleeping).
    """
    cfg = dataclasses.replace(get_config(arch, reduced=reduced),
                              kv_impl="paged")
    key = jax.random.PRNGKey(seed)
    params = dec.init_model(cfg, key)
    if requests is None:
        requests = _default_requests()
    n_req = len(requests)
    if max_seq_len is None:
        max_seq_len = max(p + g for p, g in requests) + decode_chunk
    pages_per_seq = -(-max_seq_len // page_size)
    if num_pages is None:
        num_pages = 1 + slots * pages_per_seq
    pool = PagePool(num_pages, page_size, slots, pages_per_seq)
    cache = dec.init_cache(cfg, slots, pages_per_seq * page_size,
                           dtype=compute_dtype, page_size=page_size,
                           num_pages=num_pages)
    cache["page_table"] = jnp.asarray(pool.table)

    prefill_jit = jax.jit(
        lambda p, t, c: dec.prefill(p, cfg, t, c, compute_dtype=compute_dtype)
    )
    loop_jit = jax.jit(
        lambda p, t, c: dec.decode_loop(p, cfg, t, c, 0, decode_chunk,
                                        compute_dtype=compute_dtype)
    )

    if arrival_s is not None:
        if len(arrival_s) != n_req:
            raise ValueError(
                f"arrival_s has {len(arrival_s)} entries for "
                f"{n_req} requests")
        for i in range(1, n_req):
            if arrival_s[i] < arrival_s[i - 1]:
                raise ValueError(
                    f"arrival_s must be non-decreasing (the admission "
                    f"queue is FIFO in arrival order) but arrival_s[{i}]="
                    f"{arrival_s[i]} < arrival_s[{i - 1}]="
                    f"{arrival_s[i - 1]} — sort requests, arrival_s and "
                    f"deadlines together by arrival time")
    if deadlines is None:
        deadlines = [(None, None)] * n_req
    elif isinstance(deadlines, tuple):
        deadlines = [deadlines] * n_req
    elif len(deadlines) != n_req:
        raise ValueError(
            f"deadlines has {len(deadlines)} entries for {n_req} requests")
    policy = admission if admission is not None else AdmissionPolicy(
        slots=slots)
    clk = clock if clock is not None else time.perf_counter
    real_time = clock is None

    pending = deque(enumerate(requests))   # not yet arrived (FIFO)
    arrived: deque = deque()               # admission queue: (rid, req)
    resume_q: deque = deque()              # preempted rids awaiting resume
    suspended: dict[int, dict] = {}        # rid -> {tok, done, rem}
    slot_req: list[list | None] = [None] * slots   # [rid, gen_remaining]
    cur_tok = np.zeros((slots, 1), np.int32)
    lengths = np.zeros(slots, np.int32)
    active = np.zeros(slots, bool)
    outputs: list[list[int]] = [[] for _ in requests]
    outcomes: list[str | None] = [None] * n_req
    outcome_detail: list[str | None] = [None] * n_req
    preempt_count = [0] * n_req
    el = np.dtype(compute_dtype).itemsize
    dense_equiv_len = pages_per_seq * page_size
    kv_spans: list[tuple[int, int]] = []   # (start_len, n_tokens) per slot
    toks_done = 0
    good_tokens = 0
    prefills = 0
    resumes = 0
    peak_pages = 0
    reg = obs.REGISTRY
    reg.gauge("serve.pool_pages_total").set(num_pages - 1)
    first_tok_t: list[float | None] = [None] * n_req
    ttft_s: list[float | None] = [None] * n_req
    tpot_s: list[float | None] = [None] * n_req
    total_s: list[float | None] = [None] * n_req

    def _arrival(rid: int) -> float:
        return t0 + (arrival_s[rid] if arrival_s is not None else 0.0)

    def _gauges():
        reg.gauge("serve.queue_depth").set(len(arrived) + len(resume_q))
        reg.gauge("serve.pool_pages_used").set(
            (num_pages - 1) - pool.free_pages)

    def _slack(rid: int, now: float) -> float | None:
        """Smallest remaining deadline margin (negative = missed)."""
        ttft_dl, total_dl = deadlines[rid]
        margins = []
        if ttft_dl is not None:
            # never-prefilled requests (queued reap) count queueing time
            elapsed = (ttft_s[rid] if ttft_s[rid] is not None
                       else now - _arrival(rid))
            margins.append(ttft_dl - elapsed)
        if total_dl is not None:
            margins.append(_arrival(rid) + total_dl - now)
        return min(margins) if margins else None

    def _finish_metrics(rid: int, now: float) -> None:
        slack = _slack(rid, now)
        if slack is not None:
            reg.histogram("serve.deadline_slack_s").record(slack)

    def _reject(rid: int, reason: str, detail: str | None = None) -> None:
        outcomes[rid] = REJECTED
        outcome_detail[rid] = detail if detail is not None else reason
        reg.counter("serve.rejected").inc()
        obs_trace.instant("serve.reject", "serve", rid=rid, reason=reason)

    def _timeout(rid: int, detail: str, now: float) -> None:
        outcomes[rid] = TIMED_OUT
        outcome_detail[rid] = detail
        reg.counter("serve.timed_out").inc()
        _finish_metrics(rid, now)
        obs_trace.instant("serve.timeout", "serve", rid=rid, where=detail)

    def _backlog_tokens() -> float:
        live = sum(max(0, sr[1]) for sr in slot_req if sr is not None)
        susp = sum(suspended[r]["rem"] for r in resume_q)
        return live + susp

    def drain_arrivals(now: float) -> None:
        """Move requests whose arrival time has passed into the admission
        queue, applying the bounded-queue / oversize / deadline-
        feasibility policy at the moment they arrive."""
        while pending and (now - t0) >= (
                arrival_s[pending[0][0]] if arrival_s is not None else 0.0):
            rid, (plen, g) = pending.popleft()
            need = plen + g + decode_chunk
            pages = pool.pages_for(need)
            cap = min(pool.pages_per_seq, num_pages - 1)
            if pages > cap:
                # validate NOW: waiting on an eviction can never help a
                # request the pool cannot hold even when empty
                _reject(rid, "oversize",
                        f"request {rid} needs {pages} pages for "
                        f"{need} tokens but the pool caps a sequence at "
                        f"{cap} pages (pages_per_seq="
                        f"{pool.pages_per_seq}, allocatable="
                        f"{num_pages - 1}) — raise max_seq_len/num_pages "
                        f"or shrink the request")
                continue
            backlog = _backlog_tokens() + sum(r[1][1] for r in arrived)
            reason = policy.admit_check(
                now=now, arrival=_arrival(rid), gen=g,
                ttft_deadline=deadlines[rid][0],
                total_deadline=deadlines[rid][1],
                backlog_tokens=backlog, queue_len=len(arrived))
            if reason is not None:
                _reject(rid, reason)
                continue
            arrived.append((rid, (plen, g)))

    def reap(now: float) -> None:
        """Shed queued / suspended requests whose deadline has already
        passed — they terminate ``timed_out`` instead of being admitted
        (or resumed) only to miss."""
        for q, where in ((arrived, "queued"), (resume_q, "suspended")):
            for item in list(q):
                rid = item if q is resume_q else item[0]
                ttft_dl, total_dl = deadlines[rid]
                late = ((ttft_dl is not None and ttft_s[rid] is None
                         and now > _arrival(rid) + ttft_dl)
                        or (total_dl is not None
                            and now > _arrival(rid) + total_dl))
                if late:
                    q.remove(item)
                    if q is resume_q:
                        suspended.pop(rid, None)
                    _timeout(rid, f"{where}_past_deadline", now)

    def _prefill_slot(s: int, rid: int, seq, feed_tok: int | None,
                      start_len: int, rem: int) -> None:
        """Shared admit/resume tail: prefill ``seq`` into slot ``s`` and
        mark it live.  ``feed_tok=None`` takes the argmax of the prefill
        logits (fresh admission, the TTFT edge); otherwise the saved
        next-token is fed (resume — the argmax is NOT recomputed, so the
        stream continues exactly where preemption cut it)."""
        nonlocal cache, prefills
        cache = {**cache, "page_table": jnp.asarray(pool.table)}
        sub = dec.slot_cache(cache, s)
        sub = {**sub, "length": jnp.zeros((1,), jnp.int32)}
        t_pre = clk()
        with obs_trace.span("serve.prefill", "serve", rid=rid, slot=s,
                            prompt_len=int(seq.shape[1])):
            lg, sub = prefill_jit(params, seq, sub)
            if feed_tok is None:
                cur_tok[s, 0] = int(np.argmax(np.asarray(
                    lg[0, start_len - 1, : cfg.vocab])))
            else:
                jax.block_until_ready(lg)
                cur_tok[s, 0] = feed_tok
        policy.observe_prefill(clk() - t_pre)
        prefills += 1
        cache = dec.merge_slot_cache(cache, sub, s)
        lengths[s] = start_len
        active[s] = True
        slot_req[s] = [rid, rem]

    def _prompt(rid: int, plen: int):
        return jax.random.randint(jax.random.fold_in(key, 1000 + rid),
                                  (1, plen), 0, cfg.vocab)

    def _try_preempt(rid: int, g: int, need: int) -> bool:
        """Free pages for the blocked head request by preempting the
        live slot with the most remaining work (strictly more than the
        head's whole generation — preemption must shorten the critical
        path, not shuffle it)."""
        victims = [(slot_req[s][1], s) for s in range(slots)
                   if slot_req[s] is not None
                   and slot_req[s][1] > g
                   and preempt_count[slot_req[s][0]] < max_preemptions]
        if not victims:
            return False
        _, v = max(victims)
        vrid = slot_req[v][0]
        freed_enough = (pool.available_pages + len(pool.owned_pages(v))
                        >= pool.pages_for(need))
        if not freed_enough:
            return False
        suspended[vrid] = {"tok": int(cur_tok[v, 0]),
                           "done": len(outputs[vrid]),
                           "rem": slot_req[v][1]}
        pool.preempt(v)
        resume_q.append(vrid)
        preempt_count[vrid] += 1
        slot_req[v] = None
        active[v] = False
        lengths[v] = 0
        reg.counter("serve.preemptions").inc()
        obs_trace.instant("serve.preempt", "serve", rid=vrid,
                          done=suspended[vrid]["done"], for_rid=rid)
        # hold the victim's pages for the head request across the
        # host-side bookkeeping — nothing else may race them away
        ok = pool.reserve(need)
        assert ok, "preemption freed pages that reserve() cannot see"
        return True

    def admit() -> None:
        nonlocal resumes
        now = clk()
        drain_arrivals(now)
        reap(now)
        live = sum(1 for sr in slot_req if sr is not None)
        for s in range(slots):
            if slot_req[s] is not None:
                continue
            if live >= max(1, int(policy.max_concurrency)):
                break
            if resume_q:
                # resumes have strict priority: the request already spent
                # its queueing budget once
                rid = resume_q[0]
                plen, g = requests[rid]
                st = suspended[rid]
                need = plen + g + decode_chunk
                if not pool.can_admit(need):
                    break                   # wait for an eviction
                resume_q.popleft()
                del suspended[rid]
                pool.admit(s, need)
                emitted = jnp.asarray(
                    np.asarray(outputs[rid][:st["done"]], np.int32)[None])
                seq = (jnp.concatenate([_prompt(rid, plen), emitted], axis=1)
                       if st["done"] else _prompt(rid, plen))
                _prefill_slot(s, rid, seq, st["tok"], plen + st["done"],
                              st["rem"])
                resumes += 1
                reg.counter("serve.resumes").inc()
                obs_trace.instant("serve.resume", "serve", rid=rid,
                                  done=st["done"])
            elif arrived:
                rid, (plen, g) = arrived[0]
                need = plen + g + decode_chunk
                from_res = False
                if not pool.can_admit(need):
                    if not (preemption and _try_preempt(rid, g, need)):
                        break               # wait for an eviction
                    from_res = True
                arrived.popleft()
                ttft_dl = deadlines[rid][0]
                if (ttft_dl is not None and policy.prefill_s > 0.0
                        and now + policy.prefill_s
                        > _arrival(rid) + ttft_dl):
                    # stale: even an immediate prefill would miss TTFT
                    if from_res:
                        pool.cancel_reservation(need)
                    _timeout(rid, "stale_at_admission", now)
                    continue
                pool.admit(s, need, from_reservation=from_res)
                _prefill_slot(s, rid, _prompt(rid, plen), None, plen, g)
                # the argmax above synced the prefill: the first output
                # token exists NOW — that's the TTFT edge
                done_t = clk()
                first_tok_t[rid] = done_t
                ttft_s[rid] = done_t - _arrival(rid)
                reg.histogram("serve.ttft_s").record(max(ttft_s[rid], 0.0))
                reg.counter("serve.admissions").inc()
            else:
                break
            live += 1
        _gauges()

    def _complete(s: int, rid: int, now: float) -> None:
        pool.evict(s)                       # pages recycle into the pool
        slot_req[s] = None
        active[s] = False
        lengths[s] = 0
        reg.counter("serve.evictions").inc()
        g = requests[rid][1]
        tpot_s[rid] = (now - first_tok_t[rid]) / max(1, g)
        total_s[rid] = now - _arrival(rid)
        policy.observe_tpot(tpot_s[rid])
        reg.histogram("serve.tpot_s").record(max(tpot_s[rid], 0.0))
        outcomes[rid] = COMPLETED
        outcome_detail[rid] = None
        reg.counter("serve.completed").inc()
        ttft_dl, total_dl = deadlines[rid]
        met = ((ttft_dl is None or ttft_s[rid] <= ttft_dl)
               and (total_dl is None or total_s[rid] <= total_dl))
        if met:
            nonlocal good_tokens
            good_tokens += g
            reg.counter("serve.good_tokens").inc(g)
        _finish_metrics(rid, now)
        obs_trace.instant("serve.finish", "serve", rid=rid, gen=g)

    def _shutdown(now: float) -> None:
        """max_wall_s budget exhausted: everything still open terminates
        with a typed outcome — nothing is left hanging."""
        for s in range(slots):
            if slot_req[s] is None:
                continue
            rid = slot_req[s][0]
            pool.evict(s)
            slot_req[s] = None
            active[s] = False
            lengths[s] = 0
            outcomes[rid] = PREEMPTED
            outcome_detail[rid] = "shutdown"
        for rid in list(resume_q):
            outcomes[rid] = PREEMPTED
            outcome_detail[rid] = "shutdown"
        resume_q.clear()
        suspended.clear()
        for rid, _ in list(arrived) + list(pending):
            _reject(rid, "shutdown")
        arrived.clear()
        pending.clear()
        obs_trace.instant("serve.shutdown", "serve", at_s=now - t0)

    t0 = clk()
    admit()
    while any(active) or arrived or resume_q or pending:
        now = clk()
        if max_wall_s is not None and now - t0 > max_wall_s:
            _shutdown(now)
            break
        if not any(active):
            if not arrived and not resume_q and pending:
                # open-loop idle gap: sleep until the head arrival (a
                # virtual clock spins — the test clock advances itself)
                wait = _arrival(pending[0][0]) - clk()
                if real_time and wait > 0:
                    time.sleep(wait)
            admit()
            continue
        peak_pages = max(peak_pages, (num_pages - 1) - pool.free_pages)
        chunk_t0 = clk()
        with obs_trace.span("serve.decode_chunk", "serve",
                            live=int(active.sum()), chunk=decode_chunk):
            cache = {**cache,
                     "page_table": jnp.asarray(pool.table),
                     "active": jnp.asarray(active),
                     "length": jnp.asarray(lengths)}
            toks, ntok, cache = loop_jit(params, jnp.asarray(cur_tok), cache)
            toks_h = np.asarray(toks)       # one transfer per chunk
        cur_tok = np.array(ntok)            # writable: admit() refills slots
        harvest_t = clk()
        if watchdog_s is not None and harvest_t - chunk_t0 > watchdog_s:
            # a stalled decode chunk starves every queued deadline: flag
            # it and shed the queue entries the stall made hopeless
            reg.counter("serve.stalls").inc()
            obs_trace.instant("serve.stall", "serve",
                              chunk_s=harvest_t - chunk_t0,
                              live=int(active.sum()))
            reap(harvest_t)
        for s in range(slots):
            if slot_req[s] is None:
                continue
            rid, rem = slot_req[s]
            take = min(rem, decode_chunk)
            outputs[rid].extend(int(t) for t in toks_h[s, :take])
            # byte accounting happens after the timer stops — only the
            # (start_length, tokens) span is recorded in the hot loop
            kv_spans.append((int(lengths[s]), take))
            toks_done += take
            reg.counter("serve.tokens").inc(take)
            lengths[s] += decode_chunk      # mirrors the device increment
            slot_req[s][1] = rem - decode_chunk
            if slot_req[s][1] <= 0:
                _complete(s, rid, harvest_t)
            else:
                total_dl = deadlines[rid][1]
                if (total_dl is not None
                        and harvest_t > _arrival(rid) + total_dl):
                    # past its total deadline mid-decode: keep the
                    # partial output, free the pages for live work
                    pool.evict(s)
                    slot_req[s] = None
                    active[s] = False
                    lengths[s] = 0
                    reg.counter("serve.evictions").inc()
                    _timeout(rid, "decode_past_deadline", harvest_t)
        admit()
    wall = clk() - t0
    _gauges()

    kv_bytes = sum(
        kv_read_bytes_per_token(cfg, start + i + 1,
                                cache_len=dense_equiv_len,
                                page_size=page_size, bytes_per_el=el)
        for start, n in kv_spans for i in range(n)
    )
    dense_bpt = kv_read_bytes_per_token(cfg, dense_equiv_len,
                                        cache_len=dense_equiv_len,
                                        page_size=None, bytes_per_el=el)
    ok = all(
        len(o) == g and all(0 <= t < cfg.vocab for t in o)
        for (rid, ((_, g), o)) in enumerate(zip(requests, outputs))
        if outcomes[rid] == COMPLETED
    )
    n_out = {k: sum(1 for o in outcomes if o == k) for k in OUTCOMES}
    assert all(o is not None for o in outcomes), \
        f"request without a terminal outcome: {outcomes}"
    return {
        "arch": cfg.name, "requests": n_req, "slots": slots,
        "page_size": page_size, "num_pages": num_pages,
        "generated": [len(o) for o in outputs],
        "tokens": outputs,
        "tokens_in_vocab": ok,
        "decode_tok_per_s": toks_done / max(wall, 1e-9),
        "prefills": prefills, "wall_s": wall,
        "kv_bytes_per_token_paged": kv_bytes / max(toks_done, 1),
        "kv_bytes_per_token_dense": dense_bpt,
        "peak_pages_in_use": peak_pages,
        "pool_conserved": (pool.free_pages == num_pages - 1
                           and pool.reserved_pages == 0),
        "ttft_s": ttft_s, "tpot_s": tpot_s, "total_s": total_s,
        "arrival_s": arrival_s,
        "outcomes": outcomes, "outcome_detail": outcome_detail,
        "outcome_counts": n_out,
        "preemptions": sum(preempt_count), "resumes": resumes,
        "good_tokens": good_tokens,
        "goodput_tok_per_s": good_tokens / max(wall, 1e-9),
        "admission": policy.report(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--kv-impl", choices=("dense", "paged"), default="dense")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching loop over a skewed request "
                         "mix (always paged, greedy)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy decode)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest logits (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off)")
    ap.add_argument("--sample-seed", type=int, default=None,
                    help="PRNG seed for sampling (default: --seed's value; "
                         "fixed seed => reproducible tokens)")
    ap.add_argument("--obs-dir", default=None,
                    help="enable observability and write trace.json + "
                         "metrics.jsonl to this directory")
    ap.add_argument("--replan", action="store_true",
                    help="run the reactive re-planning controller on a "
                         "background thread while --continuous serves: "
                         "windows the serve SLO signals (TTFT/TPOT p99, "
                         "queue growth), re-plans on sustained violation "
                         "(enables the metric registry)")
    ap.add_argument("--replan-window-s", type=float, default=1.0,
                    help="telemetry window span in seconds")
    ap.add_argument("--ttft-slo", type=float, default=0.0,
                    help="TTFT p99 SLO in seconds (0 = no SLO trigger)")
    ap.add_argument("--tpot-slo", type=float, default=0.0,
                    help="TPOT p99 SLO in seconds (0 = no SLO trigger)")
    ap.add_argument("--queue-bound", type=int, default=None,
                    help="admission queue depth bound (reject past it; "
                         "the --replan actuator retunes it)")
    ap.add_argument("--max-concurrency", type=int, default=None,
                    help="cap live decode slots below --batch")
    ap.add_argument("--deadline-ttft", type=float, default=None,
                    help="per-request TTFT deadline in seconds from "
                         "arrival (enables deadline-aware admission)")
    ap.add_argument("--deadline-total", type=float, default=None,
                    help="per-request total deadline in seconds from "
                         "arrival")
    ap.add_argument("--preemption", action="store_true",
                    help="preempt-and-resume when the page pool blocks "
                         "the arrived head request")
    ap.add_argument("--watchdog", type=float, default=None,
                    help="decode-chunk stall threshold in seconds "
                         "(stall => obs instant + queue shed pass)")
    args = ap.parse_args()
    if args.obs_dir:
        obs.configure(run_dir=args.obs_dir)
    controller = None
    policy = None
    if args.continuous:
        policy = AdmissionPolicy(slots=args.batch,
                                 queue_bound=args.queue_bound,
                                 max_concurrency=args.max_concurrency)
    if args.replan and args.continuous:
        from repro.core.cost_model import TrainingJob
        from repro.core.profiles import ctrdnn_layers
        from repro.core.replan import (AdmissionActuator, ReplanConfig,
                                       ReplanController)
        from repro.core.resources import default_fleet
        from repro.core.schedulers.rl import RLScheduler
        from repro.obs.bridge import snapshot_resources

        obs.REGISTRY.enabled = True   # the detector reads serve histograms
        rfleet = default_fleet()
        controller = ReplanController(
            ctrdnn_layers(), rfleet, TrainingJob(),
            RLScheduler(rounds=40, plans_per_round=16,
                        early_stop_rounds=15, chunk_rounds=10),
            snapshot_fn=lambda: snapshot_resources(rfleet[0]),
            config=ReplanConfig(window_s=args.replan_window_s,
                                ttft_slo_s=args.ttft_slo,
                                tpot_slo_s=args.tpot_slo),
            admission=AdmissionActuator(policy,
                                        ttft_slo_s=args.ttft_slo))
        controller.start()
    if args.continuous:
        deadlines = None
        if args.deadline_ttft is not None or args.deadline_total is not None:
            deadlines = (args.deadline_ttft, args.deadline_total)
        out = serve_continuous(args.arch, reduced=args.reduced,
                               slots=args.batch, admission=policy,
                               deadlines=deadlines,
                               preemption=args.preemption,
                               watchdog_s=args.watchdog)
    else:
        out = serve(args.arch, reduced=args.reduced, batch=args.batch,
                    prompt_len=args.prompt_len, gen=args.gen,
                    kv_impl=args.kv_impl, temperature=args.temperature,
                    top_k=args.top_k, top_p=args.top_p,
                    sample_seed=args.sample_seed)
    if controller is not None:
        controller.stop()
        out["replan"] = controller.report()
    if args.obs_dir:
        out["obs"] = obs.flush()
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
