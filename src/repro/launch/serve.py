"""Serving launcher: batched prefill + token-by-token decode with KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \\
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import decoder as dec


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, cache_len: int = 128,
          seed: int = 0, compute_dtype=jnp.float32, greedy: bool = True) -> dict:
    cfg = get_config(arch, reduced=reduced)
    key = jax.random.PRNGKey(seed)
    params = dec.init_model(cfg, key)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)
    ctx = None
    if cfg.cross_kv_len:
        n = cfg.encoder.frames if cfg.encoder else cfg.cross_kv_len
        ctx = jax.random.normal(key, (batch, n, cfg.d_model))

    cache = dec.init_cache(cfg, batch, cache_len, dtype=compute_dtype)
    step = jax.jit(
        lambda p, t, c, i: dec.decode_step(p, cfg, t, c, i,
                                           compute_dtype=compute_dtype)
    )
    # prefill by stepping the prompt (teacher-forced decode steps)
    t0 = time.time()
    for i in range(prompt_len):
        logits, cache = step(params, prompts[:, i : i + 1], cache, jnp.int32(i))
    prefill_s = time.time() - t0

    generated = []
    tok = jnp.argmax(logits[:, :, : cfg.vocab], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for i in range(gen):
        generated.append(np.asarray(tok)[:, 0])
        logits, cache = step(params, tok, cache, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, :, : cfg.vocab], axis=-1).astype(jnp.int32)
    decode_s = time.time() - t0
    out = np.stack(generated, axis=1)
    return {
        "arch": cfg.name, "batch": batch, "generated_shape": list(out.shape),
        "tokens_in_vocab": bool((out >= 0).all() and (out < cfg.vocab).all()),
        "prefill_s": prefill_s, "decode_s": decode_s,
        "decode_tok_per_s": batch * gen / max(decode_s, 1e-9),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    print(json.dumps(serve(args.arch, reduced=args.reduced, batch=args.batch,
                           prompt_len=args.prompt_len, gen=args.gen), indent=2))


if __name__ == "__main__":
    main()
