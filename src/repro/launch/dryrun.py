import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# This flag is dry-run-only — smoke tests and benchmarks see 1 device.

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination against the production mesh, prove it fits (memory analysis),
and extract the roofline terms (cost analysis + HLO collective bytes).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out results.json
"""

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, input_specs, supports
from repro.parallel.sharding import named
from repro.roofline import collective_bytes_from_hlo, roofline_terms

_COLL_RE = re.compile(
    r"=\s+((?:[a-z0-9]+)\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               donate: bool = True, hlo_out: str | None = None) -> dict:
    cfg = get_config(arch)
    if not supports(cfg, shape_name):
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full-attention arch at 524k context (DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    step, args, specs, donate = input_specs(cfg, shape_name, mesh)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "num_devices": mesh.size}
    with mesh:
        jitted = jax.jit(step, in_shardings=named(mesh, specs),
                         donate_argnums=donate if donate else ())
        t0 = time.perf_counter()
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.perf_counter() - t0, 2)
        t0 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t0, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": (
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
        }
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # jax <= 0.4.x: one dict per program
            ca = ca[0] if ca else {}
        rec["cost"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes_from_hlo(hlo)
        if hlo_out:
            with open(hlo_out, "w") as f:
                f.write(hlo)
        # XLA-CPU cost_analysis (and the printed HLO) single-counts
        # while-loop bodies.  Correct by the known loop structure: the
        # train step scans microbatches × pattern repeats; prefill scans
        # repeats; decode is unrolled (factor 1).  Approximation noted in
        # EXPERIMENTS.md (ops outside the double scan get over-scaled).
        from repro.launch.specs import SHAPES

        kind = SHAPES[shape_name].kind
        if kind == "train":
            n_micro = max(1, SHAPES[shape_name].global_batch
                          // max(cfg.train_microbatch, 1))
            factor = n_micro * cfg.repeats
        elif kind == "prefill":
            factor = cfg.repeats
        else:
            factor = 1
        rec["scan_correction"] = factor
        # terms from the raw (single-counted) HLO aggregates — a uniform
        # trip multiplier would over-scale non-loop ops, so memory /
        # collective terms are per-loop-iteration LOWER BOUNDS for scanned
        # (train/prefill) shapes and exact for decode (unrolled).
        rec["roofline"] = roofline_terms(
            flops=rec["cost"]["flops"],
            hbm_bytes=rec["cost"]["bytes_accessed"],
            collective_bytes=rec["collectives"]["total_bytes"],
        )
        # corrected compute floor: scan-body flops × trips ≈ true per-step
        # FLOPs (validated ≈ 6·N·D + remat for the dense archs).
        from repro.roofline import PEAK_FLOPS

        rec["roofline"]["compute_s_corrected"] = (
            rec["cost"]["flops"] * factor / PEAK_FLOPS
        )
        rec["status"] = "ok"
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--hlo-out", default=None)
    args = ap.parse_args()

    pairs = (
        [(a, s) for a in ARCH_IDS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    results = []
    for arch, shape in pairs:
        try:
            rec = dryrun_one(arch, shape, multi_pod=args.multi_pod,
                             hlo_out=args.hlo_out)
        except Exception as e:  # a failure here is a bug in the system
            rec = {"arch": arch, "shape": shape, "status": "FAILED",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        results.append(rec)
        mem = rec.get("memory", {}).get("peak_bytes_per_device", 0) / 2**30
        print(f"[{rec['status']:7s}] {arch:24s} {shape:12s} "
              f"mem/dev={mem:6.2f}GiB "
              f"lower={rec.get('lower_s', 0):6.1f}s "
              f"compile={rec.get('compile_s', 0):6.1f}s "
              + (rec.get("error", "") if rec["status"] == "FAILED" else ""),
              flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
    failed = [r for r in results if r["status"] == "FAILED"]
    if failed:
        raise SystemExit(f"{len(failed)} dry-run failures")


if __name__ == "__main__":
    main()
