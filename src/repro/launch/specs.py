"""Input shapes (assigned) + ShapeDtypeStruct stand-ins for the dry-run.

``input_specs()`` returns weak-type-correct, shardable ShapeDtypeStructs
for every model input — no device allocation ever happens for the full
configs; they are only lowered/compiled.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import steps as steps_mod
from repro.models import decoder as dec
from repro.models.config import ArchConfig
from repro.optim import adamw_init
from repro.parallel import sharding as shd

#: Gemma-2 global-attention KV cap used for the 500k decode (DESIGN.md §4)
GLOBAL_ATTN_CAP_500K = 32768


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def supports(cfg: ArchConfig, shape_name: str) -> bool:
    """long_500k needs sub-quadratic attention (SSM/hybrid/sliding-window);
    skips recorded in DESIGN.md / EXPERIMENTS.md."""
    if shape_name == "long_500k":
        return cfg.supports_long_context
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _context_sds(cfg: ArchConfig, batch: int):
    if cfg.encoder is not None:
        return _sds((batch, cfg.encoder.frames, cfg.d_model), jnp.float32)
    if cfg.cross_kv_len:
        return _sds((batch, cfg.cross_kv_len, cfg.d_model), jnp.float32)
    return None


def _template(fn, *args):
    return jax.eval_shape(fn, *args)


def param_templates(cfg: ArchConfig):
    params_t = _template(
        functools.partial(dec.init_model, cfg), jax.random.PRNGKey(0)
    )
    opt_t = _template(adamw_init, params_t)
    return params_t, opt_t


def input_specs(arch: str | ArchConfig, shape_name: str, mesh):
    """→ (step_fn, args (ShapeDtypeStructs), in_shardings PartitionSpecs).

    ``step_fn`` is the function the production launcher jits for this
    (arch × shape): ``train_step`` / ``prefill_step`` / ``serve_step``.
    """
    cfg = get_config(arch) if isinstance(arch, str) else arch
    shape = SHAPES[shape_name]
    if not supports(cfg, shape_name):
        raise ValueError(f"{cfg.name} does not support {shape_name} "
                         "(full-attention at 524k — see DESIGN.md)")
    B, S = shape.global_batch, shape.seq_len
    params_t, opt_t = param_templates(cfg)
    p_spec = shd.param_specs(params_t, cfg, mesh)

    if shape.kind == "train":
        batch_t = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        ctx = _context_sds(cfg, B)
        if ctx is not None:
            batch_t["context"] = ctx
        b_spec = shd.batch_specs(batch_t, mesh, batch_size=B)
        step = steps_mod.make_train_step(cfg, microbatch=cfg.train_microbatch)
        # params/opt donate: the updated state aliases the old buffers
        return (step, (params_t, opt_t, batch_t),
                (p_spec, shd.param_specs(opt_t, cfg, mesh), b_spec), (0, 1))

    if shape.kind == "prefill":
        batch_t = {"tokens": _sds((B, S), jnp.int32)}
        ctx = _context_sds(cfg, B)
        if ctx is not None:
            batch_t["context"] = ctx
        b_spec = shd.batch_specs(batch_t, mesh, batch_size=B)
        step = steps_mod.make_prefill_step(cfg)
        return step, (params_t, batch_t), (p_spec, b_spec), ()

    # decode: one new token against a seq_len cache
    cap = GLOBAL_ATTN_CAP_500K if shape_name == "long_500k" else None
    cache_t = _template(
        functools.partial(dec.init_cache, cfg, B, S, global_cap=cap)
    )
    token_t = _sds((B, 1), jnp.int32)
    index_t = _sds((), jnp.int32)
    c_spec = shd.cache_specs(cache_t, cfg, mesh, batch_size=B)
    t_spec = shd.batch_specs({"t": token_t}, mesh, batch_size=B)["t"]
    from jax.sharding import PartitionSpec as P

    step = steps_mod.make_serve_step(cfg)
    # cache donates: decode updates it in place
    return (step, (params_t, token_t, cache_t, index_t),
            (p_spec, t_spec, c_spec, P()), (2,))
