"""Training launcher.

On real hardware this runs the full configs against the production mesh;
on the CPU container it trains *reduced* variants end-to-end (synthetic
data, prefetch, checkpointing, metrics) — the full configs are exercised
via ``dryrun.py``.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \\
      --reduced --steps 100 --batch 8 --seq 128

``--sparse-ps`` switches to the sparse path: the reduced CTR workload
trained over the sharded parameter server (``repro.ps``), with async
double-buffered pull/push overlap and tier-aware row placement:

  PYTHONPATH=src python -m repro.launch.train --sparse-ps \\
      --steps 200 --ps-shards 4
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint import save_checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.data import PrefetchLoader, SyntheticTokenDataset
from repro.launch.steps import init_train_state, make_train_step
from repro.obs import trace as obs_trace


def train(arch: str, *, reduced: bool = True, steps: int = 50,
          batch: int = 8, seq: int = 128, lr: float = 3e-4,
          microbatch: int | None = None, seed: int = 0,
          checkpoint_dir: str | None = None, log_every: int = 10,
          compute_dtype=jnp.float32) -> dict:
    cfg = get_config(arch, reduced=reduced) if isinstance(arch, str) else arch
    key = jax.random.PRNGKey(seed)
    params, opt_state = init_train_state(cfg, key)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))

    ctx_len = cfg.encoder.frames if cfg.encoder else cfg.cross_kv_len
    ds = SyntheticTokenDataset(cfg.vocab, batch, seq, seed=seed,
                               context_len=ctx_len, d_model=cfg.d_model)
    loader = PrefetchLoader(ds, depth=2)
    step_fn = jax.jit(make_train_step(cfg, lr=lr, microbatch=microbatch,
                                      compute_dtype=compute_dtype))

    losses = []
    reg = obs.REGISTRY
    t0 = time.perf_counter()
    for i in range(steps):
        td = time.perf_counter()
        batch_np = next(loader)
        reg.histogram("train.data_s").record(time.perf_counter() - td)
        ts = time.perf_counter()
        with obs_trace.span("train.step", "train", step=i):
            jbatch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt_state, metrics = step_fn(params, opt_state, jbatch)
            # float() syncs the step — the histogram sees real step time
            losses.append(float(metrics["loss"]))
        reg.histogram("train.step_s").record(time.perf_counter() - ts)
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.perf_counter() - t0) / (i + 1):.2f}s/step)", flush=True)
    loader.close()
    if checkpoint_dir:
        save_checkpoint(checkpoint_dir, params=params, opt_state=opt_state,
                        step=steps, metadata={"arch": cfg.name})
        print(f"checkpoint -> {checkpoint_dir}")
    # head/tail means: a single-sample first-vs-last comparison is noise
    # on fresh-random batches (per-batch loss σ ≈ 0.05 at smoke scale)
    k = max(1, min(5, steps // 4))
    return {
        "arch": cfg.name, "params": n_params, "steps": steps,
        "first_loss": losses[0], "last_loss": losses[-1],
        "loss_decreased": float(np.mean(losses[-k:])) < float(np.mean(losses[:k])),
        "seconds": time.perf_counter() - t0,
    }


def train_sparse_ps(*, steps: int, batch: int | None = None,
                    lr: float | None = None, num_shards: int = 4,
                    sync: bool = False, partition: str = "mod",
                    repin_interval: int = 50, log_every: int = 10,
                    transport: str | None = None,
                    optimizer: str = "none",
                    events: list[tuple[int, str, int | None]] | None = None,
                    staleness_bound: int = 8,
                    ckpt_dir: str | None = None, ckpt_every: int = 0,
                    fault_schedule: str | None = None,
                    fault_seed: int = 0,
                    replan=None) -> dict:
    """The ``--sparse-ps`` path: reduced CTR model over the sharded PS
    (``repro.ps``) — async double-buffered pull/push unless ``sync``.
    ``batch``/``lr`` default to the CTR workload's own values.

    ``transport`` picks the PS backend (``inproc`` | ``multiproc``).
    ``optimizer="none"`` (default) keeps the static :class:`ShardedTable`
    with client-side SGD — the bit-exact oracle path; any other value
    (``sgd``/``adagrad``/``adam``) trains over the **elastic fleet** with
    the optimizer hosted on the PS shards, and ``events`` scripts fleet
    changes mid-run (see :func:`repro.ps.workload.train_ctr_elastic`).

    ``ckpt_dir`` + ``ckpt_every`` arm crash-consistent unified
    checkpoints (fleet slabs + optimizer state + tower + data cursor);
    after a correlated primary+backup loss the run restores the newest
    checkpoint and replays to a bit-exact trajectory.  ``fault_schedule``
    (``repro.ps.faults.parse_schedule`` syntax) injects deterministic
    chaos.  Both force the elastic fleet and sync mode.

    ``replan`` (a :class:`repro.core.replan.ReplanConfig`) arms the
    reactive re-planning controller: live PS telemetry + fleet health
    are windowed into interval rates, drift triggers a warm-started RL
    re-plan, and the decisions land in the summary under ``"replan"``.
    Forces the elastic fleet (the controller consumes fleet health).
    """
    import dataclasses

    from repro.ps.workload import CTRConfig, train_ctr_elastic, train_ctr_ps

    cfg = CTRConfig()
    overrides = {k: v for k, v in (("batch", batch), ("lr", lr))
                 if v is not None}
    cfg = dataclasses.replace(cfg, **overrides)
    chaos = bool((ckpt_dir and ckpt_every) or fault_schedule)
    if optimizer != "none" or events or chaos or replan is not None:
        factory = None
        if replan is not None:
            from repro.core.replan import ctr_replan_factory

            factory = ctr_replan_factory(replan)
        return train_ctr_elastic(
            cfg, steps=steps, num_shards=num_shards,
            optimizer=optimizer if optimizer != "none" else "sgd",
            transport=transport,
            mode="sync" if sync or chaos else "async",
            events=events, staleness_bound=staleness_bound,
            fault_schedule=fault_schedule, fault_seed=fault_seed,
            ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
            replan=factory,
            log_every=log_every)
    return train_ctr_ps(cfg, steps=steps, num_shards=num_shards,
                        mode="sync" if sync else "async",
                        partition=partition, repin_interval=repin_interval,
                        log_every=log_every, transport=transport)


def _parse_ps_events(specs: list[str]) -> list[tuple[int, str, int | None]]:
    """``STEP:ACTION[:SHARD]`` → scripted fleet events, e.g.
    ``40:join`` / ``80:kill:0`` / ``120:leave:1``."""
    events = []
    for spec in specs:
        parts = spec.split(":")
        if len(parts) not in (2, 3) or parts[1] not in ("join", "kill",
                                                        "leave"):
            raise SystemExit(f"bad --ps-event {spec!r} "
                             f"(want STEP:join|kill|leave[:SHARD])")
        events.append((int(parts[0]), parts[1],
                       int(parts[2]) if len(parts) == 3 else None))
    return events


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    # batch/lr defaults depend on the path (dense: 8 / 3e-4; sparse-ps:
    # the CTR workload's 256 / 0.05), so resolve after parsing
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--sparse-ps", action="store_true",
                    help="train the reduced CTR workload over the sharded "
                         "parameter server instead of a dense arch")
    ap.add_argument("--ps-shards", type=int, default=4)
    ap.add_argument("--ps-sync", action="store_true",
                    help="synchronous pull→compute→push (no overlap)")
    ap.add_argument("--ps-partition", choices=("mod", "block"), default="mod")
    ap.add_argument("--ps-transport", choices=("inproc", "multiproc"),
                    default=None,
                    help="PS backend: in-process queues (default) or one "
                         "worker process per shard")
    ap.add_argument("--ps-optimizer",
                    choices=("none", "sgd", "adagrad", "adam"),
                    default="none",
                    help="PS-hosted optimizer; any value but 'none' trains "
                         "over the elastic fleet")
    ap.add_argument("--ps-event", action="append", default=[],
                    metavar="STEP:ACTION[:SHARD]",
                    help="scripted elastic fleet event, repeatable — e.g. "
                         "'40:join', '80:kill:0', '120:leave:1'")
    ap.add_argument("--ps-staleness-bound", type=int, default=8,
                    help="max updates a pull may miss during live "
                         "migration (0 = full dual-write)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="unified fleet checkpoints (PS slabs + optimizer "
                         "state + tower + data cursor) under this "
                         "directory; restores after correlated "
                         "primary+backup loss replay bit-exactly")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint cadence in steps (0 = off)")
    ap.add_argument("--ps-fault", default=None,
                    metavar="RULE[;RULE...]",
                    help="deterministic fault schedule, e.g. "
                         "'drop_reply,op=grad,after=100,times=2;"
                         "crash,shard=0,after=400,times=1' "
                         "(see repro.ps.faults.parse_schedule)")
    ap.add_argument("--ps-fault-seed", type=int, default=0)
    ap.add_argument("--replan", action="store_true",
                    help="arm the reactive re-planning controller: window "
                         "PS telemetry + fleet health into interval rates, "
                         "re-run the warm-started RL search on drift "
                         "(forces the elastic fleet)")
    ap.add_argument("--replan-window-steps", type=int, default=25,
                    help="steps per telemetry window")
    ap.add_argument("--replan-bw-tol", type=float, default=0.5,
                    help="relative bandwidth deviation that counts as drift")
    ap.add_argument("--replan-margin", type=float, default=0.05,
                    help="fractional cost improvement required to switch "
                         "plans")
    ap.add_argument("--replan-cooldown", type=int, default=3,
                    help="windows to sit out after a replan consideration")
    ap.add_argument("--obs-dir", default=None,
                    help="enable observability and write trace.json + "
                         "metrics.jsonl to this directory (multiproc PS "
                         "workers inherit the switch and ship their spans "
                         "back as separate pid lanes)")
    args = ap.parse_args()
    if args.obs_dir:
        # before any transport spawn, so shard workers inherit REPRO_OBS
        obs.configure(run_dir=args.obs_dir)
    if args.sparse_ps:
        replan_cfg = None
        if args.replan:
            from repro.core.replan import ReplanConfig

            replan_cfg = ReplanConfig(
                window_steps=args.replan_window_steps,
                bw_tolerance=args.replan_bw_tol,
                switch_margin=args.replan_margin,
                cooldown_windows=args.replan_cooldown)
        summary = train_sparse_ps(
            steps=args.steps, batch=args.batch, lr=args.lr,
            num_shards=args.ps_shards, sync=args.ps_sync,
            partition=args.ps_partition, transport=args.ps_transport,
            optimizer=args.ps_optimizer,
            events=_parse_ps_events(args.ps_event),
            staleness_bound=args.ps_staleness_bound,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            fault_schedule=args.ps_fault, fault_seed=args.ps_fault_seed,
            replan=replan_cfg)
        summary.pop("step_times", None)
        summary.pop("step_ts", None)
        summary.pop("losses", None)
        summary.pop("injections", None)
    else:
        summary = train(args.arch, reduced=args.reduced, steps=args.steps,
                        batch=args.batch if args.batch is not None else 8,
                        seq=args.seq,
                        lr=args.lr if args.lr is not None else 3e-4,
                        microbatch=args.microbatch,
                        checkpoint_dir=args.checkpoint_dir)
    if args.obs_dir:
        summary["obs"] = obs.flush()
    print(json.dumps(summary, indent=2))


if __name__ == "__main__":
    main()
