"""Training launcher.

On real hardware this runs the full configs against the production mesh;
on the CPU container it trains *reduced* variants end-to-end (synthetic
data, prefetch, checkpointing, metrics) — the full configs are exercised
via ``dryrun.py``.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \\
      --reduced --steps 100 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.data import PrefetchLoader, SyntheticTokenDataset
from repro.launch.steps import init_train_state, make_train_step


def train(arch: str, *, reduced: bool = True, steps: int = 50,
          batch: int = 8, seq: int = 128, lr: float = 3e-4,
          microbatch: int | None = None, seed: int = 0,
          checkpoint_dir: str | None = None, log_every: int = 10,
          compute_dtype=jnp.float32) -> dict:
    cfg = get_config(arch, reduced=reduced)
    key = jax.random.PRNGKey(seed)
    params, opt_state = init_train_state(cfg, key)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))

    ctx_len = cfg.encoder.frames if cfg.encoder else cfg.cross_kv_len
    ds = SyntheticTokenDataset(cfg.vocab, batch, seq, seed=seed,
                               context_len=ctx_len, d_model=cfg.d_model)
    loader = PrefetchLoader(ds, depth=2)
    step_fn = jax.jit(make_train_step(cfg, lr=lr, microbatch=microbatch,
                                      compute_dtype=compute_dtype))

    losses = []
    t0 = time.time()
    for i in range(steps):
        batch_np = next(loader)
        jbatch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        params, opt_state, metrics = step_fn(params, opt_state, jbatch)
        losses.append(float(metrics["loss"]))
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)", flush=True)
    loader.close()
    if checkpoint_dir:
        save_checkpoint(checkpoint_dir, params=params, opt_state=opt_state,
                        step=steps, metadata={"arch": cfg.name})
        print(f"checkpoint -> {checkpoint_dir}")
    return {
        "arch": cfg.name, "params": n_params, "steps": steps,
        "first_loss": losses[0], "last_loss": losses[-1],
        "loss_decreased": losses[-1] < losses[0],
        "seconds": time.time() - t0,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()
    summary = train(args.arch, reduced=args.reduced, steps=args.steps,
                    batch=args.batch, seq=args.seq, lr=args.lr,
                    microbatch=args.microbatch,
                    checkpoint_dir=args.checkpoint_dir)
    print(json.dumps(summary, indent=2))


if __name__ == "__main__":
    main()
