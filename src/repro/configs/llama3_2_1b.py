"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 [hf:meta-llama/Llama-3.2-1B]."""

from repro.models.config import ArchConfig, LayerSpec

_LAYER = LayerSpec(mixer="attn", ffn="dense")


def config() -> ArchConfig:
    return ArchConfig(
        name="llama3.2-1b", family="dense",
        source="hf:meta-llama/Llama-3.2-1B",
        d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
        d_ff=8192, vocab=128256,
        pattern=(_LAYER,), repeats=16,
        rope_theta=500000.0, tie_embeddings=True,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="llama3.2-1b-reduced", family="dense", source="smoke",
        d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab=1024,
        pattern=(_LAYER,), repeats=2,
        rope_theta=500000.0, tie_embeddings=True,
    )
