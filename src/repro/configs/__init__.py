"""Assigned-architecture configs (``--arch <id>``) + the paper's CTR models.

Each module exports ``config()`` (the exact assigned full-size config) and
``reduced()`` (a ≤2-layer, d_model≤512, ≤4-expert variant of the same
family for CPU smoke tests).
"""

from __future__ import annotations

import importlib

ARCH_IDS = (
    "jamba-v0.1-52b",
    "rwkv6-7b",
    "chatglm3-6b",
    "olmoe-1b-7b",
    "gemma2-2b",
    "internlm2-20b",
    "whisper-large-v3",
    "llama3.2-1b",
    "qwen3-moe-30b-a3b",
    "llama-3.2-vision-11b",
)

_MODULES = {
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "rwkv6-7b": "rwkv6_7b",
    "chatglm3-6b": "chatglm3_6b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "gemma2-2b": "gemma2_2b",
    "internlm2-20b": "internlm2_20b",
    "whisper-large-v3": "whisper_large_v3",
    "llama3.2-1b": "llama3_2_1b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
}


def get_config(arch_id: str, *, reduced: bool = False):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    cfg = mod.reduced() if reduced else mod.config()
    cfg.validate()
    return cfg
