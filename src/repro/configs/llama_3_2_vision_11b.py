"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

Backbone only (assignment carve-out): the ViT vision encoder + projector
is a stub — ``input_specs()`` provides projected patch embeddings
(B, 1601, 4096) consumed by the cross-attention layers.  Pattern: every
5th layer is a cross-attention layer (8 of 40), matching the model card.
"""

from repro.models.config import ArchConfig, LayerSpec

_SELF = LayerSpec(mixer="attn", ffn="dense")
_CROSS = LayerSpec(mixer="cross_attn", ffn="dense", rope=False)


def config() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-11b", family="vlm",
        source="hf:meta-llama/Llama-3.2-11B-Vision",
        d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=128256,
        pattern=(_SELF, _SELF, _SELF, _SELF, _CROSS), repeats=8,
        rope_theta=500000.0, cross_kv_len=1601,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-11b-reduced", family="vlm", source="smoke",
        d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab=1024,
        pattern=(_SELF, _CROSS), repeats=1,
        rope_theta=500000.0, cross_kv_len=64,
    )
