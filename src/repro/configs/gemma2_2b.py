"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000 — local+global alternating attention, logit softcap
[arXiv:2408.00118].

Runs ``long_500k``: the local (sliding-window 4096) layers are
sub-quadratic; global layers attend over the full cache (DESIGN.md notes
the 32k cap used for the 500k decode dry-run).
"""

from repro.models.config import ArchConfig, LayerSpec

_LOCAL = LayerSpec(mixer="attn", ffn="dense", window=4096,
                   logit_softcap=50.0, post_norm=True)
_GLOBAL = LayerSpec(mixer="attn", ffn="dense",
                    logit_softcap=50.0, post_norm=True)


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma2-2b", family="dense", source="arXiv:2408.00118",
        d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=9216, vocab=256000,
        pattern=(_LOCAL, _GLOBAL), repeats=13,
        tie_embeddings=True, embed_scale=True, final_softcap=30.0,
        supports_long_context=True,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="gemma2-2b-reduced", family="dense", source="smoke",
        d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab=1024,
        pattern=(
            LayerSpec(mixer="attn", ffn="dense", window=32,
                      logit_softcap=50.0, post_norm=True),
            _GLOBAL,
        ),
        repeats=1,
        tie_embeddings=True, embed_scale=True, final_softcap=30.0,
        supports_long_context=True,
    )
