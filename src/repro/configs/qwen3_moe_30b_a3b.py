"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768
(per-expert), vocab=151936, MoE 128e top-8 [hf:Qwen/Qwen3-30B-A3B]."""

from repro.models.config import ArchConfig, LayerSpec

_LAYER = LayerSpec(mixer="attn", ffn="moe", qk_norm=True)


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b", family="moe", source="hf:Qwen/Qwen3-30B-A3B",
        d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=768, vocab=151936,
        pattern=(_LAYER,), repeats=48,
        moe_experts=128, moe_top_k=8, moe_d_ff=768,
        rope_theta=1000000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen3-moe-30b-a3b-reduced", family="moe", source="smoke",
        d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=128, vocab=1024,
        pattern=(_LAYER,), repeats=2,
        moe_experts=4, moe_top_k=2, moe_d_ff=128,
        rope_theta=1000000.0,
    )
