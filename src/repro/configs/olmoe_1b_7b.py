"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64e top-8 [arXiv:2409.02060]."""

from repro.models.config import ArchConfig, LayerSpec

_LAYER = LayerSpec(mixer="attn", ffn="moe", qk_norm=True)


def config() -> ArchConfig:
    return ArchConfig(
        name="olmoe-1b-7b", family="moe", source="arXiv:2409.02060",
        d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=1024, vocab=50304,
        pattern=(_LAYER,), repeats=16,
        moe_experts=64, moe_top_k=8, moe_d_ff=1024,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="olmoe-1b-7b-reduced", family="moe", source="smoke",
        d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=256, vocab=1024,
        pattern=(_LAYER,), repeats=2,
        moe_experts=4, moe_top_k=2, moe_d_ff=256,
    )
