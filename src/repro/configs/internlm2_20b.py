"""internlm2-20b [dense] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 — GQA [arXiv:2403.17297]."""

from repro.models.config import ArchConfig, LayerSpec

_LAYER = LayerSpec(mixer="attn", ffn="dense")


def config() -> ArchConfig:
    return ArchConfig(
        name="internlm2-20b", family="dense", source="arXiv:2403.17297",
        d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab=92544,
        pattern=(_LAYER,), repeats=48,
        rope_theta=1000000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="internlm2-20b-reduced", family="dense", source="smoke",
        d_model=384, n_heads=6, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab=1024,
        pattern=(_LAYER,), repeats=2,
        rope_theta=1000000.0,
    )
