"""whisper-large-v3 [audio] — 32L d_model=1280 20H d_ff=5120 vocab=51866 —
encoder-decoder, conv frontend STUB [arXiv:2212.04356].

Backbone only (assignment carve-out): the mel-spectrogram + conv feature
extractor is a stub — ``input_specs()`` provides precomputed frame
embeddings (B, 1500, 1280).  32 bidirectional encoder layers + 32 decoder
layers (self-attn + cross-attn).  Learned positions, LayerNorm, no RoPE.
Decode shapes lower ``serve_step`` with a fixed cross-KV cache;
``long_500k`` is skipped (enc-dec over 30-s windows — see DESIGN.md).
"""

from repro.models.config import ArchConfig, EncoderConfig, LayerSpec

_DEC = LayerSpec(mixer="attn+cross", ffn="dense", rope=False)


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-large-v3", family="audio", source="arXiv:2212.04356",
        d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
        d_ff=5120, vocab=51866,
        pattern=(_DEC,), repeats=32,
        pos_embed="learned", max_position=32768, norm="ln",
        encoder=EncoderConfig(num_layers=32, frames=1500),
        cross_kv_len=1500, tie_embeddings=True,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="whisper-large-v3-reduced", family="audio", source="smoke",
        d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab=1024,
        pattern=(_DEC,), repeats=2,
        pos_embed="learned", max_position=512, norm="ln",
        encoder=EncoderConfig(num_layers=2, frames=64),
        cross_kv_len=64, tie_embeddings=True,
    )
