"""rwkv6-7b [ssm] — 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536 — Finch, data-dependent decay [arXiv:2404.05892].

Attention-free recurrent state → runs ``long_500k`` natively (O(1)
per-token state, no KV growth).
"""

from repro.models.config import ArchConfig, LayerSpec

_LAYER = LayerSpec(mixer="rwkv", ffn="channel_mix", rope=False)


def config() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-7b", family="ssm", source="arXiv:2404.05892",
        d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
        d_ff=14336, vocab=65536,
        pattern=(_LAYER,), repeats=32,
        pos_embed="none", rwkv_head_size=64,
        supports_long_context=True,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-7b-reduced", family="ssm", source="smoke",
        d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab=1024,
        pattern=(_LAYER,), repeats=2,
        pos_embed="none", rwkv_head_size=64,
        supports_long_context=True,
    )
