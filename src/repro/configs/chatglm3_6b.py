"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — 2-d (partial) RoPE, GQA [arXiv:2406.12793]."""

from repro.models.config import ArchConfig, LayerSpec

_LAYER = LayerSpec(mixer="attn", ffn="dense", rope_fraction=0.5)


def config() -> ArchConfig:
    return ArchConfig(
        name="chatglm3-6b", family="dense", source="arXiv:2406.12793",
        d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
        d_ff=13696, vocab=65024,
        pattern=(_LAYER,), repeats=28,
        rope_theta=10000.0,
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="chatglm3-6b-reduced", family="dense", source="smoke",
        d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab=1024,
        pattern=(_LAYER,), repeats=2,
    )
