"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2 — Mamba+attention 1:7 interleave, MoE every
2nd layer [arXiv:2403.19887].

Jamba block structure: 8-layer period with ONE attention layer (index 3)
and seven Mamba layers; MoE replaces the dense FFN on every second layer.
No positional embeddings (Mamba carries position).  Runs ``long_500k``:
only 4 attention layers hold KV caches; everything else is O(1) state.
"""

from repro.models.config import ArchConfig, LayerSpec


def _pattern() -> tuple[LayerSpec, ...]:
    specs = []
    for i in range(8):
        mixer = "attn" if i == 3 else "mamba"
        ffn = "moe" if i % 2 == 1 else "dense"
        specs.append(LayerSpec(mixer=mixer, ffn=ffn, rope=False))
    return tuple(specs)


def config() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b", family="hybrid", source="arXiv:2403.19887",
        d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=14336, vocab=65536,
        pattern=_pattern(), repeats=4,
        moe_experts=16, moe_top_k=2, moe_d_ff=14336,
        pos_embed="none",
        mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
        supports_long_context=True,
        train_microbatch=16,  # §Perf cycle 2: 8192-wide mamba activations
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="jamba-v0.1-52b-reduced", family="hybrid", source="smoke",
        d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=512, vocab=1024,
        pattern=(
            LayerSpec(mixer="mamba", ffn="dense", rope=False),
            LayerSpec(mixer="attn", ffn="moe", rope=False),
        ),
        repeats=1,
        moe_experts=4, moe_top_k=2, moe_d_ff=512,
        pos_embed="none",
        supports_long_context=True,
    )
