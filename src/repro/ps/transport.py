"""Pluggable worker↔PS transport (HeterPS §3's network hop, made real).

Every PS consumer (:class:`~repro.ps.sharding.ShardedTable`,
:class:`~repro.ps.elastic.ElasticPSFleet`, and through them
``PSClient``) speaks the message protocol of
:mod:`repro.ps.server` to shard endpoints through one of two backends:

* :class:`InProcTransport` — shards are :class:`~repro.ps.server.
  ShardServer` objects behind per-shard mailbox queues in this process.
  Deterministic and copy-free: the backend for tests, CI and the
  bit-exact oracle path.
* :class:`MultiprocTransport` — each shard is a **real OS process**
  running :func:`~repro.ps.server.shard_main` behind a duplex
  ``multiprocessing`` connection (an AF_UNIX socketpair / OS pipe — the
  same framing a TCP deployment would use).  Requests to distinct
  shards fly in parallel (`request_many` sends to every shard before
  collecting replies); requests to one shard are serialized by a
  per-shard lock, which is also what makes the transport safe under
  ``PSClient``'s puller/pusher thread pair.

Failure semantics are part of the contract, and they come in **three**
grades:

* a shard that answers with ``{"err": ...}`` raises
  :class:`PSShardError` — the shard is alive, the request was bad;
* a shard that is *slow* (poll deadline expired but the worker process
  is still alive, an injected transient fault, a stale/duplicated
  reply) raises :class:`PSShardSlow` **internally** — the base-class
  retry loop consumes it: exponential backoff + jitter, optional hedged
  resends for idempotent ops, and escalation to ``PSShardLost`` only
  after ``RetryPolicy.max_attempts``;
* a shard that is *gone* (killed, crashed, closed pipe, or escalated
  from slow) raises :class:`PSShardLost` — what the elastic fleet's
  recovery path catches.  On the multiprocess backend the message
  carries the op name, elapsed time and the worker's exit code, so a
  hung worker is never misreported as a dead one.

Retries are safe for **every** op — including non-idempotent ``grad``
pushes — because each logical request carries a transport-assigned
``seq`` and :class:`~repro.ps.server.ShardServer` keeps a bounded
seq→reply cache: a resent request is answered from the cache without
re-applying (classic at-most-once RPC).  Stale replies (a timed-out
attempt's answer arriving late, or a fault-injected duplicate) are
discarded by seq mismatch.

``MultiprocTransport`` additionally runs a **heartbeat** thread: dead
worker processes are detected within ``heartbeat_s`` and reported
through ``on_shard_lost`` (the elastic fleet hooks this to recover
proactively) instead of on the next pull/push touch.

``kill()`` is the fault injector: it terminates the worker *without*
any flush, so whatever the shard acked last is exactly what a replica
must reproduce.  :class:`repro.ps.faults.FaultInjector` wraps any
transport for deterministic chaos (delays, dropped/dup replies,
transient recv errors, scheduled crashes).
"""

from __future__ import annotations

import contextlib
import dataclasses
import itertools
import random
import threading
import time
from collections import deque

from repro.obs import trace as obs_trace
from repro.ps.server import ShardServer, shard_main


class PSShardError(RuntimeError):
    """The shard processed the request and reported a failure."""


class PSShardLost(RuntimeError):
    """The shard is gone (killed, crashed, or escalated from slow) — the
    request may or may not have been applied.  Recovery promotes the
    replica."""


class PSShardSlow(RuntimeError):
    """Transient: the shard did not answer in time but its process is
    (or may be) alive — retryable.  Consumed by the transport's retry
    loop and escalated to :class:`PSShardLost` after
    ``RetryPolicy.max_attempts``; callers normally never see it."""


#: ops whose replies carry no state change on the shard — safe to hedge
#: (race a duplicate in-flight request) even *without* the seq cache
IDEMPOTENT_OPS = frozenset(
    {"pull", "snapshot", "stats", "ping", "demote"})


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Per-request retry/hedging knobs shared by every backend.

    ``max_attempts`` counts the first try; ``backoff_s`` doubles (times
    ``backoff_mult``) up to ``max_backoff_s``, with up to ``jitter``
    fraction of uniform extra sleep so a fleet of clients doesn't
    retry in lockstep.  ``hedge_s`` (multiproc only): after this many
    seconds without a reply to an *idempotent* op, resend the same
    request (same seq) so the duplicate races the original — first
    reply wins, the loser is discarded by seq.
    """

    max_attempts: int = 3
    backoff_s: float = 0.05
    backoff_mult: float = 2.0
    max_backoff_s: float = 1.0
    jitter: float = 0.5
    hedge_s: float | None = None


def _check(reply: dict, shard_id: int) -> dict:
    if reply.get("err"):
        raise PSShardError(
            f"shard {shard_id} failed request:\n{reply['err']}")
    return reply


def _raise_lost(lost: set[int]):
    err = PSShardLost(f"shards lost mid-request: {sorted(lost)}")
    err.shard_ids = lost
    raise err


class Transport:
    """Abstract worker↔PS message channel.

    ``add_shard`` brings a new endpoint up (the *elastic join* primitive),
    ``request``/``request_many`` are blocking RPCs, ``stop_shard`` is a
    graceful leave, ``kill_shard`` a hard failure.  Implementations keep
    per-shard FIFO ordering — the protocol relies on it (an ``install``
    sent before a ``grad`` must be applied first).

    Backends implement the single-attempt primitive :meth:`_attempt`;
    the base class owns the retry loop (:meth:`request`): it assigns the
    request ``seq``, holds the backend's per-shard lock across all
    attempts (so resends stay FIFO with respect to concurrent clients),
    consumes :class:`PSShardSlow`, discards stale replies by seq, and
    escalates to :class:`PSShardLost` when the policy is exhausted.

    ``on_shard_lost`` (settable) is called with a shard id when a
    failure *detector* (the multiproc heartbeat) notices a dead worker
    out-of-band; ``counters`` accumulates retry/hedge/heartbeat
    diagnostics (also mirrored as obs instants when enabled).
    """

    name = "abstract"

    def __init__(self, *, retry: RetryPolicy | None = None,
                 retry_seed: int = 0):
        self.retry = retry if retry is not None else RetryPolicy()
        self._retry_rng = random.Random(retry_seed)
        #: failure-detector callback: fn(shard_id) — set by the fleet
        self.on_shard_lost = None
        self.counters = {"retries": 0, "hedges": 0, "escalations": 0,
                         "stale_replies": 0, "heartbeat_misses": 0}
        self._seq = itertools.count(1)

    # --- backend primitives ----------------------------------------------
    def _attempt(self, shard_id: int, msg: dict) -> dict:
        """One send→recv roundtrip.  Raise :class:`PSShardSlow` for a
        retryable condition, :class:`PSShardLost` for a dead endpoint."""
        raise NotImplementedError

    def _shard_lock(self, shard_id: int):
        """Context manager serializing requests to one shard — held
        across *all* attempts of one logical request."""
        return contextlib.nullcontext()

    def _mark_lost(self, shard_id: int) -> None:
        """Drop backend state for an escalated shard (reap/forget)."""

    def add_shard(self, shard_id: int, *, dim: int, optimizer: str = "none",
                  hyper: dict | None = None) -> None:
        raise NotImplementedError

    # --- retrying RPC ----------------------------------------------------
    def _bump(self, key: str, shard_id: int, detail: str = "") -> None:
        self.counters[key] += 1
        if obs_trace.enabled():
            obs_trace.instant(f"ps.transport.{key}", "ps", shard=shard_id,
                              detail=detail)

    def request(self, shard_id: int, msg: dict) -> dict:
        msg = dict(msg)
        msg.setdefault("seq", next(self._seq))
        with self._shard_lock(shard_id):
            return self._request_locked(shard_id, msg)

    def _request_locked(self, shard_id: int, msg: dict) -> dict:
        """The retry loop (per-shard lock held, seq already assigned)."""
        pol = self.retry
        backoff = pol.backoff_s
        last: Exception | None = None
        for attempt in range(max(1, pol.max_attempts)):
            if attempt:
                self._bump("retries", shard_id,
                           f"op={msg.get('op')} attempt={attempt + 1}")
                time.sleep(backoff
                           * (1.0 + pol.jitter * self._retry_rng.random()))
                backoff = min(backoff * pol.backoff_mult, pol.max_backoff_s)
            try:
                reply = self._attempt(shard_id, msg)
            except PSShardSlow as e:
                last = e
                continue
            if reply.get("seq", msg["seq"]) != msg["seq"]:
                # a stale/duplicated reply from an earlier attempt (or a
                # fault-injected dup) — discard and go again
                self._bump("stale_replies", shard_id)
                last = PSShardSlow(
                    f"stale reply seq={reply.get('seq')} "
                    f"(expected {msg['seq']})")
                continue
            return reply
        self._bump("escalations", shard_id, f"op={msg.get('op')}")
        self._mark_lost(shard_id)
        raise PSShardLost(
            f"shard {shard_id} lost: op={msg.get('op')!r} escalated after "
            f"{max(1, pol.max_attempts)} attempt(s): {last}") from last

    def request_many(self, pairs: list[tuple[int, dict]]) -> list[dict]:
        """Issue several (shard, msg) requests; replies in call order.

        Partial-failure contract (what elastic recovery leans on): every
        *live* shard in ``pairs`` has processed its message and had its
        reply consumed before :class:`PSShardLost` is raised for the dead
        ones — the exception carries ``shard_ids``, and no reply is left
        in flight to desynchronize a later request.  Default
        implementation is sequential; backends override to overlap
        shards.
        """
        replies, lost = [], set()
        for s, m in pairs:
            try:
                replies.append(self.request(s, m))
            except PSShardLost:
                lost.add(s)
                replies.append(None)
        if lost:
            _raise_lost(lost)
        return replies

    def stop_shard(self, shard_id: int) -> None:
        raise NotImplementedError

    def kill_shard(self, shard_id: int) -> None:
        raise NotImplementedError

    @property
    def live_shards(self) -> set[int]:
        raise NotImplementedError

    def collect_obs(self) -> list[dict]:
        """Drain every live shard's trace buffer into the caller's global
        trace buffer (:data:`repro.obs.trace.BUFFER`) — multiproc worker
        events arrive stamped with the worker's pid, giving the merged
        Chrome trace one lane per shard process.  Best-effort: a shard
        lost mid-drain just contributes nothing.  No-op (and no RPCs)
        when observability is disabled."""
        if not obs_trace.enabled():
            return []
        events: list[dict] = []
        for s in sorted(self.live_shards):
            try:
                reply = self.request(s, {"op": "obs"})
            except (PSShardError, PSShardLost):
                continue
            events.extend(reply.get("events", ()))
        obs_trace.BUFFER.extend(events)
        return events

    def _drain_shard_obs(self, shard_id: int) -> None:
        """Best-effort trace drain of one shard (graceful-stop prologue,
        so a leaving shard's spans survive into the merged trace)."""
        if not obs_trace.enabled():
            return
        try:
            reply = self.request(shard_id, {"op": "obs"})
        except (PSShardError, PSShardLost):
            return
        obs_trace.BUFFER.extend(reply.get("events", ()))

    def close(self) -> None:
        self.collect_obs()
        for s in sorted(self.live_shards):
            try:
                self.stop_shard(s)
            except PSShardLost:
                pass


class InProcTransport(Transport):
    """Shard endpoints in this process behind mailbox queues.

    ``request`` enqueues the message, drains the shard's mailbox and
    returns the reply — synchronous and deterministic, but through the
    exact message surface the multiprocess backend uses, so everything
    above the transport is backend-agnostic.  A per-shard lock makes the
    drain atomic under concurrent clients (PSClient's threads).
    """

    name = "inproc"

    def __init__(self, *, retry: RetryPolicy | None = None,
                 retry_seed: int = 0):
        super().__init__(retry=retry, retry_seed=retry_seed)
        self._servers: dict[int, ShardServer] = {}
        self._locks: dict[int, threading.RLock] = {}
        self._mail: dict[int, deque] = {}

    def add_shard(self, shard_id, *, dim, optimizer="none", hyper=None):
        if shard_id in self._servers:
            raise ValueError(f"shard {shard_id} already exists")
        self._servers[shard_id] = ShardServer(
            shard_id, dim, optimizer=optimizer, hyper=hyper)
        self._locks[shard_id] = threading.RLock()
        self._mail[shard_id] = deque()

    def _shard_lock(self, shard_id):
        lock = self._locks.get(shard_id)
        return lock if lock is not None else contextlib.nullcontext()

    def _attempt(self, shard_id, msg):
        try:
            server = self._servers[shard_id]
        except KeyError:
            raise PSShardLost(f"shard {shard_id} is not live")
        mail = self._mail[shard_id]
        mail.append(msg)
        reply = None
        while mail:                      # drain the mailbox in order
            reply = server.safe_handle(mail.popleft())
        return _check(reply, shard_id)

    def stop_shard(self, shard_id):
        self.request(shard_id, {"op": "shutdown"})
        self._drop(shard_id)

    def kill_shard(self, shard_id):
        # hard failure: state vanishes with no flush, exactly like a
        # terminated process
        if shard_id not in self._servers:
            raise PSShardLost(f"shard {shard_id} is not live")
        self._drop(shard_id)

    def _mark_lost(self, shard_id):
        self._drop(shard_id)

    def _drop(self, shard_id):
        self._servers.pop(shard_id, None)
        self._locks.pop(shard_id, None)
        self._mail.pop(shard_id, None)

    @property
    def live_shards(self):
        return set(self._servers)


class _Remote:
    __slots__ = ("conn", "proc", "lock")

    def __init__(self, conn, proc):
        self.conn = conn
        self.proc = proc
        self.lock = threading.RLock()


class MultiprocTransport(Transport):
    """One OS process per shard, speaking pickled messages over a duplex
    ``multiprocessing`` connection.

    ``start_method="spawn"`` (default) gives clean numpy-only children —
    :mod:`repro.ps.server` never imports jax, and ``repro.ps``'s lazy
    ``__init__`` keeps the import graph shallow, so worker startup is
    fast.  ``request_timeout`` bounds every recv *attempt*: a worker
    that misses the deadline but is still alive surfaces as
    :class:`PSShardSlow` (hung ≠ dead) and is retried per
    ``RetryPolicy``; a closed pipe or exited process surfaces as
    :class:`PSShardLost` immediately, with the op name, elapsed time
    and worker exit code in the message.

    ``heartbeat_s`` (default 1.0; ``None`` disables) runs a background
    thread that polls worker liveness, so a crashed shard is detected
    within the heartbeat deadline — not on the next pull/push — and
    reported through ``on_shard_lost``.  ``hedge_s`` (or
    ``retry.hedge_s``) arms hedged resends for idempotent ops.
    """

    name = "multiproc"

    def __init__(self, *, start_method: str = "spawn",
                 request_timeout: float = 60.0,
                 retry: RetryPolicy | None = None, retry_seed: int = 0,
                 heartbeat_s: float | None = 1.0,
                 hedge_s: float | None = None):
        import multiprocessing as mp

        if retry is None:
            retry = RetryPolicy(hedge_s=hedge_s)
        elif hedge_s is not None:
            retry = dataclasses.replace(retry, hedge_s=hedge_s)
        super().__init__(retry=retry, retry_seed=retry_seed)
        self._ctx = mp.get_context(start_method)
        self._timeout = float(request_timeout)
        self._shards: dict[int, _Remote] = {}
        self._hb_stop = threading.Event()
        self._hb_thread: threading.Thread | None = None
        self.heartbeat_s = heartbeat_s
        if heartbeat_s:
            self._hb_thread = threading.Thread(
                target=self._heartbeat_loop, args=(float(heartbeat_s),),
                daemon=True, name="ps-heartbeat")
            self._hb_thread.start()

    def add_shard(self, shard_id, *, dim, optimizer="none", hyper=None):
        if shard_id in self._shards:
            raise ValueError(f"shard {shard_id} already exists")
        parent, child = self._ctx.Pipe(duplex=True)
        proc = self._ctx.Process(
            target=shard_main, args=(child, shard_id, dim, optimizer, hyper),
            daemon=True, name=f"ps-shard-{shard_id}")
        proc.start()
        child.close()
        self._shards[shard_id] = _Remote(parent, proc)

    # --- failure detector ------------------------------------------------
    def _heartbeat_loop(self, interval: float) -> None:
        """Poll worker liveness; a dead process is reaped and reported
        through ``on_shard_lost`` within ~``interval`` of its death.
        ``kill_shard``/``stop_shard`` remove the shard from the map
        first, so intentional removals never fire the callback."""
        while not self._hb_stop.wait(interval):
            for sid, r in list(self._shards.items()):
                if r.proc.is_alive():
                    continue
                # re-check under the shard lock: a racing request may
                # have reaped (or be mid-roundtrip with) this shard
                with r.lock:
                    if self._shards.get(sid) is not r or r.proc.is_alive():
                        continue
                    code = r.proc.exitcode
                    self._reap(sid)
                self._bump("heartbeat_misses", sid, f"exitcode={code}")
                cb = self.on_shard_lost
                if cb is not None:
                    try:
                        cb(sid)
                    except Exception:
                        # the detector must survive a failing handler;
                        # the caller sees the loss on next touch anyway
                        pass

    # --- RPC -------------------------------------------------------------
    def _remote(self, shard_id) -> _Remote:
        try:
            return self._shards[shard_id]
        except KeyError:
            raise PSShardLost(f"shard {shard_id} is not live")

    def _shard_lock(self, shard_id):
        r = self._shards.get(shard_id)
        return r.lock if r is not None else contextlib.nullcontext()

    def _mark_lost(self, shard_id):
        self._reap(shard_id)

    def _send(self, r: _Remote, shard_id: int, msg: dict) -> None:
        try:
            r.conn.send(msg)
        except (BrokenPipeError, OSError):
            code = r.proc.exitcode
            self._reap(shard_id)
            raise PSShardLost(
                f"shard {shard_id} pipe closed on send "
                f"(op={msg.get('op')!r}, exitcode={code})")

    def _attempt(self, shard_id, msg):
        r = self._remote(shard_id)
        self._send(r, shard_id, msg)
        return self._recv(r, shard_id, msg)

    def _recv(self, r: _Remote, shard_id: int, msg: dict) -> dict:
        """Receive the reply to ``msg``, discarding stale-seq replies.

        Hung-vs-dead split: a poll deadline with the worker still alive
        raises :class:`PSShardSlow` (no reap — the worker may answer the
        retried request); EOF / closed pipe / exited process raises
        :class:`PSShardLost` with op, elapsed and exit code, after
        reaping.  If ``retry.hedge_s`` is set and ``msg`` is idempotent,
        a duplicate request is sent once after that long with no reply —
        same seq, so whichever reply lands first wins.
        """
        op, seq = msg.get("op"), msg.get("seq")
        t0 = time.monotonic()
        deadline = t0 + self._timeout
        hedge_at = (t0 + self.retry.hedge_s
                    if self.retry.hedge_s is not None
                    and op in IDEMPOTENT_OPS else None)
        while True:
            now = time.monotonic()
            wait = min(0.25, max(0.0, deadline - now))
            if hedge_at is not None:
                wait = min(wait, max(0.0, hedge_at - now))
            try:
                if r.conn.poll(wait):
                    reply = r.conn.recv()
                    if seq is not None and reply.get("seq", seq) != seq:
                        # a previous attempt's late reply (or a dup) —
                        # drop it and keep waiting for ours
                        self._bump("stale_replies", shard_id)
                        continue
                    return _check(reply, shard_id)
            except (EOFError, OSError):
                code = r.proc.exitcode
                self._reap(shard_id)
                raise PSShardLost(
                    f"shard {shard_id} died mid-request (op={op!r}, "
                    f"elapsed={time.monotonic() - t0:.3f}s, "
                    f"exitcode={code})")
            if not r.proc.is_alive():
                code = r.proc.exitcode
                self._reap(shard_id)
                raise PSShardLost(
                    f"shard {shard_id} process exited (op={op!r}, "
                    f"elapsed={time.monotonic() - t0:.3f}s, "
                    f"exitcode={code})")
            now = time.monotonic()
            if hedge_at is not None and now >= hedge_at:
                # hedged read: race a duplicate of the same request —
                # the seq cache makes the duplicate free server-side
                hedge_at = None
                self._bump("hedges", shard_id, f"op={op}")
                self._send(r, shard_id, msg)
                continue
            if now > deadline:
                # hung, NOT dead: the process is alive but silent — let
                # the retry loop decide (escalation reaps)
                raise PSShardSlow(
                    f"shard {shard_id} no reply (op={op!r}, "
                    f"elapsed={now - t0:.3f}s, timeout={self._timeout}s, "
                    f"process alive)")

    def request_many(self, pairs):
        """Send to every shard first, then collect — distinct shards
        serve concurrently, so an N-shard op costs ~one RPC, not N.

        Honors the base-class partial-failure contract: a dead shard is
        noted, every live shard's reply is still collected, then one
        :class:`PSShardLost` with ``shard_ids`` is raised.  A *slow*
        shard falls back to the per-shard retry loop (resend + backoff,
        seq-deduped server-side) before being declared lost.
        """
        # lock per shard in sorted order (deadlock-free under concurrent
        # request_many calls), keeping each shard's send→recv FIFO intact
        order = sorted({s for s, _ in pairs})
        lost: set[int] = set()
        remotes = {}
        for s in order:
            try:
                remotes[s] = self._remote(s)
            except PSShardLost:
                lost.add(s)
        for s in order:
            if s in remotes:
                remotes[s].lock.acquire()
        try:
            seqd = []
            for s, m in pairs:
                m = dict(m)
                m.setdefault("seq", next(self._seq))
                seqd.append((s, m))
                if s in lost:
                    continue
                try:
                    self._send(remotes[s], s, m)
                except PSShardLost:
                    lost.add(s)
            replies = []
            for s, m in seqd:
                if s in lost:
                    replies.append(None)
                    continue
                try:
                    replies.append(self._recv(remotes[s], s, m))
                except PSShardSlow:
                    # retry continuation: resend/backoff under the lock
                    # we already hold (counts the overlapped first try
                    # as attempt zero)
                    try:
                        replies.append(self._request_locked(s, m))
                    except PSShardLost:
                        lost.add(s)
                        replies.append(None)
                except PSShardLost:
                    lost.add(s)
                    replies.append(None)
        finally:
            for s in reversed(order):
                if s in remotes:
                    remotes[s].lock.release()
        if lost:
            _raise_lost(lost)
        return replies

    # --- lifecycle -------------------------------------------------------
    def _reap(self, shard_id) -> None:
        r = self._shards.pop(shard_id, None)
        if r is None:
            return
        try:
            r.conn.close()
        except OSError:
            pass
        if r.proc.is_alive():
            r.proc.terminate()
        r.proc.join(timeout=1.0)
        if r.proc.is_alive():
            # SIGTERM stays pending on a stopped (SIGSTOP) process —
            # SIGKILL does not
            r.proc.kill()
            r.proc.join(timeout=5.0)

    def stop_shard(self, shard_id):
        r = self._remote(shard_id)
        with r.lock:
            try:
                self.request(shard_id, {"op": "shutdown"})
            except PSShardLost:
                pass                 # raced its own clean exit — fine
            self._reap(shard_id)

    def kill_shard(self, shard_id):
        """Fault injection: SIGTERM the worker, no flush, no goodbye."""
        r = self._remote(shard_id)
        with r.lock:
            self._reap(shard_id)

    @property
    def live_shards(self):
        return set(self._shards)

    def close(self) -> None:
        self._hb_stop.set()
        if self._hb_thread is not None:
            self._hb_thread.join(timeout=5.0)
            self._hb_thread = None
        super().close()


def make_transport(kind: str | Transport | None, **kw) -> Transport:
    """``"inproc"`` | ``"multiproc"`` | an existing instance | None
    (→ in-proc).  The string form is what CLI flags pass through."""
    if kind is None:
        return InProcTransport(**kw)
    if isinstance(kind, Transport):
        return kind
    if kind == "inproc":
        return InProcTransport(**kw)
    if kind == "multiproc":
        return MultiprocTransport(**kw)
    raise ValueError(f"unknown transport {kind!r} "
                     f"(expected inproc|multiproc)")
