"""Pluggable worker↔PS transport (HeterPS §3's network hop, made real).

Every PS consumer (:class:`~repro.ps.sharding.ShardedTable`,
:class:`~repro.ps.elastic.ElasticPSFleet`, and through them
``PSClient``) speaks the message protocol of
:mod:`repro.ps.server` to shard endpoints through one of two backends:

* :class:`InProcTransport` — shards are :class:`~repro.ps.server.
  ShardServer` objects behind per-shard mailbox queues in this process.
  Deterministic and copy-free: the backend for tests, CI and the
  bit-exact oracle path.
* :class:`MultiprocTransport` — each shard is a **real OS process**
  running :func:`~repro.ps.server.shard_main` behind a duplex
  ``multiprocessing`` connection (an AF_UNIX socketpair / OS pipe — the
  same framing a TCP deployment would use).  Requests to distinct
  shards fly in parallel (`request_many` sends to every shard before
  collecting replies); requests to one shard are serialized by a
  per-shard lock, which is also what makes the transport safe under
  ``PSClient``'s puller/pusher thread pair.

Failure semantics are part of the contract: a shard that answers with
``{"err": ...}`` raises :class:`PSShardError` (the shard is alive — bad
request); a dead/hung endpoint raises :class:`PSShardLost` (what the
elastic fleet's recovery path catches).  ``kill()`` is the fault
injector: it terminates the worker *without* any flush, so whatever the
shard acked last is exactly what a replica must reproduce.
"""

from __future__ import annotations

import threading
import time
from collections import deque

from repro.obs import trace as obs_trace
from repro.ps.server import ShardServer, shard_main


class PSShardError(RuntimeError):
    """The shard processed the request and reported a failure."""


class PSShardLost(RuntimeError):
    """The shard is gone (killed, crashed, or timed out) — the request
    may or may not have been applied.  Recovery promotes the replica."""


def _check(reply: dict, shard_id: int) -> dict:
    if reply.get("err"):
        raise PSShardError(
            f"shard {shard_id} failed request:\n{reply['err']}")
    return reply


def _raise_lost(lost: set[int]):
    err = PSShardLost(f"shards lost mid-request: {sorted(lost)}")
    err.shard_ids = lost
    raise err


class Transport:
    """Abstract worker↔PS message channel.

    ``add_shard`` brings a new endpoint up (the *elastic join* primitive),
    ``request``/``request_many`` are blocking RPCs, ``stop_shard`` is a
    graceful leave, ``kill_shard`` a hard failure.  Implementations keep
    per-shard FIFO ordering — the protocol relies on it (an ``install``
    sent before a ``grad`` must be applied first).
    """

    name = "abstract"

    def add_shard(self, shard_id: int, *, dim: int, optimizer: str = "none",
                  hyper: dict | None = None) -> None:
        raise NotImplementedError

    def request(self, shard_id: int, msg: dict) -> dict:
        raise NotImplementedError

    def request_many(self, pairs: list[tuple[int, dict]]) -> list[dict]:
        """Issue several (shard, msg) requests; replies in call order.

        Partial-failure contract (what elastic recovery leans on): every
        *live* shard in ``pairs`` has processed its message and had its
        reply consumed before :class:`PSShardLost` is raised for the dead
        ones — the exception carries ``shard_ids``, and no reply is left
        in flight to desynchronize a later request.  Default
        implementation is sequential; backends override to overlap
        shards.
        """
        replies, lost = [], set()
        for s, m in pairs:
            try:
                replies.append(self.request(s, m))
            except PSShardLost:
                lost.add(s)
                replies.append(None)
        if lost:
            _raise_lost(lost)
        return replies

    def stop_shard(self, shard_id: int) -> None:
        raise NotImplementedError

    def kill_shard(self, shard_id: int) -> None:
        raise NotImplementedError

    @property
    def live_shards(self) -> set[int]:
        raise NotImplementedError

    def collect_obs(self) -> list[dict]:
        """Drain every live shard's trace buffer into the caller's global
        trace buffer (:data:`repro.obs.trace.BUFFER`) — multiproc worker
        events arrive stamped with the worker's pid, giving the merged
        Chrome trace one lane per shard process.  Best-effort: a shard
        lost mid-drain just contributes nothing.  No-op (and no RPCs)
        when observability is disabled."""
        if not obs_trace.enabled():
            return []
        events: list[dict] = []
        for s in sorted(self.live_shards):
            try:
                reply = self.request(s, {"op": "obs"})
            except (PSShardError, PSShardLost):
                continue
            events.extend(reply.get("events", ()))
        obs_trace.BUFFER.extend(events)
        return events

    def _drain_shard_obs(self, shard_id: int) -> None:
        """Best-effort trace drain of one shard (graceful-stop prologue,
        so a leaving shard's spans survive into the merged trace)."""
        if not obs_trace.enabled():
            return
        try:
            reply = self.request(shard_id, {"op": "obs"})
        except (PSShardError, PSShardLost):
            return
        obs_trace.BUFFER.extend(reply.get("events", ()))

    def close(self) -> None:
        self.collect_obs()
        for s in sorted(self.live_shards):
            try:
                self.stop_shard(s)
            except PSShardLost:
                pass


class InProcTransport(Transport):
    """Shard endpoints in this process behind mailbox queues.

    ``request`` enqueues the message, drains the shard's mailbox and
    returns the reply — synchronous and deterministic, but through the
    exact message surface the multiprocess backend uses, so everything
    above the transport is backend-agnostic.  A per-shard lock makes the
    drain atomic under concurrent clients (PSClient's threads).
    """

    name = "inproc"

    def __init__(self):
        self._servers: dict[int, ShardServer] = {}
        self._locks: dict[int, threading.Lock] = {}
        self._mail: dict[int, deque] = {}

    def add_shard(self, shard_id, *, dim, optimizer="none", hyper=None):
        if shard_id in self._servers:
            raise ValueError(f"shard {shard_id} already exists")
        self._servers[shard_id] = ShardServer(
            shard_id, dim, optimizer=optimizer, hyper=hyper)
        self._locks[shard_id] = threading.Lock()
        self._mail[shard_id] = deque()

    def request(self, shard_id, msg):
        try:
            server = self._servers[shard_id]
        except KeyError:
            raise PSShardLost(f"shard {shard_id} is not live")
        with self._locks[shard_id]:
            mail = self._mail[shard_id]
            mail.append(msg)
            reply = None
            while mail:                      # drain the mailbox in order
                reply = server.safe_handle(mail.popleft())
        return _check(reply, shard_id)

    def stop_shard(self, shard_id):
        self.request(shard_id, {"op": "shutdown"})
        self._drop(shard_id)

    def kill_shard(self, shard_id):
        # hard failure: state vanishes with no flush, exactly like a
        # terminated process
        if shard_id not in self._servers:
            raise PSShardLost(f"shard {shard_id} is not live")
        self._drop(shard_id)

    def _drop(self, shard_id):
        self._servers.pop(shard_id, None)
        self._locks.pop(shard_id, None)
        self._mail.pop(shard_id, None)

    @property
    def live_shards(self):
        return set(self._servers)


class _Remote:
    __slots__ = ("conn", "proc", "lock")

    def __init__(self, conn, proc):
        self.conn = conn
        self.proc = proc
        self.lock = threading.RLock()


class MultiprocTransport(Transport):
    """One OS process per shard, speaking pickled messages over a duplex
    ``multiprocessing`` connection.

    ``start_method="spawn"`` (default) gives clean numpy-only children —
    :mod:`repro.ps.server` never imports jax, and ``repro.ps``'s lazy
    ``__init__`` keeps the import graph shallow, so worker startup is
    fast.  ``request_timeout`` bounds every recv: a hung shard surfaces
    as :class:`PSShardLost` instead of a hung trainer (the CI lane runs
    these tests under a hard per-test timeout on top).
    """

    name = "multiproc"

    def __init__(self, *, start_method: str = "spawn",
                 request_timeout: float = 60.0):
        import multiprocessing as mp

        self._ctx = mp.get_context(start_method)
        self._timeout = float(request_timeout)
        self._shards: dict[int, _Remote] = {}

    def add_shard(self, shard_id, *, dim, optimizer="none", hyper=None):
        if shard_id in self._shards:
            raise ValueError(f"shard {shard_id} already exists")
        parent, child = self._ctx.Pipe(duplex=True)
        proc = self._ctx.Process(
            target=shard_main, args=(child, shard_id, dim, optimizer, hyper),
            daemon=True, name=f"ps-shard-{shard_id}")
        proc.start()
        child.close()
        self._shards[shard_id] = _Remote(parent, proc)

    # --- RPC -------------------------------------------------------------
    def _remote(self, shard_id) -> _Remote:
        try:
            return self._shards[shard_id]
        except KeyError:
            raise PSShardLost(f"shard {shard_id} is not live")

    def _send(self, r: _Remote, shard_id: int, msg: dict) -> None:
        try:
            r.conn.send(msg)
        except (BrokenPipeError, OSError):
            self._reap(shard_id)
            raise PSShardLost(f"shard {shard_id} pipe closed on send")

    def _recv(self, r: _Remote, shard_id: int) -> dict:
        deadline = time.monotonic() + self._timeout
        while True:
            try:
                if r.conn.poll(min(0.25, max(0.0,
                                             deadline - time.monotonic()))):
                    return _check(r.conn.recv(), shard_id)
            except (EOFError, OSError):
                self._reap(shard_id)
                raise PSShardLost(f"shard {shard_id} died mid-request")
            if not r.proc.is_alive():
                self._reap(shard_id)
                raise PSShardLost(f"shard {shard_id} process exited")
            if time.monotonic() > deadline:
                self._reap(shard_id)
                raise PSShardLost(
                    f"shard {shard_id} timed out after {self._timeout}s")

    def request(self, shard_id, msg):
        r = self._remote(shard_id)
        with r.lock:
            self._send(r, shard_id, msg)
            return self._recv(r, shard_id)

    def request_many(self, pairs):
        """Send to every shard first, then collect — distinct shards
        serve concurrently, so an N-shard op costs ~one RPC, not N.

        Honors the base-class partial-failure contract: a dead shard is
        noted, every live shard's reply is still collected, then one
        :class:`PSShardLost` with ``shard_ids`` is raised.
        """
        # lock per shard in sorted order (deadlock-free under concurrent
        # request_many calls), keeping each shard's send→recv FIFO intact
        order = sorted({s for s, _ in pairs})
        lost: set[int] = set()
        remotes = {}
        for s in order:
            try:
                remotes[s] = self._remote(s)
            except PSShardLost:
                lost.add(s)
        for s in order:
            if s in remotes:
                remotes[s].lock.acquire()
        try:
            for s, m in pairs:
                if s in lost:
                    continue
                try:
                    self._send(remotes[s], s, m)
                except PSShardLost:
                    lost.add(s)
            replies = []
            for s, _ in pairs:
                if s in lost:
                    replies.append(None)
                    continue
                try:
                    replies.append(self._recv(remotes[s], s))
                except PSShardLost:
                    lost.add(s)
                    replies.append(None)
        finally:
            for s in reversed(order):
                if s in remotes:
                    remotes[s].lock.release()
        if lost:
            _raise_lost(lost)
        return replies

    # --- lifecycle -------------------------------------------------------
    def _reap(self, shard_id) -> None:
        r = self._shards.pop(shard_id, None)
        if r is None:
            return
        try:
            r.conn.close()
        except OSError:
            pass
        if r.proc.is_alive():
            r.proc.terminate()
        r.proc.join(timeout=5.0)

    def stop_shard(self, shard_id):
        r = self._remote(shard_id)
        with r.lock:
            self._send(r, shard_id, {"op": "shutdown"})
            try:
                self._recv(r, shard_id)
            except PSShardLost:
                pass                 # raced its own clean exit — fine
        self._reap(shard_id)

    def kill_shard(self, shard_id):
        """Fault injection: SIGTERM the worker, no flush, no goodbye."""
        r = self._remote(shard_id)
        with r.lock:
            self._reap(shard_id)

    @property
    def live_shards(self):
        return set(self._shards)


def make_transport(kind: str | Transport | None, **kw) -> Transport:
    """``"inproc"`` | ``"multiproc"`` | an existing instance | None
    (→ in-proc).  The string form is what CLI flags pass through."""
    if kind is None:
        return InProcTransport()
    if isinstance(kind, Transport):
        return kind
    if kind == "inproc":
        return InProcTransport()
    if kind == "multiproc":
        return MultiprocTransport(**kw)
    raise ValueError(f"unknown transport {kind!r} "
                     f"(expected inproc|multiproc)")
