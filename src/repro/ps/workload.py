"""Reduced CTR-over-PS workload (HeterPS §6's sparse workload, scaled to
the CPU container) — shared by ``launch/train.py --sparse-ps``,
``benchmarks/bench_ps.py`` and the PS tests.

One step: pull the batch's embedding rows from the sharded PS, run a
dense tower on the concatenated slot embeddings, push the row gradients
back.  :func:`train_ctr_ps` drives it either *synchronously*
(pull → compute → push, the baseline) or *asynchronously* through
:class:`~repro.ps.client.PSClient` (double-buffered overlap), with the
tier placer re-pinning hot rows on a fixed cadence.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import AccessMonitor, PrefetchLoader
from repro.ps.client import PSClient
from repro.ps.elastic import ElasticPSFleet, PSUnrecoverable
from repro.ps.faults import FaultInjector
from repro.ps.placement import TierPlacer
from repro.ps.sharding import ShardedTable
from repro.ps.snapshot import FleetCheckpointer, load_fleet_checkpoint
from repro.ps.telemetry import PSTelemetry
from repro.ps.transport import make_transport


@dataclasses.dataclass(frozen=True)
class CTRConfig:
    """Criteo-style reduced CTR model: 26 sparse slots → dense tower."""

    vocab: int = 200_000
    emb_dim: int = 16
    slots: int = 26
    tower: tuple[int, ...] = (512, 512, 256)
    batch: int = 256
    seed: int = 0
    lr: float = 0.05
    emb_lr_scale: float = 10.0   # sparse rows see few updates each → hotter lr


def click_stream(cfg: CTRConfig) -> Iterator[dict]:
    """Synthetic click log: zipf-ish sparse ids (hot head, long tail —
    drives the tier monitor) with a planted logistic structure so the
    logloss actually decreases."""
    rng = np.random.default_rng(cfg.seed)
    w_true = rng.standard_normal(cfg.slots) * 0.7
    while True:
        ids = (rng.pareto(1.2, (cfg.batch, cfg.slots)) * 1000).astype(
            np.int64) % cfg.vocab
        sig = (np.sin(ids % 97) * w_true).sum(-1)
        y = (sig + rng.standard_normal(cfg.batch) * 0.5 > 0)
        yield {"ids": ids.astype(np.int32),
               "label": y.astype(np.float32)}


def init_tower(cfg: CTRConfig, key) -> dict:
    dims = (cfg.slots * cfg.emb_dim,) + tuple(cfg.tower) + (1,)
    keys = jax.random.split(key, len(dims) - 1)
    return {
        "w": [jax.random.normal(k, (a, b)) * (a**-0.5)
              for k, (a, b) in zip(keys, itertools.pairwise(dims))],
        "b": [jnp.zeros((b,)) for b in dims[1:]],
    }


def make_step_fn(cfg: CTRConfig):
    """jitted ``(tower, emb_rows, labels) → (tower', emb_row_grads, loss)``.

    The embedding rows enter as a *pulled* activation ``(B, slots, D)``;
    differentiating w.r.t. them yields exactly the per-row gradients the
    PS push wants — the table itself never crosses the jit boundary.
    """

    def bce(logit, y):
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    def loss_fn(tower, emb, labels):
        h = emb.reshape(emb.shape[0], cfg.slots * cfg.emb_dim)
        for i, (w, b) in enumerate(zip(tower["w"], tower["b"])):
            h = h @ w + b
            if i < len(tower["w"]) - 1:
                h = jnp.tanh(h)
        return bce(h[:, 0], labels)

    def step(tower, emb, labels):
        loss, (g_tower, g_emb) = jax.value_and_grad(
            loss_fn, argnums=(0, 1))(tower, emb, labels)
        tower = jax.tree.map(lambda p, g: p - cfg.lr * g, tower, g_tower)
        return tower, g_emb, loss

    return jax.jit(step)


def make_table(cfg: CTRConfig, num_shards: int, *,
               partition: str = "mod", rpc_latency_s: float = 0.0,
               with_monitor: bool = True, transport=None) -> ShardedTable:
    return ShardedTable(
        cfg.vocab, cfg.emb_dim, num_shards,
        jax.random.PRNGKey(cfg.seed), init_scale=0.05, partition=partition,
        monitor=AccessMonitor(cfg.vocab) if with_monitor else None,
        telemetry=PSTelemetry(num_shards), rpc_latency_s=rpc_latency_s,
        transport=transport)


def make_fleet(cfg: CTRConfig, num_shards: int, *,
               optimizer: str = "sgd", transport=None,
               staleness_bound: int = 8,
               rpc_latency_s: float = 0.0) -> ElasticPSFleet:
    return ElasticPSFleet(
        cfg.vocab, cfg.emb_dim, num_shards=num_shards, optimizer=optimizer,
        transport=transport, telemetry=PSTelemetry(num_shards),
        key=jax.random.PRNGKey(cfg.seed), init_scale=0.05,
        staleness_bound=staleness_bound, rpc_latency_s=rpc_latency_s)


def train_ctr_ps(cfg: CTRConfig | None = None, *, steps: int = 200,
                 num_shards: int = 4, mode: str = "async",
                 partition: str = "mod", rpc_latency_s: float = 0.0,
                 repin_interval: int = 50, depth: int = 2,
                 log_every: int = 0, transport=None) -> dict:
    """Train the reduced CTR model over the sharded PS.

    ``mode="sync"``: pull → compute → push each step (the baseline the
    overlap benchmark compares against).  ``mode="async"``: the
    :class:`PSClient` double-buffers pulls and pushes around the compute.
    Returns a summary with per-step wall times, losses, tier stats and
    the telemetry report.
    """
    if mode not in ("sync", "async"):
        raise ValueError(f"mode must be sync|async, got {mode!r}")
    cfg = cfg or CTRConfig()
    table = make_table(cfg, num_shards, partition=partition,
                       rpc_latency_s=rpc_latency_s, transport=transport)
    placer = TierPlacer(table, table.monitor, interval=repin_interval)
    step_fn = make_step_fn(cfg)
    tower = init_tower(cfg, jax.random.PRNGKey(cfg.seed + 1))
    emb_lr = cfg.lr * cfg.emb_lr_scale

    losses: list[float] = []
    times: list[float] = []
    ts: list[float] = []        # absolute per-step finish times (for
    t_start = time.perf_counter()  # steady-state rate measurement)

    if mode == "sync":
        stream = click_stream(cfg)
        for i in range(steps):
            t0 = time.perf_counter()
            b = next(stream)
            rows = table.pull(b["ids"])
            tower, g_emb, loss = step_fn(tower, rows,
                                         jnp.asarray(b["label"]))
            table.push(b["ids"], jax.block_until_ready(g_emb), lr=emb_lr)
            placer.step(i)
            losses.append(float(loss))
            times.append(time.perf_counter() - t0)
            ts.append(time.perf_counter() - t_start)
            if log_every and i % log_every == 0:
                print(f"step {i:4d} logloss {losses[-1]:.4f} "
                      f"({times[-1] * 1e3:.1f} ms)", flush=True)
    else:
        loader = PrefetchLoader(
            itertools.islice(click_stream(cfg), steps), depth=depth)
        client = PSClient(table, loader, ids_key="ids", depth=depth)
        try:
            for i, (b, rows) in enumerate(client):
                t0 = time.perf_counter()
                tower, g_emb, loss = step_fn(tower, rows,
                                             jnp.asarray(b["label"]))
                client.push(b["ids"], jax.block_until_ready(g_emb),
                            lr=emb_lr)
                placer.step(i)
                losses.append(float(loss))
                times.append(time.perf_counter() - t0)
                ts.append(time.perf_counter() - t_start)
                if log_every and i % log_every == 0:
                    print(f"step {i:4d} logloss {losses[-1]:.4f} "
                          f"({times[-1] * 1e3:.1f} ms)", flush=True)
        finally:
            client.close()
            loader.close()

    wall = time.perf_counter() - t_start
    tel = table.telemetry.totals()
    # cost-model bridge: the measured PS traffic re-anchors the CPU
    # resource type's bandwidth terms and yields a measured embedding-layer
    # ODT (the LayerProfile shape the scheduler's cost model consumes)
    from repro.core.resources import CPU_CORE

    measured_res = table.telemetry.to_resource(CPU_CORE)
    odt_sync, odt_act = table.telemetry.embedding_odt(len(losses) * cfg.batch)
    table.close()
    return {
        "mode": mode, "steps": len(losses), "num_shards": num_shards,
        "first_loss": losses[0], "last_loss": losses[-1],
        "loss_decreased": losses[-1] < losses[0],
        "seconds": wall,
        "step_times": times,
        "step_ts": ts,
        "steps_per_sec": len(losses) / wall if wall > 0 else 0.0,
        "repins": placer.repins,
        "tier_stats": placer.last_stats,
        "pull_gb": tel["pull"]["bytes"] / 1e9,
        "push_gb": tel["push"]["bytes"] / 1e9,
        "pull_bw_gbs": tel["pull"]["bandwidth"] / 1e9,
        "push_bw_gbs": tel["push"]["bandwidth"] / 1e9,
        "hot_pull_fraction": tel["pull"]["hot_fraction"],
        "measured_ingest_bw": measured_res.ingest_bw,
        "measured_net_bw": measured_res.net_bw,
        "embedding_odt_sync": odt_sync,
        "embedding_odt_act": odt_act,
    }


def train_ctr_elastic(cfg: CTRConfig | None = None, *, steps: int = 200,
                      num_shards: int = 3, optimizer: str = "sgd",
                      transport=None, mode: str = "sync",
                      events: list[tuple[int, str, int | None]] | None = None,
                      staleness_bound: int = 8, depth: int = 2,
                      rpc_latency_s: float = 0.0,
                      fault_schedule=None, fault_seed: int = 0,
                      ckpt_dir: str | None = None, ckpt_every: int = 0,
                      ckpt_keep: int = 2, max_restores: int = 4,
                      replan=None,
                      log_every: int = 0) -> dict:
    """Train the reduced CTR model over an **elastic** PS fleet, with
    scripted fleet events injected mid-training.

    ``events`` is a list of ``(step, action, shard)`` where ``action`` is
    ``"join"`` (shard ignored), ``"kill"`` or ``"leave"`` — e.g.
    ``[(40, "join", None), (80, "kill", 0)]`` grows the fleet at step 40
    and hard-kills shard 0 at step 80 (replica recovery kicks in on the
    next touch).  Training never pauses: the loop keeps issuing
    pull/push through every event.

    The sync replication + deterministic PS-hosted optimizer make the
    run's loss trajectory **bit-equal** (``mode="sync"``) to the same run
    without any events — the acceptance pin for lossless recovery.
    Returns the per-step ``losses`` so callers can compare trajectories.

    Chaos knobs: ``fault_schedule`` (anything
    :func:`repro.ps.faults.parse_schedule` accepts) wraps the transport
    in a seeded :class:`~repro.ps.faults.FaultInjector`.  ``ckpt_dir`` +
    ``ckpt_every`` arm periodic unified checkpoints
    (:class:`~repro.ps.snapshot.FleetCheckpointer`); on a correlated
    primary+backup loss (:class:`PSUnrecoverable`) the loop restores the
    newest checkpoint, rewinds the (deterministic) batch stream to its
    cursor and **replays** — the loss trajectory from the restore step
    is bit-equal to a fault-free run (sync mode; pinned in
    tests/test_chaos.py).

    ``replan`` is a factory ``fleet -> ReplanController`` (see
    ``core/replan.py``): the controller is built once the fleet exists,
    ``observe()``-d after every step (step-driven windows — the training
    loop stays single-threaded), and its :meth:`report` lands in the
    result under ``"replan"``.  A factory rather than a controller keeps
    this module free of scheduler imports.
    """
    if mode not in ("sync", "async"):
        raise ValueError(f"mode must be sync|async, got {mode!r}")
    if ckpt_dir and ckpt_every and mode != "sync":
        raise ValueError("checkpoint/restore replay requires mode='sync' "
                         "(async pipelines have no exact cursor)")
    cfg = cfg or CTRConfig()
    if fault_schedule is not None:
        transport = FaultInjector(make_transport(transport), fault_schedule,
                                  seed=fault_seed)
    fleet = make_fleet(cfg, num_shards, optimizer=optimizer,
                       transport=transport, staleness_bound=staleness_bound,
                       rpc_latency_s=rpc_latency_s)
    by_step: dict[int, list[tuple[str, int | None]]] = {}
    for step, action, shard in (events or []):
        by_step.setdefault(int(step), []).append((action, shard))

    def fire(i: int) -> None:
        for action, shard in by_step.get(i, []):
            if action == "join":
                fleet.join()
            elif action == "kill":
                if shard in fleet.transport.live_shards:
                    fleet.kill(shard)
            elif action == "leave":
                if shard in fleet.transport.live_shards:
                    fleet.leave(shard)
            else:
                raise ValueError(f"unknown fleet event {action!r}")

    controller = replan(fleet) if replan is not None else None
    step_fn = make_step_fn(cfg)
    tower = init_tower(cfg, jax.random.PRNGKey(cfg.seed + 1))
    # the fleet's PS-hosted optimizer applies the lr server-side, so the
    # pushed payload is the raw (deduped, summed) gradient
    emb_lr = cfg.lr * cfg.emb_lr_scale
    losses: list[float] = []
    ts: list[float] = []
    t_start = time.perf_counter()

    restores = 0
    ckpt: FleetCheckpointer | None = None
    if mode == "sync":
        if ckpt_dir and ckpt_every:
            ckpt = FleetCheckpointer(fleet, ckpt_dir, every=ckpt_every,
                                     keep=ckpt_keep)
        stream = click_stream(cfg)
        i = 0
        while i < steps:
            try:
                b = next(stream)
                rows = fleet.pull(b["ids"])
                tower, g_emb, loss = step_fn(tower, rows,
                                             jnp.asarray(b["label"]))
                fleet.push(b["ids"], jax.block_until_ready(g_emb),
                           lr=emb_lr)
                fire(i)
                losses.append(float(loss))
                ts.append(time.perf_counter() - t_start)
                if controller is not None:
                    controller.observe(num_examples=cfg.batch)
                if ckpt is not None:
                    # post-step state: fleet slabs + tower + cursor i+1
                    ckpt.maybe_save(i, tower, metadata={"cursor": i + 1,
                                                        "seed": cfg.seed})
                if log_every and i % log_every == 0:
                    print(f"step {i:4d} logloss {losses[-1]:.4f}",
                          flush=True)
                i += 1
            except PSUnrecoverable:
                # correlated primary+backup loss — replica promotion is
                # out of moves; restore the newest unified checkpoint
                # and replay the deterministic stream from its cursor
                if ckpt is None or restores >= max_restores:
                    raise
                restores += 1
                ckpt.wait()
                try:
                    tower, snap, step0, _ = load_fleet_checkpoint(
                        ckpt_dir, params_template=tower)
                except FileNotFoundError:
                    raise  # nothing durable yet — genuinely lost
                fleet.restore_snapshot(snap)
                del losses[step0 + 1:]
                del ts[step0 + 1:]
                stream = click_stream(cfg)
                for _ in range(step0 + 1):   # skip replayed batches
                    next(stream)
                i = step0 + 1
                if log_every:
                    print(f"restored checkpoint step {step0}, replaying "
                          f"from step {i}", flush=True)
        if ckpt is not None:
            ckpt.wait()
    else:
        loader = PrefetchLoader(
            itertools.islice(click_stream(cfg), steps), depth=depth)
        client = PSClient(fleet, loader, ids_key="ids", depth=depth)
        try:
            for i, (b, rows) in enumerate(client):
                tower, g_emb, loss = step_fn(tower, rows,
                                             jnp.asarray(b["label"]))
                client.push(b["ids"], jax.block_until_ready(g_emb),
                            lr=emb_lr)
                fire(i)
                losses.append(float(loss))
                ts.append(time.perf_counter() - t_start)
                if controller is not None:
                    controller.observe(num_examples=cfg.batch)
        finally:
            client.close()
            loader.close()

    wall = time.perf_counter() - t_start
    tel = fleet.telemetry.totals()
    fleet_events = list(fleet.events)
    stats = fleet.stats()
    tr = fleet.transport
    transport_counters = dict(tr.counters)
    injections: list[dict] = []
    if isinstance(tr, FaultInjector):
        injections = list(tr.injections)
        for k, v in tr.inner.counters.items():
            transport_counters[k] = transport_counters.get(k, 0) + v
    fleet.close()
    recoveries = [e for e in fleet_events if e["kind"] == "recover"]
    joins = [e for e in fleet_events if e["kind"] == "join"]
    replan_report = controller.report() if controller is not None else None
    return {
        "replan": replan_report,
        "mode": mode, "steps": len(losses), "optimizer": optimizer,
        "first_loss": losses[0], "last_loss": losses[-1],
        "loss_decreased": losses[-1] < losses[0],
        "losses": losses,
        "seconds": wall,
        "step_ts": ts,
        "steps_per_sec": len(losses) / wall if wall > 0 else 0.0,
        "live_shards": stats["live_shards"],
        "events": fleet_events,
        "recovery_seconds": sum(e["seconds"] for e in recoveries),
        "join_seconds": sum(e["seconds"] for e in joins),
        "restores": restores,
        "checkpoints": list(ckpt.saved) if ckpt is not None else [],
        "injections": injections,
        "transport_counters": transport_counters,
        "pull_gb": tel["pull"]["bytes"] / 1e9,
        "push_gb": tel["push"]["bytes"] / 1e9,
    }
