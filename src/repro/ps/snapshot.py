"""Crash-consistent unified checkpoints for the elastic PS fleet.

Replica promotion (:meth:`~repro.ps.elastic.ElasticPSFleet.recover`)
survives *single* failures; a correlated loss — one preempted zone
taking a bucket's primary **and** backup — needs durable state.  This
module drains the fleet into a **unified checkpoint**: per-bucket slabs
+ PS optimizer state + acked-counter watermark, written *alongside* the
dense tower params and the data cursor in one atomic
:mod:`repro.checkpoint.io` directory, so training state can never be
split across two half-written files.

Consistency model:

* :func:`snapshot_fleet` captures under the fleet's lock, after
  finishing any in-flight migrations (a mid-migration capture would
  miss ``buffer_only`` pushes the source primary never saw).  No pull/
  push can interleave, so the capture is a single point on the update
  timeline — its per-bucket ``acked`` counters are the watermark.
* :class:`FleetCheckpointer` drains synchronously (cheap RPCs) but
  writes **asynchronously** in a background thread, so the training
  loop pays snapshot-drain time, not disk time.  The write is staged
  and published by ``os.replace`` + an atomic ``LATEST`` pointer: a
  crash mid-write leaves the previous checkpoint selectable and a
  ``.tmp-`` orphan, never a torn manifest.
* :func:`load_fleet_checkpoint` + :meth:`~repro.ps.elastic.
  ElasticPSFleet.restore_snapshot` reload bit-exactly; replaying the
  (deterministic) batch stream from the checkpoint's cursor then
  reproduces the fault-free loss trajectory bit-for-bit — the
  acceptance pin in ``tests/test_chaos.py``.
"""

from __future__ import annotations

import os
import re
import shutil
import threading
import time

import numpy as np

from repro import obs
from repro.checkpoint import io as ckpt_io
from repro.obs import trace as obs_trace
from repro.ps.transport import PSShardLost

_STEP_RE = re.compile(r"^step-(\d{8})$")


def _step_name(step: int) -> str:
    return f"step-{step:08d}"


def snapshot_fleet(fleet) -> dict:
    """Drain every bucket's primary into host memory (one consistent
    point: slab rows, optimizer state, acked watermark).

    Holds the fleet lock for the duration, finishing in-flight
    migrations first; a shard lost mid-drain triggers recovery and a
    retry against the promoted replicas (bit-identical by invariant).
    Raises :class:`~repro.ps.elastic.PSUnrecoverable` if recovery is
    impossible — there is nothing consistent left to save.
    """
    with fleet._mu:
        for b in sorted(fleet._migrations):
            fleet.finish_migration(b)
        nb = fleet.spec.num_buckets
        while True:
            msgs = [(int(fleet.primary[b]), {"op": "snapshot", "bucket": b})
                    for b in range(nb)]
            try:
                replies = fleet.transport.request_many(msgs)
                break
            except PSShardLost as e:
                fleet.recover(getattr(e, "shard_ids", None))
        buckets = {
            b: {"rows": rep["rows"], "opt": rep["opt"],
                "acked": int(rep["acked"])}
            for b, rep in enumerate(replies)}
        meta = {"vocab": fleet.spec.vocab, "dim": fleet.spec.dim,
                "num_buckets": nb, "optimizer": fleet.optimizer,
                "hyper": dict(fleet.hyper),
                "acked": [buckets[b]["acked"] for b in range(nb)]}
    return {"buckets": buckets, "meta": meta}


def pack_snapshot(snap: dict) -> dict[str, np.ndarray]:
    """Flatten a fleet snapshot into named arrays for ``extra_arrays``."""
    out: dict[str, np.ndarray] = {}
    for b, st in snap["buckets"].items():
        pre = f"ps/bucket{int(b):05d}/"
        out[pre + "rows"] = np.asarray(st["rows"], np.float32)
        out[pre + "acked"] = np.asarray(int(st["acked"]), np.int64)
        for k, v in st["opt"].items():
            out[pre + "opt/" + k] = np.asarray(v)
    return out


def unpack_snapshot(arrays: dict[str, np.ndarray], meta: dict) -> dict:
    """Inverse of :func:`pack_snapshot` (``meta`` from the manifest)."""
    buckets: dict[int, dict] = {}
    for key, arr in arrays.items():
        if not key.startswith("ps/bucket"):
            continue
        bstr, field = key[len("ps/"):].split("/", 1)
        st = buckets.setdefault(int(bstr[len("bucket"):]),
                                {"rows": None, "opt": {}, "acked": 0})
        if field == "rows":
            st["rows"] = arr
        elif field == "acked":
            st["acked"] = int(arr)
        elif field.startswith("opt/"):
            st["opt"][field[len("opt/"):]] = arr
    return {"buckets": buckets, "meta": dict(meta)}


def save_fleet_checkpoint(root: str, step: int, *, params, snap: dict,
                          metadata: dict | None = None,
                          extra_arrays: dict | None = None,
                          keep: int = 0) -> int:
    """Write ``root/step-<step>/`` atomically, flip ``LATEST``, prune.

    Returns payload bytes.  ``keep > 0`` retains only the newest
    ``keep`` complete steps (pruned *after* the pointer flip, so the
    pointer target always survives)."""
    t0 = time.perf_counter()
    name = _step_name(step)
    arrays = pack_snapshot(snap)
    for k, v in (extra_arrays or {}).items():
        arrays[k] = np.asarray(v)
    snap_meta = dict(snap["meta"])
    snap_meta["step"] = int(step)
    meta = {"ps": snap_meta, **(metadata or {})}
    with obs_trace.span("ps.ckpt.write", "ps", step=step):
        nbytes = ckpt_io.save_checkpoint(
            os.path.join(root, name), params=params, step=step,
            metadata=meta, extra_arrays=arrays, atomic=True)
        ckpt_io.write_pointer(root, name)
        if keep > 0:
            prune_checkpoints(root, keep=keep)
    seconds = time.perf_counter() - t0
    obs.REGISTRY.counter("ps.ckpt.saves").inc()
    obs.REGISTRY.counter("ps.ckpt.bytes").inc(nbytes)
    obs.REGISTRY.counter("ps.ckpt.ms").inc(int(seconds * 1e3))
    if obs_trace.enabled():
        obs_trace.instant("ps.ckpt.saved", "ps", step=step, bytes=nbytes,
                          seconds=round(seconds, 4))
    return nbytes


def list_checkpoints(root: str) -> list[tuple[int, str]]:
    """Complete (published) steps under ``root``, ascending."""
    if not os.path.isdir(root):
        return []
    out = []
    for entry in os.listdir(root):
        m = _STEP_RE.match(entry)
        if m and os.path.isdir(os.path.join(root, entry)):
            out.append((int(m.group(1)), os.path.join(root, entry)))
    return sorted(out)


def prune_checkpoints(root: str, *, keep: int) -> None:
    """Drop all but the newest ``keep`` steps, plus any ``.tmp-`` orphans
    an interrupted save left behind.  Never removes the ``LATEST``
    target."""
    latest = ckpt_io.read_pointer(root)
    steps = list_checkpoints(root)
    for _, path in steps[:-keep] if keep > 0 else []:
        if latest and os.path.samefile(path, latest):
            continue
        shutil.rmtree(path, ignore_errors=True)
    for entry in os.listdir(root) if os.path.isdir(root) else []:
        if ".tmp-" in entry:
            shutil.rmtree(os.path.join(root, entry), ignore_errors=True)


def load_fleet_checkpoint(root: str, *, params_template
                          ) -> tuple[object, dict, int, dict]:
    """Load the newest complete checkpoint: ``(params, snap, step,
    metadata)``.  ``snap`` feeds :meth:`ElasticPSFleet.restore_snapshot`;
    resolution goes through the ``LATEST`` pointer, so an interrupted
    save is never selected."""
    path = ckpt_io.read_pointer(root)
    if path is None:
        steps = list_checkpoints(root)   # pre-pointer fallback
        if not steps:
            raise FileNotFoundError(f"no complete checkpoint under {root}")
        path = steps[-1][1]
    params, _, step = ckpt_io.load_checkpoint(
        path, params_template=params_template)
    manifest = ckpt_io.load_manifest(path)
    extra = ckpt_io.load_extra_arrays(path)
    snap = unpack_snapshot(extra, manifest["metadata"].get("ps", {}))
    return params, snap, step, manifest["metadata"]


class FleetCheckpointer:
    """Periodic async checkpointing of (fleet state + dense params).

    ``maybe_save(step, params)`` fires every ``every`` steps: the fleet
    drain is synchronous (a consistent capture requires the fleet lock)
    but serialization + disk I/O happen on a background writer thread —
    at most one in flight; a new save joins the previous writer first,
    so checkpoints publish in step order.  Call :meth:`wait` before
    reading ``LATEST`` (restore paths do) and :meth:`close` when done.
    """

    def __init__(self, fleet, root: str, *, every: int = 0, keep: int = 2,
                 background: bool = True):
        self.fleet = fleet
        self.root = root
        self.every = int(every)
        self.keep = int(keep)
        self.background = background
        self._writer: threading.Thread | None = None
        self._write_error: BaseException | None = None
        #: (step, bytes) of completed saves, for tests/benchmarks
        self.saved: list[tuple[int, int]] = []

    def maybe_save(self, step: int, params, *, metadata: dict | None = None,
                   extra_arrays: dict | None = None) -> bool:
        if not self.every or (step + 1) % self.every:
            return False
        self.save(step, params, metadata=metadata,
                  extra_arrays=extra_arrays)
        return True

    def save(self, step: int, params, *, metadata: dict | None = None,
             extra_arrays: dict | None = None) -> None:
        self.wait()                       # publish in order, bound memory
        with obs_trace.span("ps.ckpt.drain", "ps", step=step):
            snap = snapshot_fleet(self.fleet)

        def write():
            try:
                nbytes = save_fleet_checkpoint(
                    self.root, step, params=params, snap=snap,
                    metadata=metadata, extra_arrays=extra_arrays,
                    keep=self.keep)
                self.saved.append((step, nbytes))
            except BaseException as e:    # surfaced by the next wait()
                self._write_error = e

        if self.background:
            self._writer = threading.Thread(
                target=write, daemon=True, name="ps-ckpt-writer")
            self._writer.start()
        else:
            write()
            self.wait()

    def wait(self) -> None:
        """Join the in-flight writer; re-raise any write failure (a
        checkpoint that silently failed to persist must not look like
        durability)."""
        w, self._writer = self._writer, None
        if w is not None:
            w.join()
        if self._write_error is not None:
            e, self._write_error = self._write_error, None
            raise RuntimeError("fleet checkpoint write failed") from e

    def close(self) -> None:
        self.wait()
