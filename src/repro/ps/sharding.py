"""Sharded parameter-server table — HeterPS §3's CPU-PS tier, scaled out.

The paper keeps huge sparse embedding tables on CPU parameter servers and
shards them across hosts; workers pull only the touched rows and push
sparse row gradients back.  :class:`ShardedTable` vocab-partitions one
logical ``(V, D)`` table across ``N`` PS shards:

* storage is one ``(V, D)`` array in *shard-major* layout — shard ``s``'s
  rows form the contiguous slab ``[offset_s, offset_s + rows_s)``.  On
  real hardware that slab layout is exactly what a ``NamedSharding`` over
  a PS mesh axis consumes (one slab per host); on the CPU container the
  slabs are process-local.  Keeping one array makes routed ``pull`` a
  single gather and routed ``push`` a single COO scatter-add — O(ids),
  independent of the shard count;
* pushes dedup duplicate ids via ``dedup_rows`` before the scatter so an
  adaptive optimizer on the PS sees each row once per step;
* tier-aware placement is *physical*: a fixed-capacity **hot-row cache**
  (``hot_rows`` + an id→slot map) holds the rows the access monitor
  marked DEVICE-tier.  Pulls serve hot ids from the cache and cold ids
  from main storage; pushes write through to both, so the two stay
  bit-identical.  On TPU runtimes the cache lives in HBM
  (``memory_kind="device"``) while main storage is demoted to
  ``pinned_host``; on CPU both are plain arrays and the per-shard
  ``tiers`` codes simulate the storage tiers;
* every pull/push is metered per shard (bytes, rows, wall time) by an
  attached :class:`~repro.ps.telemetry.PSTelemetry`, and an optional
  simulated RPC latency models the worker↔PS network hop the CPU
  container doesn't have.

Routing is bit-exact against the single-shard oracle
(:class:`repro.parallel.ps.SparseEmbedding`): a row lives in exactly one
slab slot, so its scatter contributions arrive in the same stream order
as in the unsharded table (pinned by ``tests/test_ps.py``).
"""

from __future__ import annotations

import dataclasses
import functools
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.ps import dedup_rows

#: tier codes stored in the per-shard placement arrays (int8); index-aligned
#: with ``repro.data.cache.Tier`` ordering DEVICE < HOST < DISK.
TIER_DEVICE, TIER_HOST, TIER_DISK = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class RoutingSpec:
    """Static routing metadata — hashable, so jit can close over it.

    ``partition="mod"`` (default) assigns row ``i`` to shard ``i % N`` —
    balanced under the zipf-skewed id streams of CTR logs.  ``"block"``
    assigns contiguous vocab ranges (shard ``s`` owns
    ``[s*block, (s+1)*block)``) — the layout a range-partitioned
    key-value PS would use.
    """

    vocab: int
    dim: int
    num_shards: int
    partition: str = "mod"

    def __post_init__(self):
        if self.partition not in ("mod", "block"):
            raise ValueError(f"unknown partition {self.partition!r}")
        if not 1 <= self.num_shards <= max(1, self.vocab):
            raise ValueError(
                f"num_shards={self.num_shards} outside [1, vocab={self.vocab}]")

    @property
    def block(self) -> int:
        return -(-self.vocab // self.num_shards)  # ceil

    @property
    def shard_rows(self) -> tuple[int, ...]:
        if self.partition == "mod":
            return tuple(
                (self.vocab - s + self.num_shards - 1) // self.num_shards
                for s in range(self.num_shards))
        return tuple(
            max(0, min(self.block, self.vocab - s * self.block))
            for s in range(self.num_shards))

    @property
    def offsets(self) -> tuple[int, ...]:
        """Slab start of each shard in the shard-major storage layout."""
        out, acc = [], 0
        for r in self.shard_rows:
            out.append(acc)
            acc += r
        return tuple(out)

    def route(self, ids):
        """ids → (owner shard, local row).  Works on jnp and np arrays."""
        if self.partition == "mod":
            return ids % self.num_shards, ids // self.num_shards
        block = self.block
        mod = jnp if isinstance(ids, jax.Array) else np
        return mod.clip(ids // block, 0, self.num_shards - 1), ids % block

    def flatten(self, ids):
        """ids → slot in the shard-major ``(V, D)`` storage array."""
        owner, local = self.route(ids)
        if isinstance(ids, jax.Array):
            return jnp.asarray(self.offsets, ids.dtype)[owner] + local
        return np.asarray(self.offsets, dtype=np.asarray(ids).dtype)[
            owner] + local

    def global_rows(self, shard: int) -> np.ndarray:
        """Global row ids owned by ``shard``, in local-row (slab) order."""
        if self.partition == "mod":
            return np.arange(shard, self.vocab, self.num_shards)
        lo = shard * self.block
        return np.arange(lo, lo + self.shard_rows[shard])


@functools.partial(jax.jit, static_argnames=("spec",))
def sharded_pull(data, hot_rows, slot_of, ids, *, spec: RoutingSpec):
    """Routed pull: hot ids from the cache, cold ids from main storage.

    ``data`` is the shard-major ``(V, D)`` storage; ``hot_rows``/
    ``slot_of`` the placement cache (``slot_of[i] < 0`` → cold).  Values
    are identical either way (write-through invariant), so the result is
    bit-identical to a single-table gather regardless of placement.
    """
    cold = data[spec.flatten(ids)]
    if hot_rows is None or hot_rows.shape[0] == 0:
        return cold
    slot = slot_of[ids]
    hot = hot_rows[jnp.clip(slot, 0)]
    return jnp.where((slot >= 0)[..., None], hot, cold)


@functools.partial(jax.jit, static_argnames=("spec", "dedup"))
def sharded_update(data, ids, row_grads, lr, *, spec: RoutingSpec,
                   dedup: bool = True):
    """Routed push into main storage: one COO scatter-add of
    ``-lr * row_grads`` at the ids' storage slots.

    With ``dedup`` the (ids, grads) stream is first reduced to one summed
    row per distinct id (``dedup_rows``); padding slots carry the id
    ``spec.vocab`` and are mapped past the end of storage, so the scatter
    drops them — no masked zero-adds, hence per-row accumulation order
    (and bits) matches the single-table scatter exactly.  Returns
    ``(new_data, pushed_ids, summed_updates)`` so the caller can apply
    the same updates to the hot cache.
    """
    ids = ids.reshape(-1)
    g = row_grads.reshape(-1, spec.dim)
    if dedup:
        ids, g = dedup_rows(ids, g, fill_id=spec.vocab)
    u = (-lr * g).astype(data.dtype)
    tgt = jnp.where(ids < spec.vocab, spec.flatten(ids), data.shape[0])
    return data.at[tgt].add(u, mode="drop"), ids, u


@jax.jit
def _hot_apply(hot_rows, slot_of, ids, updates):
    """Write-through: apply the already-summed push updates to the cached
    copies of hot rows (cold / padding ids drop)."""
    slot = slot_of[ids]
    tgt = jnp.where(slot >= 0, slot, hot_rows.shape[0])
    return hot_rows.at[tgt].add(updates, mode="drop")


class ShardedTable:
    """One logical embedding table, vocab-partitioned across N PS shards.

    Parameters:
      monitor: optional :class:`repro.data.cache.AccessMonitor` — every
        pull records row-access counts (the data-management module's
        input signal).
      telemetry: optional :class:`repro.ps.telemetry.PSTelemetry` —
        per-shard pull/push bytes + wall-time accounting.
      hot_capacity: row capacity of the hot cache (0 disables it until a
        :class:`~repro.ps.placement.TierPlacer` is attached anyway —
        the cache only fills on re-pin).
      rpc_latency_s: simulated per-op worker↔PS network latency (both
        pull and push pay it).  0 on real deployments where the network
        is physical; the overlap benchmark sets it to model the paper's
        CPU-PS hop on a single-process container.

    Thread-safety: the pusher and the placer both mutate state; a small
    lock makes (storage, cache, slot-map) transitions atomic so a
    concurrent pull always snapshots a coherent triple.
    """

    def __init__(self, vocab: int, dim: int, num_shards: int, key=None, *,
                 partition: str = "mod", dtype=jnp.float32, monitor=None,
                 telemetry=None, hot_capacity: int = 4096,
                 rpc_latency_s: float = 0.0, init_scale: float | None = None):
        self.spec = RoutingSpec(vocab, dim, num_shards, partition)
        self.monitor = monitor
        self.telemetry = telemetry
        self.hot_capacity = int(hot_capacity)
        self.rpc_latency_s = float(rpc_latency_s)
        self._mu = threading.Lock()
        self._data_version = 0   # bumped on every storage swap (push/demote)
        if key is not None:
            scale = dim**-0.5 if init_scale is None else init_scale
            dense = jax.random.normal(key, (vocab, dim), dtype) * scale
            self.data = self._to_slabs(dense)
        else:
            self.data = jnp.zeros((vocab, dim), dtype)
        # hot-row cache: empty until the first re-pin
        self.hot_rows = jnp.zeros((0, dim), dtype)
        self.slot_of = jnp.full((vocab + 1,), -1, jnp.int32)
        # simulated storage-tier placement (row granularity, per shard);
        # everything starts cold, matching a freshly loaded table
        self.tiers = [np.full((r,), TIER_DISK, np.int8)
                      for r in self.spec.shard_rows]
        # host copy of the slot map for O(ids) hot-hit accounting — counts
        # rows actually served from the cache, not merely DEVICE-coded
        self._slot_np = np.full((vocab + 1,), -1, np.int32)
        self._cache_active = False

    # --- construction / inspection ------------------------------------
    def _to_slabs(self, dense):
        """(V, D) vocab order → shard-major slab order."""
        perm = np.concatenate([self.spec.global_rows(s)
                               for s in range(self.spec.num_shards)])
        return jnp.asarray(dense)[perm]

    @classmethod
    def from_dense(cls, table, num_shards: int, *, partition: str = "mod",
                   **kw) -> "ShardedTable":
        t = cls(table.shape[0], table.shape[1], num_shards,
                partition=partition, dtype=table.dtype, **kw)
        t.data = t._to_slabs(table)
        return t

    def to_dense(self):
        """Reassemble the logical ``(V, D)`` table (tests/checkpointing)."""
        perm = np.concatenate([self.spec.global_rows(s)
                               for s in range(self.spec.num_shards)])
        inv = np.empty_like(perm)
        inv[perm] = np.arange(perm.size)
        return self.data[inv]

    @property
    def shards(self) -> list:
        """Per-shard slab views of the storage array."""
        return [self.data[o:o + r] for o, r in
                zip(self.spec.offsets, self.spec.shard_rows)]

    @property
    def vocab(self) -> int:
        return self.spec.vocab

    @property
    def dim(self) -> int:
        return self.spec.dim

    @property
    def num_shards(self) -> int:
        return self.spec.num_shards

    # --- PS operations -------------------------------------------------
    def _account(self, op: str, ids_np: np.ndarray, seconds: float,
                 bytes_per_row: int) -> None:
        if self.telemetry is None:
            return
        owner, local = self.spec.route(ids_np)
        owner, local = owner.ravel(), local.ravel()
        S = self.spec.num_shards
        per_shard = np.bincount(owner, minlength=S)
        hot = None
        if self._cache_active:
            hot = np.bincount(
                owner[self._slot_np[ids_np.ravel()] >= 0], minlength=S)
        self.telemetry.record(op, rows=per_shard,
                              bytes_=per_shard * bytes_per_row,
                              seconds=seconds, hot_rows=hot)

    def _check_ids(self, ids_np: np.ndarray) -> None:
        if ids_np.size and (ids_np.min() < 0 or ids_np.max() >= self.vocab):
            raise ValueError(
                f"ids out of range for vocab={self.vocab}: "
                f"[{ids_np.min()}, {ids_np.max()}]")

    def pull(self, ids):
        """PS pull: fetch the touched rows.  ``ids (...,)`` → ``(..., D)``."""
        t0 = time.perf_counter()
        ids = jnp.asarray(ids)
        ids_np = np.asarray(ids)
        self._check_ids(ids_np)
        if self.monitor is not None:
            self.monitor.record(ids_np)
        with self._mu:   # coherent (storage, cache, slot-map) snapshot
            data, hot, slot = self.data, self.hot_rows, self.slot_of
        out = sharded_pull(data, hot, slot, ids, spec=self.spec)
        jax.block_until_ready(out)
        if self.rpc_latency_s:
            time.sleep(self.rpc_latency_s)
        self._account("pull", ids_np, time.perf_counter() - t0,
                      self.spec.dim * out.dtype.itemsize)
        return out

    def push(self, ids, row_grads, *, lr: float, dedup: bool = True):
        """PS push: apply ``-lr * row_grads`` to the owning shards (and
        write through to the hot cache, keeping the two bit-identical)."""
        t0 = time.perf_counter()
        ids = jnp.asarray(ids)
        ids_np = np.asarray(ids)
        self._check_ids(ids_np)
        grads = jnp.asarray(row_grads)
        while True:
            with self._mu:
                base, version = self.data, self._data_version
            data_new, pushed_ids, updates = sharded_update(
                base, ids, grads, lr, spec=self.spec, dedup=dedup)
            jax.block_until_ready(data_new)
            with self._mu:
                if self._data_version != version:
                    # storage was swapped under us (another push, or a
                    # memory-kind demotion) — redo against the new array so
                    # no update is lost; at most one retry in steady state
                    continue
                # the hot write-through must use the *current* cache/slot-
                # map (a re-pin may have landed since the scatter started)
                if self.hot_rows.shape[0]:
                    self.hot_rows = jax.block_until_ready(_hot_apply(
                        self.hot_rows, self.slot_of, pushed_ids, updates))
                self.data = data_new
                self._data_version += 1
                break
        if self.rpc_latency_s:
            time.sleep(self.rpc_latency_s)
        if self.telemetry is not None:
            itemsize = self.data.dtype.itemsize
            if dedup:
                # the wire carries one summed row per distinct id — reuse
                # the deduped ids the scatter produced (drop the padding)
                wire_ids = np.asarray(pushed_ids)
                wire_ids = wire_ids[wire_ids < self.vocab]
            else:
                wire_ids = ids_np
            self._account("push", wire_ids, time.perf_counter() - t0,
                          self.spec.dim * itemsize + ids_np.itemsize)
        return self

    # --- tier placement (written by TierPlacer) -------------------------
    def set_tiers(self, global_tiers: np.ndarray) -> dict:
        """Install a per-row tier assignment (array of
        ``repro.data.cache.Tier`` over the *global* vocab) into the
        per-shard tier arrays; returns per-tier row counts."""
        from repro.data.cache import Tier

        codes = np.full((self.vocab,), TIER_DISK, np.int8)
        codes[global_tiers == Tier.DEVICE] = TIER_DEVICE
        codes[global_tiers == Tier.HOST] = TIER_HOST
        for s in range(self.num_shards):
            self.tiers[s] = codes[self.spec.global_rows(s)]
        return {
            "device_rows": int((codes == TIER_DEVICE).sum()),
            "host_rows": int((codes == TIER_HOST).sum()),
            "disk_rows": int((codes == TIER_DISK).sum()),
        }

    def install_hot_rows(self, hot_ids: np.ndarray) -> int:
        """Re-pin: load ``hot_ids`` (truncated to capacity) into the hot
        cache and rebuild the slot map.  Returns the cached row count."""
        hot_ids = np.asarray(hot_ids, np.int64).ravel()[:self.hot_capacity]
        if hot_ids.size == 0:
            return 0
        slot = np.full((self.vocab + 1,), -1, np.int32)
        slot[hot_ids] = np.arange(hot_ids.size, dtype=np.int32)
        slot_j = jnp.asarray(slot)
        # pad the cache to its fixed capacity so repins with different hot
        # set sizes don't retrigger jit traces of the pull/push paths
        pad = np.zeros((self.hot_capacity,), np.int64)
        pad[:hot_ids.size] = hot_ids
        flat = self.spec.flatten(jnp.asarray(pad))
        with self._mu:
            self.hot_rows = _to_memory_kind(self.data[flat], "device")
            self.slot_of = slot_j
            self._slot_np = slot
            self._cache_active = True
        return int(hot_ids.size)

    def demote_storage(self) -> None:
        """Best-effort: move main storage to host memory (TPU runtimes) —
        the hot cache is the only HBM-resident copy after this."""
        with self._mu:
            self.data = _to_memory_kind(self.data, "pinned_host")
            self._data_version += 1   # make any in-flight push retry

    def tier_counts(self) -> np.ndarray:
        """(num_shards, 3) rows per (DEVICE, HOST, DISK) tier per shard."""
        return np.stack([np.bincount(t, minlength=3) for t in self.tiers])


def _to_memory_kind(arr, kind: str):
    """device_put with a memory kind on runtimes that support it (TPU);
    identity elsewhere — the CPU container simulates tiers in software."""
    dev = jax.devices()[0]
    if dev.platform != "tpu":
        return arr
    try:
        sharding = jax.sharding.SingleDeviceSharding(dev, memory_kind=kind)
        return jax.device_put(arr, sharding)
    except (ValueError, TypeError, NotImplementedError):
        return arr
