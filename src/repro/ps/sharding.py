"""Sharded parameter-server table — HeterPS §3's CPU-PS tier, scaled out.

The paper keeps huge sparse embedding tables on CPU parameter servers and
shards them across hosts; workers pull only the touched rows and push
sparse row gradients back.  :class:`ShardedTable` vocab-partitions one
logical ``(V, D)`` table across ``N`` PS shards and **speaks the message
protocol** of :mod:`repro.ps.server` to them through a pluggable
:class:`~repro.ps.transport.Transport`:

* each shard is an endpoint owning one slab bucket — a
  :class:`~repro.ps.server.ShardServer` behind an in-process queue
  (default: deterministic, the tests/CI oracle path) or a real worker
  process (:class:`~repro.ps.transport.MultiprocTransport`);
* ``pull`` routes ids to their owners client-side, fans the per-shard
  requests out in one ``request_many`` round, and reassembles the rows
  in id order; ``push`` dedups duplicate ids via ``dedup_rows`` and
  pre-scales the update **client-side in jnp** (``-lr * summed_grads``),
  so the shard's f32 ``+=`` lands bit-identically to the single-table
  XLA scatter-add of the pre-refactor oracle (pinned in
  ``tests/test_ps.py`` / ``tests/test_ps_transport.py``);
* tier-aware placement stays **client-side**: a fixed-capacity
  **hot-row cache** (``hot_rows`` + an id→slot map) holds the rows the
  access monitor marked DEVICE-tier.  Pulls merge hot rows over the
  transport's cold rows; pushes write through to both, so the two stay
  bit-identical.  On TPU runtimes the cache lives in HBM
  (``memory_kind="device"``); shard slabs are the host/remote tier;
* every pull/push is metered per shard (bytes, rows, wall time) by an
  attached :class:`~repro.ps.telemetry.PSTelemetry` — with a real
  transport the timings now include the actual IPC hop; an optional
  simulated RPC latency still models a slower network on top.

The pre-refactor fused jnp kernels (:func:`sharded_pull`,
:func:`sharded_update` over one shard-major storage array) are kept
below as the reference implementation the message path is equivalence-
pinned against.  For elastic fleets (shards joining/leaving at runtime,
replicas, PS-hosted optimizers) see :mod:`repro.ps.elastic`.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.ps import dedup_rows
from repro.ps.transport import Transport, make_transport

#: tier codes stored in the per-shard placement arrays (int8); index-aligned
#: with ``repro.data.cache.Tier`` ordering DEVICE < HOST < DISK.
TIER_DEVICE, TIER_HOST, TIER_DISK = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class RoutingSpec:
    """Static routing metadata — hashable, so jit can close over it.

    ``partition="mod"`` (default) assigns row ``i`` to shard ``i % N`` —
    balanced under the zipf-skewed id streams of CTR logs.  ``"block"``
    assigns contiguous vocab ranges (shard ``s`` owns
    ``[s*block, (s+1)*block)``) — the layout a range-partitioned
    key-value PS would use.
    """

    vocab: int
    dim: int
    num_shards: int
    partition: str = "mod"

    def __post_init__(self):
        if self.partition not in ("mod", "block"):
            raise ValueError(f"unknown partition {self.partition!r}")
        if not 1 <= self.num_shards <= max(1, self.vocab):
            raise ValueError(
                f"num_shards={self.num_shards} outside [1, vocab={self.vocab}]")

    @property
    def block(self) -> int:
        return -(-self.vocab // self.num_shards)  # ceil

    @property
    def shard_rows(self) -> tuple[int, ...]:
        if self.partition == "mod":
            return tuple(
                (self.vocab - s + self.num_shards - 1) // self.num_shards
                for s in range(self.num_shards))
        return tuple(
            max(0, min(self.block, self.vocab - s * self.block))
            for s in range(self.num_shards))

    @property
    def offsets(self) -> tuple[int, ...]:
        """Slab start of each shard in the shard-major storage layout."""
        out, acc = [], 0
        for r in self.shard_rows:
            out.append(acc)
            acc += r
        return tuple(out)

    def route(self, ids):
        """ids → (owner shard, local row).  Works on jnp and np arrays."""
        if self.partition == "mod":
            return ids % self.num_shards, ids // self.num_shards
        block = self.block
        mod = jnp if isinstance(ids, jax.Array) else np
        return mod.clip(ids // block, 0, self.num_shards - 1), ids % block

    def flatten(self, ids):
        """ids → slot in the shard-major ``(V, D)`` storage array."""
        owner, local = self.route(ids)
        if isinstance(ids, jax.Array):
            return jnp.asarray(self.offsets, ids.dtype)[owner] + local
        return np.asarray(self.offsets, dtype=np.asarray(ids).dtype)[
            owner] + local

    def global_rows(self, shard: int) -> np.ndarray:
        """Global row ids owned by ``shard``, in local-row (slab) order."""
        if self.partition == "mod":
            return np.arange(shard, self.vocab, self.num_shards)
        lo = shard * self.block
        return np.arange(lo, lo + self.shard_rows[shard])


# --------------------------------------------------------------------------
# reference jnp kernels (pre-refactor single-array path — the oracle the
# message path is pinned against, and still the fused TPU formulation)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("spec",))
def sharded_pull(data, hot_rows, slot_of, ids, *, spec: RoutingSpec):
    """Routed pull over one shard-major ``(V, D)`` storage array: hot ids
    from the cache, cold ids from main storage.  Values are identical
    either way (write-through invariant), so the result is bit-identical
    to a single-table gather regardless of placement."""
    cold = data[spec.flatten(ids)]
    if hot_rows is None or hot_rows.shape[0] == 0:
        return cold
    slot = slot_of[ids]
    hot = hot_rows[jnp.clip(slot, 0)]
    return jnp.where((slot >= 0)[..., None], hot, cold)


@functools.partial(jax.jit, static_argnames=("spec", "dedup"))
def sharded_update(data, ids, row_grads, lr, *, spec: RoutingSpec,
                   dedup: bool = True):
    """Routed push into shard-major storage: one COO scatter-add of
    ``-lr * row_grads`` at the ids' storage slots.

    With ``dedup`` the (ids, grads) stream is first reduced to one summed
    row per distinct id (``dedup_rows``); padding slots carry the id
    ``spec.vocab`` and are mapped past the end of storage, so the scatter
    drops them — no masked zero-adds, hence per-row accumulation order
    (and bits) matches the single-table scatter exactly.  Returns
    ``(new_data, pushed_ids, summed_updates)``.
    """
    ids = ids.reshape(-1)
    g = row_grads.reshape(-1, spec.dim)
    if dedup:
        ids, g = dedup_rows(ids, g, fill_id=spec.vocab)
    u = (-lr * g).astype(data.dtype)
    tgt = jnp.where(ids < spec.vocab, spec.flatten(ids), data.shape[0])
    return data.at[tgt].add(u, mode="drop"), ids, u


@functools.partial(jax.jit, static_argnames=("dedup", "vocab", "dim"))
def _client_update(ids, row_grads, lr, *, vocab: int, dim: int,
                   dedup: bool = True):
    """Client half of a push: dedup + pre-scale in jnp, exactly as
    :func:`sharded_update` would — the shard's ``+=`` of the result is
    then the same IEEE add as the oracle's scatter.  Returns
    ``(pushed_ids, updates)`` (padding ids carry ``vocab``)."""
    ids = ids.reshape(-1)
    g = row_grads.reshape(-1, dim)
    if dedup:
        ids, g = dedup_rows(ids, g, fill_id=vocab)
    return ids, (-lr * g).astype(jnp.float32)


@jax.jit
def _hot_apply(hot_rows, slot_of, ids, updates):
    """Write-through: apply the already-summed push updates to the cached
    copies of hot rows (cold / padding ids drop)."""
    slot = slot_of[ids]
    tgt = jnp.where(slot >= 0, slot, hot_rows.shape[0])
    return hot_rows.at[tgt].add(updates, mode="drop")


@jax.jit
def _merge_hot(cold, hot_rows, slot_of, ids):
    """Overlay hot-cache rows onto transport-pulled cold rows (selection
    only — bit-neutral under the write-through invariant)."""
    slot = slot_of[ids]
    hot = hot_rows[jnp.clip(slot, 0)]
    return jnp.where((slot >= 0)[..., None], hot, cold)


class ShardedTable:
    """One logical embedding table, vocab-partitioned across N PS shards
    behind a :class:`~repro.ps.transport.Transport`.

    Parameters:
      transport: ``None`` (→ in-process queue backend), ``"inproc"`` /
        ``"multiproc"``, or a :class:`Transport` instance.  Shard ``s``
        becomes endpoint ``s`` owning bucket ``s`` (its slab).
      monitor: optional :class:`repro.data.cache.AccessMonitor` — every
        pull records row-access counts (the data-management module's
        input signal).
      telemetry: optional :class:`repro.ps.telemetry.PSTelemetry` —
        per-shard pull/push bytes + wall-time accounting.
      hot_capacity: row capacity of the hot cache (0 disables it until a
        :class:`~repro.ps.placement.TierPlacer` is attached anyway —
        the cache only fills on re-pin).
      rpc_latency_s: extra simulated per-op worker↔PS latency on top of
        the transport's real cost (the overlap benchmark calibrates it
        to model the paper's cross-host network on a single box).

    Thread-safety: pulls snapshot the (hot cache, slot map) pair under
    ``_mu``; pushes and hot-cache re-pins serialize on ``_update_mu`` so
    a re-pin landing mid-push can neither lose nor double-apply a
    write-through (pulls stay wait-free — they may observe a push's
    shard-side effect before its hot write-through, the same bounded
    staleness the async client already trades on).
    """

    def __init__(self, vocab: int, dim: int, num_shards: int, key=None, *,
                 partition: str = "mod", dtype=jnp.float32, monitor=None,
                 telemetry=None, hot_capacity: int = 4096,
                 rpc_latency_s: float = 0.0, init_scale: float | None = None,
                 transport: str | Transport | None = None):
        self.spec = RoutingSpec(vocab, dim, num_shards, partition)
        self.monitor = monitor
        self.telemetry = telemetry
        self.hot_capacity = int(hot_capacity)
        self.rpc_latency_s = float(rpc_latency_s)
        self.dtype = dtype
        self._mu = threading.Lock()
        self._update_mu = threading.RLock()
        self.transport = make_transport(transport)
        for s in range(num_shards):
            self.transport.add_shard(s, dim=dim, optimizer="none")
        if key is not None:
            scale = dim**-0.5 if init_scale is None else init_scale
            dense = jax.random.normal(key, (vocab, dim), dtype) * scale
            self._load_dense(dense)
        else:
            self._load_dense(jnp.zeros((vocab, dim), dtype))
        # hot-row cache: empty until the first re-pin
        self.hot_rows = jnp.zeros((0, dim), dtype)
        self.slot_of = jnp.full((vocab + 1,), -1, jnp.int32)
        # simulated storage-tier placement (row granularity, per shard);
        # everything starts cold, matching a freshly loaded table
        self.tiers = [np.full((r,), TIER_DISK, np.int8)
                      for r in self.spec.shard_rows]
        # host copy of the slot map for O(ids) hot-hit accounting — counts
        # rows actually served from the cache, not merely DEVICE-coded
        self._slot_np = np.full((vocab + 1,), -1, np.int32)
        self._cache_active = False

    # --- construction / inspection ------------------------------------
    def _load_dense(self, dense) -> None:
        """Ship a vocab-order ``(V, D)`` table to the shards as slabs."""
        dense_np = np.asarray(dense, np.float32)
        for s in range(self.spec.num_shards):
            self.transport.request(s, {
                "op": "create", "bucket": s,
                "rows": dense_np[self.spec.global_rows(s)]})

    @classmethod
    def from_dense(cls, table, num_shards: int, *, partition: str = "mod",
                   **kw) -> "ShardedTable":
        t = cls(table.shape[0], table.shape[1], num_shards,
                partition=partition, dtype=table.dtype, **kw)
        t._load_dense(table)
        return t

    def to_dense(self):
        """Reassemble the logical ``(V, D)`` table (tests/checkpointing)."""
        dense = np.empty((self.vocab, self.dim), np.float32)
        replies = self.transport.request_many(
            [(s, {"op": "snapshot", "bucket": s})
             for s in range(self.num_shards)])
        for s, rep in enumerate(replies):
            dense[self.spec.global_rows(s)] = rep["rows"]
        return jnp.asarray(dense, self.dtype)

    @property
    def shards(self) -> list:
        """Per-shard slab snapshots (local-row order)."""
        return [jnp.asarray(rep["rows"], self.dtype)
                for rep in self.transport.request_many(
                    [(s, {"op": "snapshot", "bucket": s})
                     for s in range(self.num_shards)])]

    @property
    def vocab(self) -> int:
        return self.spec.vocab

    @property
    def dim(self) -> int:
        return self.spec.dim

    @property
    def num_shards(self) -> int:
        return self.spec.num_shards

    # --- transport routing ----------------------------------------------
    def _shard_messages(self, op: str, ids_flat: np.ndarray,
                        payload: np.ndarray | None = None, **extra):
        """Group a flat id stream by owner shard into per-shard messages.
        Returns ``(messages, segments)`` where ``segments[i]`` are the
        positions in ``ids_flat`` message ``i`` covers."""
        owner, local = self.spec.route(ids_flat)
        order = np.argsort(owner, kind="stable")
        counts = np.bincount(owner, minlength=self.spec.num_shards)
        msgs, segs, start = [], [], 0
        for s in range(self.spec.num_shards):
            n = int(counts[s])
            if n == 0:
                continue
            seg = order[start:start + n]
            start += n
            msg = {"op": op, "buckets": np.full((n,), s, np.int64),
                   "ids": local[seg], **extra}
            if payload is not None:
                msg["updates" if op == "add" else "grads"] = payload[seg]
            msgs.append((s, msg))
            segs.append(seg)
        return msgs, segs

    def _fetch(self, ids_flat: np.ndarray) -> np.ndarray:
        """Raw routed pull over the transport (no metering, no cache) —
        rows in ``ids_flat`` order."""
        msgs, segs = self._shard_messages("pull", ids_flat)
        out = np.empty((ids_flat.size, self.dim), np.float32)
        for seg, rep in zip(segs, self.transport.request_many(msgs)):
            out[seg] = rep["rows"]
        return out

    # --- PS operations -------------------------------------------------
    def _account(self, op: str, ids_np: np.ndarray, seconds: float,
                 bytes_per_row: int) -> None:
        if self.telemetry is None:
            return
        owner, local = self.spec.route(ids_np)
        owner, local = owner.ravel(), local.ravel()
        S = self.spec.num_shards
        per_shard = np.bincount(owner, minlength=S)
        hot = None
        if self._cache_active:
            hot = np.bincount(
                owner[self._slot_np[ids_np.ravel()] >= 0], minlength=S)
        self.telemetry.record(op, rows=per_shard,
                              bytes_=per_shard * bytes_per_row,
                              seconds=seconds, hot_rows=hot)

    def _check_ids(self, ids_np: np.ndarray) -> None:
        if ids_np.size and (ids_np.min() < 0 or ids_np.max() >= self.vocab):
            raise ValueError(
                f"ids out of range for vocab={self.vocab}: "
                f"[{ids_np.min()}, {ids_np.max()}]")

    def pull(self, ids):
        """PS pull: fetch the touched rows.  ``ids (...,)`` → ``(..., D)``."""
        t0 = time.perf_counter()
        ids = jnp.asarray(ids)
        ids_np = np.asarray(ids)
        self._check_ids(ids_np)
        if self.monitor is not None:
            self.monitor.record(ids_np)
        cold = self._fetch(ids_np.ravel().astype(np.int64))
        out = jnp.asarray(cold.reshape(ids_np.shape + (self.dim,)),
                          self.dtype)
        with self._mu:   # coherent (cache, slot-map) snapshot
            hot, slot = self.hot_rows, self.slot_of
        if hot.shape[0]:
            out = _merge_hot(out, hot, slot, ids)
        jax.block_until_ready(out)
        if self.rpc_latency_s:
            time.sleep(self.rpc_latency_s)
        self._account("pull", ids_np, time.perf_counter() - t0,
                      self.spec.dim * out.dtype.itemsize)
        return out

    def push(self, ids, row_grads, *, lr: float, dedup: bool = True):
        """PS push: apply ``-lr * row_grads`` at the owning shards (and
        write through to the hot cache, keeping the two bit-identical).

        The dedup + ``-lr`` pre-scale runs client-side in jnp (identical
        to the oracle's :func:`sharded_update` prologue); shards apply
        the summed per-row updates with a plain f32 add."""
        t0 = time.perf_counter()
        ids = jnp.asarray(ids)
        ids_np = np.asarray(ids)
        self._check_ids(ids_np)
        grads = jnp.asarray(row_grads)
        pushed_ids, updates = _client_update(
            ids, grads, lr, vocab=self.vocab, dim=self.dim, dedup=dedup)
        jax.block_until_ready(updates)
        pushed_np = np.asarray(pushed_ids)
        u_np = np.asarray(updates)
        live = pushed_np < self.vocab        # drop dedup padding slots
        wire_ids = pushed_np[live].astype(np.int64)
        with self._update_mu:
            msgs, _ = self._shard_messages("add", wire_ids,
                                           payload=u_np[live])
            self.transport.request_many(msgs)
            # write-through must see the *current* cache/slot-map (a
            # re-pin serializes on _update_mu, so it can't land between
            # the shard apply and this update)
            with self._mu:
                if self.hot_rows.shape[0]:
                    self.hot_rows = jax.block_until_ready(_hot_apply(
                        self.hot_rows, self.slot_of, pushed_ids, updates))
        if self.rpc_latency_s:
            time.sleep(self.rpc_latency_s)
        if self.telemetry is not None:
            itemsize = np.dtype(np.float32).itemsize
            # the wire carries one summed row per distinct id when
            # deduping; raw duplicates otherwise
            acct_ids = wire_ids if dedup else ids_np
            self._account("push", acct_ids, time.perf_counter() - t0,
                          self.spec.dim * itemsize + ids_np.itemsize)
        return self

    # --- tier placement (written by TierPlacer) -------------------------
    def set_tiers(self, global_tiers: np.ndarray) -> dict:
        """Install a per-row tier assignment (array of
        ``repro.data.cache.Tier`` over the *global* vocab) into the
        per-shard tier arrays; returns per-tier row counts."""
        from repro.data.cache import Tier

        codes = np.full((self.vocab,), TIER_DISK, np.int8)
        codes[global_tiers == Tier.DEVICE] = TIER_DEVICE
        codes[global_tiers == Tier.HOST] = TIER_HOST
        for s in range(self.num_shards):
            self.tiers[s] = codes[self.spec.global_rows(s)]
        return {
            "device_rows": int((codes == TIER_DEVICE).sum()),
            "host_rows": int((codes == TIER_HOST).sum()),
            "disk_rows": int((codes == TIER_DISK).sum()),
        }

    def install_hot_rows(self, hot_ids: np.ndarray) -> int:
        """Re-pin: load ``hot_ids`` (truncated to capacity) into the hot
        cache and rebuild the slot map.  Returns the cached row count."""
        hot_ids = np.asarray(hot_ids, np.int64).ravel()[:self.hot_capacity]
        if hot_ids.size == 0:
            return 0
        slot = np.full((self.vocab + 1,), -1, np.int32)
        slot[hot_ids] = np.arange(hot_ids.size, dtype=np.int32)
        slot_j = jnp.asarray(slot)
        # pad the cache to its fixed capacity so repins with different hot
        # set sizes don't retrigger jit traces of the pull/push paths
        pad = np.zeros((self.hot_capacity,), np.int64)
        pad[:hot_ids.size] = hot_ids
        with self._update_mu:    # no push between fetch and install
            rows = jnp.asarray(self._fetch(pad), self.dtype)
            with self._mu:
                self.hot_rows = _to_memory_kind(rows, "device")
                self.slot_of = slot_j
                self._slot_np = slot
                self._cache_active = True
        return int(hot_ids.size)

    def demote_storage(self) -> None:
        """Tiering hint: shard slabs are the cold tier once the hot cache
        covers the head of the distribution.  Client-side this is a
        broadcast notification — on CPU shard servers it is a no-op; a
        TPU/accelerator shard would move its slab off-device."""
        self.transport.request_many(
            [(s, {"op": "demote"}) for s in sorted(
                self.transport.live_shards)])

    def tier_counts(self) -> np.ndarray:
        """(num_shards, 3) rows per (DEVICE, HOST, DISK) tier per shard."""
        return np.stack([np.bincount(t, minlength=3) for t in self.tiers])

    def close(self) -> None:
        """Shut the shard endpoints down (idempotent)."""
        self.transport.close()
        if self.telemetry is not None:
            self.telemetry.close()


def _to_memory_kind(arr, kind: str):
    """device_put with a memory kind on runtimes that support it (TPU);
    identity elsewhere — the CPU container simulates tiers in software."""
    dev = jax.devices()[0]
    if dev.platform != "tpu":
        return arr
    try:
        sharding = jax.sharding.SingleDeviceSharding(dev, memory_kind=kind)
        return jax.device_put(arr, sharding)
    except (ValueError, TypeError, NotImplementedError):
        return arr
