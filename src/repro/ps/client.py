"""Async PS client — overlap pull/push with compute (HeterPS §3).

The paper's workers hide the worker↔PS network hop behind compute: while
step *i* computes, the rows batch *i+1* needs are already being pulled
and the gradients of step *i−1* are being pushed.  :class:`PSClient`
implements that as a double-buffered iterator over a batch stream
(typically a :class:`~repro.data.pipeline.PrefetchLoader`):

* a **puller** thread walks the stream, pulls each batch's rows from the
  :class:`~repro.ps.sharding.ShardedTable`, and stages ``(batch, rows)``
  pairs in a bounded queue (``depth`` = number of in-flight pulls);
* a **pusher** thread drains a push queue of ``(ids, grads)`` and applies
  them to the table;
* the main thread iterates ``(batch, rows)`` and calls :meth:`push` —
  both calls are non-blocking in steady state, so step time approaches
  ``max(compute, pull, push)`` instead of their sum.

Consistency: updates are applied in push order, but a pull staged while
pushes are in flight may read pre-push rows — bounded staleness of at
most ``depth`` steps, the standard async-PS trade (HeterPS trains CTR
models asynchronously for exactly this reason).  Shard arrays are
immutable jax values swapped atomically, so readers never see torn rows.
Shutdown follows ``PrefetchLoader``'s contract: timed puts + a sentinel,
so neither side can hang.
"""

from __future__ import annotations

import queue
import threading
import time

from repro import obs
from repro.obs import trace as obs_trace

#: stream-end marker (same pattern as data.pipeline's sentinel)
_STOP = object()


class PSClient:
    """Double-buffered async pull/push over a :class:`ShardedTable`.

    Iterating yields ``(batch, rows)`` where ``rows = table.pull(
    batch[ids_key])`` was issued one step ahead; :meth:`push` enqueues a
    gradient push applied in the background.  Call :meth:`close` when
    done (drains queued pushes by default).
    """

    def __init__(self, table, loader, *, ids_key: str = "ids",
                 depth: int = 2, put_timeout: float = 0.05):
        self.table = table
        self._ids_key = ids_key
        self._put_timeout = put_timeout
        self._pull_q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._push_q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._stop = threading.Event()
        self._done = False
        self._closed = False
        self._lock = threading.Lock()
        self.steps_pulled = 0
        self.steps_pushed = 0
        self._pushes_enqueued = 0
        self._pushes_dropped = 0
        self._pusher_error: BaseException | None = None
        self._puller_error: BaseException | None = None

        def puller():
            try:
                for batch in loader:
                    ids = batch[self._ids_key]
                    with obs_trace.span("ps.client.pull", "ps",
                                        step=self.steps_pulled):
                        rows = self.table.pull(ids)
                    with self._lock:
                        self.steps_pulled += 1
                    placed = False
                    while not self._stop.is_set():
                        try:
                            self._pull_q.put((batch, rows),
                                             timeout=self._put_timeout)
                            placed = True
                            break
                        except queue.Full:
                            continue
                    if not placed:
                        return  # close() requested while queue stayed full
            except BaseException as e:  # surfaced by __next__ at stream end
                self._puller_error = e
            finally:
                # always terminate the stream; make room by dropping staged
                # pulls once close() was requested (the consumer is gone)
                wait = self._put_timeout
                while True:
                    try:
                        self._pull_q.put(_STOP, timeout=wait)
                        return
                    except queue.Full:
                        if self._stop.is_set():
                            try:
                                self._pull_q.get_nowait()
                            except queue.Empty:
                                pass
                        else:
                            wait = min(wait * 2, 1.0)

        def pusher():
            while True:
                item = self._push_q.get()
                if item is _STOP:
                    return
                ids, grads, lr, dedup = item
                try:
                    with obs_trace.span("ps.client.push_apply", "ps",
                                        step=self.steps_pushed):
                        self.table.push(ids, grads, lr=lr, dedup=dedup)
                except BaseException as e:  # surface in flush()/close()
                    self._pusher_error = e
                    return
                with self._lock:
                    self.steps_pushed += 1

        self._puller = threading.Thread(target=puller, daemon=True)
        self._pusher = threading.Thread(target=pusher, daemon=True)
        self._puller.start()
        self._pusher.start()

    # --- pull side -------------------------------------------------------
    def __iter__(self):
        return self

    def __next__(self):
        if self._done:
            raise StopIteration
        item = self._pull_q.get()
        if item is _STOP:
            self._done = True
            if self._puller_error is not None:
                # a pull failed mid-stream — surface it rather than letting
                # training end early looking like a clean (short) run
                raise RuntimeError("PS pull failed") from self._puller_error
            raise StopIteration
        return item  # (batch, rows)

    # --- push side -------------------------------------------------------
    def push(self, ids, row_grads, *, lr: float, dedup: bool = True) -> None:
        """Queue an async push of ``-lr * row_grads`` at ``ids``."""
        if self._closed:
            raise RuntimeError("push() after close()")
        self._raise_pusher_error()
        self._push_q.put((ids, row_grads, lr, dedup))
        with self._lock:
            self._pushes_enqueued += 1

    def flush(self, timeout: float = 60.0) -> None:
        """Block until every queued push has been applied to the table.

        A pusher thread that already died (push raised, or it consumed
        the stop sentinel with work still queued) can never drain the
        queue — detected immediately and raised with the pending-push
        count, instead of spinning out the full ``timeout``.
        """
        deadline = time.monotonic() + timeout
        while True:
            self._raise_pusher_error()
            with self._lock:
                pending = self._pushes_enqueued - self.steps_pushed
                if pending <= 0:
                    return
            if not self._pusher.is_alive():
                # re-raise any error that landed between the check above
                # and the thread's exit, then fail fast — nothing will
                # ever apply these pushes
                self._raise_pusher_error()
                raise RuntimeError(
                    f"pusher thread exited with {pending} push(es) pending")
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"PS push queue did not drain: {pending} push(es) "
                    f"pending after {timeout}s")
            time.sleep(0.001)

    def _raise_pusher_error(self):
        if self._pusher_error is not None:
            raise RuntimeError("PS push failed") from self._pusher_error

    # --- lifecycle ---------------------------------------------------------
    def close(self, *, drain: bool = True, timeout: float = 10.0) -> None:
        """Stop both threads; with ``drain`` (default) queued pushes are
        applied first so the table reflects every ``push()`` call.

        Idempotent and deterministic even when the drain fails: a second
        ``close()`` is a no-op, and the failure paths (drain timeout /
        pusher thread died) re-raise with the number of dropped pushes —
        also recorded as ``pushes_dropped`` in :meth:`stats`, so the
        counters stay consistent (``pushed + dropped == enqueued``)."""
        if self._closed:
            return
        self._closed = True
        drain_error: BaseException | None = None
        sp = obs_trace.span(
            "ps.client.drain", "ps",
            pending=max(0, self._pushes_enqueued - self.steps_pushed))
        with sp:
            try:
                if drain and self._pusher_error is None:
                    self.flush(timeout=timeout)
            except (TimeoutError, RuntimeError) as e:
                drain_error = e
            finally:
                # even if the drain raised, stop both threads — a failed
                # close must not leave the puller/pusher running against
                # the table
                self._stop.set()
                # wake the pusher; drop a stale (unapplied) push to make
                # room if the queue is full
                while True:
                    try:
                        self._push_q.put(_STOP, timeout=self._put_timeout)
                        break
                    except queue.Full:
                        try:
                            self._push_q.get_nowait()
                        except queue.Empty:
                            pass
                self._puller.join(timeout)
                self._pusher.join(timeout)
            with self._lock:
                self._pushes_dropped = max(
                    0, self._pushes_enqueued - self.steps_pushed)
                dropped = self._pushes_dropped
            sp.args["dropped"] = dropped
        self._final_telemetry(dropped)
        # a pusher failure means queued gradients were dropped — surface it
        # even when the training loop already issued its last push()
        if self._pusher_error is not None:
            raise RuntimeError(
                f"PS push failed: {dropped} push(es) dropped"
            ) from self._pusher_error
        if drain_error is not None:
            if isinstance(drain_error, TimeoutError):
                raise TimeoutError(
                    f"PS push queue did not drain: {dropped} push(es) "
                    f"dropped") from drain_error
            raise RuntimeError(
                f"pusher thread exited with pushes pending: {dropped} "
                f"push(es) dropped") from drain_error

    def _final_telemetry(self, dropped: int) -> None:
        """Session-registry counters + final metrics snapshot at close —
        no-ops when obs is disabled / no run dir is configured."""
        reg = obs.REGISTRY
        reg.counter("ps.client.steps_pulled").inc(self.steps_pulled)
        reg.counter("ps.client.steps_pushed").inc(self.steps_pushed)
        reg.counter("ps.client.pushes_dropped").inc(dropped)
        obs.flush()

    def stats(self) -> dict:
        with self._lock:
            return {"steps_pulled": self.steps_pulled,
                    "steps_pushed": self.steps_pushed,
                    "pushes_enqueued": self._pushes_enqueued,
                    "pushes_dropped": self._pushes_dropped}
