"""Deterministic fault injection over the PS transport seam.

Chaos engineering for the parameter server: :class:`FaultInjector` wraps
any :class:`~repro.ps.transport.Transport` (decorator over the PR-6
seam) and perturbs the request stream according to a declarative,
seed-driven schedule of :class:`FaultRule`\\ s — the failure oracle the
chaos tests and ``benchmarks/bench_chaos.py`` replay.  Because the rules
and the RNG are seeded, a chaos run is *reproducible*: the same schedule
against the same workload injects the same faults at the same requests.

Fault kinds (one rule each):

==============  ========================================================
kind            effect at the wrapped transport's ``_attempt``
==============  ========================================================
``delay``       sleep ``delay_s`` before forwarding (slow network/shard)
``drop_reply``  forward the request (the shard **applies** it), discard
                the reply, surface a retryable timeout — exercises the
                server's seq-dedup: the retry must not double-apply
``dup_reply``   forward, but hand back a stale-seq duplicate first and
                stash the real reply for the retry — exercises the
                client's stale-reply discard
``recv_error``  transient failure *before* the request is sent (conn
                reset) — the retry's resend is the first delivery
``crash``       kill the worker via ``inner.kill_shard`` and raise
                :class:`~repro.ps.transport.PSShardLost` — replica
                promotion (or checkpoint restore) takes it from there
==============  ========================================================

Everything except ``crash`` is *masked* by the transport retry layer:
training under such a schedule must produce a bit-exact loss trajectory
vs a fault-free run (pinned in tests/test_chaos.py).  ``crash`` is the
real thing — recovery, not retry, territory.

The injector is itself a :class:`Transport`, so it composes: the
fleet's retry loop sits on top (the injector *is* the outermost
``request``), per-shard locking and loss bookkeeping delegate to the
wrapped backend, and the seq counter is **shared** with the inner
transport so cached replies can never collide.
"""

from __future__ import annotations

import dataclasses
import random
import time

from repro.obs import trace as obs_trace
from repro.ps.transport import PSShardLost, PSShardSlow, Transport

KINDS = ("delay", "drop_reply", "dup_reply", "recv_error", "crash")


@dataclasses.dataclass
class FaultRule:
    """One line of a fault schedule.

    Matching: a rule fires when the request's op matches ``op`` (None =
    any), the target shard matches ``shard`` (None = any), the global
    attempt index is in ``[after, until)``, fewer than ``times`` fires
    have happened (None = unlimited), and a seeded coin lands under
    ``prob``.  ``delay_s`` only applies to ``kind="delay"``.
    """

    kind: str
    op: str | None = None
    shard: int | None = None
    prob: float = 1.0
    after: int = 0
    until: int | None = None
    times: int | None = None
    delay_s: float = 0.0
    fired: int = 0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")

    def matches(self, n: int, op: str | None, shard: int) -> bool:
        if self.op is not None and op != self.op:
            return False
        if self.shard is not None and shard != self.shard:
            return False
        if n < self.after or (self.until is not None and n >= self.until):
            return False
        if self.times is not None and self.fired >= self.times:
            return False
        return True


def parse_schedule(spec) -> list[FaultRule]:
    """Build a fault schedule from rules, dicts, or a compact string.

    Accepts a list of :class:`FaultRule`/dicts, or a string of
    ``;``-separated rules, each ``key=value`` pairs joined by ``,`` —
    the CLI surface::

        "crash,op=grad,shard=1,after=50,times=1;delay,delay_s=0.01,prob=0.2"

    (a bare first token is the ``kind``).
    """
    if spec is None:
        return []
    if isinstance(spec, str):
        rules = []
        for part in filter(None, (p.strip() for p in spec.split(";"))):
            kw: dict = {}
            for i, tok in enumerate(t.strip() for t in part.split(",")):
                if "=" not in tok:
                    if i != 0:
                        raise ValueError(f"bad fault token {tok!r} in "
                                         f"{part!r}")
                    kw["kind"] = tok
                    continue
                k, v = tok.split("=", 1)
                if k in ("shard", "after", "until", "times"):
                    kw[k] = int(v)
                elif k in ("prob", "delay_s"):
                    kw[k] = float(v)
                else:
                    kw[k] = v
            rules.append(FaultRule(**kw))
        return rules
    out = []
    for r in spec:
        out.append(r if isinstance(r, FaultRule) else FaultRule(**dict(r)))
    return out


class FaultInjector(Transport):
    """Transport decorator injecting faults from a seeded schedule.

    All lifecycle and bookkeeping (locks, loss reaping, heartbeat
    callback, live-shard set) delegate to ``inner``; only the
    send/recv attempt is perturbed.  ``injections`` records every fired
    fault (``{"n", "kind", "op", "shard"}``) for assertions, and each
    fire lands as a ``ps.fault.<kind>`` obs instant when tracing.
    """

    def __init__(self, inner: Transport, schedule=None, *, seed: int = 0):
        self.inner = inner
        super().__init__(retry=inner.retry)
        self.name = f"faults({inner.name})"
        self._seq = inner._seq          # shared: seqs must never collide
        self.rules = parse_schedule(schedule)
        self._rng = random.Random(seed)
        self._n = 0                     # global attempt index
        #: (shard, seq) → real reply stashed by a dup_reply fire
        self._stash: dict[tuple[int, int | None], dict] = {}
        self.injections: list[dict] = []

    # --- schedule --------------------------------------------------------
    def _fire(self, rule: FaultRule, n: int, op, shard_id: int) -> None:
        rule.fired += 1
        self.injections.append(
            {"n": n, "kind": rule.kind, "op": op, "shard": shard_id})
        if obs_trace.enabled():
            obs_trace.instant(f"ps.fault.{rule.kind}", "ps", n=n, op=op,
                              shard=shard_id)

    def _attempt(self, shard_id: int, msg: dict) -> dict:
        key = (shard_id, msg.get("seq"))
        stashed = self._stash.pop(key, None)
        if stashed is not None:
            # the retry after a dup_reply fire: the "real" reply that was
            # in flight behind the duplicate arrives now
            return stashed
        self._n += 1
        n, op = self._n, msg.get("op")
        structural: FaultRule | None = None
        for rule in self.rules:
            if not rule.matches(n, op, shard_id):
                continue
            if rule.prob < 1.0 and self._rng.random() >= rule.prob:
                continue
            if rule.kind == "delay":
                self._fire(rule, n, op, shard_id)
                time.sleep(rule.delay_s)
            elif structural is None:    # first structural fault wins
                structural = rule
        if structural is None:
            return self.inner._attempt(shard_id, msg)
        self._fire(structural, n, op, shard_id)
        kind = structural.kind
        if kind == "recv_error":
            # never reached the shard — the retry's resend is delivery #1
            raise PSShardSlow(
                f"fault-injected recv error (op={op!r}, shard={shard_id})")
        if kind == "crash":
            try:
                self.inner.kill_shard(shard_id)
            except PSShardLost:
                pass                    # already gone — still report lost
            err = PSShardLost(
                f"fault-injected crash of shard {shard_id} (op={op!r})")
            err.shard_ids = {shard_id}
            raise err
        reply = self.inner._attempt(shard_id, msg)
        if kind == "drop_reply":
            # the shard applied the request; the reply evaporates — the
            # retry must be answered from the server's seq cache
            raise PSShardSlow(
                f"fault-injected dropped reply (op={op!r}, "
                f"shard={shard_id})")
        # dup_reply: a stale-seq duplicate arrives first; the real reply
        # waits in the stash for the retry
        self._stash[key] = reply
        stale = dict(reply)
        stale["seq"] = -1
        return stale

    # --- delegation ------------------------------------------------------
    def _shard_lock(self, shard_id):
        return self.inner._shard_lock(shard_id)

    def _mark_lost(self, shard_id):
        self.inner._mark_lost(shard_id)

    @property
    def on_shard_lost(self):
        return self.inner.on_shard_lost

    @on_shard_lost.setter
    def on_shard_lost(self, fn):
        self.inner.on_shard_lost = fn

    def add_shard(self, shard_id, *, dim, optimizer="none", hyper=None):
        self.inner.add_shard(shard_id, dim=dim, optimizer=optimizer,
                             hyper=hyper)

    def stop_shard(self, shard_id):
        self.inner.stop_shard(shard_id)

    def kill_shard(self, shard_id):
        self.inner.kill_shard(shard_id)

    @property
    def live_shards(self):
        return self.inner.live_shards

    def collect_obs(self):
        return self.inner.collect_obs()

    def close(self):
        self.inner.close()
