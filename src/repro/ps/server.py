"""PS shard server — the state + event loop one parameter-server worker
runs (HeterPS §3's CPU-PS tier as a real process).

A shard owns a set of **buckets** (contiguous vocab slabs, the unit of
placement, migration and replication).  Each bucket carries its slab
rows, the PS-hosted optimizer state (Adagrad / Adam accumulators — the
client's dedup-before-push guarantees one update per row per step, so
adaptive statistics are well-defined), and an ``acked`` update counter
(what "the shard's last acked state" means for replica recovery).

This module is deliberately **numpy-only** — no jax import — so a
spawned shard process (``repro.ps.transport.MultiprocTransport``) starts
in milliseconds instead of paying the jax import + backend init.  The
arithmetic is still bit-exact against the jnp client kernels: a routed
gather is a row copy either way, and f32 ``+=`` of a client-computed
update is the same IEEE add as XLA's scatter-add (pinned in
tests/test_ps_transport.py).

The wire protocol is plain dicts with numpy payloads (picklable for the
multiprocess transport, zero-copy for the in-process one):

==========  =====================================  =======================
op          request fields                         reply
==========  =====================================  =======================
create      bucket, rows                           ok
pull        buckets (k,), ids (k,) local           rows (k, D)
add         buckets, ids, updates                  ok, acked  (pre-scaled)
grad        buckets, ids, grads, lr [, replica]    ok, acked  (PS optimizer)
snapshot    bucket                                 rows, opt, acked
install     bucket, rows, opt, acked               ok
drop        bucket                                 ok
stats       —                                      buckets, rows, counters
obs         —                                      events (trace drain), pid
demote      —                                      ok (tiering hint, no-op)
shutdown    —                                      ok (event loop exits)
==========  =====================================  =======================

Observability: every handled op records a span into the server's *own*
:class:`~repro.obs.trace.TraceBuffer` (gated on the session obs switch,
which spawned workers inherit via ``REPRO_OBS``); the ``obs`` op drains
that buffer so the client side can merge worker timelines — stamped with
the worker's pid — into the main process trace
(:meth:`repro.ps.transport.Transport.collect_obs`).

Every reply carries ``shard``; failures come back as ``{"err": ...}``
instead of killing the event loop (a bad request must not look like a
crashed shard to the failure detector).

At-most-once execution: requests may carry a transport-assigned ``seq``.
The server echoes it into the reply and keeps a bounded seq→reply cache
(:data:`REPLY_CACHE_SIZE` entries), so a *retried* request — the
transport resends after a timeout or an injected fault — is answered
from the cache without re-applying.  That is what makes retrying a
non-idempotent ``grad`` push safe: the update lands exactly once no
matter how many times the message arrives.
"""

from __future__ import annotations

import os
import traceback
from collections import OrderedDict

import numpy as np

from repro.obs import trace as obs_trace

#: retained seq→reply entries per shard — a few times the deepest
#: request pipeline any one client keeps in flight, tiny vs slab memory
REPLY_CACHE_SIZE = 16

#: optimizer names accepted by :class:`ShardServer` (``"none"`` applies
#: pre-scaled updates verbatim — the client-side-SGD mode ShardedTable
#: uses to stay bit-exact with the ``SparseEmbedding`` oracle).
OPTIMIZERS = ("none", "sgd", "adagrad", "adam")


def make_opt_state(optimizer: str, rows: int, dim: int) -> dict:
    """Fresh per-bucket optimizer slots (f32, one entry per slab row)."""
    if optimizer in ("none", "sgd"):
        return {}
    if optimizer == "adagrad":
        return {"acc": np.zeros((rows, dim), np.float32)}
    if optimizer == "adam":
        return {"m": np.zeros((rows, dim), np.float32),
                "v": np.zeros((rows, dim), np.float32),
                "t": np.zeros((rows,), np.int64)}
    raise ValueError(f"unknown optimizer {optimizer!r}")


def apply_grads(optimizer: str, hyper: dict, slab: np.ndarray, opt: dict,
                local: np.ndarray, grads: np.ndarray, lr: float) -> None:
    """Apply one deduped gradient batch in place (one update per row).

    Deterministic: replaying the same update stream on a replica bucket
    reproduces the primary's slab and optimizer state bit-for-bit, which
    is what makes synchronous replication → promotion lossless.
    """
    g = grads.astype(np.float32, copy=False)
    lr32 = np.float32(lr)
    if optimizer in ("none",):
        # pre-scaled updates: slab[local] += grads (grads already -lr·g)
        np.add.at(slab, local, g)
    elif optimizer == "sgd":
        np.add.at(slab, local, -lr32 * g)
    elif optimizer == "adagrad":
        acc = opt["acc"]
        acc[local] += g * g
        slab[local] += -lr32 * g / (np.sqrt(acc[local])
                                    + np.float32(hyper.get("eps", 1e-8)))
    elif optimizer == "adam":
        b1 = np.float32(hyper.get("beta1", 0.9))
        b2 = np.float32(hyper.get("beta2", 0.999))
        eps = np.float32(hyper.get("eps", 1e-8))
        t = opt["t"]
        t[local] += 1
        tl = t[local].astype(np.float32)[:, None]
        m = opt["m"][local] * b1 + (1 - b1) * g
        v = opt["v"][local] * b2 + (1 - b2) * g * g
        opt["m"][local] = m
        opt["v"][local] = v
        m_hat = m / (1 - b1 ** tl)
        v_hat = v / (1 - b2 ** tl)
        slab[local] += -lr32 * m_hat / (np.sqrt(v_hat) + eps)
    else:
        raise ValueError(f"unknown optimizer {optimizer!r}")


class ShardServer:
    """One PS shard's state and request handler.

    The same object backs both transports: the in-process backend calls
    :meth:`handle` directly (behind a queue), the multiprocess backend
    runs it inside :func:`shard_main`'s event loop.
    """

    def __init__(self, shard_id: int, dim: int, *, optimizer: str = "none",
                 hyper: dict | None = None):
        if optimizer not in OPTIMIZERS:
            raise ValueError(f"optimizer must be one of {OPTIMIZERS}, "
                             f"got {optimizer!r}")
        self.shard_id = shard_id
        self.dim = dim
        self.optimizer = optimizer
        self.hyper = dict(hyper or {})
        #: bucket id → {"rows": (n, D) f32, "opt": {...}, "acked": int}
        self.buckets: dict[int, dict] = {}
        self.counters = {"pulls": 0, "pushes": 0, "replica_pushes": 0,
                         "pull_rows": 0, "push_rows": 0,
                         "dedup_replays": 0}
        #: per-server trace ring — drained over the wire by the "obs" op
        self.trace = obs_trace.TraceBuffer(capacity=16384)
        #: seq → reply, bounded LRU — at-most-once retry semantics
        self._replies: OrderedDict[int, dict] = OrderedDict()

    # --- per-op handlers -------------------------------------------------
    def _bucket(self, b: int) -> dict:
        try:
            return self.buckets[int(b)]
        except KeyError:
            raise KeyError(f"shard {self.shard_id} does not own bucket {b}")

    def _grouped(self, buckets: np.ndarray, ids: np.ndarray):
        """Yield (bucket_state, local_ids, segment_index) per distinct
        bucket, preserving a stable order for deterministic replays."""
        buckets = np.asarray(buckets)
        order = np.argsort(buckets, kind="stable")
        bounds = np.flatnonzero(np.diff(buckets[order])) + 1
        for seg in np.split(order, bounds):
            yield self._bucket(buckets[seg[0]]), ids[seg], seg

    def handle(self, msg: dict) -> dict:
        op = msg["op"]
        if op == "obs":
            # trace drain — not itself spanned (a span recorded mid-drain
            # would straddle the buffer handoff)
            return {"shard": self.shard_id, "ok": True,
                    "pid": os.getpid(), "events": self.trace.drain()}
        with obs_trace.span(f"ps.shard.{op}", "ps", buffer=self.trace,
                            shard=self.shard_id):
            return self._handle_op(op, msg)

    def _handle_op(self, op: str, msg: dict) -> dict:
        out: dict = {"shard": self.shard_id, "ok": True}
        if op == "pull":
            ids = msg["ids"]
            rows = np.empty((ids.shape[0], self.dim), np.float32)
            for st, local, seg in self._grouped(msg["buckets"], ids):
                rows[seg] = st["rows"][local]
            self.counters["pulls"] += 1
            self.counters["pull_rows"] += int(ids.shape[0])
            out["rows"] = rows
        elif op in ("add", "grad"):
            ids = msg["ids"]
            payload = msg["updates"] if op == "add" else msg["grads"]
            lr = float(msg.get("lr", 0.0))
            acked = {}
            for st, local, seg in self._grouped(msg["buckets"], ids):
                apply_grads(self.optimizer if op == "grad" else "none",
                            self.hyper, st["rows"], st["opt"], local,
                            payload[seg], lr)
                st["acked"] += 1
                acked[int(msg["buckets"][seg[0]])] = st["acked"]
            key = "replica_pushes" if msg.get("replica") else "pushes"
            self.counters[key] += 1
            if not msg.get("replica"):
                self.counters["push_rows"] += int(ids.shape[0])
            out["acked"] = acked
        elif op == "create":
            rows = np.array(msg["rows"], np.float32, copy=True)
            self.buckets[int(msg["bucket"])] = {
                "rows": rows, "acked": 0,
                "opt": make_opt_state(self.optimizer, rows.shape[0],
                                      self.dim)}
        elif op == "snapshot":
            st = self._bucket(msg["bucket"])
            out.update(rows=st["rows"].copy(),
                       opt={k: v.copy() for k, v in st["opt"].items()},
                       acked=st["acked"])
        elif op == "install":
            self.buckets[int(msg["bucket"])] = {
                "rows": np.array(msg["rows"], np.float32, copy=True),
                "opt": {k: np.array(v, copy=True)
                        for k, v in msg["opt"].items()},
                "acked": int(msg["acked"])}
        elif op == "drop":
            self.buckets.pop(int(msg["bucket"]), None)
        elif op == "stats":
            out.update(
                buckets=sorted(self.buckets),
                acked={b: st["acked"] for b, st in self.buckets.items()},
                rows=int(sum(st["rows"].shape[0]
                             for st in self.buckets.values())),
                counters=dict(self.counters))
        elif op in ("demote", "shutdown"):
            pass  # tiering hint / loop control — nothing to do state-side
        else:
            raise ValueError(f"unknown op {op!r}")
        return out

    def safe_handle(self, msg: dict) -> dict:
        """:meth:`handle` with failures encoded in the reply — a bad
        request must not be indistinguishable from a dead shard.

        If ``msg`` carries a ``seq`` already answered, the cached reply
        is replayed **without re-executing** the op (at-most-once
        semantics for transport retries); fresh replies echo the seq and
        enter the bounded cache — error replies too, so a retried bad
        request fails identically instead of re-raising server-side.
        """
        seq = msg.get("seq")
        if seq is not None and seq in self._replies:
            self.counters["dedup_replays"] += 1
            return self._replies[seq]
        try:
            reply = self.handle(msg)
        except Exception:
            reply = {"shard": self.shard_id, "ok": False,
                     "err": traceback.format_exc(limit=8)}
        if seq is not None:
            reply["seq"] = seq
            self._replies[seq] = reply
            while len(self._replies) > REPLY_CACHE_SIZE:
                self._replies.popitem(last=False)
        return reply


def shard_main(conn, shard_id: int, dim: int, optimizer: str = "none",
               hyper: dict | None = None) -> None:
    """Event loop of a shard worker process: recv → handle → send until a
    ``shutdown`` op (clean exit) or a closed pipe (client died)."""
    server = ShardServer(shard_id, dim, optimizer=optimizer, hyper=hyper)
    if obs_trace.enabled():
        # name this worker's pid lane in the merged Perfetto trace (only
        # here — an in-process server shares the client's pid)
        obs_trace.label_process(f"ps-shard-{shard_id}", buffer=server.trace)
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            return               # client side went away — nothing to flush
        reply = server.safe_handle(msg)
        try:
            conn.send(reply)
        except (BrokenPipeError, OSError):
            return
        if msg.get("op") == "shutdown":
            conn.close()
            return
