"""Sharded parameter-server subsystem (HeterPS §3).

``ShardedTable`` vocab-partitions sparse embedding tables across PS
shards with jit-compatible routed pull/push; ``PSClient`` overlaps the
pulls/pushes with compute (double-buffered); ``TierPlacer`` re-pins hot
rows from the access monitor's decisions; ``PSTelemetry`` meters
per-shard traffic and feeds it back to the cost model.
"""

from repro.ps.client import PSClient
from repro.ps.placement import TierPlacer
from repro.ps.sharding import (
    RoutingSpec, ShardedTable, sharded_pull, sharded_update,
    TIER_DEVICE, TIER_HOST, TIER_DISK,
)
from repro.ps.telemetry import PSTelemetry, ShardCounters
from repro.ps.workload import (
    CTRConfig, click_stream, init_tower, make_step_fn, make_table,
    train_ctr_ps,
)

__all__ = [
    "PSClient", "TierPlacer", "RoutingSpec", "ShardedTable",
    "sharded_pull", "sharded_update", "TIER_DEVICE", "TIER_HOST",
    "TIER_DISK", "PSTelemetry", "ShardCounters", "CTRConfig",
    "click_stream", "init_tower", "make_step_fn", "make_table",
    "train_ctr_ps",
]
