"""Sharded parameter-server subsystem (HeterPS §3).

``ShardedTable`` vocab-partitions sparse embedding tables across PS
shards behind a pluggable ``Transport`` (in-process queues or real
worker processes); ``ElasticPSFleet`` makes the shard set elastic —
join/leave/kill with live migration and replica recovery; ``PSClient``
overlaps the pulls/pushes with compute (double-buffered); ``TierPlacer``
re-pins hot rows from the access monitor's decisions; ``PSTelemetry``
meters per-shard traffic and feeds it back to the cost model.

Exports resolve lazily (PEP 562): a spawned shard worker process imports
``repro.ps.server`` through this package, and must get the numpy-only
event loop without paying the jax import the client-side modules need.
"""

_EXPORTS = {
    "PSClient": "repro.ps.client",
    "TierPlacer": "repro.ps.placement",
    "RoutingSpec": "repro.ps.sharding",
    "ShardedTable": "repro.ps.sharding",
    "sharded_pull": "repro.ps.sharding",
    "sharded_update": "repro.ps.sharding",
    "TIER_DEVICE": "repro.ps.sharding",
    "TIER_HOST": "repro.ps.sharding",
    "TIER_DISK": "repro.ps.sharding",
    "PSTelemetry": "repro.ps.telemetry",
    "ShardCounters": "repro.ps.telemetry",
    "CTRConfig": "repro.ps.workload",
    "click_stream": "repro.ps.workload",
    "init_tower": "repro.ps.workload",
    "make_step_fn": "repro.ps.workload",
    "make_table": "repro.ps.workload",
    "train_ctr_ps": "repro.ps.workload",
    "train_ctr_elastic": "repro.ps.workload",
    "Transport": "repro.ps.transport",
    "InProcTransport": "repro.ps.transport",
    "MultiprocTransport": "repro.ps.transport",
    "make_transport": "repro.ps.transport",
    "PSShardError": "repro.ps.transport",
    "PSShardLost": "repro.ps.transport",
    "PSShardSlow": "repro.ps.transport",
    "RetryPolicy": "repro.ps.transport",
    "ShardServer": "repro.ps.server",
    "ElasticPSFleet": "repro.ps.elastic",
    "BucketSpec": "repro.ps.elastic",
    "PSUnrecoverable": "repro.ps.elastic",
    "FaultInjector": "repro.ps.faults",
    "FaultRule": "repro.ps.faults",
    "parse_schedule": "repro.ps.faults",
    "FleetCheckpointer": "repro.ps.snapshot",
    "snapshot_fleet": "repro.ps.snapshot",
    "load_fleet_checkpoint": "repro.ps.snapshot",
    "save_fleet_checkpoint": "repro.ps.snapshot",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib

        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
