"""Tier-aware row placement — HeterPS §3's data-management loop, closed.

The paper's monitor "counts the access frequency of each parameter …
and the data management module dynamically adjusts it to the high-speed
storage devices".  ``data/cache.py``'s :class:`AccessMonitor` is the
counting half; :class:`TierPlacer` is the acting half: every ``interval``
steps it recomputes the placement from the (EMA-aged) access counts and
re-pins rows:

* the decision lands in the table's per-shard ``tiers`` arrays
  (simulated storage tiers — pull telemetry then reports the DEVICE-tier
  hit fraction, so placement quality is observable), and
* the DEVICE-tier rows — hottest first — are loaded into the table's
  **hot-row cache** (:meth:`ShardedTable.install_hot_rows`), which on
  TPU runtimes lives in HBM (``memory_kind="device"``) while main
  storage is demoted to ``pinned_host``; on CPU both are plain arrays,
* the counts are aged *after* acting (EMA), so the hot set drifts with
  the access distribution instead of fossilizing the warm-up traffic.
"""

from __future__ import annotations

import numpy as np

from repro.data.cache import Tier
from repro.ps.sharding import ShardedTable


class TierPlacer:
    """Periodically re-pins a :class:`ShardedTable`'s rows from its
    :class:`~repro.data.cache.AccessMonitor`'s placement decisions."""

    def __init__(self, table: ShardedTable, monitor, *, interval: int = 100,
                 age_on_repin: bool = True):
        if monitor.counts.shape[0] != table.vocab:
            raise ValueError(
                f"monitor covers {monitor.counts.shape[0]} rows, table has "
                f"{table.vocab}")
        self.table = table
        self.monitor = monitor
        self.interval = max(1, int(interval))
        self.age_on_repin = age_on_repin
        self.repins = 0
        self.last_stats: dict | None = None

    def step(self, step_idx: int) -> dict | None:
        """Call once per training step; re-pins every ``interval`` steps
        (and not at step 0, when no accesses have been counted yet).
        Returns the placement stats when a re-pin happened."""
        if step_idx == 0 or step_idx % self.interval:
            return None
        return self.repin()

    def repin(self) -> dict:
        # one snapshot for both the tier decision and the hottest-first
        # ordering — the puller thread keeps recording while we run
        counts = self.monitor.snapshot_counts()
        placement = self.monitor.placement(counts)
        stats = self.table.set_tiers(placement)
        # hottest DEVICE-tier rows first, so a capacity-truncated cache
        # keeps the head of the access distribution
        hot = np.flatnonzero(placement == Tier.DEVICE)
        hot = hot[np.argsort(-counts[hot], kind="stable")]
        stats["cached_rows"] = self.table.install_hot_rows(hot)
        if self.repins == 0:
            # after the first re-pin the hot cache covers the head of the
            # distribution — main storage can live in (TPU) host memory
            self.table.demote_storage()
        if self.age_on_repin:
            # age *after* acting so the decision reflects the full window,
            # and the next window starts discounted (EMA drift)
            self.monitor.age()
        self.repins += 1
        self.last_stats = stats
        return stats
