"""Per-shard PS traffic accounting, surfaced to the cost model.

HeterPS's cost model (Formulas 2/5) needs per-stage communication times,
which the analytic profiles derive from nominal ``net_bw``/``ingest_bw``
constants (``core/resources.py``).  The PS subsystem *measures* the real
thing: every pull/push records per-shard rows, bytes and wall time.  Two
bridges feed the measurements back:

* :meth:`PSTelemetry.to_resource` — a ``ResourceType`` whose bandwidth
  terms are replaced by the observed pull/push bandwidths, so fleet
  definitions can be re-anchored to measured PS throughput;
* :meth:`PSTelemetry.embedding_odt` — measured ``(sync, activation)``
  seconds per ``B_o`` profiling window, the exact shape
  ``LayerProfile.odt_sync``/``odt_act`` consume (``core/profiles.py``).

Counters are updated from the client's puller/pusher threads; a lock
keeps the row/byte/time triples coherent.
"""

from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro.core.profiles import B_O
from repro.core.resources import ResourceType


@dataclasses.dataclass
class ShardCounters:
    """Cumulative traffic of one PS shard (one direction)."""

    ops: int = 0
    rows: int = 0
    bytes: int = 0
    seconds: float = 0.0   # wall time this shard had an op in flight
    hot_rows: int = 0      # rows served from the DEVICE tier

    def bandwidth(self) -> float:
        return self.bytes / self.seconds if self.seconds > 0 else 0.0


class PSTelemetry:
    """Pull/push byte + latency accounting for an N-shard table."""

    def __init__(self, num_shards: int):
        self.num_shards = num_shards
        self._lock = threading.Lock()
        self.pull = [ShardCounters() for _ in range(num_shards)]
        self.push = [ShardCounters() for _ in range(num_shards)]
        self.events: list[dict] = []

    def ensure(self, num_shards: int) -> None:
        """Grow the per-shard counter lists (elastic fleets add shards at
        runtime; counters for departed shards are kept — traffic history
        stays additive)."""
        with self._lock:
            while self.num_shards < num_shards:
                self.pull.append(ShardCounters())
                self.push.append(ShardCounters())
                self.num_shards += 1

    def record_event(self, event: dict) -> None:
        """Log one fleet lifecycle event (join/leave/kill/migrate/recover
        dicts from :class:`~repro.ps.elastic.ElasticPSFleet`)."""
        with self._lock:
            self.events.append(dict(event))

    def record(self, op: str, *, rows: np.ndarray, bytes_: np.ndarray,
               seconds: float, hot_rows: np.ndarray | None = None) -> None:
        """Account one pull/push: per-shard ``rows``/``bytes_`` arrays of
        length ``num_shards``; ``seconds`` is the op's wall time, charged
        to every shard the op touched (shard RPCs fly in parallel)."""
        side = self.pull if op == "pull" else self.push
        with self._lock:
            for s in range(min(self.num_shards, len(rows))):
                if rows[s] == 0:
                    continue
                c = side[s]
                c.ops += 1
                c.rows += int(rows[s])
                c.bytes += int(bytes_[s])
                c.seconds += seconds
                if hot_rows is not None:
                    c.hot_rows += int(hot_rows[s])

    # --- reporting ------------------------------------------------------
    def _totals(self, side) -> dict:
        rows = sum(c.rows for c in side)
        bytes_ = sum(c.bytes for c in side)
        secs = max((c.seconds for c in side), default=0.0)
        return {"ops": max((c.ops for c in side), default=0),
                "rows": rows, "bytes": bytes_,
                "seconds": secs,
                "bandwidth": bytes_ / secs if secs > 0 else 0.0,
                "hot_fraction": (sum(c.hot_rows for c in side) / rows
                                 if rows else 0.0)}

    def totals(self) -> dict:
        """Aggregate pull/push traffic.  ``seconds`` is the max over
        shards (shards serve concurrently); bandwidth is effective
        logical-table bandwidth including any simulated RPC latency."""
        return {"pull": self._totals(self.pull),
                "push": self._totals(self.push)}

    def shard_report(self) -> list[dict]:
        out = []
        for s in range(self.num_shards):
            out.append({
                "shard": s,
                "pull_rows": self.pull[s].rows,
                "pull_bytes": self.pull[s].bytes,
                "pull_bw": self.pull[s].bandwidth(),
                "push_rows": self.push[s].rows,
                "push_bytes": self.push[s].bytes,
                "push_bw": self.push[s].bandwidth(),
                "hot_fraction": (self.pull[s].hot_rows / self.pull[s].rows
                                 if self.pull[s].rows else 0.0),
            })
        return out

    # --- cost-model bridges --------------------------------------------
    def to_resource(self, base: ResourceType, *,
                    name_suffix: str = "+ps") -> ResourceType:
        """``base`` with its bandwidth terms replaced by measured PS
        bandwidths: pulls bound data ingest (``ingest_bw``), pull+push
        bound parameter sync (``net_bw``).  Unmeasured terms keep the
        nominal constants."""
        t = self.totals()
        ingest = t["pull"]["bandwidth"]
        net_b = t["pull"]["bytes"] + t["push"]["bytes"]
        net_s = t["pull"]["seconds"] + t["push"]["seconds"]
        net = net_b / net_s if net_s > 0 else 0.0
        return dataclasses.replace(
            base,
            name=base.name + name_suffix,
            ingest_bw=ingest if ingest > 0 else base.ingest_bw,
            net_bw=net if net > 0 else base.net_bw,
        )

    def embedding_odt(self, num_examples: int) -> tuple[float, float]:
        """Measured ``(odt_sync, odt_act)`` seconds per ``B_o`` window for
        an embedding layer, from observed traffic over ``num_examples``
        training examples — drop-in for ``LayerProfile`` fields."""
        if num_examples <= 0:
            return 0.0, 0.0
        t = self.totals()
        per_ex = (t["pull"]["seconds"] + t["push"]["seconds"]) / num_examples
        act_per_ex = t["pull"]["seconds"] / num_examples
        return per_ex * B_O, act_per_ex * B_O
