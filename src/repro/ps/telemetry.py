"""Per-shard PS traffic accounting, surfaced to the cost model.

HeterPS's cost model (Formulas 2/5) needs per-stage communication times,
which the analytic profiles derive from nominal ``net_bw``/``ingest_bw``
constants (``core/resources.py``).  The PS subsystem *measures* the real
thing: every pull/push records per-shard rows, bytes and wall time.  Two
bridges feed the measurements back:

* :meth:`PSTelemetry.to_resource` — a ``ResourceType`` whose bandwidth
  terms are replaced by the observed pull/push bandwidths, so fleet
  definitions can be re-anchored to measured PS throughput;
* :meth:`PSTelemetry.embedding_odt` — measured ``(sync, activation)``
  seconds per ``B_o`` profiling window, the exact shape
  ``LayerProfile.odt_sync``/``odt_act`` consume (``core/profiles.py``).

Storage is the obs spine: each :class:`PSTelemetry` owns a private
always-enabled :class:`repro.obs.metrics.Registry` (these counters are
load-bearing — the cost-model bridge and ``bench_ps`` read them — so
they record regardless of the session's obs switch), and
:class:`ShardCounters` is a per-shard/per-direction *view* over the
registry's ``ps.ops/rows/bytes/seconds/hot_rows`` counters.  Whole-
process metric snapshots (``repro.obs.export``) therefore include PS
traffic for free, and ``repro.obs.bridge.snapshot_resources`` can
recompute the same bandwidths straight from the registry — the
arithmetic here is unchanged from the pre-registry implementation
(bit-compatibility pinned in ``tests/test_obs.py``).

Counters are updated from the client's puller/pusher threads; a lock
keeps the row/byte/time triples coherent.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading

import numpy as np

from repro.core.profiles import B_O
from repro.core.resources import ResourceType
from repro.obs import metrics as obs_metrics

#: distinct registry name per telemetry instance — concurrent tables
#: (e.g. the overlap benchmark's sync + async runs) must not collide
_SEQ = itertools.count()


class ShardCounters:
    """Cumulative traffic of one PS shard (one direction) — a view over
    the owning registry's counters."""

    __slots__ = ("_ops", "_rows", "_bytes", "_seconds", "_hot")

    def __init__(self, registry: obs_metrics.Registry, direction: str,
                 shard: int):
        lab = {"dir": direction, "shard": shard}
        self._ops = registry.counter("ps.ops", **lab)
        self._rows = registry.counter("ps.rows", **lab)
        self._bytes = registry.counter("ps.bytes", **lab)
        self._seconds = registry.counter("ps.seconds", **lab)
        self._hot = registry.counter("ps.hot_rows", **lab)

    @property
    def ops(self) -> int:
        return int(self._ops.value)

    @property
    def rows(self) -> int:
        return int(self._rows.value)

    @property
    def bytes(self) -> int:
        return int(self._bytes.value)

    @property
    def seconds(self) -> float:
        """Wall time this shard had an op in flight."""
        return self._seconds.value

    @property
    def hot_rows(self) -> int:
        """Rows served from the DEVICE tier."""
        return int(self._hot.value)

    def add(self, *, ops: int = 0, rows: int = 0, bytes_: int = 0,
            seconds: float = 0.0, hot_rows: int = 0) -> None:
        if ops:
            self._ops.inc(ops)
        if rows:
            self._rows.inc(rows)
        if bytes_:
            self._bytes.inc(bytes_)
        if seconds:
            self._seconds.inc(seconds)
        if hot_rows:
            self._hot.inc(hot_rows)

    def bandwidth(self) -> float:
        secs = self.seconds
        return self.bytes / secs if secs > 0 else 0.0


class PSTelemetry:
    """Pull/push byte + latency accounting for an N-shard table."""

    def __init__(self, num_shards: int, *,
                 registry: obs_metrics.Registry | None = None):
        self.num_shards = num_shards
        #: always-enabled by default: these counters feed the cost model
        #: and benchmarks even when session-wide obs is off
        self.registry = registry if registry is not None else \
            obs_metrics.Registry(f"ps{next(_SEQ)}", enabled=True)
        self._lock = threading.Lock()
        self.pull = [ShardCounters(self.registry, "pull", s)
                     for s in range(num_shards)]
        self.push = [ShardCounters(self.registry, "push", s)
                     for s in range(num_shards)]
        self.events: list[dict] = []

    def ensure(self, num_shards: int) -> None:
        """Grow the per-shard counter lists (elastic fleets add shards at
        runtime; counters for departed shards are kept — traffic history
        stays additive)."""
        with self._lock:
            while self.num_shards < num_shards:
                s = self.num_shards
                self.pull.append(ShardCounters(self.registry, "pull", s))
                self.push.append(ShardCounters(self.registry, "push", s))
                self.num_shards += 1

    def close(self) -> None:
        """Mark the backing registry closed (idempotent).  Called by the
        owning table/fleet on shutdown so the live-metrics bridge stops
        folding this telemetry's cumulative traffic into fresh
        bandwidth snapshots; reads (``totals``/``shard_report``) keep
        working as history."""
        self.registry.close()

    def record_event(self, event: dict) -> None:
        """Log one fleet lifecycle event (join/leave/kill/migrate/recover
        dicts from :class:`~repro.ps.elastic.ElasticPSFleet`)."""
        with self._lock:
            self.events.append(dict(event))

    def record(self, op: str, *, rows: np.ndarray, bytes_: np.ndarray,
               seconds: float, hot_rows: np.ndarray | None = None) -> None:
        """Account one pull/push: per-shard ``rows``/``bytes_`` arrays of
        length ``num_shards``; ``seconds`` is the op's wall time, charged
        to every shard the op touched (shard RPCs fly in parallel)."""
        side = self.pull if op == "pull" else self.push
        with self._lock:
            for s in range(min(self.num_shards, len(rows))):
                if rows[s] == 0:
                    continue
                side[s].add(
                    ops=1, rows=int(rows[s]), bytes_=int(bytes_[s]),
                    seconds=seconds,
                    hot_rows=int(hot_rows[s]) if hot_rows is not None else 0)

    # --- reporting ------------------------------------------------------
    def _totals(self, side) -> dict:
        rows = sum(c.rows for c in side)
        bytes_ = sum(c.bytes for c in side)
        secs = max((c.seconds for c in side), default=0.0)
        return {"ops": max((c.ops for c in side), default=0),
                "rows": rows, "bytes": bytes_,
                "seconds": secs,
                "bandwidth": bytes_ / secs if secs > 0 else 0.0,
                "hot_fraction": (sum(c.hot_rows for c in side) / rows
                                 if rows else 0.0)}

    def totals(self) -> dict:
        """Aggregate pull/push traffic.  ``seconds`` is the max over
        shards (shards serve concurrently); bandwidth is effective
        logical-table bandwidth including any simulated RPC latency."""
        return {"pull": self._totals(self.pull),
                "push": self._totals(self.push)}

    def shard_report(self) -> list[dict]:
        out = []
        for s in range(self.num_shards):
            out.append({
                "shard": s,
                "pull_rows": self.pull[s].rows,
                "pull_bytes": self.pull[s].bytes,
                "pull_bw": self.pull[s].bandwidth(),
                "push_rows": self.push[s].rows,
                "push_bytes": self.push[s].bytes,
                "push_bw": self.push[s].bandwidth(),
                "hot_fraction": (self.pull[s].hot_rows / self.pull[s].rows
                                 if self.pull[s].rows else 0.0),
            })
        return out

    # --- cost-model bridges --------------------------------------------
    def to_resource(self, base: ResourceType, *,
                    name_suffix: str = "+ps") -> ResourceType:
        """``base`` with its bandwidth terms replaced by measured PS
        bandwidths: pulls bound data ingest (``ingest_bw``), pull+push
        bound parameter sync (``net_bw``).  Unmeasured terms keep the
        nominal constants."""
        t = self.totals()
        ingest = t["pull"]["bandwidth"]
        net_b = t["pull"]["bytes"] + t["push"]["bytes"]
        net_s = t["pull"]["seconds"] + t["push"]["seconds"]
        net = net_b / net_s if net_s > 0 else 0.0
        return dataclasses.replace(
            base,
            name=base.name + name_suffix,
            ingest_bw=ingest if ingest > 0 else base.ingest_bw,
            net_bw=net if net > 0 else base.net_bw,
        )

    def embedding_odt(self, num_examples: int) -> tuple[float, float]:
        """Measured ``(odt_sync, odt_act)`` seconds per ``B_o`` window for
        an embedding layer, from observed traffic over ``num_examples``
        training examples — drop-in for ``LayerProfile`` fields."""
        if num_examples <= 0:
            return 0.0, 0.0
        t = self.totals()
        per_ex = (t["pull"]["seconds"] + t["push"]["seconds"]) / num_examples
        act_per_ex = t["pull"]["seconds"] / num_examples
        return per_ex * B_O, act_per_ex * B_O
