"""Elastic PS fleet — shards join/leave/fail at runtime (HeterPS §3 +
the elastic parameter-service design space from PAPERS.md).

:class:`ElasticPSFleet` hosts one logical ``(V, D)`` embedding table on a
*changing* set of shard processes behind any
:class:`~repro.ps.transport.Transport`.  The unit of placement is the
**bucket** — a contiguous vocab slab (:class:`BucketSpec`) — and three
mechanisms make the fleet elastic without ever pausing training:

**Replication.**  Every bucket has a primary and (with ``replicas=1``) a
backup on a different shard.  A push is one fan-out: the primary gets the
``grad`` message, the backup gets the *same* message flagged ``replica``.
Because the PS-hosted optimizer (:func:`repro.ps.server.apply_grads`) is
deterministic and per-shard FIFO keeps the update order, the backup's
slab + optimizer state stay **bit-identical** to the primary's — which is
what makes recovery lossless.

**Recovery.**  A lost shard (``kill()``, crash, or timeout — surfaced as
:class:`~repro.ps.transport.PSShardLost`) triggers :meth:`recover`: every
bucket it primaried is promoted to its backup, every bucket it backed is
re-replicated from its primary (snapshot → install), and in-flight
migrations touching the shard are aborted to the surviving replica.  The
promoted slab is exactly the lost shard's last acked state (pinned by the
property tests in ``tests/test_ps_elastic.py``).

**Live migration.**  Moving bucket *B* from shard *src* to *dst* never
blocks pulls or pushes:

1. *begin* (atomic w.r.t. pushes): snapshot *B* at src — slab, optimizer
   state, acked counter — and install it at dst; mark *B* migrating.
2. while migrating, pushes touching *B* are appended to a drain buffer
   for dst.  The first ``staleness_bound`` of them skip src (cheap,
   single-apply); beyond the bound every push is **dual-written** to src
   too, so a pull against the migrating range — still served by src — is
   never stale by more than ``staleness_bound`` updates.  The backup
   keeps receiving every push throughout, so replication never weakens.
3. *finish*: drain the buffer to dst in push order, flip the primary map,
   drop *B* at src.  If dst already held *B*'s replica, the whole dance
   collapses to a map flip (the replica is bit-identical by invariant).

``join()`` = spawn a shard + migrate it a fair share of buckets;
``leave()`` = migrate everything away, then a graceful stop;
``kill()`` = fault injection (terminate, no flush).

The fleet exposes the same ``pull/push(ids, grads, lr=..., dedup=...)``
surface as :class:`~repro.ps.sharding.ShardedTable`, so
:class:`~repro.ps.client.PSClient` overlaps it with compute unchanged.
"""

from __future__ import annotations

import functools
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs_trace
from repro.parallel.ps import dedup_rows
from repro.ps.server import OPTIMIZERS
from repro.ps.transport import PSShardLost, Transport, make_transport


class PSUnrecoverable(RuntimeError):
    """Replica promotion cannot save this fleet: some bucket lost its
    primary *and* every replica (correlated failure — e.g. a preempted
    zone taking both copies).  The only way back is a durable
    checkpoint: :meth:`ElasticPSFleet.restore_snapshot` +
    :mod:`repro.ps.snapshot`'s :class:`~repro.ps.snapshot.
    FleetCheckpointer`."""


class BucketSpec:
    """Contiguous vocab slabs — the unit of placement, migration and
    replication.  More buckets than shards (default 4×) keeps rebalance
    granular: a joining shard can take a fair share without splitting."""

    def __init__(self, vocab: int, dim: int, num_buckets: int):
        if not 1 <= num_buckets <= vocab:
            raise ValueError(
                f"num_buckets={num_buckets} outside [1, vocab={vocab}]")
        self.vocab = vocab
        self.dim = dim
        self.num_buckets = num_buckets
        self.bucket_rows = -(-vocab // num_buckets)  # ceil

    def bucket_of(self, ids):
        mod = jnp if isinstance(ids, jax.Array) else np
        return mod.clip(ids // self.bucket_rows, 0, self.num_buckets - 1)

    def local(self, ids):
        return ids - self.bucket_of(ids) * self.bucket_rows

    def rows_in(self, bucket: int) -> int:
        lo = bucket * self.bucket_rows
        return max(0, min(self.bucket_rows, self.vocab - lo))

    def global_rows(self, bucket: int) -> np.ndarray:
        lo = bucket * self.bucket_rows
        return np.arange(lo, lo + self.rows_in(bucket))


@functools.partial(jax.jit, static_argnames=("vocab", "dim"))
def _dedup_sum(ids, grads, *, vocab: int, dim: int):
    """Client half of an elastic push: one summed f32 gradient row per
    distinct id (padding slots carry ``vocab``) — the one-update-per-row
    guarantee the PS-hosted adaptive optimizers rely on."""
    return dedup_rows(ids.reshape(-1),
                      grads.reshape(-1, dim).astype(jnp.float32),
                      fill_id=vocab)


class ElasticPSFleet:
    """One logical embedding table on an elastic shard fleet.

    Parameters:
      optimizer: PS-hosted update rule applied **on the shard** from raw
        summed gradients — ``"sgd"`` | ``"adagrad"`` | ``"adam"``
        (``hyper`` carries betas/eps).
      replicas: 0 (no fault tolerance) or 1 (synchronous backup per
        bucket; requires ≥2 shards to actually place one).
      staleness_bound: max number of in-migration pushes a pull against
        the migrating range may miss; 0 → full dual-write (never stale).
      transport: ``None``/``"inproc"`` | ``"multiproc"`` | instance.
      telemetry: optional :class:`~repro.ps.telemetry.PSTelemetry`;
        grown on join, also records join/leave/kill/migration/recovery
        events.
    """

    def __init__(self, vocab: int, dim: int, *, num_shards: int = 2,
                 num_buckets: int | None = None, optimizer: str = "sgd",
                 hyper: dict | None = None, replicas: int = 1,
                 staleness_bound: int = 8,
                 transport: str | Transport | None = None,
                 telemetry=None, key=None, init_scale: float | None = None,
                 rpc_latency_s: float = 0.0):
        if num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if optimizer not in OPTIMIZERS or optimizer == "none":
            raise ValueError(
                f"fleet optimizer must be one of {OPTIMIZERS[1:]}, "
                f"got {optimizer!r}")
        if replicas not in (0, 1):
            raise ValueError("replicas must be 0 or 1")
        self.spec = BucketSpec(
            vocab, dim, num_buckets or max(1, min(vocab, 4 * num_shards)))
        self.optimizer = optimizer
        self.hyper = dict(hyper or {})
        self.replicas = replicas
        self.staleness_bound = int(staleness_bound)
        self.telemetry = telemetry
        self.rpc_latency_s = float(rpc_latency_s)
        self.transport = make_transport(transport)
        # proactive failure detection: the multiproc heartbeat reports a
        # dead worker here within its deadline, instead of waiting for
        # the next pull/push to trip over it
        self.transport.on_shard_lost = self._on_lost
        self._mu = threading.RLock()
        self._next_sid = 0
        self.events: list[dict] = []
        #: bucket → shard maps (−1 = no backup placed)
        nb = self.spec.num_buckets
        self.primary = np.empty((nb,), np.int64)
        self.backup = np.full((nb,), -1, np.int64)
        #: bucket → in-flight migration state
        self._migrations: dict[int, dict] = {}

        for _ in range(num_shards):
            self._spawn()
        for b in range(nb):
            self.primary[b] = b % num_shards
            if replicas and num_shards > 1:
                self.backup[b] = (b + 1) % num_shards

        if key is not None:
            scale = dim**-0.5 if init_scale is None else init_scale
            dense = jax.random.normal(key, (vocab, dim), jnp.float32) * scale
        else:
            dense = jnp.zeros((vocab, dim), jnp.float32)
        self._load_dense(np.asarray(dense, np.float32))

    # --- construction ----------------------------------------------------
    def _spawn(self) -> int:
        sid = self._next_sid
        self._next_sid += 1
        self.transport.add_shard(sid, dim=self.spec.dim,
                                 optimizer=self.optimizer, hyper=self.hyper)
        if self.telemetry is not None:
            self.telemetry.ensure(self._next_sid)
        return sid

    def _load_dense(self, dense: np.ndarray) -> None:
        msgs = []
        for b in range(self.spec.num_buckets):
            rows = dense[self.spec.global_rows(b)]
            msgs.append((int(self.primary[b]),
                         {"op": "create", "bucket": b, "rows": rows}))
            if self.backup[b] >= 0:
                msgs.append((int(self.backup[b]),
                             {"op": "create", "bucket": b, "rows": rows}))
        self.transport.request_many(msgs)

    @classmethod
    def from_dense(cls, table, **kw) -> "ElasticPSFleet":
        t = np.asarray(table, np.float32)
        fleet = cls(t.shape[0], t.shape[1], **kw)
        fleet._load_dense(t)
        return fleet

    # --- helpers ---------------------------------------------------------
    def _event(self, kind: str, **fields) -> None:
        ev = {"kind": kind, **fields}
        self.events.append(ev)
        if self.telemetry is not None:
            self.telemetry.record_event(ev)
        # lifecycle markers on the trace timeline (join/leave/kill/
        # migrate/recover show up as instants in the fleet's lane)
        obs_trace.instant("ps.fleet." + kind, "ps", **fields)

    def _check_ids(self, ids_np: np.ndarray) -> None:
        if ids_np.size and (ids_np.min() < 0
                            or ids_np.max() >= self.spec.vocab):
            raise ValueError(
                f"ids out of range for vocab={self.spec.vocab}: "
                f"[{ids_np.min()}, {ids_np.max()}]")

    def _group(self, owner: np.ndarray, bucket: np.ndarray,
               local: np.ndarray, payload: np.ndarray | None, op: str,
               **extra) -> list[tuple[int, dict]]:
        """One message per distinct owner shard (ids grouped stably)."""
        order = np.argsort(owner, kind="stable")
        bounds = np.flatnonzero(np.diff(owner[order])) + 1
        msgs = []
        for seg in np.split(order, bounds):
            msg = {"op": op, "buckets": bucket[seg], "ids": local[seg],
                   **extra}
            if payload is not None:
                msg["grads" if op == "grad" else "updates"] = payload[seg]
            msgs.append((int(owner[seg[0]]), msg))
        return msgs

    def _primary_load(self) -> dict[int, int]:
        live = self.transport.live_shards
        load = {s: 0 for s in live}
        for b in range(self.spec.num_buckets):
            if self.primary[b] in load:
                load[int(self.primary[b])] += 1
        return load

    def _pick_backup(self, bucket: int, exclude: set[int] = frozenset()
                     ) -> int:
        """Least-loaded live shard ≠ primary (−1 if none exists)."""
        p = int(self.primary[bucket])
        cand = [s for s in self.transport.live_shards
                if s != p and s not in exclude]
        if not cand or not self.replicas:
            return -1
        load = self._primary_load()
        return min(cand, key=lambda s: (load.get(s, 0), s))

    def _replicate(self, bucket: int, dst: int) -> None:
        """snapshot(primary) → install(dst): dst becomes the bit-exact
        replica of the bucket's current state."""
        snap = self.transport.request(
            int(self.primary[bucket]), {"op": "snapshot", "bucket": bucket})
        self.transport.request(dst, {
            "op": "install", "bucket": bucket, "rows": snap["rows"],
            "opt": snap["opt"], "acked": snap["acked"]})

    # --- PS operations ---------------------------------------------------
    def pull(self, ids):
        """Pull the touched rows: ``ids (...,)`` → ``(..., D)`` jnp f32.
        A shard lost mid-pull triggers recovery and a transparent retry."""
        t0 = time.perf_counter()
        ids_np = np.asarray(ids)
        self._check_ids(ids_np)
        flat = ids_np.ravel().astype(np.int64)
        bucket = np.asarray(self.spec.bucket_of(flat))
        local = flat - bucket * self.spec.bucket_rows
        out = np.empty((flat.size, self.spec.dim), np.float32)
        while True:
            with self._mu:
                owner = self.primary[bucket]
            order = np.argsort(owner, kind="stable")
            bounds = np.flatnonzero(np.diff(owner[order])) + 1
            segs = np.split(order, bounds) if flat.size else []
            msgs = [(int(owner[seg[0]]),
                     {"op": "pull", "buckets": bucket[seg],
                      "ids": local[seg]}) for seg in segs]
            try:
                replies = self.transport.request_many(msgs)
            except PSShardLost as e:
                self.recover(getattr(e, "shard_ids", None))
                continue
            for seg, rep in zip(segs, replies):
                out[seg] = rep["rows"]
            break
        if self.rpc_latency_s:
            time.sleep(self.rpc_latency_s)
        self._account("pull", bucket, owner, time.perf_counter() - t0,
                      self.spec.dim * 4)
        return jnp.asarray(out.reshape(ids_np.shape + (self.spec.dim,)))

    def push(self, ids, row_grads, *, lr: float, dedup: bool = True):
        """Push raw row gradients; the shard-side optimizer applies them.

        Fan-out per bucket: primary ``grad`` + backup ``grad(replica)``;
        migrating buckets buffer for the destination instead (dual-
        writing to the source past ``staleness_bound``).  A shard lost
        mid-push needs **no resend**: its buckets' surviving replicas
        received the same update, and recovery promotes them.
        """
        t0 = time.perf_counter()
        ids_np = np.asarray(ids)
        self._check_ids(ids_np)
        if dedup:
            pids, summed = _dedup_sum(jnp.asarray(ids),
                                      jnp.asarray(row_grads),
                                      vocab=self.spec.vocab,
                                      dim=self.spec.dim)
            jax.block_until_ready(summed)
            pids_np = np.asarray(pids)
            live = pids_np < self.spec.vocab
            flat = pids_np[live].astype(np.int64)
            grads = np.asarray(summed)[live]
        else:
            flat = ids_np.ravel().astype(np.int64)
            grads = np.asarray(row_grads, np.float32).reshape(
                -1, self.spec.dim)
        bucket = np.asarray(self.spec.bucket_of(flat))
        local = flat - bucket * self.spec.bucket_rows
        with self._mu:
            migrating = np.array(
                [b in self._migrations for b in bucket], bool) \
                if self._migrations else np.zeros(bucket.shape, bool)
            msgs: list[tuple[int, dict]] = []
            steady = ~migrating
            if steady.any():
                ow = self.primary[bucket[steady]]
                msgs += self._group(ow, bucket[steady], local[steady],
                                    grads[steady], "grad", lr=float(lr))
                bk = self.backup[bucket[steady]]
                has_bk = bk >= 0
                if has_bk.any():
                    msgs += self._group(
                        bk[has_bk], bucket[steady][has_bk],
                        local[steady][has_bk], grads[steady][has_bk],
                        "grad", lr=float(lr), replica=True)
            if migrating.any():
                for b in np.unique(bucket[migrating]):
                    sel = migrating & (bucket == b)
                    item = (local[sel], grads[sel], float(lr))
                    mig = self._migrations[int(b)]
                    mig["buffer"].append(item)
                    dual = mig["buffer_only"] >= self.staleness_bound
                    if dual:
                        msgs.append((int(self.primary[b]), {
                            "op": "grad", "buckets": bucket[sel],
                            "ids": item[0], "grads": item[1],
                            "lr": float(lr)}))
                    else:
                        mig["buffer_only"] += 1
                    if self.backup[b] >= 0:
                        msgs.append((int(self.backup[b]), {
                            "op": "grad", "buckets": bucket[sel],
                            "ids": item[0], "grads": item[1],
                            "lr": float(lr), "replica": True}))
            try:
                self.transport.request_many(msgs)
            except PSShardLost as e:
                self.recover(getattr(e, "shard_ids", None))
            owner = self.primary[bucket]
        if self.rpc_latency_s:
            time.sleep(self.rpc_latency_s)
        self._account("push", bucket, owner, time.perf_counter() - t0,
                      self.spec.dim * 4 + 8)
        return self

    def _account(self, op: str, bucket: np.ndarray, owner: np.ndarray,
                 seconds: float, bytes_per_row: int) -> None:
        if self.telemetry is None or owner.size == 0:
            return
        self.telemetry.ensure(self._next_sid)
        per_shard = np.bincount(owner, minlength=self._next_sid)
        self.telemetry.record(op, rows=per_shard,
                              bytes_=per_shard * bytes_per_row,
                              seconds=seconds)

    # --- elasticity ------------------------------------------------------
    def join(self, *, rebalance: bool = True) -> int:
        """Bring a new shard process up and (by default) migrate it a
        fair share of buckets.  Training continues throughout."""
        t0 = time.perf_counter()
        with self._mu:
            sid = self._spawn()
            moved = []
            if rebalance:
                live = self.transport.live_shards
                target = self.spec.num_buckets // max(1, len(live))
                load = self._primary_load()
                donors = sorted((b for b in range(self.spec.num_buckets)
                                 if b not in self._migrations),
                                key=lambda b: -load.get(
                                    int(self.primary[b]), 0))
                for b in donors:
                    if len(moved) >= target:
                        break
                    if self.primary[b] == sid or self.backup[b] == sid:
                        continue
                    self.migrate(b, sid)
                    moved.append(b)
        self._event("join", shard=sid, buckets=moved,
                    seconds=time.perf_counter() - t0)
        return sid

    def leave(self, shard_id: int) -> None:
        """Graceful decommission: migrate every bucket away, re-home the
        replicas it held, then stop the process."""
        t0 = time.perf_counter()
        with self._mu:
            live = sorted(self.transport.live_shards - {shard_id})
            if not live:
                raise RuntimeError("cannot decommission the last shard")
            load = self._primary_load()
            load.pop(shard_id, None)
            for b in np.flatnonzero(self.primary == shard_id):
                dst = min(load, key=lambda s: (load[s], s))
                self.migrate(int(b), dst)
                load[dst] += 1
            for b in np.flatnonzero(self.backup == shard_id):
                b = int(b)
                nb = self._pick_backup(b, exclude={shard_id})
                self.backup[b] = nb
                if nb >= 0:
                    self._replicate(b, nb)
            self.transport.stop_shard(shard_id)
        self._event("leave", shard=shard_id,
                    seconds=time.perf_counter() - t0)

    def kill(self, shard_id: int) -> None:
        """Fault injection: terminate the shard with no flush.  State is
        recovered from replicas on the next touch (or ``recover()``)."""
        self.transport.kill_shard(shard_id)
        self._event("kill", shard=shard_id)

    def _on_lost(self, shard_id: int) -> None:
        """Heartbeat callback (failure-detector thread): recover
        proactively so the next pull/push already sees a healthy map.
        An unrecoverable fleet is left for the training thread to trip
        over — raising out of the detector would only kill it."""
        self._event("detected", shard=int(shard_id))
        try:
            self.recover({int(shard_id)})
        except PSUnrecoverable:
            pass
        except PSShardLost:
            pass  # another shard died mid-recovery — next touch retries

    def recover(self, lost: set[int] | None = None) -> list[int]:
        """Re-home every bucket whose primary/backup died: promote the
        backup (bit-exact last-acked state), then re-replicate.  Returns
        the shards recovered from."""
        t0 = time.perf_counter()
        with self._mu:
            live = self.transport.live_shards
            dead = {int(s) for s in set(self.primary) | set(self.backup)
                    if s >= 0 and s not in live}
            if lost:
                dead |= {s for s in lost if s not in live}
            if not dead:
                return []
            # abort migrations involving a dead shard first — the
            # surviving replica carries every push (incl. buffered ones)
            for b, mig in list(self._migrations.items()):
                src, dst = int(self.primary[b]), mig["dst"]
                if src not in dead and dst not in dead:
                    continue
                if dst not in dead:
                    self.transport.request(dst, {"op": "drop", "bucket": b})
                elif src not in dead and mig["buffer_only"] > 0:
                    # dst died holding buffer-only pushes src never saw —
                    # the backup saw every one of them, so it becomes the
                    # primary and the stale src is rebuilt as its replica
                    k = int(self.backup[b])
                    if k < 0 or k in dead:
                        raise PSUnrecoverable(
                            f"bucket {b} lost migration dst {dst} with "
                            f"{mig['buffer_only']} unreplicated pushes and "
                            f"no live backup — unrecoverable")
                    self.primary[b], self.backup[b] = k, src
                    self._replicate(b, src)
                del self._migrations[b]
            for b in range(self.spec.num_buckets):
                p, k = int(self.primary[b]), int(self.backup[b])
                if p in dead and k in dead:
                    raise PSUnrecoverable(
                        f"bucket {b} lost both primary {p} and backup {k} "
                        f"— unrecoverable (replicas={self.replicas})")
                if p in dead:
                    if k < 0:
                        raise PSUnrecoverable(
                            f"bucket {b} lost primary {p} with no backup "
                            f"— unrecoverable (replicas={self.replicas})")
                    self.primary[b], k = k, p  # promote
                    self.backup[b] = -1
                if int(self.backup[b]) in dead:
                    self.backup[b] = -1
                if self.backup[b] < 0 and self.replicas:
                    nb = self._pick_backup(b)
                    if nb >= 0:
                        self._replicate(b, nb)
                        self.backup[b] = nb
        recovered = sorted(dead)
        self._event("recover", shards=recovered,
                    seconds=time.perf_counter() - t0)
        return recovered

    def restore_snapshot(self, snap: dict) -> None:
        """Reload the whole fleet from a :func:`repro.ps.snapshot.
        snapshot_fleet` capture — the recovery path when replica
        promotion is out of moves (:class:`PSUnrecoverable`).

        Every surviving shard is wiped of its (stale) buckets, fresh
        shards are spawned until enough exist to host primaries (+ a
        backup when ``replicas=1``), ownership is reassigned round-robin
        over the live set, and each bucket's slab + optimizer state +
        acked counter is installed bit-exactly as captured.  In-flight
        migrations are discarded (their state predates the snapshot's
        watermark).
        """
        meta = snap.get("meta", {})
        for k, want in (("vocab", self.spec.vocab), ("dim", self.spec.dim),
                        ("num_buckets", self.spec.num_buckets),
                        ("optimizer", self.optimizer)):
            if k in meta and meta[k] != want:
                raise ValueError(
                    f"snapshot {k}={meta[k]!r} != fleet {k}={want!r}")
        nb = self.spec.num_buckets
        buckets = {int(b): st for b, st in snap["buckets"].items()}
        missing = [b for b in range(nb) if b not in buckets]
        if missing:
            raise ValueError(f"snapshot missing buckets {missing}")
        t0 = time.perf_counter()
        with self._mu:
            self._migrations.clear()
            need = 2 if self.replicas else 1
            while len(self.transport.live_shards) < need:
                self._spawn()
            live = sorted(self.transport.live_shards)
            # survivors may host buckets whose state post- or pre-dates
            # the snapshot in unknown ways — wipe before reinstall
            self.transport.request_many(
                [(s, {"op": "drop", "bucket": b})
                 for s in live for b in range(nb)])
            msgs = []
            for b in range(nb):
                p = live[b % len(live)]
                k = (live[(b + 1) % len(live)]
                     if self.replicas and len(live) > 1 else -1)
                self.primary[b], self.backup[b] = p, k
                st = buckets[b]
                body = {"op": "install", "bucket": b, "rows": st["rows"],
                        "opt": st["opt"], "acked": int(st["acked"])}
                msgs.append((p, body))
                if k >= 0:
                    msgs.append((k, body))
            self.transport.request_many(msgs)
        self._event("restore", shards=live, buckets=nb,
                    step=meta.get("step"),
                    seconds=time.perf_counter() - t0)

    # --- live migration --------------------------------------------------
    def migrate(self, bucket: int, dst: int) -> None:
        """Move ``bucket`` to shard ``dst`` (begin + immediate finish —
        the no-traffic case; concurrent trainers use the staged form
        implicitly via ``join``/``leave`` under load)."""
        self.begin_migration(bucket, dst)
        self.finish_migration(bucket)

    def begin_migration(self, bucket: int, dst: int) -> None:
        """Stage 1: snapshot at src, install at dst, start buffering.
        If dst holds the bucket's replica this is a pure map flip."""
        with self._mu:
            bucket = int(bucket)
            src = int(self.primary[bucket])
            if dst == src:
                return
            if bucket in self._migrations:
                raise RuntimeError(f"bucket {bucket} is already migrating")
            if dst not in self.transport.live_shards:
                raise PSShardLost(f"migration destination {dst} not live")
            if dst == int(self.backup[bucket]):
                # the replica is bit-identical by invariant — flip roles
                self.primary[bucket], self.backup[bucket] = dst, src
                self._event("migrate", bucket=bucket, src=src, dst=dst,
                            promoted_replica=True, seconds=0.0)
                return
            self._replicate(bucket, dst)
            self._migrations[bucket] = {
                "dst": dst, "buffer": [], "buffer_only": 0,
                "t0": time.perf_counter()}

    def migration_backlog(self, bucket: int) -> int:
        """Pushes buffered for the destination (staged-API observability;
        ``buffer_only`` of them are invisible at the source — bounded by
        ``staleness_bound``)."""
        with self._mu:
            mig = self._migrations.get(int(bucket))
            return len(mig["buffer"]) if mig else 0

    def migration_staleness(self, bucket: int) -> int:
        """How many updates a pull of the migrating range may currently
        miss (≤ ``staleness_bound`` by construction)."""
        with self._mu:
            mig = self._migrations.get(int(bucket))
            return mig["buffer_only"] if mig else 0

    def finish_migration(self, bucket: int) -> None:
        """Stage 2: drain the buffer to dst in push order, flip the
        primary map, drop the bucket at src."""
        with self._mu:
            bucket = int(bucket)
            mig = self._migrations.get(bucket)
            if mig is None:
                return
            src, dst = int(self.primary[bucket]), mig["dst"]
            for local, grads, lr in mig["buffer"]:
                self.transport.request(dst, {
                    "op": "grad",
                    "buckets": np.full(local.shape, bucket, np.int64),
                    "ids": local, "grads": grads, "lr": lr})
            self.primary[bucket] = dst
            del self._migrations[bucket]
            try:
                self.transport.request(src, {"op": "drop", "bucket": bucket})
            except PSShardLost:
                pass  # src died after we copied everything out — fine
            self._event("migrate", bucket=bucket, src=src, dst=dst,
                        drained=len(mig["buffer"]),
                        seconds=time.perf_counter() - mig["t0"])

    # --- inspection ------------------------------------------------------
    def to_dense(self):
        """Reassemble the logical table from the bucket primaries."""
        dense = np.empty((self.spec.vocab, self.spec.dim), np.float32)
        with self._mu:
            msgs = [(int(self.primary[b]), {"op": "snapshot", "bucket": b})
                    for b in range(self.spec.num_buckets)]
            replies = self.transport.request_many(msgs)
        for b, rep in enumerate(replies):
            dense[self.spec.global_rows(b)] = rep["rows"]
        return jnp.asarray(dense)

    def owners(self) -> tuple[np.ndarray, np.ndarray]:
        """Copies of the (primary, backup) bucket→shard maps."""
        with self._mu:
            return self.primary.copy(), self.backup.copy()

    def stats(self) -> dict:
        with self._mu:
            live = sorted(self.transport.live_shards)
            shard_stats = {
                s: rep for s, rep in zip(live, self.transport.request_many(
                    [(s, {"op": "stats"}) for s in live]))}
            return {"live_shards": live,
                    "primary": self.primary.tolist(),
                    "backup": self.backup.tolist(),
                    "migrating": sorted(self._migrations),
                    "shards": shard_stats,
                    "events": list(self.events)}

    @property
    def vocab(self) -> int:
        return self.spec.vocab

    @property
    def dim(self) -> int:
        return self.spec.dim

    @property
    def num_shards(self) -> int:
        return len(self.transport.live_shards)

    def close(self) -> None:
        self.transport.close()
        if self.telemetry is not None:
            self.telemetry.close()
