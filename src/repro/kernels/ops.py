"""Jit'd dispatch wrappers for the Pallas kernels.

On a TPU runtime the compiled kernels run natively; on CPU (this
container) ``interpret=True`` executes the kernel body in Python for
correctness validation, and callers that need speed use the jnp
references.  ``auto`` picks per-backend.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import moe as moe_kernels
from repro.kernels import paged_attention as paged_k
from repro.kernels.embedding_bag import embedding_bag as _embedding_bag_kernel
from repro.kernels.flash_attention import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_Q,
    flash_attention as _flash_kernel,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    softcap: float | None = None, impl: str = "auto"):
    """Padded/validated entry point. q,k,v: (B, H, S, hd)."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       softcap=softcap)
    interpret = impl == "interpret"
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    bq = min(DEFAULT_BLOCK_Q, Sq)
    bk = min(DEFAULT_BLOCK_K, Sk)
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        # padded keys land at positions > any query → masked out by causal;
        # for non-causal, mask via window=None path needs explicit care, so
        # only pad when causal or no padding needed.
        assert causal, "non-causal needs Sk % block_k == 0"
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out = _flash_kernel(q, k, v, causal=causal, window=window,
                        softcap=softcap, block_q=bq, block_k=bk,
                        interpret=interpret)
    return out[:, :, :Sq]


def embedding_bag(ids, table, *, impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.embedding_bag_ref(ids, table)
    return _embedding_bag_kernel(ids, table, interpret=impl == "interpret")


def _moe_impl(impl: str) -> str:
    """Resolve the MoE impl: ``auto`` compiles on TPU, otherwise runs the
    jnp slot formulation (same algorithm, fast on CPU); ``interpret``
    executes the kernel bodies in the Pallas interpreter."""
    if impl == "auto":
        return "pallas" if _on_tpu() else "slot"
    if impl not in ("slot", "interpret", "pallas"):
        raise ValueError(
            f"unknown MoE impl {impl!r}: expected auto/slot/interpret/"
            "pallas (the scatter/gather oracle is nn.moe.moe_ffn's "
            "impl='ref', not a kernels-layer path)")
    return impl


def paged_attention_decode(q, k_pages, v_pages, page_table, q_pos, *,
                           window: int | None = None,
                           softcap: float | None = None,
                           impl: str = "auto"):
    """Paged one-token decode attention.  q: (B, KV, G, hd) grouped
    queries; k/v_pages: (num_pages, page_size, KV, hd); page_table:
    (B, P) int32; q_pos: (B,) int32.  Returns (B, KV, G, hd).

    ``auto`` compiles the Pallas kernel on TPU and runs the jnp
    gather-over-pages formulation elsewhere; ``interpret`` executes the
    kernel body in the Pallas interpreter.  The dense ring-buffer oracle
    is ``nn.attention.decode_attention`` (``ArchConfig.kv_impl="dense"``),
    not a kernels-layer path.
    """
    if impl == "gather" or (impl == "auto" and not _on_tpu()):
        return paged_k.paged_decode_gather(q, k_pages, v_pages, page_table,
                                           q_pos, window=window,
                                           softcap=softcap)
    if impl not in ("auto", "interpret", "pallas"):
        raise ValueError(
            f"unknown paged-attention impl {impl!r}: expected "
            "auto/gather/interpret/pallas")
    return paged_k.paged_decode_pallas(q, k_pages, v_pages, page_table,
                                       q_pos, window=window, softcap=softcap,
                                       interpret=impl == "interpret")


def moe_dispatch(x, eid, pos, wtok, *, num_experts: int, capacity: int,
                 top_k: int, impl: str = "auto"):
    """Capacity-slab dispatch (G,S,D)→(G,E,C,D); differentiable.

    ``impl="ref"`` is not accepted here — the reference scatter/gather
    oracle lives in :func:`repro.nn.moe.moe_ffn` (``impl="ref"``).
    """
    return moe_kernels.moe_dispatch(x, eid, pos, wtok, num_experts,
                                    capacity, top_k, _moe_impl(impl))


def moe_combine(buf, eid, pos, w, *, impl: str = "auto"):
    """Gate-weighted combine (G,E,C,D)→(G,S,D); differentiable."""
    return moe_kernels.moe_combine(buf, eid, pos, w, _moe_impl(impl))
