"""Pallas TPU kernels (validated interpret=True on CPU) + jnp oracles."""

from repro.kernels.ops import (
    embedding_bag,
    flash_attention,
    moe_combine,
    moe_dispatch,
)

__all__ = ["embedding_bag", "flash_attention", "moe_combine", "moe_dispatch"]
