"""Pallas TPU kernels (validated interpret=True on CPU) + jnp oracles."""

from repro.kernels.ops import (
    embedding_bag,
    flash_attention,
    moe_combine,
    moe_dispatch,
    paged_attention_decode,
)
from repro.kernels.paged_attention import PagePool

__all__ = ["embedding_bag", "flash_attention", "moe_combine", "moe_dispatch",
           "paged_attention_decode", "PagePool"]
