"""Pallas TPU fused MoE dispatch/combine — capacity-slab scatter/gather.

The MoE FFN is the heaviest layer class in the OLMoE/Qwen3-MoE configs,
and HeterPS schedules exactly these compute-intensive layers onto
accelerators — so the accelerator path has to be more than the XLA
default.  The expensive part of GShard-style MoE is not the expert
matmuls (dense einsums the MXU already loves) but the *data movement*
around them: the reference path materializes a K-times-repeated copy of
the tokens, scatter-adds it into the ``(E, C, D)`` capacity slabs, and
later gathers an ``(N·K, D)`` intermediate back out.

Here the routing *metadata* (which token fills which expert slot) is
computed once with cheap integer ops (:func:`slot_maps`), and the heavy
D-dimensional row movement happens in two Pallas kernels:

* **dispatch** — grid ``(G, E, C)``: each step DMAs one source token row
  HBM→VMEM (row id scalar-prefetched from the slot map, like
  ``embedding_bag``) and writes it, scaled by the slot weight, into its
  slab slot.  The repeated ``(G, N·K, D)`` source and the scatter pass
  never exist in HBM.
* **combine** — grid ``(G, S, K)`` with K sequential: a per-token f32
  VMEM accumulator sums the K gate-weighted expert rows; the
  ``(G, N·K, D)`` gathered intermediate never materializes.

Gradients: both ops are linear in their float inputs and each one's
transpose is the other, so ``custom_vjp`` implements dispatch's backward
as a combine (and vice versa) — the backward pass reuses the same
kernels.  ``combine``'s weight gradient needs the gathered expert rows
and falls back to an XLA gather (same bytes the forward reference path
moves anyway); ``dispatch`` treats its weight as a constant because the
model only ever passes the non-differentiable keep mask there.

On CPU (this container) ``impl="slot"`` runs the same slot-map
formulation as pure-jnp gathers — measurably faster than the reference
scatter/gather (see ``bench_kernels``) — and ``impl="interpret"``
executes the kernel bodies in the Pallas interpreter for the
equivalence suite.  Compiled Pallas runs on a real TPU backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


# --------------------------------------------------------------------------
# routing metadata (cheap integer ops, shared by every impl)
# --------------------------------------------------------------------------


def slot_maps(eid, pos, keep, *, num_experts: int, capacity: int):
    """Invert the token→slot routing into per-slot source maps.

    eid, pos, keep: ``(G, NK)`` — expert id, position-in-expert and keep
    mask per (token, k) slot, with ``NK = S·K`` and source token
    ``s = nk // K``.  Returns ``slot_nk (G, E, C) int32`` — the flat
    (token, k) index claiming each slot, ``-1`` for empty slots.

    Kept slots are claimed by exactly one (token, k) pair: ``pos`` is an
    exclusive running count per (group, expert), so indices are unique;
    dropped pairs are steered to the out-of-range position ``C`` and
    discarded by ``mode="drop"``.
    """
    G, NK = eid.shape
    E, C = num_experts, capacity

    pos_sc = jnp.where(keep, pos, C)  # C is out of bounds -> dropped
    nk_ids = jnp.broadcast_to(jnp.arange(NK, dtype=jnp.int32), (G, NK))

    def per_group(e_g, p_g, nk_g):
        empty = jnp.full((E, C), -1, jnp.int32)
        return empty.at[e_g, p_g].set(nk_g, mode="drop")

    slot_nk = jax.vmap(per_group)(eid, pos_sc, nk_ids)
    return slot_nk


def slot_sources(slot_nk, *, top_k: int):
    """slot_nk ``(G, E, C)`` flat (token,k) ids → token row ids (−1 kept)."""
    return jnp.where(slot_nk >= 0, slot_nk // top_k, -1)


def slot_weights(slot_nk, wtok):
    """Scatter per-(token,k) weights ``wtok (G, NK)`` onto the slots.

    Empty slots get weight 0, which is what makes the ``max(src, 0)``
    row-select in the kernels safe.
    """
    G, NK = wtok.shape
    safe = jnp.maximum(slot_nk, 0)
    w = jnp.take_along_axis(
        wtok, safe.reshape(G, -1), axis=1
    ).reshape(slot_nk.shape)
    return jnp.where(slot_nk >= 0, w, 0.0).astype(wtok.dtype)


# --------------------------------------------------------------------------
# Pallas kernels
# --------------------------------------------------------------------------


def _dispatch_kernel(src_ref, w_ref, x_ref, out_ref):
    g = pl.program_id(0)
    e = pl.program_id(1)
    c = pl.program_id(2)
    w = w_ref[g, e, c].astype(jnp.float32)
    row = x_ref[...].astype(jnp.float32) * w
    out_ref[...] = row.reshape(out_ref.shape).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_experts", "capacity",
                                             "interpret"))
def dispatch_pallas(x, slot_src, slot_w, *, num_experts: int, capacity: int,
                    interpret: bool = False):
    """x: (G, S, D); slot_src/slot_w: (G, E, C) → slabs (G, E, C, D).

    One grid step per slot: the source row is scalar-prefetched (SMEM) so
    each step DMAs exactly one ``(1, D)`` row HBM→VMEM — the K-repeated
    token buffer of the reference path never materializes.
    """
    G, S, D = x.shape
    E, C = num_experts, capacity

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # slot_src (int32), slot_w (f32)
        grid=(G, E, C),
        in_specs=[
            pl.BlockSpec((1, 1, D),
                         lambda g, e, c, src, w: (g, jnp.maximum(src[g, e, c], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D),
                               lambda g, e, c, src, w: (g, e, c, 0)),
    )
    return pl.pallas_call(
        _dispatch_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, E, C, D), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(slot_src, slot_w.astype(jnp.float32), x)


def _combine_kernel(eid_ref, pos_ref, w_ref, buf_ref, out_ref, acc_ref, *,
                    top_k: int):
    g = pl.program_id(0)
    s = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[g, s, k].astype(jnp.float32)
    acc_ref[...] += buf_ref[...].reshape(acc_ref.shape).astype(jnp.float32) * w

    @pl.when(k == top_k - 1)
    def _finalize():
        out_ref[...] = acc_ref[...].reshape(out_ref.shape).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def combine_pallas(buf, eid, pos, w, *, interpret: bool = False):
    """buf: (G, E, C, D); eid/pos/w: (G, S, K) → tokens (G, S, D).

    Grid (G, S, K) with K sequential: the expert row for (token, k) is
    block-selected via the scalar-prefetched (eid, pos) pair and summed
    gate-weighted into a f32 VMEM accumulator — the (G, S, K, D) gather
    intermediate never exists.
    """
    G, E, C, D = buf.shape
    _, S, K = eid.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # eid, pos (int32), w (f32)
        grid=(G, S, K),
        in_specs=[
            pl.BlockSpec((1, 1, 1, D),
                         lambda g, s, k, e, p, w: (g, e[g, s, k], p[g, s, k], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda g, s, k, e, p, w: (g, s, 0)),
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_combine_kernel, top_k=K),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, S, D), buf.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(eid, pos, w.astype(jnp.float32), buf)


# --------------------------------------------------------------------------
# jnp slot formulation (the CPU fast path; same algorithm as the kernels)
# --------------------------------------------------------------------------


def dispatch_slot(x, slot_src, slot_w):
    """Gather-formulated dispatch: slab row = slot_w · x[slot_src]."""
    G, S, D = x.shape

    def per_group(x_g, src_g, w_g):
        rows = x_g[jnp.maximum(src_g, 0)]                  # (E, C, D)
        return rows * w_g[..., None].astype(x_g.dtype)

    return jax.vmap(per_group)(x, slot_src, slot_w)


def combine_slot(buf, eid, pos, w):
    """Gather + gate-weighted sum over k (identical math to the kernel)."""

    def per_group(b_g, e_g, p_g, w_g):
        rows = b_g[e_g, p_g]                               # (S, K, D)
        return (rows * w_g[..., None].astype(b_g.dtype)).sum(axis=1)

    return jax.vmap(per_group)(buf, eid, pos, w)


# --------------------------------------------------------------------------
# differentiable entry points (custom_vjp: dispatchᵀ = combine)
# --------------------------------------------------------------------------


def _dispatch_impl(x, eid, pos, wtok, *, num_experts, capacity, top_k, impl):
    slot_nk = slot_maps(eid, pos, wtok != 0, num_experts=num_experts,
                        capacity=capacity)
    slot_src = slot_sources(slot_nk, top_k=top_k)
    slot_w = slot_weights(slot_nk, wtok)
    if impl == "interpret" or impl == "pallas":
        return dispatch_pallas(x, slot_src, slot_w, num_experts=num_experts,
                               capacity=capacity,
                               interpret=impl == "interpret")
    return dispatch_slot(x, slot_src, slot_w)


def _combine_impl(buf, eid, pos, w, *, impl):
    if impl == "interpret" or impl == "pallas":
        return combine_pallas(buf, eid, pos, w, interpret=impl == "interpret")
    return combine_slot(buf, eid, pos, w)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def moe_dispatch(x, eid, pos, wtok, num_experts, capacity, top_k, impl):
    """Differentiable dispatch: (G,S,D) tokens → (G,E,C,D) capacity slabs.

    eid/pos: ``(G, S·K)`` int32 routing; wtok: ``(G, S·K)`` per-(token,k)
    weight — the keep mask in the forward model, treated as a constant
    under differentiation (it is a 0/1 comparison output).
    """
    return _dispatch_impl(x, eid, pos, wtok, num_experts=num_experts,
                          capacity=capacity, top_k=top_k, impl=impl)


def _moe_dispatch_fwd(x, eid, pos, wtok, num_experts, capacity, top_k, impl):
    out = _dispatch_impl(x, eid, pos, wtok, num_experts=num_experts,
                         capacity=capacity, top_k=top_k, impl=impl)
    return out, (eid, pos, wtok, x.shape)


def _moe_dispatch_bwd(num_experts, capacity, top_k, impl, res, dbuf):
    eid, pos, wtok, x_shape = res
    G, S, D = x_shape
    K = eid.shape[1] // S
    # dispatch is linear in x with matrix Pᵀ; its transpose is combine:
    # dx[s] = Σ_k wtok[s,k] · dbuf[eid, pos].  Dropped pairs carry
    # pos ≥ C — clamp them to slot 0 (their weight is 0) so the combine
    # kernel's block index never leaves the (E, C) slab: compiled Pallas
    # does not clamp, unlike the CPU gather paths.
    safe_pos = jnp.where(wtok != 0, pos, 0)
    dx = _combine_impl(
        dbuf,
        eid.reshape(G, S, K), safe_pos.reshape(G, S, K),
        wtok.reshape(G, S, K), impl=impl,
    ).astype(jnp.result_type(dbuf))
    return dx, None, None, jnp.zeros_like(wtok)


moe_dispatch.defvjp(_moe_dispatch_fwd, _moe_dispatch_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def moe_combine(buf, eid, pos, w, impl):
    """Differentiable combine: (G,E,C,D) slabs → (G,S,D) tokens.

    eid/pos/w: ``(G, S, K)``; w is the (differentiable) gate·keep weight.
    """
    return _combine_impl(buf, eid, pos, w, impl=impl)


def _moe_combine_fwd(buf, eid, pos, w, impl):
    return _combine_impl(buf, eid, pos, w, impl=impl), (buf, eid, pos, w)


def _moe_combine_bwd(impl, res, dy):
    buf, eid, pos, w = res
    G, E, C, D = buf.shape
    _, S, K = eid.shape
    # combineᵀ = dispatch: dbuf[e,c] = w[s,k] · dy[s] for the slot's owner
    keep = w != 0
    dbuf = _dispatch_impl(
        dy, eid.reshape(G, S * K), pos.reshape(G, S * K),
        jnp.where(keep, w, 0.0).reshape(G, S * K).astype(jnp.float32),
        num_experts=E, capacity=C, top_k=K, impl=impl,
    ).astype(buf.dtype)
    # dw[s,k] = ⟨dy[s], buf[eid, pos]⟩ — needs the gathered rows; XLA
    # gather here (backward only; same bytes the fwd reference moves)
    def per_group(b_g, e_g, p_g, dy_g):
        rows = b_g[e_g, p_g]                               # (S, K, D)
        return jnp.einsum("skd,sd->sk", rows.astype(jnp.float32),
                          dy_g.astype(jnp.float32))

    dw = jax.vmap(per_group)(buf, eid, pos, dy)
    dw = jnp.where(keep, dw, 0.0).astype(w.dtype)
    return dbuf, None, None, dw


moe_combine.defvjp(_moe_combine_fwd, _moe_combine_bwd)
