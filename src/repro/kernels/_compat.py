"""Pallas API compatibility across jax versions (see DESIGN.md)."""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships this as TPUCompilerParams; newer jax renamed it.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
