"""Pallas TPU embedding-bag — fused sparse lookup + sum-pool.

The paper's data-intensive hot-spot: CTR models gather hundreds of sparse
feature rows per example and sum-pool them (§1: embedding layers process
~10 TB inputs).  TPU adaptation: the ids are *scalar-prefetched* (SMEM) so
each grid step's table row block is DMA'd HBM→VMEM based on the id value
— the gather never materializes (rows, dim) in HBM, and the pooled
accumulator lives in the output VMEM block.

Grid: (batch, bag) with the bag dimension sequential ("arbitrary") —
step (n, b) adds ``table[ids[n, b]]`` into ``out[n]``.

Validated in interpret mode against ``ref.embedding_bag_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _kernel(ids_ref, table_ref, out_ref, acc_ref, *, bag: int):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += table_ref[...].astype(jnp.float32)  # f32 accumulation

    @pl.when(b == bag - 1)
    def _finalize():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(ids, table, *, interpret: bool = False):
    """ids: (N, bag) int32 row ids; table: (V, dim) → (N, dim) sum-pooled.

    dim should be lane-aligned (multiple of 128) for the TPU path.
    """
    N, bag = ids.shape
    V, dim = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N, bag),
        in_specs=[
            # one table row per step, selected by the prefetched id
            pl.BlockSpec((1, dim), lambda n, b, ids: (ids[n, b], 0)),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda n, b, ids: (n, 0)),
        scratch_shapes=[pltpu.VMEM((1, dim), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, bag=bag),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, dim), table.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(ids, table)
