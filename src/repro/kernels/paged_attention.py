"""Paged KV-cache decode attention — Pallas TPU kernel + page pool.

Token-by-token decode is the bandwidth-bound stage of the serving
workload (HeterPS's data-intensive layer class): every generated token
re-reads the whole KV cache, so a dense ``(B, max_len, KV, hd)`` ring
buffer charges *max-length* KV bandwidth to every sequence regardless of
its true length, and a batch slot reserves max-length HBM even while it
serves a ten-token prompt.

This module stores KV state in a **shared page pool** instead:

* ``k_pages / v_pages: (num_pages, page_size, KV, hd)`` — one pool per
  attention layer, shared by every sequence in the batch.  Page 0 is a
  reserved scratch page: inactive batch slots park their writes there so
  the decode step stays branch-free.
* ``page_table: (B, pages_per_seq) int32`` — per-sequence logical→
  physical page map (:class:`PagePool` owns allocation on the host).
  Logical position ``t`` of sequence ``b`` lives at
  ``k_pages[page_table[b, t // page_size], t % page_size]``.

The decode kernel runs on a ``(B, KV, pages)`` grid with the page axis
sequential, online-softmax accumulators in VMEM (same algorithm as
``flash_attention``), and the page table + per-sequence positions
scalar-prefetched (SMEM) so each grid step DMAs exactly one *used* page
HBM→VMEM.  Steps past the sequence's last used page — and, for
sliding-window layers, pages wholly before the window — clamp their
block index to the previous step's, which the Pallas pipeline recognizes
as "same block" and skips the DMA: a 12-token sequence in a 4096-token
pool moves one page of KV, not 4096 rows.

On CPU (this container) the same formulation runs as a jnp
gather-over-pages (:func:`paged_decode_gather`) — the fast path the
serve loop uses — and ``interpret=True`` executes the kernel body in the
Pallas interpreter for the equivalence suite.  The dense ring-buffer
``nn.attention.decode_attention`` is kept as the ``impl="ref"`` oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30

#: page 0 is never allocated: it is the scratch page inactive slots
#: write to (and the clamp target for defensive out-of-range indices)
SCRATCH_PAGE = 0


# --------------------------------------------------------------------------
# host-side page pool (allocation / admit / evict)
# --------------------------------------------------------------------------


class PagePool:
    """Host-side allocator for the shared KV page pool.

    Pages are identified by physical index ``1 .. num_pages-1`` (page 0
    is the reserved scratch page).  ``table`` is the dense
    ``(slots, pages_per_seq)`` page-table array the device kernels
    consume; unallocated entries point at the scratch page.

    Invariants (property-tested in ``tests/test_serve_paged.py``):
      * no physical page is owned by two live slots;
      * ``free + Σ owned == num_pages - 1`` across any admit/preempt/
        evict sequence (the free list is conserved — freed pages
        recycle; reservations withhold availability without moving
        pages, so they never break conservation).

    **Preempt/reserve seam** (overload robustness): :meth:`preempt`
    releases a live slot's pages exactly like :meth:`evict` but records
    the event — the host keeps the sequence's generated tokens and later
    re-admits it by prefilling prompt + generated-so-far.
    :meth:`reserve` withholds free pages from ordinary admissions (e.g.
    for the request whose arrival triggered a preemption, so the pages
    the victim just released cannot be raced away by another admission
    path); an admission with ``from_reservation=True`` consumes them.
    """

    def __init__(self, num_pages: int, page_size: int, slots: int,
                 pages_per_seq: int):
        assert num_pages >= 2, "need at least one allocatable page"
        self.num_pages = num_pages
        self.page_size = page_size
        self.slots = slots
        self.pages_per_seq = pages_per_seq
        # LIFO free list: recently freed (cache-warm) pages go out first
        self._free: list[int] = list(range(num_pages - 1, 0, -1))
        self._owned: list[list[int]] = [[] for _ in range(slots)]
        self._reserved = 0
        self.preempt_count = 0
        self.table = np.full((slots, pages_per_seq), SCRATCH_PAGE, np.int32)

    # -- queries ----------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def reserved_pages(self) -> int:
        return self._reserved

    @property
    def available_pages(self) -> int:
        """Free pages not withheld by a reservation."""
        return len(self._free) - self._reserved

    def owned_pages(self, slot: int) -> tuple[int, ...]:
        return tuple(self._owned[slot])

    def pages_for(self, tokens: int) -> int:
        """Pages needed to hold ``tokens`` cache entries."""
        return max(1, -(-tokens // self.page_size))

    def can_admit(self, tokens: int, *, from_reservation: bool = False) -> bool:
        n = self.pages_for(tokens)
        avail = len(self._free) if from_reservation else self.available_pages
        return n <= self.pages_per_seq and n <= avail

    # -- mutations --------------------------------------------------------

    def reserve(self, tokens: int) -> bool:
        """Withhold the pages ``tokens`` positions need from ordinary
        admissions; ``False`` (no-op) when they are not available."""
        n = self.pages_for(tokens)
        if n > self.pages_per_seq or n > self.available_pages:
            return False
        self._reserved += n
        return True

    def cancel_reservation(self, tokens: int) -> None:
        """Return a :meth:`reserve`-d allotment to general availability."""
        n = self.pages_for(tokens)
        if n > self._reserved:
            raise ValueError(
                f"cancelling {n} pages but only {self._reserved} reserved")
        self._reserved -= n

    def admit(self, slot: int, tokens: int, *,
              from_reservation: bool = False) -> None:
        """Allocate pages covering ``tokens`` positions to an empty slot.

        ``from_reservation=True`` consumes a matching :meth:`reserve`
        allotment instead of drawing on general availability."""
        if self._owned[slot]:
            raise ValueError(f"slot {slot} already live")
        n = self.pages_for(tokens)
        if n > self.pages_per_seq:
            raise ValueError(
                f"{tokens} tokens need {n} pages > pages_per_seq="
                f"{self.pages_per_seq}")
        if from_reservation:
            if n > self._reserved:
                raise ValueError(
                    f"admit from_reservation needs {n} pages but only "
                    f"{self._reserved} are reserved")
            self._reserved -= n
        elif n > self.available_pages:
            raise MemoryError(
                f"pool exhausted: need {n} pages, {self.available_pages} "
                f"available ({len(self._free)} free, {self._reserved} "
                f"reserved)")
        if n > len(self._free):
            raise MemoryError(
                f"pool exhausted: need {n} pages, {len(self._free)} free")
        self.grow(slot, tokens)

    def preempt(self, slot: int) -> int:
        """Release a live slot's pages back to the pool so a more urgent
        request can run; the host keeps the sequence's tokens and resumes
        it later via prefill.  Returns the number of pages freed."""
        n = len(self._owned[slot])
        if n == 0:
            raise ValueError(f"slot {slot} is not live — nothing to preempt")
        self.evict(slot)
        self.preempt_count += 1
        return n

    def grow(self, slot: int, tokens: int) -> None:
        """Extend a slot's allocation to cover ``tokens`` positions
        (never draws pages below the reserved watermark)."""
        need = self.pages_for(tokens)
        if need > self.pages_per_seq:
            raise ValueError(f"{tokens} tokens exceed pages_per_seq capacity")
        while len(self._owned[slot]) < need:
            if not self._free or self.available_pages <= 0:
                raise MemoryError("pool exhausted")
            pid = self._free.pop()
            self.table[slot, len(self._owned[slot])] = pid
            self._owned[slot].append(pid)

    def evict(self, slot: int) -> None:
        """Free all of a slot's pages back to the pool."""
        while self._owned[slot]:
            self._free.append(self._owned[slot].pop())
        self.table[slot, :] = SCRATCH_PAGE


# --------------------------------------------------------------------------
# Pallas kernel
# --------------------------------------------------------------------------


def _page_window(q_pos, page_size: int, window):
    """(first, last) logical pages overlapping the live attention span
    for a query at position ``q_pos`` (valid keys: max(0, q_pos-window+1)
    .. q_pos)."""
    last = q_pos // page_size
    if window is None:
        first = jnp.zeros_like(last)
    else:
        first = jnp.maximum(q_pos - (window - 1), 0) // page_size
    return first, last


def _decode_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale, page_size, num_pages_seq,
                   window, softcap_val):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = pos_ref[b]
    first, last = _page_window(q_pos, page_size, window)

    @pl.when((p >= first) & (p <= last))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)                 # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (ps, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                           # (G, ps)
        if softcap_val is not None:
            s = softcap_val * jnp.tanh(s / softcap_val)
        kpos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        ok = kpos <= q_pos
        if window is not None:
            ok &= kpos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.exp(s - m_new)
        l_scr[...] = l_prev * alpha + pexp.sum(-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            pexp, v_ref[0, :, 0, :].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(p == num_pages_seq - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "interpret"))
def paged_decode_pallas(q, k_pages, v_pages, page_table, q_pos, *,
                        window: int | None = None,
                        softcap: float | None = None,
                        interpret: bool = False):
    """q: (B, KV, G, hd) grouped queries; k/v_pages: (N, ps, KV, hd);
    page_table: (B, P) int32; q_pos: (B,) int32 — the new token's
    position (== tokens already cached).  Returns (B, KV, G, hd).

    Grid (B, KV, P) with the page axis sequential.  The index map clamps
    the physical page into the live ``[first, last]`` page span, so
    out-of-span grid steps repeat the previous block index and the
    pipeline skips their DMA — only *used* pages move HBM→VMEM.
    """
    B, KV, G, hd = q.shape
    N, ps, _, _ = k_pages.shape
    P = page_table.shape[1]
    scale = 1.0 / float(np.sqrt(hd))

    def page_map(b, kv, p, pt, pos):
        first, last = _page_window(pos[b], ps, window)
        pe = jnp.clip(p, first, last)
        return (jnp.maximum(pt[b, pe], 0), 0, kv, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # page_table, q_pos (SMEM)
        grid=(B, KV, P),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, kv, p, pt, pos: (b, kv, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd), page_map),
            pl.BlockSpec((1, ps, 1, hd), page_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, kv, p, pt, pos: (b, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _decode_kernel, scale=scale, page_size=ps, num_pages_seq=P,
            window=window, softcap_val=softcap,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(page_table, q_pos, q, k_pages, v_pages)


# --------------------------------------------------------------------------
# jnp gather-over-pages formulation (the CPU fast path)
# --------------------------------------------------------------------------


def paged_decode_gather(q, k_pages, v_pages, page_table, q_pos, *,
                        window: int | None = None,
                        softcap: float | None = None):
    """Same math as the kernel as pure-jnp gathers: gather the sequence's
    table pages into (B, P·ps, KV, hd), mask to the live span, grouped
    GQA softmax.  Op order mirrors ``nn.attention.decode_attention`` so
    the dense oracle and the paged path agree to float rounding."""
    B, KV, G, hd = q.shape
    N, ps, _, _ = k_pages.shape
    P = page_table.shape[1]
    kg = k_pages[page_table].reshape(B, P * ps, KV, hd).astype(q.dtype)
    vg = v_pages[page_table].reshape(B, P * ps, KV, hd).astype(q.dtype)
    kpos = jnp.arange(P * ps, dtype=jnp.int32)[None]        # (1, P·ps)
    valid = kpos <= q_pos[:, None]
    if window is not None:
        valid &= kpos > (q_pos[:, None] - window)

    scale = 1.0 / float(np.sqrt(hd))
    logits = jnp.einsum("bkgd,bskd->bkgs", q, kg).astype(jnp.float32) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(vg.dtype)
    return jnp.einsum("bkgs,bskd->bkgd", w, vg)


# --------------------------------------------------------------------------
# pool writes (shared by decode step and batched prefill)
# --------------------------------------------------------------------------


def paged_write(k_pages, v_pages, k_new, v_new, page_table, q_pos, active):
    """Write one token's k/v (B, KV, hd) into each sequence's page for
    position ``q_pos``.  Inactive or out-of-capacity slots are steered to
    the scratch page (live pages are never touched by dead slots)."""
    B = q_pos.shape[0]
    ps = k_pages.shape[1]
    P = page_table.shape[1]
    logical = jnp.minimum(q_pos // ps, P - 1)
    pid = jnp.take_along_axis(page_table, logical[:, None], axis=1)[:, 0]
    ok = active & (q_pos < P * ps)
    pid = jnp.where(ok, pid, SCRATCH_PAGE)
    row = q_pos % ps
    k_pages = k_pages.at[pid, row].set(k_new.astype(k_pages.dtype))
    v_pages = v_pages.at[pid, row].set(v_new.astype(v_pages.dtype))
    return k_pages, v_pages


def paged_write_prefill(k_pages, v_pages, k_seq, v_seq, page_table, lengths):
    """Scatter a whole prefilled sequence (B, S, KV, hd) into the pool in
    one shot; positions ≥ the sequence's true length land on the scratch
    page (right-padded batched prefill)."""
    B, S = k_seq.shape[:2]
    ps = k_pages.shape[1]
    P = page_table.shape[1]
    t = jnp.arange(S, dtype=jnp.int32)[None]                # (1, S)
    logical = jnp.minimum(t // ps, P - 1)
    pid = jnp.take_along_axis(page_table, logical, axis=1)  # (B, S)
    ok = (t < lengths[:, None]) & (t < P * ps)
    pid = jnp.where(ok, pid, SCRATCH_PAGE)
    row = jnp.broadcast_to(t % ps, (B, S))
    k_pages = k_pages.at[pid, row].set(k_seq.astype(k_pages.dtype))
    v_pages = v_pages.at[pid, row].set(v_seq.astype(v_pages.dtype))
    return k_pages, v_pages
