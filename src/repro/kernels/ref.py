"""Pure-jnp oracles for the Pallas kernels (the allclose references)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None,
                        softcap: float | None = None):
    """q, k, v: (B, H, S, hd) → (B, H, Sq, hd).  Direct softmax attention."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= qpos >= kpos
    if window is not None:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)


def embedding_bag_ref(ids, table):
    """ids: (N, bag) int32; table: (V, dim) → (N, dim) sum-pooled."""
    return table[ids].sum(axis=1)
