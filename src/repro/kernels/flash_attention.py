"""Pallas TPU flash attention — blockwise online-softmax on the MXU.

TPU adaptation of the attention hot-spot (DESIGN.md §2): the score tile
lives in VMEM ((block_q, block_k) f32), K/V stream HBM→VMEM block by
block, accumulation in f32 VREGs.  Supports causal masking, sliding
window, and Gemma-2 logit soft-capping.  Block sizes default to MXU/lane
aligned (128) multiples.

Grid: (batch·heads, q_blocks, kv_blocks) with the kv dimension sequential
("arbitrary") so the VMEM scratch accumulators carry across kv steps.

Validated in interpret mode against ``ref.flash_attention_ref`` (the
pure-jnp oracle) over a shape/dtype sweep — see tests/test_kernels.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, block_q, block_k, n_kv, causal, window, softcap_val):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                    # (bk, hd)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                           # (bq, bk)
    if softcap_val is not None:
        s = softcap_val * jnp.tanh(s / softcap_val)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        ok &= qpos >= kpos
    if window is not None:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_prev * alpha + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"),
)
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    softcap: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q, k, v: (B, H, S, hd) (kv heads pre-expanded) → (B, H, Sq, hd).

    Sq must divide by block_q and Sk by block_k (pad upstream; ops.py
    handles padding + GQA expansion).
    """
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    scale = 1.0 / math.sqrt(hd)
    n_kv = Sk // block_k
    qf = q.reshape(B * H, Sq, hd)
    kf = k.reshape(B * H, Sk, hd)
    vf = v.reshape(B * H, Sk, hd)

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, block_q=block_q, block_k=block_k,
            n_kv=n_kv, causal=causal, window=window, softcap_val=softcap,
        ),
        grid=(B * H, Sq // block_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, hd)
