"""Basic pure-JAX NN building blocks (no flax/optax dependency)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, *, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale


def rmsnorm_init(d: int):
    return jnp.ones((d,), dtype=jnp.float32)


def rmsnorm(x, w, *, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


def layernorm_init(d: int):
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layernorm(x, p, *, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * p["w"] + p["b"]
    return y.astype(dt)


def softcap(x, cap: float):
    """Gemma-2 style logit soft-capping: cap·tanh(x/cap)."""
    return cap * jnp.tanh(x / cap)


# --- rotary position embeddings -------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0, fraction: float = 1.0):
    """Inverse frequencies for the rotated part of the head dim."""
    rot = int(head_dim * fraction) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot)), rot


def apply_rope(x, positions, *, theta: float = 10000.0, fraction: float = 1.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq).

    ``fraction < 1`` rotates only the first ``fraction`` of the head dim —
    ChatGLM's 2-d/partial RoPE (half the dims carry positional phase).
    """
    head_dim = x.shape[-1]
    inv, rot = rope_freqs(head_dim, theta, fraction)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, rot/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1).astype(x.dtype)


def swiglu(x, w1, w3, w2):
    """SwiGLU FFN: (silu(x·w1) ⊙ x·w3)·w2 — bf16-friendly."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def cross_entropy_loss(logits, labels, *, vocab: int):
    """Mean token cross-entropy; ignores labels < 0 and pad-vocab tail."""
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    labels = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
