"""RWKV-6 ("Finch") block — attention-free mixer with data-dependent decay.

Time-mix: per-head matrix-valued state ``S (B, H, hd, hd)`` updated as
``S_t = diag(w_t)·S_{t-1} + kᵀ_t v_t`` where the decay ``w_t`` is
*data-dependent* (low-rank LoRA on the shifted input — the headline
RWKV-6 change over RWKV-5's static decay).  Readout uses the bonus ``u``
for the current token.  Training scans time with ``lax.scan`` (the state
is the carry); decode is the same step applied once — O(1) per token,
which is why rwkv6 runs ``long_500k``.

Channel-mix: squared-ReLU gated FFN with token shift.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.nn.base import rmsnorm


def _lora_init(key, d: int, rank: int, out: int):
    k1, k2 = jax.random.split(key)
    return {
        "a": jax.random.normal(k1, (d, rank)) * (1.0 / math.sqrt(d)),
        "b": jnp.zeros((rank, out)),
    }


def _lora(p, x):
    return jnp.tanh(x @ p["a"]) @ p["b"]


def init_time_mix(key, d_model: int, *, head_size: int = 64,
                  decay_rank: int = 64, mix_rank: int = 32):
    H = d_model // head_size
    ks = jax.random.split(key, 12)
    s = 1.0 / math.sqrt(d_model)
    p = {
        "mu": jnp.full((5, d_model), 0.5),        # static shift-mix for r,k,v,g,w
        "mix_lora": _lora_init(ks[0], d_model, mix_rank, 5 * d_model),
        "wr": jax.random.normal(ks[1], (d_model, d_model)) * s,
        "wk": jax.random.normal(ks[2], (d_model, d_model)) * s,
        "wv": jax.random.normal(ks[3], (d_model, d_model)) * s,
        "wg": jax.random.normal(ks[4], (d_model, d_model)) * s,
        "wo": jax.random.normal(ks[5], (d_model, d_model)) * s,
        "decay_base": jnp.full((d_model,), -6.0),
        "decay_lora": _lora_init(ks[6], d_model, decay_rank, d_model),
        "u": jax.random.normal(ks[7], (H, head_size)) * 0.1,  # current-token bonus
        "ln_x": jnp.ones((d_model,)),             # per-head group norm weight
    }
    return p


def _five_streams(p, x, x_prev):
    """r,k,v,g,w inputs after data-dependent token shift.

    x, x_prev: (..., D).  Returns tuple of five (..., D) tensors.
    """
    d = x.shape[-1]
    delta = x_prev - x
    lora = _lora(p["mix_lora"], x + 0.5 * delta)       # (..., 5D)
    lora = lora.reshape(lora.shape[:-1] + (5, d))
    outs = []
    for j in range(5):
        mix = p["mu"][j] + lora[..., j, :]
        outs.append(x + delta * mix)
    return outs


def time_mix(p, x, *, head_size: int = 64, return_state: bool = False):
    """Full-sequence time-mix. x: (B, S, D) → (B, S, D).

    ``return_state=True`` additionally returns the decode cache after the
    sequence ({"state", "tm_shift"}), for batched prefill."""
    B, S, D = x.shape
    H = D // head_size
    from repro.parallel.act import shard_heads

    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :S]
    xr, xk, xv, xg, xw = _five_streams(p, x, x_prev)
    r = shard_heads((xr @ p["wr"]).reshape(B, S, H, head_size), axis=2)
    k = shard_heads((xk @ p["wk"]).reshape(B, S, H, head_size), axis=2)
    v = shard_heads((xv @ p["wv"]).reshape(B, S, H, head_size), axis=2)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay in (0,1): w = exp(-exp(base + lora(xw)))
    w = jnp.exp(-jnp.exp(p["decay_base"] + _lora(p["decay_lora"], xw)))
    w = w.reshape(B, S, H, head_size).astype(jnp.float32)

    kv = jnp.einsum("bshi,bshj->bshij", k.astype(jnp.float32), v.astype(jnp.float32))

    def step(S_state, inp):
        w_t, kv_t, r_t = inp                           # (B,H,hd), (B,H,hd,hd), (B,H,hd)
        out = jnp.einsum(
            "bhi,bhij->bhj", r_t, S_state + p["u"][None, :, :, None] * kv_t
        )
        S_new = w_t[..., None] * S_state + kv_t
        return S_new, out

    S0 = jnp.zeros((B, H, head_size, head_size), jnp.float32)
    S_last, out = jax.lax.scan(
        step,
        S0,
        (
            jnp.moveaxis(w, 1, 0),
            jnp.moveaxis(kv, 1, 0),
            jnp.moveaxis(r.astype(jnp.float32), 1, 0),
        ),
    )
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, D)     # (B,S,D)
    out = rmsnorm(out, p["ln_x"])                      # group-norm stand-in
    y = (out.astype(x.dtype) * g) @ p["wo"]
    if return_state:
        return y, {"state": S_last,
                   "tm_shift": x[:, -1].astype(jnp.float32)}
    return y


def init_channel_mix(key, d_model: int, d_ff: int):
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d_model)
    return {
        "mu_k": jnp.full((d_model,), 0.5),
        "mu_r": jnp.full((d_model,), 0.5),
        "wk": jax.random.normal(ks[0], (d_model, d_ff)) * s,
        "wv": jax.random.normal(ks[1], (d_ff, d_model)) * (1.0 / math.sqrt(d_ff)),
        "wr": jax.random.normal(ks[2], (d_model, d_model)) * s,
    }


def channel_mix(p, x, x_prev):
    from repro.parallel.act import shard_last_dim

    xk = x + (x_prev - x) * p["mu_k"]
    xr = x + (x_prev - x) * p["mu_r"]
    k = shard_last_dim(jnp.square(jax.nn.relu(xk @ p["wk"])))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])


def channel_mix_seq(p, x):
    B, S, D = x.shape
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :S]
    return channel_mix(p, x, x_prev)


def init_rwkv_cache(batch: int, d_model: int, *, head_size: int = 64):
    H = d_model // head_size
    return {
        "state": jnp.zeros((batch, H, head_size, head_size), jnp.float32),
        "tm_shift": jnp.zeros((batch, d_model), jnp.float32),
        "cm_shift": jnp.zeros((batch, d_model), jnp.float32),
    }


def decode_time_mix(p, x, cache, *, head_size: int = 64):
    """One-token time-mix. x: (B, 1, D)."""
    B, _, D = x.shape
    H = D // head_size
    xt = x[:, 0]
    xr, xk, xv, xg, xw = _five_streams(p, xt, cache["tm_shift"].astype(xt.dtype))
    r = (xr @ p["wr"]).reshape(B, H, head_size).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(B, H, head_size).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(B, H, head_size).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    w = jnp.exp(-jnp.exp(p["decay_base"] + _lora(p["decay_lora"], xw)))
    w = w.reshape(B, H, head_size).astype(jnp.float32)
    kv = jnp.einsum("bhi,bhj->bhij", k, v)
    out = jnp.einsum("bhi,bhij->bhj", r, cache["state"] + p["u"][None, :, :, None] * kv)
    new_state = w[..., None] * cache["state"] + kv
    out = rmsnorm(out.reshape(B, D), p["ln_x"]).astype(x.dtype)
    y = (out * g) @ p["wo"]
    return y[:, None, :], {"state": new_state, "tm_shift": xt.astype(jnp.float32)}


def decode_channel_mix(p, x, cache):
    xt = x[:, 0]
    y = channel_mix(p, xt, cache["cm_shift"].astype(xt.dtype))
    return y[:, None, :], {"cm_shift": xt.astype(jnp.float32)}
