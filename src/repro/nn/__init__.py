"""Pure-JAX NN layer library (no flax/optax)."""
