"""Mamba selective-SSM block (Jamba's sequence mixer) — pure JAX.

Training/prefill uses ``jax.lax.associative_scan`` over time (parallel
prefix scan → log-depth HLO, TPU-friendly); decode carries the SSM state
``h (B, d_inner, d_state)`` and the causal-conv window, with O(1) work per
new token — this is why Jamba runs the ``long_500k`` shape at all.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def init_mamba(key, d_model: int, *, d_state: int = 16, d_conv: int = 4,
               expand: int = 2, dt_rank: int | None = None):
    din = expand * d_model
    dt_rank = dt_rank or max(1, d_model // 16)
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d_model)
    si = 1.0 / math.sqrt(din)
    return {
        "in_proj": jax.random.normal(ks[0], (d_model, 2 * din)) * s,
        "conv_w": jax.random.normal(ks[1], (d_conv, din)) * (1.0 / math.sqrt(d_conv)),
        "conv_b": jnp.zeros((din,)),
        "x_proj": jax.random.normal(ks[2], (din, dt_rank + 2 * d_state)) * si,
        "dt_proj": jax.random.normal(ks[3], (dt_rank, din)) * (1.0 / math.sqrt(dt_rank)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((din,), 0.01))),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32), (din, d_state))),
        "D": jnp.ones((din,)),
        "out_proj": jax.random.normal(ks[4], (din, d_model)) * si,
    }


def _ssm_inputs(p, xc, dt_rank: int, d_state: int):
    """Shared by train & decode: per-step dt/B/C and discretization."""
    proj = xc @ p["x_proj"]                                   # (..., R+2N)
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])    # (..., din)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (din, N)
    Abar = jnp.exp(dt[..., None].astype(jnp.float32) * A)     # (..., din, N)
    # Bbar·x — Euler discretization dt*B*x
    Bx = (dt * xc)[..., None] * Bc[..., None, :].astype(dt.dtype)
    return Abar, Bx.astype(jnp.float32), Cc


#: time-chunk length for the selective scan: bounds the live
#: (B, chunk, d_inner, d_state) f32 discretization tensors to one chunk
#: (§Perf cycle 2 — the full-sequence associative scan materialized the
#: whole (B, S, din, N) several times over in jamba's backward)
SCAN_CHUNK = 512


def mamba(p, x, *, d_state: int = 16, d_conv: int = 4, chunk: int = SCAN_CHUNK,
          return_state: bool = False):
    """Full-sequence forward. x: (B, S, D) → (B, S, D).

    Chunked selective scan: sequential ``lax.scan`` over time chunks
    carrying the SSM state, parallel ``associative_scan`` within a chunk;
    the discretization (Ābar, B̄·x) is computed *inside* the (rematted)
    chunk body so no (B, S, din, N) tensor ever materializes.

    ``return_state=True`` additionally returns the decode cache after the
    sequence ({"h", "conv"} — exactly what stepping ``decode_mamba`` over
    the same tokens would carry), for batched prefill.
    """
    B, S, D = x.shape
    from repro.parallel.act import shard_last_dim

    din = p["in_proj"].shape[1] // 2
    dt_rank = p["dt_proj"].shape[0]
    xz = x @ p["in_proj"]
    xc, z = jnp.split(xz, 2, axis=-1)                         # (B,S,din)
    xc, z = shard_last_dim(xc), shard_last_dim(z)
    # depthwise causal conv1d along time
    xpad = jnp.pad(xc, ((0, 0), (d_conv - 1, 0), (0, 0)))
    conv_tail = xpad[:, S:, :]          # last d_conv-1 raw (pre-conv) inputs
    xc = sum(
        xpad[:, i : i + S, :] * p["conv_w"][i] for i in range(d_conv)
    ) + p["conv_b"]
    xc = jax.nn.silu(xc)

    def combine(a, b):
        a1, bx1 = a
        a2, bx2 = b
        return a2 * a1, a2 * bx1 + bx2

    C = min(chunk, S)
    if S % C:
        C = S  # single chunk for ragged short sequences
    nc = S // C
    xcs = jnp.moveaxis(xc.reshape(B, nc, C, din), 1, 0)       # (nc,B,C,din)

    @jax.checkpoint
    def chunk_body(h0, xc_c):
        Abar, Bx, Cc = _ssm_inputs(p, xc_c, dt_rank, d_state)  # (B,C,din,N)
        A_cum, h_rel = jax.lax.associative_scan(combine, (Abar, Bx), axis=1)
        h = h_rel + A_cum * h0[:, None]                        # carry state in
        y = jnp.einsum("bcdn,bcn->bcd", h, Cc.astype(h.dtype))
        return h[:, -1], y.astype(xc_c.dtype)

    h0 = jnp.zeros((B, din, d_state), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_body, h0, xcs)             # (nc,B,C,din)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, din)
    y = y + p["D"] * xc
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if return_state:
        return out, {"h": h_last, "conv": conv_tail}
    return out


def init_mamba_cache(batch: int, d_model: int, *, d_state: int = 16,
                     d_conv: int = 4, expand: int = 2, dtype=jnp.float32):
    din = expand * d_model
    return {
        "h": jnp.zeros((batch, din, d_state), jnp.float32),
        "conv": jnp.zeros((batch, d_conv - 1, din), dtype),
    }


def decode_mamba(p, x, cache, *, d_state: int = 16, d_conv: int = 4):
    """One-token decode. x: (B, 1, D). Returns (y (B,1,D), new_cache)."""
    B = x.shape[0]
    dt_rank = p["dt_proj"].shape[0]
    xz = x[:, 0] @ p["in_proj"]
    xc, z = jnp.split(xz, 2, axis=-1)                         # (B, din)
    window = jnp.concatenate([cache["conv"], xc[:, None, :].astype(cache["conv"].dtype)], axis=1)
    xconv = jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
    xconv = jax.nn.silu(xconv)
    Abar, Bx, Cc = _ssm_inputs(p, xconv, dt_rank, d_state)    # (B,din,N)
    h = Abar * cache["h"] + Bx
    y = jnp.einsum("bdn,bn->bd", h, Cc.astype(h.dtype)).astype(x.dtype)
    y = y + p["D"] * xconv
    y = y * jax.nn.silu(z)
    new_cache = {"h": h, "conv": window[:, 1:, :]}
    return (y @ p["out_proj"])[:, None, :], new_cache
