"""GQA attention with RoPE, sliding window, logit soft-capping, cross-attn,
KV-cache decode, and a blockwise (flash-style, online-softmax) path for long
sequences — pure JAX.  The Pallas TPU kernel in ``repro.kernels`` implements
the same blockwise algorithm for the MXU; this module is the XLA fallback
and the numerical reference for shapes the kernel doesn't cover.

Sharding note: GQA is computed with KV heads *expanded* to the full head
count before the score einsum, so one head axis (divisible by the 16-wide
``model`` mesh axis for most archs) carries the tensor parallelism; the
expansion is a broadcast XLA keeps fused.  The (KV, G) grouped form would
leave both factors smaller than the mesh axis and drop head sharding.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.kernels import paged_attention as paged_k
from repro.nn.base import apply_rope, rmsnorm, softcap
from repro.parallel import act

NEG_INF = -1e30
#: sequences longer than this use the blockwise path (bounds the live
#: logits tile instead of materializing the full S×S score matrix)
BLOCKWISE_THRESHOLD = 2048
KV_BLOCK = 1024


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    causal: bool = True
    window: int | None = None          # sliding-window size (Gemma-2 local)
    logit_softcap: float | None = None
    rope: bool = True
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    qk_norm: bool = False              # Qwen3-style per-head RMS on q/k


def init_attention(key, d_model: int, spec: AttnSpec, *, kv_dim: int | None = None):
    kq, kk, kv, ko = jax.random.split(key, 4)
    kv_dim = kv_dim or d_model
    s = 1.0 / math.sqrt(d_model)
    p = {
        "wq": jax.random.normal(kq, (d_model, spec.n_heads * spec.head_dim)) * s,
        "wk": jax.random.normal(kk, (kv_dim, spec.n_kv_heads * spec.head_dim)) * s,
        "wv": jax.random.normal(kv, (kv_dim, spec.n_kv_heads * spec.head_dim)) * s,
        "wo": jax.random.normal(ko, (spec.n_heads * spec.head_dim, d_model))
        * (1.0 / math.sqrt(spec.n_heads * spec.head_dim)),
    }
    if spec.qk_norm:
        p["q_norm"] = jnp.ones((spec.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((spec.head_dim,), jnp.float32)
    return p


def _expand_kv(x, n_heads: int):
    """(B, S, KV, hd) → (B, S, H, hd) by repeating each KV head G times."""
    B, S, KV, hd = x.shape
    if KV == n_heads:
        return x
    g = n_heads // KV
    x = jnp.broadcast_to(x[:, :, :, None, :], (B, S, KV, g, hd))
    return x.reshape(B, S, n_heads, hd)


def _mask_bias(q_pos, k_pos, *, causal, window):
    """(B, Sq, Sk) additive mask from query/key positions."""
    # k_pos < 0 marks padding (blockwise path pads keys with -1e9)
    ok = (k_pos >= 0)[..., None, :] & jnp.ones(
        q_pos.shape[:-1] + (q_pos.shape[-1], 1), bool
    )
    d = q_pos[..., :, None] - k_pos[..., None, :]
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF)


def _sdpa_direct(q, k, v, q_pos, k_pos, spec: AttnSpec):
    """Direct attention. q,k,v: (B,S,H,hd) (kv pre-expanded)."""
    scale = 1.0 / math.sqrt(spec.head_dim)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    if spec.logit_softcap:
        logits = softcap(logits, spec.logit_softcap)
    logits += _mask_bias(q_pos, k_pos, causal=spec.causal, window=spec.window)[
        :, None
    ]
    logits = act.shard_heads(logits, axis=1)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", w, v)


def _sdpa_blockwise(q, k, v, q_pos, k_pos, spec: AttnSpec):
    """Flash-style online-softmax over KV blocks (lax.scan); the scan body
    is rematerialized (jax.checkpoint) so backward recomputes the score
    tile per block instead of saving (B,H,Sq,KV_BLOCK) per iteration.
    Same math as ``_sdpa_direct`` (tested to allclose)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(spec.head_dim)
    nblk = -(-Sk // KV_BLOCK)
    pad = nblk * KV_BLOCK - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-(10**9))
    kb = jnp.moveaxis(k.reshape(B, nblk, KV_BLOCK, H, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nblk, KV_BLOCK, H, hd), 1, 0)
    pb = jnp.moveaxis(k_pos.reshape(B, nblk, KV_BLOCK), 1, 0)

    @jax.checkpoint
    def body(carry, blk):
        m, l, acc = carry
        kj, vj, pj = blk  # (B,KB,H,hd), (B,KB,H,hd), (B,KB)
        s = jnp.einsum("bqhd,bshd->bhqs", q, kj).astype(jnp.float32) * scale
        if spec.logit_softcap:
            s = softcap(s, spec.logit_softcap)
        s += _mask_bias(q_pos, pj, causal=spec.causal, window=spec.window)[:, None]
        s = act.shard_heads(s, axis=1)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqs,bshd->bhqd", p, vj.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = act.shard_heads(jnp.zeros((B, H, Sq, hd), jnp.float32), axis=1)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B,Sq,H,hd)


def _project_qkv(p, x, kv_x, spec: AttnSpec, q_pos, k_pos):
    B, Sq, _ = x.shape
    H, KV, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    q = (x @ p["wq"]).reshape(B, Sq, H, hd)
    k = (kv_x @ p["wk"]).reshape(B, kv_x.shape[1], KV, hd)
    v = (kv_x @ p["wv"]).reshape(B, kv_x.shape[1], KV, hd)
    if spec.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if spec.rope:
        q = apply_rope(q, q_pos, theta=spec.rope_theta, fraction=spec.rope_fraction)
        k = apply_rope(k, k_pos, theta=spec.rope_theta, fraction=spec.rope_fraction)
    q = act.shard_heads(q, axis=2)
    k = act.shard_heads(_expand_kv(k, H), axis=2)
    v = act.shard_heads(_expand_kv(v, H), axis=2)
    return q, k, v


def attention(p, x, spec: AttnSpec, *, positions, kv_x=None, kv_positions=None):
    """Full-sequence attention (training / prefill / encoder).

    x: (B, Sq, D); kv_x: cross-attention source (B, Sk, Dkv) or None.
    positions: (B, Sq) int32.  Returns (B, Sq, D).
    """
    self_attn = kv_x is None
    kv_x = x if self_attn else kv_x
    k_pos = positions if self_attn else kv_positions
    q, k, v = _project_qkv(p, x, kv_x, spec, positions, k_pos)
    Sk = k.shape[1]
    if max(x.shape[1], Sk) <= BLOCKWISE_THRESHOLD:
        o = _sdpa_direct(q, k, v, positions, k_pos, spec)
    else:
        o = _sdpa_blockwise(q, k, v, positions, k_pos, spec)
    B, Sq = x.shape[:2]
    return o.reshape(B, Sq, spec.n_heads * spec.head_dim) @ p["wo"]


def init_kv_cache(batch: int, max_len: int, spec: AttnSpec, dtype=jnp.bfloat16):
    shape = (batch, max_len, spec.n_kv_heads, spec.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def decode_attention(p, x, cache, index, spec: AttnSpec, *, cross: bool = False):
    """One-token decode. x: (B, 1, D); ``cache['k']``: (B, L, KV, hd).

    The cache is a *ring buffer*: the new token writes slot ``index % L``
    and ``cache['pos']`` records true positions for masking — a
    sliding-window layer keeps ``L = window`` regardless of context length
    (this is what makes gemma2 ``long_500k`` decode fit).  Cross-attention
    (``cross=True``) reads a fixed precomputed cache and writes nothing.
    Returns (out (B,1,D), new_cache).
    """
    B = x.shape[0]
    H, KV, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    # index: scalar for self-decode; the cross path also accepts a (B,)
    # per-sequence position vector (continuous batching, ragged lengths)
    idx = jnp.asarray(index, jnp.int32)
    q_pos = jnp.broadcast_to(jnp.atleast_1d(idx)[:, None], (B, 1))
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    if spec.qk_norm:
        q = rmsnorm(q, p["q_norm"])
    if spec.rope:
        q = apply_rope(q, q_pos, theta=spec.rope_theta, fraction=spec.rope_fraction)
    if not cross:
        L = cache["k"].shape[1]
        slot = jnp.mod(index, L)
        k_new = (x @ p["wk"]).reshape(B, 1, KV, hd)
        v_new = (x @ p["wv"]).reshape(B, 1, KV, hd)
        if spec.qk_norm:
            k_new = rmsnorm(k_new, p["k_norm"])
        if spec.rope:
            k_new = apply_rope(
                k_new, q_pos, theta=spec.rope_theta, fraction=spec.rope_fraction
            )
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1
            ),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1
            ),
            "pos": jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], jnp.full((B, 1), index, jnp.int32), slot, axis=1
            ),
        }
        k, v = cache["k"], cache["v"]
        k_pos = cache["pos"]
        valid = (k_pos >= 0) & (k_pos <= index)
        if spec.window is not None:
            valid &= k_pos > index - spec.window
    else:
        k, v = cache["k"], cache["v"]
        S = k.shape[1]
        valid = jnp.ones((B, S), bool)
    # grouped GQA at decode: q-len is 1, so the (KV, G) form never needs
    # the 4-6x KV expansion the training path uses for head sharding.
    scale = 1.0 / math.sqrt(hd)
    G = H // KV
    qg = q.reshape(B, 1, KV, G, hd)
    kq = k.astype(q.dtype)
    vq = v.astype(q.dtype)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kq).astype(jnp.float32) * scale
    if spec.logit_softcap:
        logits = softcap(logits, spec.logit_softcap)
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(vq.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, vq).reshape(B, 1, H * hd)
    return o @ p["wo"], cache


# --------------------------------------------------------------------------
# batched prefill (one forward that also yields the cacheable k/v)
# --------------------------------------------------------------------------


def _project_q(p, x, spec: AttnSpec, positions):
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, spec.n_heads, spec.head_dim)
    if spec.qk_norm:
        q = rmsnorm(q, p["q_norm"])
    if spec.rope:
        q = apply_rope(q, positions, theta=spec.rope_theta,
                       fraction=spec.rope_fraction)
    return q


def prefill_attention(p, x, spec: AttnSpec, *, positions, lengths=None):
    """Full-sequence self-attention that ALSO returns the (unexpanded,
    post-rope) k/v so the caller can fill a decode cache in one shot.

    x: (B, S, D); positions: (B, S); ``lengths (B,)`` masks right-padded
    prompts — padded keys are never attended (padded *queries* produce
    garbage rows the caller discards).  Returns
    (out (B, S, D), k (B, S, KV, hd), v (B, S, KV, hd)).
    """
    B, S, _ = x.shape
    H, KV, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    q = _project_q(p, x, spec, positions)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    if spec.qk_norm:
        k = rmsnorm(k, p["k_norm"])
    if spec.rope:
        k = apply_rope(k, positions, theta=spec.rope_theta,
                       fraction=spec.rope_fraction)
    k_pos = positions
    if lengths is not None:
        k_pos = jnp.where(positions < lengths[:, None], positions, -1)
    qs = act.shard_heads(q, axis=2)
    ke = act.shard_heads(_expand_kv(k, H), axis=2)
    ve = act.shard_heads(_expand_kv(v, H), axis=2)
    if S <= BLOCKWISE_THRESHOLD:
        o = _sdpa_direct(qs, ke, ve, positions, k_pos, spec)
    else:
        o = _sdpa_blockwise(qs, ke, ve, positions, k_pos, spec)
    return o.reshape(B, S, H * hd) @ p["wo"], k, v


def attention_with_kv(p, x, k, v, spec: AttnSpec, *, positions):
    """Cross-attention over precomputed (projected, unexpanded) k/v — the
    full-sequence analogue of ``decode_attention(cross=True)``: q is
    normed/roped at ``positions``, every key is attended (non-causal,
    no window)."""
    B, S, _ = x.shape
    H = spec.n_heads
    q = _project_q(p, x, spec, positions)
    Sk = k.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(Sk, dtype=jnp.int32), (B, Sk))
    cspec = dataclasses.replace(spec, causal=False, window=None)
    o = _sdpa_direct(
        act.shard_heads(q, axis=2),
        act.shard_heads(_expand_kv(k.astype(q.dtype), H), axis=2),
        act.shard_heads(_expand_kv(v.astype(q.dtype), H), axis=2),
        positions, k_pos, cspec,
    )
    return o.reshape(B, S, H * spec.head_dim) @ p["wo"]


# --------------------------------------------------------------------------
# paged KV-cache decode (shared page pool; see kernels/paged_attention.py)
# --------------------------------------------------------------------------


def init_paged_kv_cache(num_pages: int, page_size: int, spec: AttnSpec,
                        dtype=jnp.bfloat16):
    """One layer's share of the page pool: (num_pages, page_size, KV, hd)
    k/v arrays.  The page table / lengths live once per model (they are
    shared by every layer), not here."""
    shape = (num_pages, page_size, spec.n_kv_heads, spec.head_dim)
    return {"kp": jnp.zeros(shape, dtype), "vp": jnp.zeros(shape, dtype)}


def paged_decode_attention(p, x, cache, page_table, q_pos, spec: AttnSpec, *,
                           active=None, impl: str = "auto"):
    """One-token decode against the shared page pool.

    x: (B, 1, D); ``cache`` holds this layer's pool ({"kp", "vp"});
    page_table: (B, P) int32; q_pos: (B,) int32 — per-sequence position
    of the new token (== tokens already cached, ragged across the
    batch).  Writes the new k/v into the sequence's page, then attends
    positions ``max(0, q_pos-window+1) .. q_pos`` — reading only the
    pages that hold them.  Same GQA grouped form / rope / qk-norm /
    softcap / window semantics as :func:`decode_attention` (the dense
    oracle).  Returns (out (B, 1, D), {"kp", "vp"}).
    """
    B = x.shape[0]
    H, KV, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    if active is None:
        active = jnp.ones((B,), bool)
    pos2 = q_pos[:, None]                                   # (B, 1)
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    k_new = (x @ p["wk"]).reshape(B, 1, KV, hd)
    v_new = (x @ p["wv"]).reshape(B, 1, KV, hd)
    if spec.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k_new = rmsnorm(k_new, p["k_norm"])
    if spec.rope:
        q = apply_rope(q, pos2, theta=spec.rope_theta,
                       fraction=spec.rope_fraction)
        k_new = apply_rope(k_new, pos2, theta=spec.rope_theta,
                           fraction=spec.rope_fraction)
    kp, vp = paged_k.paged_write(cache["kp"], cache["vp"], k_new[:, 0],
                                 v_new[:, 0], page_table, q_pos, active)
    qg = q[:, 0].reshape(B, KV, H // KV, hd)
    o = kernel_ops.paged_attention_decode(
        qg, kp, vp, page_table, q_pos, window=spec.window,
        softcap=spec.logit_softcap, impl=impl,
    )
    out = o.reshape(B, 1, H * hd) @ p["wo"]
    return out, {"kp": kp, "vp": vp}
