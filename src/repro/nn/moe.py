"""Feed-forward layers: dense SwiGLU and top-k MoE with capacity dispatch.

The MoE uses scatter/gather dispatch with a per-expert capacity (GShard
style, capacity factor 1.25 by default): static shapes (shardable under
pjit — experts lay on the ``model`` mesh axis), FLOPs proportional to the
*active* experts, tokens over capacity dropped through the residual path.
Router load-balance auxiliary loss follows Switch Transformer.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def init_dense_ffn(key, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = 1.0 / math.sqrt(d_model), 1.0 / math.sqrt(d_ff)
    return {
        "w1": jax.random.normal(k1, (d_model, d_ff)) * s_in,
        "w3": jax.random.normal(k3, (d_model, d_ff)) * s_in,
        "w2": jax.random.normal(k2, (d_ff, d_model)) * s_out,
    }


def dense_ffn(p, x):
    from repro.parallel.act import shard_last_dim

    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    return shard_last_dim(h) @ p["w2"]


def init_moe(key, d_model: int, d_ff: int, num_experts: int, *,
             router_scale: float | None = None):
    kr, k1, k2, k3 = jax.random.split(key, 4)
    s_in, s_out = 1.0 / math.sqrt(d_model), 1.0 / math.sqrt(d_ff)
    return {
        "router": jax.random.normal(kr, (d_model, num_experts))
        * (router_scale or s_in),
        "w1": jax.random.normal(k1, (num_experts, d_model, d_ff)) * s_in,
        "w3": jax.random.normal(k3, (num_experts, d_model, d_ff)) * s_in,
        "w2": jax.random.normal(k2, (num_experts, d_ff, d_model)) * s_out,
    }


def moe_capacity(num_tokens: int, num_experts: int, top_k: int,
                 capacity_factor: float = 1.25) -> int:
    cap = int(math.ceil(num_tokens * top_k * capacity_factor / num_experts))
    return max(8, -(-cap // 8) * 8)  # round up to 8 for TPU lane alignment


def moe_route(router, x, *, top_k: int, capacity: int):
    """Top-k capacity routing for ``x (G, S, D)``.

    Returns ``(probs, gate, eid_f, pos, keep)`` where probs: (G, S, E)
    router softmax; gate: (G, S, K) renormalized top-k weights; and
    eid_f / pos / keep: (G, S·K) flat per-(token, k) expert id,
    position-in-expert (exclusive running count within the group) and
    under-capacity mask.  Shared by the reference oracle, the jnp slot
    path and the Pallas kernels — the property tests pin its invariants.
    """
    G, S, _ = x.shape
    E = router.shape[1]
    K = top_k

    logits = (x @ router).astype(jnp.float32)                # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, K)                      # (G, S, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) slot within its expert, per group
    eid_f = eid.reshape(G, S * K)                            # (G, NK)
    onehot = jax.nn.one_hot(eid_f, E, dtype=jnp.int32)       # (G, NK, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - 1                # exclusive rank
    pos = jnp.take_along_axis(pos_in_e, eid_f[..., None], axis=2)[..., 0]
    keep = pos < capacity                                    # (G, NK)
    return probs, gate, eid_f, pos, keep


def ref_dispatch(x, eid_f, safe_pos, keep, *, num_experts: int,
                 capacity: int, top_k: int):
    """Oracle scatter dispatch: K-repeated source + ``.at[].add`` into
    the (E, C) capacity slabs.  Single source of truth for the reference
    path — ``moe_ffn(impl="ref")`` and ``bench_kernels`` both use it."""
    E, C, K = num_experts, capacity, top_k
    D = x.shape[-1]

    def dispatch(xg, eg, pg, kg):
        src = jnp.repeat(xg, K, axis=0) * kg[:, None].astype(xg.dtype)
        return jnp.zeros((E, C, D), xg.dtype).at[eg, pg].add(src,
                                                             mode="drop")

    return jax.vmap(dispatch)(x, eid_f, safe_pos, keep)


def ref_combine(buf, eid_f, safe_pos, w, *, top_k: int):
    """Oracle gather combine: explicit (G, N·K, D) gather + gate-weighted
    sum over k.  ``w (G, N·K)`` is the gate·keep weight."""
    G, NK = eid_f.shape
    S, K = NK // top_k, top_k
    D = buf.shape[-1]

    def combine(og, eg, pg):
        return og[eg, pg]                                    # (NK, D)

    y_f = jax.vmap(combine)(buf, eid_f, safe_pos)            # (G, NK, D)
    return (y_f * w[..., None].astype(y_f.dtype)).reshape(G, S, K, D).sum(2)


def moe_ffn(p, x, *, top_k: int, capacity_factor: float = 1.25,
            impl: str = "auto"):
    """x: (B, S, D) → (y (B, S, D), aux) with aux = load-balance loss terms.

    GShard-style *grouped* dispatch: each sequence is a routing group
    (G = B groups, shardable over the data axes), with per-group expert
    capacity C = ceil(S·K·cf / E).  Position-in-expert is a cumulative
    count *within the group* — no cross-shard prefix sum — so every step
    of dispatch is data-parallel while the expert dim lays on the
    ``model`` axis.  Tokens over a group's capacity fall through the
    residual path.

    ``impl="ref"`` is the pure-JAX scatter/gather oracle (K-repeated
    source, ``.at[].add`` dispatch, explicit gather combine).  Any other
    impl routes the data movement through the fused dispatch/combine
    layer in :mod:`repro.kernels.ops` (``auto`` → compiled Pallas on TPU,
    jnp slot formulation elsewhere; ``interpret``/``slot``/``pallas``
    force a path), with gradients via the kernels' ``custom_vjp``.
    """
    from repro.parallel.act import shard_batch_act, shard_moe_group_buffer

    B, S, D = x.shape
    E = p["router"].shape[1]
    K = top_k
    C = moe_capacity(S, E, K, capacity_factor)               # per group

    probs, gate, eid_f, pos, keep = moe_route(p["router"], x, top_k=K,
                                              capacity=C)
    safe_pos = jnp.where(keep, pos, 0)

    if impl == "ref":
        buf = ref_dispatch(x, eid_f, safe_pos, keep, num_experts=E,
                           capacity=C, top_k=K)              # (G, E, C, D)
    else:
        from repro.kernels import ops as kops

        buf = kops.moe_dispatch(x, eid_f, pos,
                                keep.astype(jnp.float32),
                                num_experts=E, capacity=C, top_k=K,
                                impl=impl)
    buf = shard_moe_group_buffer(buf)

    # batched expert SwiGLU — the expert dim shards over the model axis
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w1"]))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["w3"])
    out = jnp.einsum("gecf,efd->gecd", h, p["w2"])           # (G, E, C, D)
    out = shard_moe_group_buffer(out)

    w = (gate.reshape(B, S * K) * keep).astype(x.dtype)
    if impl == "ref":
        y = ref_combine(out, eid_f, safe_pos, w, top_k=K)
    else:
        y = kops.moe_combine(
            out, eid_f.reshape(B, S, K), safe_pos.reshape(B, S, K),
            w.reshape(B, S, K), impl=impl,
        )
    y = shard_batch_act(y)

    # Switch-style load-balance aux loss
    eid = eid_f.reshape(B, S, K)
    density = jax.nn.one_hot(eid[..., 0], E).mean((0, 1))    # top-1 share
    mean_prob = probs.mean((0, 1))
    aux = E * jnp.sum(density * mean_prob)
    return y, {"aux_loss": aux, "dropped": 1.0 - keep.mean()}
