"""Live-metrics → cost-model bridge (the reactive re-planner's seam).

HeterPS schedules against *analytic* ``ResourceType``/``LayerProfile``
constants computed once, offline (``core/resources.py`` /
``core/profiles.py``).  This module turns the obs spine's **measured**
signals into those exact shapes, so a future re-planner can hand the
fused RL search live profiles instead of nominal ones:

* :func:`snapshot_resources` — one coherent snapshot: a ``ResourceType``
  whose bandwidth terms are re-anchored to measured PS traffic (the same
  arithmetic as :meth:`repro.ps.telemetry.PSTelemetry.to_resource`, read
  from the metric registries), measured embedding-layer ODT seconds, and
  the serve-side SLO signals (queue depth, page-pool occupancy, TTFT /
  TPOT percentiles) the admission policy would tune against;
* :func:`apply_measured_odt` — graft measured ``(sync, act)`` seconds
  onto a ``LayerProfile``, index-aligned with the fleet, exactly what
  ``core/cost_model.py`` consumes.
"""

from __future__ import annotations

import dataclasses

from repro.core.profiles import LayerProfile
from repro.core.resources import ResourceType
from repro.obs import metrics as obs_metrics


def _ps_traffic(registries=None) -> dict:
    """Aggregate PS pull/push traffic over every live registry carrying
    ``PSTelemetry``-named counters (``ps.bytes``/``ps.seconds`` labeled
    ``dir=pull|push``, one shard per label) — per-registry ``seconds`` is
    the max over shards (shards serve concurrently), matching
    ``PSTelemetry.totals``; registries (independent tables) add up."""
    out = {d: {"bytes": 0.0, "seconds": 0.0, "rows": 0.0}
           for d in ("pull", "push")}
    for reg in (registries if registries is not None
                else obs_metrics.all_registries()):
        for d in ("pull", "push"):
            per_shard_secs = [m.value for lab, m in reg.find("ps.seconds")
                              if lab.get("dir") == d]
            if not per_shard_secs:
                continue
            out[d]["seconds"] += max(per_shard_secs)
            out[d]["bytes"] += sum(m.value for lab, m in reg.find("ps.bytes")
                                   if lab.get("dir") == d)
            out[d]["rows"] += sum(m.value for lab, m in reg.find("ps.rows")
                                  if lab.get("dir") == d)
    return out


def _serve_signals(registry=None) -> dict:
    reg = registry if registry is not None else obs_metrics.REGISTRY
    sig: dict = {
        "queue_depth": reg.value("serve.queue_depth"),
        "pool_pages_used": reg.value("serve.pool_pages_used"),
        "pool_pages_total": reg.value("serve.pool_pages_total"),
        "evictions": reg.value("serve.evictions"),
        "admissions": reg.value("serve.admissions"),
        "tokens": reg.value("serve.tokens"),
    }
    for name, key in (("serve.ttft_s", "ttft"), ("serve.tpot_s", "tpot")):
        for _, hist in reg.find(name):
            sig[key] = hist.snapshot()
    return sig


def fleet_health(fleet) -> dict:
    """Degradation signals of an :class:`~repro.ps.elastic.ElasticPSFleet`
    — the failure-domain inputs a reactive re-planner needs alongside
    bandwidths: live vs referenced shards, buckets currently missing a
    replica, in-flight migrations, and the transport's retry/hedge/
    heartbeat counters (escalations = shards declared dead)."""
    import numpy as np

    with fleet._mu:
        live = set(fleet.transport.live_shards)
        referenced = {int(s) for s in set(fleet.primary) | set(fleet.backup)
                      if s >= 0}
        unreplicated = (int(np.count_nonzero(fleet.backup < 0))
                        if fleet.replicas else 0)
        health = {
            "live_shards": sorted(live),
            "dead_shards": sorted(referenced - live),
            "buckets_unreplicated": unreplicated,
            "migrating": len(fleet._migrations),
            "transport": dict(fleet.transport.counters),
            "events": {
                k: sum(1 for e in fleet.events if e["kind"] == k)
                for k in ("kill", "recover", "detected", "restore")},
        }
    inner = getattr(fleet.transport, "inner", None)
    if inner is not None:            # FaultInjector: fold backend counters
        for k, v in inner.counters.items():
            health["transport"][k] = health["transport"].get(k, 0) + v
    health["degraded"] = bool(health["dead_shards"]
                              or health["buckets_unreplicated"])
    return health


def snapshot_resources(base: ResourceType, *, telemetry=None,
                       num_examples: int | None = None,
                       registry=None, fleet=None) -> dict:
    """Turn live metrics into the shapes ``core/profiles.py`` consumes.

    Returns ``{"resource": ResourceType, "embedding_odt": (sync, act),
    "serve": {...}, "ps": {...}}`` — plus ``"ps_health"`` when ``fleet``
    (an ``ElasticPSFleet``) is given, so a re-planner sees degraded
    shards, not just bandwidths.  ``telemetry`` (a ``PSTelemetry``)
    takes precedence for the PS side; otherwise the traffic is read from
    the metric registries.  Bandwidth terms with no traffic keep the
    ``base`` constants — a cold snapshot degrades to the analytic model.
    """
    if telemetry is not None:
        res = telemetry.to_resource(base)
        odt = (telemetry.embedding_odt(num_examples)
               if num_examples else (0.0, 0.0))
        t = telemetry.totals()
        ps = {d: {k: t[d][k] for k in ("bytes", "seconds", "rows")}
              for d in ("pull", "push")}
    else:
        ps = _ps_traffic()
        pull_s, push_s = ps["pull"]["seconds"], ps["push"]["seconds"]
        ingest = ps["pull"]["bytes"] / pull_s if pull_s > 0 else 0.0
        net_b = ps["pull"]["bytes"] + ps["push"]["bytes"]
        net_s = pull_s + push_s
        net = net_b / net_s if net_s > 0 else 0.0
        res = dataclasses.replace(
            base, name=base.name + "+obs",
            ingest_bw=ingest if ingest > 0 else base.ingest_bw,
            net_bw=net if net > 0 else base.net_bw)
        if num_examples:
            from repro.core.profiles import B_O

            per_ex = net_s / num_examples
            act_per_ex = pull_s / num_examples
            odt = (per_ex * B_O, act_per_ex * B_O)
        else:
            odt = (0.0, 0.0)
    out = {"resource": res, "embedding_odt": odt,
           "serve": _serve_signals(registry), "ps": ps}
    if fleet is not None:
        out["ps_health"] = fleet_health(fleet)
    return out


def apply_measured_odt(profile: LayerProfile, sync: float,
                       act: float) -> LayerProfile:
    """``profile`` with its per-type ODT terms replaced by one measured
    ``(sync, act)`` pair, broadcast across the fleet's resource types —
    the drop-in the scheduler's cost model consumes."""
    n = len(profile.oct)
    return dataclasses.replace(
        profile, odt_sync=(float(sync),) * n, odt_act=(float(act),) * n)
