"""Live-metrics → cost-model bridge (the reactive re-planner's seam).

HeterPS schedules against *analytic* ``ResourceType``/``LayerProfile``
constants computed once, offline (``core/resources.py`` /
``core/profiles.py``).  This module turns the obs spine's **measured**
signals into those exact shapes, so a future re-planner can hand the
fused RL search live profiles instead of nominal ones:

* :func:`snapshot_resources` — one coherent snapshot: a ``ResourceType``
  whose bandwidth terms are re-anchored to measured PS traffic (the same
  arithmetic as :meth:`repro.ps.telemetry.PSTelemetry.to_resource`, read
  from the metric registries), measured embedding-layer ODT seconds, and
  the serve-side SLO signals (queue depth, page-pool occupancy, TTFT /
  TPOT percentiles) the admission policy would tune against;
* :func:`apply_measured_odt` — graft measured ``(sync, act)`` seconds
  onto a ``LayerProfile``, index-aligned with the fleet, exactly what
  ``core/cost_model.py`` consumes.
"""

from __future__ import annotations

import dataclasses

from repro.core.profiles import LayerProfile
from repro.core.resources import ResourceType
from repro.obs import metrics as obs_metrics


def _ps_traffic(registries=None) -> dict:
    """Aggregate PS pull/push traffic over every live registry carrying
    ``PSTelemetry``-named counters (``ps.bytes``/``ps.seconds`` labeled
    ``dir=pull|push``, one shard per label) — per-registry ``seconds`` is
    the max over shards (shards serve concurrently), matching
    ``PSTelemetry.totals``; registries (independent tables) add up.

    Closed registries are skipped: every ``PSTelemetry`` owns a fresh
    named registry that outlives its table in ``all_registries()``, so
    without the filter a snapshot taken after e.g. ``bench_ps``'s sync
    run would sum dead clients' cumulative traffic into the *live*
    bandwidths the re-planner consumes."""
    out = {d: {"bytes": 0.0, "seconds": 0.0, "rows": 0.0}
           for d in ("pull", "push")}
    for reg in (registries if registries is not None
                else obs_metrics.live_registries()):
        if reg.closed:
            continue
        for d in ("pull", "push"):
            per_shard_secs = [m.value for lab, m in reg.find("ps.seconds")
                              if lab.get("dir") == d]
            if not per_shard_secs:
                continue
            out[d]["seconds"] += max(per_shard_secs)
            out[d]["bytes"] += sum(m.value for lab, m in reg.find("ps.bytes")
                                   if lab.get("dir") == d)
            out[d]["rows"] += sum(m.value for lab, m in reg.find("ps.rows")
                                  if lab.get("dir") == d)
    return out


def _serve_signals(registry=None) -> dict:
    reg = registry if registry is not None else obs_metrics.REGISTRY
    sig: dict = {
        "queue_depth": reg.value("serve.queue_depth"),
        "pool_pages_used": reg.value("serve.pool_pages_used"),
        "pool_pages_total": reg.value("serve.pool_pages_total"),
        "evictions": reg.value("serve.evictions"),
        "admissions": reg.value("serve.admissions"),
        "tokens": reg.value("serve.tokens"),
        # overload-robustness outcome counters (PR 10) — the admission
        # actuator's breach/health inputs
        "completed": reg.value("serve.completed"),
        "rejected": reg.value("serve.rejected"),
        "timed_out": reg.value("serve.timed_out"),
        "preemptions": reg.value("serve.preemptions"),
        "resumes": reg.value("serve.resumes"),
        "good_tokens": reg.value("serve.good_tokens"),
        "stalls": reg.value("serve.stalls"),
    }
    for name, key in (("serve.ttft_s", "ttft"), ("serve.tpot_s", "tpot"),
                      ("serve.deadline_slack_s", "deadline_slack")):
        hists = [h for _, h in reg.find(name)]
        if not hists:
            continue
        # find() may match several labeled histograms under one name —
        # merge them into one pooled snapshot (bucket counts add, the
        # GROWTH quantile bound holds against the union) instead of
        # silently keeping whichever iterated last
        sig[key] = (hists[0].snapshot() if len(hists) == 1
                    else obs_metrics.merge_histograms(hists))
        sig[key]["streams"] = len(hists)
    return sig


def fleet_health(fleet) -> dict:
    """Degradation signals of an :class:`~repro.ps.elastic.ElasticPSFleet`
    — the failure-domain inputs a reactive re-planner needs alongside
    bandwidths: live vs referenced shards, buckets currently missing a
    replica, in-flight migrations, and the transport's retry/hedge/
    heartbeat counters (escalations = shards declared dead)."""
    import numpy as np

    with fleet._mu:
        live = set(fleet.transport.live_shards)
        referenced = {int(s) for s in set(fleet.primary) | set(fleet.backup)
                      if s >= 0}
        unreplicated = (int(np.count_nonzero(fleet.backup < 0))
                        if fleet.replicas else 0)
        health = {
            "live_shards": sorted(live),
            "dead_shards": sorted(referenced - live),
            "buckets_unreplicated": unreplicated,
            "migrating": len(fleet._migrations),
            "transport": dict(fleet.transport.counters),
            "events": {
                k: sum(1 for e in fleet.events if e["kind"] == k)
                for k in ("kill", "recover", "detected", "restore")},
        }
    inner = getattr(fleet.transport, "inner", None)
    if inner is not None:            # FaultInjector: fold backend counters
        for k, v in inner.counters.items():
            health["transport"][k] = health["transport"].get(k, 0) + v
    health["degraded"] = bool(health["dead_shards"]
                              or health["buckets_unreplicated"])
    return health


def snapshot_resources(base: ResourceType, *, telemetry=None,
                       num_examples: int | None = None,
                       registry=None, fleet=None) -> dict:
    """Turn live metrics into the shapes ``core/profiles.py`` consumes.

    Returns ``{"resource": ResourceType, "embedding_odt": (sync, act),
    "serve": {...}, "ps": {...}}`` — plus ``"ps_health"`` when ``fleet``
    (an ``ElasticPSFleet``) is given, so a re-planner sees degraded
    shards, not just bandwidths.  ``telemetry`` (a ``PSTelemetry``)
    takes precedence for the PS side; otherwise the traffic is read from
    the metric registries.  Bandwidth terms with no traffic keep the
    ``base`` constants — a cold snapshot degrades to the analytic model.
    """
    if telemetry is not None:
        res = telemetry.to_resource(base)
        odt = (telemetry.embedding_odt(num_examples)
               if num_examples else (0.0, 0.0))
        t = telemetry.totals()
        ps = {d: {k: t[d][k] for k in ("bytes", "seconds", "rows")}
              for d in ("pull", "push")}
    else:
        ps = _ps_traffic()
        pull_s, push_s = ps["pull"]["seconds"], ps["push"]["seconds"]
        ingest = ps["pull"]["bytes"] / pull_s if pull_s > 0 else 0.0
        net_b = ps["pull"]["bytes"] + ps["push"]["bytes"]
        net_s = pull_s + push_s
        net = net_b / net_s if net_s > 0 else 0.0
        res = dataclasses.replace(
            base, name=base.name + "+obs",
            ingest_bw=ingest if ingest > 0 else base.ingest_bw,
            net_bw=net if net > 0 else base.net_bw)
        if num_examples:
            from repro.core.profiles import B_O

            per_ex = net_s / num_examples
            act_per_ex = pull_s / num_examples
            odt = (per_ex * B_O, act_per_ex * B_O)
        else:
            odt = (0.0, 0.0)
    out = {"resource": res, "embedding_odt": odt,
           "serve": _serve_signals(registry), "ps": ps}
    if fleet is not None:
        out["ps_health"] = fleet_health(fleet)
    return out


@dataclasses.dataclass(frozen=True)
class SnapshotDelta:
    """Interval rates between two :func:`snapshot_resources` snapshots.

    The metric registries are **cumulative since process start**, so a
    re-planner that read two snapshots and divided lifetime bytes by
    lifetime seconds would see a *lifetime average* — a mid-run bandwidth
    collapse gets diluted toward invisibility as the run ages.  This is
    the windowed view: every byte/second/count field is the difference
    ``cur − prev``, and the bandwidth properties are Δbytes/Δseconds over
    the window only.  Gauges (queue depth, pool occupancy) are sampled at
    the window end plus a growth term; histograms stay lifetime (their
    buckets are not exposed in snapshots) but ride along with the count
    of requests that *completed inside the window*, so SLO checks can be
    gated on the window actually having seen traffic.
    """

    seconds: float               #: wall-clock span of the window
    pull_bytes: float
    push_bytes: float
    pull_seconds: float          #: PS in-flight seconds within the window
    push_seconds: float
    tokens: float                #: serve tokens emitted in the window
    queue_depth: float           #: depth at window end (gauge)
    queue_growth: float          #: depth end − depth start
    ttft: dict | None            #: lifetime TTFT snapshot at window end
    tpot: dict | None
    ttft_completed: float        #: requests whose TTFT landed in-window
    tpot_completed: float
    ps_degraded: bool            #: fleet health at window end
    dead_shards: int
    fleet_events: int            #: lifecycle events (join/leave/kill/
    #: detected/recover/restore) that fired inside the window
    # overload-robustness outcome deltas (PR 10) — defaulted so snapshots
    # taken before the serve loop ran (or by older callers) still diff
    completed: float = 0.0       #: requests completed in the window
    rejected: float = 0.0       #: admission rejections in the window
    timed_out: float = 0.0       #: deadline timeouts in the window
    preempted: float = 0.0       #: slot preemptions in the window
    resumed: float = 0.0        #: preempted requests resumed in-window
    good_tokens: float = 0.0     #: deadline-met tokens in the window

    @property
    def goodput_tok_per_s(self) -> float:
        """Windowed deadline-met tokens per second (0.0 = none)."""
        return self.good_tokens / self.seconds if self.seconds > 0 else 0.0

    @property
    def ingest_bw(self) -> float:
        """Windowed pull bandwidth (0.0 = no pull traffic this window)."""
        return (self.pull_bytes / self.pull_seconds
                if self.pull_seconds > 0 else 0.0)

    @property
    def net_bw(self) -> float:
        """Windowed pull+push bandwidth (0.0 = no traffic this window)."""
        b = self.pull_bytes + self.push_bytes
        s = self.pull_seconds + self.push_seconds
        return b / s if s > 0 else 0.0

    @property
    def has_ps_traffic(self) -> bool:
        return (self.pull_seconds + self.push_seconds) > 0.0

    def resource(self, base: ResourceType) -> ResourceType:
        """``base`` re-anchored to this window's measured bandwidths
        (terms without window traffic keep the ``base`` constants)."""
        ingest, net = self.ingest_bw, self.net_bw
        return dataclasses.replace(
            base, name=base.name + "+win",
            ingest_bw=ingest if ingest > 0 else base.ingest_bw,
            net_bw=net if net > 0 else base.net_bw)

    def embedding_odt(self, num_examples: float) -> tuple[float, float]:
        """Windowed measured ``(odt_sync, odt_act)`` seconds per ``B_O``
        profiling window, from this window's PS traffic over
        ``num_examples`` training examples processed in the window."""
        from repro.core.profiles import B_O

        if num_examples <= 0 or not self.has_ps_traffic:
            return 0.0, 0.0
        per_ex = (self.pull_seconds + self.push_seconds) / num_examples
        act_per_ex = self.pull_seconds / num_examples
        return per_ex * B_O, act_per_ex * B_O


def _hist_count(sig: dict, key: str) -> float:
    h = sig.get(key)
    return float(h["count"]) if h else 0.0


def snapshot_delta(prev: dict, cur: dict, seconds: float) -> SnapshotDelta:
    """The windowed difference of two :func:`snapshot_resources` dicts
    (``prev`` taken ``seconds`` before ``cur``)."""
    pp, cp = prev["ps"], cur["ps"]
    ps_, cs = prev["serve"], cur["serve"]
    health = cur.get("ps_health")
    ev_prev = sum(prev["ps_health"]["events"].values()) \
        if prev.get("ps_health") else 0
    ev_cur = sum(health["events"].values()) if health else 0
    return SnapshotDelta(
        seconds=float(seconds),
        pull_bytes=cp["pull"]["bytes"] - pp["pull"]["bytes"],
        push_bytes=cp["push"]["bytes"] - pp["push"]["bytes"],
        pull_seconds=cp["pull"]["seconds"] - pp["pull"]["seconds"],
        push_seconds=cp["push"]["seconds"] - pp["push"]["seconds"],
        tokens=cs["tokens"] - ps_["tokens"],
        queue_depth=cs["queue_depth"],
        queue_growth=cs["queue_depth"] - ps_["queue_depth"],
        ttft=cs.get("ttft"),
        tpot=cs.get("tpot"),
        ttft_completed=_hist_count(cs, "ttft") - _hist_count(ps_, "ttft"),
        tpot_completed=_hist_count(cs, "tpot") - _hist_count(ps_, "tpot"),
        ps_degraded=bool(health["degraded"]) if health else False,
        dead_shards=len(health["dead_shards"]) if health else 0,
        fleet_events=ev_cur - ev_prev,
        # .get(): hand-built snapshot dicts predating PR 10 lack these
        completed=cs.get("completed", 0.0) - ps_.get("completed", 0.0),
        rejected=cs.get("rejected", 0.0) - ps_.get("rejected", 0.0),
        timed_out=cs.get("timed_out", 0.0) - ps_.get("timed_out", 0.0),
        preempted=cs.get("preemptions", 0.0) - ps_.get("preemptions", 0.0),
        resumed=cs.get("resumes", 0.0) - ps_.get("resumes", 0.0),
        good_tokens=cs.get("good_tokens", 0.0) - ps_.get("good_tokens", 0.0),
    )


def apply_measured_odt(profile: LayerProfile, sync: float,
                       act: float) -> LayerProfile:
    """``profile`` with its per-type ODT terms replaced by one measured
    ``(sync, act)`` pair, broadcast across the fleet's resource types —
    the drop-in the scheduler's cost model consumes."""
    n = len(profile.oct)
    return dataclasses.replace(
        profile, odt_sync=(float(sync),) * n, odt_act=(float(act),) * n)
