"""Span tracing — ring-buffered events exported as Chrome trace JSON.

Zero-dependency (stdlib only): the spawned PS shard workers import this
through :mod:`repro.ps.server`'s numpy-only path.  Events follow the
Chrome trace-event format (load the exported file in Perfetto /
``chrome://tracing``):

* :func:`span` — a ``with``-scoped complete event (``ph="X"``) carrying
  wall duration, pid/tid lanes and arbitrary JSON-safe args;
* :func:`instant` — a zero-duration marker (``ph="i"``) for lifecycle
  events (fleet join/kill/recover, evictions);
* :class:`TraceBuffer` — bounded ring of event dicts.  The process-global
  :data:`BUFFER` backs the main timeline; a PS shard server keeps its
  *own* buffer and ships it back over the transport's ``obs`` op
  (:meth:`repro.ps.transport.Transport.collect_obs`), where the events —
  stamped with the worker's pid at record time — merge into the global
  buffer as distinct process lanes.

Timestamps are ``time.perf_counter_ns()`` microseconds.  On Linux that
clock is CLOCK_MONOTONIC, which is system-wide: events recorded in
different processes on one machine share a timeline, so the merged trace
needs no cross-process clock alignment (per-lane monotonicity is pinned
in ``tests/test_obs.py``).

Enabled state mirrors :mod:`repro.obs.metrics`: off by default, flipped
by ``repro.obs.configure`` (which also sets ``REPRO_OBS`` so workers
spawned afterwards inherit it).  Disabled, :func:`span` returns a shared
no-op context manager — one branch + no allocation.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque

from repro.obs import metrics as _metrics

#: default ring capacity — bounds trace memory on long runs (oldest
#: events fall off; a serve/train session keeps the recent window)
DEFAULT_CAPACITY = 65536

_enabled = _metrics.env_enabled()


def enabled() -> bool:
    return _enabled


def set_enabled(flag: bool) -> None:
    global _enabled
    _enabled = bool(flag)


def now_us() -> float:
    return time.perf_counter_ns() / 1e3


class TraceBuffer:
    """Bounded, thread-safe ring of Chrome trace-event dicts."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self._events: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()

    def add(self, event: dict) -> None:
        with self._lock:
            self._events.append(event)

    def extend(self, events) -> None:
        with self._lock:
            self._events.extend(events)

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def drain(self) -> list[dict]:
        with self._lock:
            out = list(self._events)
            self._events.clear()
        return out

    def __len__(self) -> int:
        return len(self._events)


#: the process-global timeline every un-buffered span lands in
BUFFER = TraceBuffer()


class _NoopSpan:
    __slots__ = ()

    @property
    def args(self):
        # fresh throwaway dict so call sites can annotate span args
        # (`sp.args["dropped"] = n`) without checking the enabled switch
        return {}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("name", "cat", "args", "buf", "t0")

    def __init__(self, name, cat, buf, args):
        self.name = name
        self.cat = cat
        self.buf = buf
        self.args = args

    def __enter__(self):
        self.t0 = now_us()
        return self

    def __exit__(self, *exc):
        t1 = now_us()
        self.buf.add({
            "name": self.name, "cat": self.cat, "ph": "X",
            "ts": self.t0, "dur": t1 - self.t0,
            "pid": os.getpid(), "tid": threading.get_native_id(),
            "args": self.args,
        })
        return False


def span(name: str, cat: str = "repro", *, buffer: TraceBuffer | None = None,
         **args):
    """``with span("serve.prefill", rid=3): ...`` — records a complete
    event on exit.  Near-free when disabled (shared no-op object)."""
    if not _enabled:
        return _NOOP
    return _Span(name, cat, buffer if buffer is not None else BUFFER, args)


def instant(name: str, cat: str = "repro", *,
            buffer: TraceBuffer | None = None, **args) -> None:
    """Zero-duration marker (lifecycle events)."""
    if not _enabled:
        return
    (buffer if buffer is not None else BUFFER).add({
        "name": name, "cat": cat, "ph": "i", "s": "p",
        "ts": now_us(),
        "pid": os.getpid(), "tid": threading.get_native_id(),
        "args": args,
    })


def label_process(name: str, *, buffer: TraceBuffer | None = None) -> None:
    """Name this process's pid lane in the merged trace (``ph="M"``)."""
    (buffer if buffer is not None else BUFFER).add({
        "name": "process_name", "ph": "M", "ts": 0,
        "pid": os.getpid(), "tid": threading.get_native_id(),
        "args": {"name": name},
    })


def merged(*event_lists) -> list[dict]:
    """Merge event lists onto one timeline: metadata first, then events
    sorted by timestamp — which makes every (pid, tid) lane monotonic."""
    meta, evs = [], []
    for lst in event_lists:
        for e in lst:
            (meta if e.get("ph") == "M" else evs).append(e)
    evs.sort(key=lambda e: e.get("ts", 0.0))
    return meta + evs


def chrome_trace(events: list[dict]) -> dict:
    """Wrap merged events in the Chrome trace-event envelope."""
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome(path: str, *event_lists) -> str:
    """Merge ``event_lists`` (default: the global buffer) and write a
    Perfetto-loadable Chrome trace JSON.  Returns the path."""
    if not event_lists:
        event_lists = (BUFFER.events(),)
    with open(path, "w") as f:
        json.dump(chrome_trace(merged(*event_lists)), f, default=str)
    return path
