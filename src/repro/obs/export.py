"""Run-directory export: JSONL metric snapshots + Chrome trace files.

One run directory holds the whole session's observability output:

* ``metrics.jsonl`` — append-only; each line is one timestamped snapshot
  of every live registry (:func:`repro.obs.metrics.snapshot_all`), so a
  run's metric trajectory is greppable / loadable with one
  ``json.loads`` per line;
* ``trace.json`` — the merged Chrome trace (main-process buffer + any
  worker events already collected into it), loadable in Perfetto.

Stdlib-only, like the rest of the obs spine.
"""

from __future__ import annotations

import json
import os
import time

from repro.obs import metrics, trace

METRICS_FILE = "metrics.jsonl"
TRACE_FILE = "trace.json"


def metrics_snapshot(extra: dict | None = None) -> dict:
    """One timestamped snapshot of every live registry."""
    snap = {"unix_ts": time.time(), "registries": metrics.snapshot_all()}
    if extra:
        snap["extra"] = extra
    return snap


def write_metrics(run_dir: str, extra: dict | None = None) -> str:
    """Append one snapshot line to ``<run_dir>/metrics.jsonl``."""
    os.makedirs(run_dir, exist_ok=True)
    path = os.path.join(run_dir, METRICS_FILE)
    with open(path, "a") as f:
        f.write(json.dumps(metrics_snapshot(extra), default=str) + "\n")
    return path


def write_trace(run_dir: str, *event_lists) -> str:
    """Write the merged Chrome trace to ``<run_dir>/trace.json``."""
    os.makedirs(run_dir, exist_ok=True)
    path = os.path.join(run_dir, TRACE_FILE)
    return trace.write_chrome(path, *event_lists)


def read_metrics(run_dir: str) -> list[dict]:
    path = os.path.join(run_dir, METRICS_FILE)
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def read_trace(run_dir: str) -> dict:
    with open(os.path.join(run_dir, TRACE_FILE)) as f:
        return json.load(f)
