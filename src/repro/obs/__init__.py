"""Unified tracing + metrics spine (``repro.obs``).

Zero-dependency observability for the whole system: thread-safe metric
registries with streaming quantiles (:mod:`repro.obs.metrics`), ring-
buffered span tracing exported as Perfetto-loadable Chrome trace JSON —
including per-process buffers shipped back from spawned PS shard workers
(:mod:`repro.obs.trace`), run-directory export (:mod:`repro.obs.export`)
and the live-metrics → cost-model bridge (:mod:`repro.obs.bridge`).

Session control lives here:

* :func:`configure` — enable/disable instrumentation and pick a run
  directory; sets ``REPRO_OBS`` so shard workers spawned afterwards
  inherit the state;
* :func:`enabled` — the one branch every instrumentation site checks;
* :func:`flush` — write ``trace.json`` + append a ``metrics.jsonl``
  snapshot to the configured run directory.

The package init stays jax-free (and ``metrics``/``trace`` are stdlib-
only): the spawned PS shard worker imports this through
``repro.ps.server``'s numpy-only path — pinned in
``tests/test_ps_transport.py``.  ``bridge`` (which touches
``repro.core``) resolves lazily.
"""

from __future__ import annotations

import os

from repro.obs import metrics, trace
from repro.obs.metrics import REGISTRY, Registry
from repro.obs.trace import BUFFER, instant, span

__all__ = [
    "BUFFER", "REGISTRY", "Registry", "configure", "enabled", "flush",
    "instant", "metrics", "run_dir", "snapshot_resources", "span", "trace",
]

_run_dir: str | None = None


def enabled() -> bool:
    return trace.enabled()


def run_dir() -> str | None:
    return _run_dir


def configure(*, enabled: bool | None = None,
              run_dir: str | None = None) -> None:
    """Flip instrumentation on/off and/or set the export directory.

    Passing ``run_dir`` implies ``enabled=True`` unless overridden.
    The enabled state is mirrored into the ``REPRO_OBS`` environment
    variable so shard worker processes spawned from here on inherit it.
    """
    global _run_dir
    if run_dir is not None:
        _run_dir = run_dir
        if enabled is None:
            enabled = True
    if enabled is not None:
        trace.set_enabled(enabled)
        REGISTRY.enabled = enabled
        os.environ["REPRO_OBS"] = "1" if enabled else "0"


def flush(extra: dict | None = None) -> dict | None:
    """Export the session to the configured run directory: write the
    merged Chrome trace and append one metrics snapshot.  Returns the
    paths (``None`` when no run directory is configured)."""
    if _run_dir is None:
        return None
    from repro.obs import export

    return {"trace": export.write_trace(_run_dir),
            "metrics": export.write_metrics(_run_dir, extra)}


def snapshot_resources(base, **kw):
    """Lazy re-export of :func:`repro.obs.bridge.snapshot_resources`
    (keeps ``repro.core`` out of the shard-worker import path)."""
    from repro.obs.bridge import snapshot_resources as _snap

    return _snap(base, **kw)
