"""Thread-safe metric primitives — the measurement half of ``repro.obs``.

Zero-dependency (stdlib only — a spawned PS shard worker imports this
module through :mod:`repro.ps.server`'s numpy-only path, so neither jax
nor numpy may appear here).  Three metric kinds behind one
:class:`Registry`:

* :class:`Counter` — monotonically increasing float/int accumulator;
* :class:`Gauge` — last-written value (queue depth, pool occupancy);
* :class:`Histogram` — streaming distribution with bounded-relative-error
  quantiles: values land in geometric buckets of growth ``GROWTH``
  (≈9%/bucket), so any reported quantile is within a factor ``GROWTH`` of
  the true order statistic — the invariant the hypothesis property tests
  pin.  Exact ``min``/``max``/``sum``/``count`` ride along.

Registries are *near-free when disabled*: every mutator's first action is
one attribute check on the owning registry, so a disabled registry costs
an attribute load + branch per call site and records nothing.  The
module-level :data:`REGISTRY` is the default sink for instrumentation
and starts disabled unless the ``REPRO_OBS`` environment variable is set
(how spawned shard workers inherit the session's obs state); subsystems
whose counters are load-bearing (``PSTelemetry`` — the cost-model bridge
reads them) create private always-enabled registries instead.
"""

from __future__ import annotations

import math
import os
import threading
import weakref
from typing import Sequence

#: geometric bucket growth: quantiles are exact within this factor
GROWTH = 2.0 ** 0.125            # ≈ 1.0905 → ≤ ~9% relative error
_LOG_G = math.log(GROWTH)
#: lower edge of bucket 0 — values at or below land in the floor bucket
#: and report the exact observed minimum (1 ns in seconds units)
FLOOR = 1e-9

#: every live registry, for whole-process snapshots (weak: a registry
#: dies with its owner — e.g. a closed table's telemetry)
_REGISTRIES: "weakref.WeakSet[Registry]" = weakref.WeakSet()
_REG_LOCK = threading.Lock()


def env_enabled() -> bool:
    """Initial enabled state: the ``REPRO_OBS`` env var (``1``/``true``).
    Spawned worker processes inherit it, which is how a shard server
    knows the parent session configured observability."""
    return os.environ.get("REPRO_OBS", "").lower() in ("1", "true", "on")


class Counter:
    """Monotonic accumulator (float adds, so fractional seconds work)."""

    __slots__ = ("_reg", "_lock", "_v")

    def __init__(self, registry: "Registry"):
        self._reg = registry
        self._lock = threading.Lock()
        self._v = 0.0

    def inc(self, n: float = 1.0) -> None:
        if not self._reg.enabled:
            return
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        return self._v

    def snapshot(self) -> dict:
        return {"value": self._v}


class Gauge:
    """Last-written value."""

    __slots__ = ("_reg", "_v")

    def __init__(self, registry: "Registry"):
        self._reg = registry
        self._v = 0.0

    def set(self, v: float) -> None:
        if not self._reg.enabled:
            return
        self._v = float(v)

    @property
    def value(self) -> float:
        return self._v

    def snapshot(self) -> dict:
        return {"value": self._v}


class Histogram:
    """Streaming distribution over positive values (latencies, sizes).

    Values fall into geometric buckets ``[FLOOR·G^i, FLOOR·G^(i+1))``;
    :meth:`quantile` walks the cumulative counts to the requested rank
    and returns the bucket's geometric midpoint clamped to the exact
    observed ``[min, max]`` — guaranteed within a factor :data:`GROWTH`
    of the true order statistic (values ≤ :data:`FLOOR` are floored and
    report the exact minimum).
    """

    __slots__ = ("_reg", "_lock", "_buckets", "count", "total",
                 "_min", "_max")

    def __init__(self, registry: "Registry"):
        self._reg = registry
        self._lock = threading.Lock()
        self._buckets: dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self._min = math.inf
        self._max = -math.inf

    @staticmethod
    def bucket_of(v: float) -> int:
        if v <= FLOOR:
            return -1                     # floor bucket
        return int(math.log(v / FLOOR) // _LOG_G)

    def record(self, v: float) -> None:
        if not self._reg.enabled:
            return
        v = float(v)
        b = self.bucket_of(v)
        with self._lock:
            self._buckets[b] = self._buckets.get(b, 0) + 1
            self.count += 1
            self.total += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def min(self) -> float:
        return self._min if self.count else 0.0

    @property
    def max(self) -> float:
        return self._max if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` ∈ [0, 1] (within a factor GROWTH)."""
        with self._lock:
            if not self.count:
                return 0.0
            if q <= 0.0:
                return self._min
            if q >= 1.0:
                return self._max
            # rank of the order statistic ceil(q·n) (1-based), 0-indexed
            rank = min(self.count - 1, max(0, math.ceil(q * self.count) - 1))
            cum = 0
            for b in sorted(self._buckets):
                cum += self._buckets[b]
                if cum > rank:
                    if b < 0:
                        return self._min   # floored values: min is exact
                    est = FLOOR * math.exp((b + 0.5) * _LOG_G)
                    return min(max(est, self._min), self._max)
            return self._max               # unreachable, defensively

    def percentiles(self) -> dict:
        return {"p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        with self._lock:
            count, total = self.count, self.total
        return {"count": count, "sum": total,
                "mean": total / count if count else 0.0,
                "min": self.min, "max": self.max, **self.percentiles()}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Registry:
    """Get-or-create store of named, labeled metrics.

    ``enabled`` gates every mutator of every owned metric: a disabled
    registry's counters/gauges/histograms record nothing and cost one
    branch per call.  Reads (``snapshot``/``value``) always work.
    """

    def __init__(self, name: str = "default", *, enabled: bool = True):
        self.name = name
        self.enabled = enabled
        #: set by :meth:`close` when the owning subsystem shuts down —
        #: live-state aggregators (``bridge._ps_traffic``) skip closed
        #: registries so a finished client's cumulative traffic can't
        #: bleed into a later snapshot's bandwidths; whole-run exports
        #: (``snapshot_all``) still include them as history
        self.closed = False
        self._lock = threading.Lock()
        #: (kind, name, labels-tuple) → metric
        self._metrics: dict[tuple, object] = {}
        with _REG_LOCK:
            _REGISTRIES.add(self)

    def close(self) -> None:
        """Mark this registry as belonging to a shut-down owner.  Reads
        keep working (history), but :func:`live_registries` — and with it
        the live-metrics bridge — stops aggregating it.  Idempotent."""
        self.closed = True

    # --- get-or-create ---------------------------------------------------
    def _get(self, kind: str, name: str, labels: dict):
        key = (kind, name, tuple(sorted(labels.items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                clash = next((k for k in self._metrics
                              if k[1] == name and k[0] != kind), None)
                if clash is not None:
                    raise TypeError(
                        f"metric {name!r} already registered as {clash[0]}")
                m = self._metrics[key] = _KINDS[kind](self)
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", name, labels)

    # --- reads -----------------------------------------------------------
    def find(self, name: str) -> list[tuple[dict, object]]:
        """All (labels, metric) pairs registered under ``name``."""
        with self._lock:
            return [(dict(k[2]), m) for k, m in self._metrics.items()
                    if k[1] == name]

    def value(self, name: str, default: float = 0.0, **labels) -> float:
        key_labels = tuple(sorted(labels.items()))
        with self._lock:
            for (kind, n, lab), m in self._metrics.items():
                if n == name and lab == key_labels and kind != "histogram":
                    return m.value
        return default

    def snapshot(self) -> list[dict]:
        with self._lock:
            items = list(self._metrics.items())
        return [{"name": name, "type": kind, "labels": dict(labels),
                 **m.snapshot()}
                for (kind, name, labels), m in sorted(
                    items, key=lambda kv: (kv[0][1], kv[0][2]))]

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


def all_registries() -> list[Registry]:
    with _REG_LOCK:
        return sorted(_REGISTRIES, key=lambda r: r.name)


def live_registries() -> list[Registry]:
    """Every registry whose owner has not been closed — the set
    *current-state* aggregation (the cost-model bridge) must use, as
    opposed to whole-run exports which want closed history too."""
    return [r for r in all_registries() if not r.closed]


def merge_histograms(hists: Sequence[Histogram]) -> dict:
    """One :meth:`Histogram.snapshot`-shaped dict over the union of
    several histograms' samples, as if every value had been recorded into
    a single histogram (bucket counts add; the quantile walk is the same
    as :meth:`Histogram.quantile`, so the GROWTH error bound holds
    against the pooled sample).  The aggregation fix for ``find()``
    matching multiple labeled histograms under one metric name."""
    buckets: dict[int, int] = {}
    count, total = 0, 0.0
    mn, mx = math.inf, -math.inf
    for h in hists:
        with h._lock:
            for b, n in h._buckets.items():
                buckets[b] = buckets.get(b, 0) + n
            count += h.count
            total += h.total
            mn = min(mn, h._min)
            mx = max(mx, h._max)
    if not count:
        return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0, "max": 0.0,
                "p50": 0.0, "p95": 0.0, "p99": 0.0}

    def quantile(q: float) -> float:
        if q <= 0.0:
            return mn
        if q >= 1.0:
            return mx
        rank = min(count - 1, max(0, math.ceil(q * count) - 1))
        cum = 0
        for b in sorted(buckets):
            cum += buckets[b]
            if cum > rank:
                if b < 0:
                    return mn
                est = FLOOR * math.exp((b + 0.5) * _LOG_G)
                return min(max(est, mn), mx)
        return mx

    return {"count": count, "sum": total, "mean": total / count,
            "min": mn, "max": mx, "p50": quantile(0.50),
            "p95": quantile(0.95), "p99": quantile(0.99)}


def snapshot_all() -> dict:
    """``{registry_name: snapshot}`` over every live registry (named
    collisions merge under one key in creation order)."""
    out: dict[str, list] = {}
    for reg in all_registries():
        snap = reg.snapshot()
        if not snap:
            continue
        out.setdefault(reg.name, []).extend(snap)
    return out


#: default sink for optional instrumentation (serve/train/client spans'
#: metric twins) — disabled unless the session configured observability
REGISTRY = Registry("default", enabled=env_enabled())
