"""HeterPS stage pipeline — GPipe-style schedule on a ``stage`` mesh axis.

The paper (§3, §5.1) partitions the model into stages (consecutive layers
on one resource type, from the scheduling plan), runs data parallelism
*within* a stage and pipeline parallelism *between* stages, with
microbatches flowing stage-to-stage.  TPU mapping (DESIGN.md §2): stages
live on submeshes of the pod — here a dedicated ``stage`` mesh axis —
and activations move with ``jax.lax.ppermute`` (ICI neighbor hops).

The schedule is the classic fill/drain loop: ``T = M + S - 1`` ticks for
``M`` microbatches over ``S`` stages; at tick ``t`` stage ``s`` computes
microbatch ``t - s``.  The loop is differentiable (ppermute transposes to
the reverse permutation), so ``jax.grad`` of the pipelined loss yields
the backward pipeline automatically — 1F1B-style scheduling is left to
XLA's latency-hiding scheduler on real hardware.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# top-level jax.shard_map arrived after 0.4.x
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map


def make_stage_mesh(num_stages: int):
    from repro.launch.mesh import make_mesh_compat

    return make_mesh_compat((num_stages,), ("stage",))


def pipeline_apply(
    stage_fn: Callable,
    stage_params,
    microbatches,
    mesh,
    *,
    axis: str = "stage",
):
    """Run ``microbatches`` through the stage pipeline.

    stage_fn: (params_one_stage, x (mb, d)) → y (mb, d) — the same
      callable for every stage (heterogeneity lives in the params).
    stage_params: pytree with leading dim = num_stages (one slice per
      stage, produced from the scheduling plan's stage partition).
    microbatches: (M, mb, d) — M microbatches.
    Returns (M, mb, d_out): the last stage's outputs, microbatch order.
    """
    S = mesh.shape[axis]
    M = microbatches.shape[0]
    T = M + S - 1
    fwd_perm = [(i, i + 1) for i in range(S - 1)]

    def body(params_blk, xs):
        params_local = jax.tree.map(lambda a: a[0], params_blk)
        sidx = jax.lax.axis_index(axis)
        state = jnp.zeros_like(
            jax.eval_shape(lambda p, x: stage_fn(p, x), params_local, xs[0])
        )
        outs = []
        for t in range(T):
            mb_idx = min(t, M - 1)
            inp = jnp.where(sidx == 0, xs[mb_idx], state)
            y = stage_fn(params_local, inp)
            outs.append(y)
            if t < T - 1:
                state = jax.lax.ppermute(y, axis, fwd_perm)
        # microbatch m exits the last stage at tick m + S - 1
        stacked = jnp.stack(outs[S - 1 :], axis=0)  # (M, mb, d)
        return stacked[None]  # (1, M, mb, d) per-stage block

    out = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P(None)),
        out_specs=P(axis),
    )(stage_params, microbatches)
    return out[-1]  # the last stage's collected outputs


def pipeline_loss(
    stage_fn: Callable,
    loss_fn: Callable,
    stage_params,
    microbatches,
    labels,
    mesh,
    *,
    axis: str = "stage",
):
    """Differentiable pipelined loss: mean over microbatches of
    ``loss_fn(last_stage_out, labels_mb)``.  ``jax.grad`` of this w.r.t.
    ``stage_params`` backpropagates through the ppermute chain — the
    backward pipeline."""
    outs = pipeline_apply(stage_fn, stage_params, microbatches, mesh, axis=axis)
    losses = jax.vmap(loss_fn)(outs, labels)
    return losses.mean()


def stack_stage_params(per_stage: list):
    """[stage pytrees with identical structure] → stacked (S, …) pytree."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_stage)
