"""Activation sharding constraints, mesh-context aware.

XLA's SPMD propagation can resolve the (batch over ``data``) × (params
FSDP-sharded over ``data``) conflict in the wrong direction — replicating
activations and all-gathering the batch instead of the weights.  These
helpers re-anchor activations to batch sharding at block boundaries.
They no-op when no mesh is active (single-device smoke tests) or when a
dim doesn't divide.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def current_mesh():
    import warnings

    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            return am
    except Exception:
        pass
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            m = jax.interpreters.pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def _axes_for_batch(mesh, n: int):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    prod = 1
    for a in axes:
        prod *= mesh.shape[a]
    if axes and prod and n % prod == 0:
        return axes
    return None


def _model_axis(mesh, n: int):
    if "model" in mesh.axis_names and n % mesh.shape["model"] == 0:
        return "model"
    return None


def shard_batch_act(x):
    """(B, …) activations: batch over ("pod","data")."""
    mesh = current_mesh()
    if mesh is None:
        return x
    axes = _axes_for_batch(mesh, x.shape[0])
    if axes is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(axes, *([None] * (x.ndim - 1)))
    )


def shard_logits(x):
    """(B, S, V) logits: batch over data axes, vocab over model."""
    mesh = current_mesh()
    if mesh is None:
        return x
    axes = _axes_for_batch(mesh, x.shape[0])
    vax = _model_axis(mesh, x.shape[-1])
    if axes is None and vax is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(axes, *([None] * (x.ndim - 2)), vax)
    )


def shard_heads(x, *, axis: int):
    """Constrain the head dim of an attention intermediate to the model
    axis (keeps tensor parallelism through the score einsums); batch dim 0
    stays on the data axes."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = [None] * x.ndim
    spec[0] = _axes_for_batch(mesh, x.shape[0])
    spec[axis] = _model_axis(mesh, x.shape[axis])
    if spec[0] is None and spec[axis] is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_last_dim(x):
    """(B, …, F) hiddens: batch over data axes, feature over model —
    forces Megatron column-parallel FFN/state layout (no all-reduce of
    the wide hidden)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = [None] * x.ndim
    spec[0] = _axes_for_batch(mesh, x.shape[0])
    spec[-1] = _model_axis(mesh, x.shape[-1])
    if spec[0] is None and spec[-1] is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def gather_params(p, cfg):
    """Explicit FSDP: constrain each weight to its *compute* layout —
    model-axis sharding only, data-axis replicated.  XLA then all-gathers
    the FSDP-sharded weights (cotangent: reduce-scatter) instead of
    un-sharding the activations' batch dim."""
    mesh = current_mesh()
    if mesh is None:
        return p
    model = mesh.shape.get("model", 1)
    from repro.parallel.sharding import _spec_for

    def f(path, leaf):
        if leaf.ndim < 2 or not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        spec = _spec_for(path, leaf, data=1, model=model, d_ff=cfg.d_ff)
        return jax.lax.with_sharding_constraint(leaf, spec)

    return jax.tree_util.tree_map_with_path(f, p)


def shard_moe_group_buffer(x):
    """(G, E, C, D) grouped expert buffers: groups over the data axes,
    experts over the model axis."""
    mesh = current_mesh()
    if mesh is None:
        return x
    gax = _axes_for_batch(mesh, x.shape[0])
    eax = _model_axis(mesh, x.shape[1])
    if gax is None and eax is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(gax, eax, *([None] * (x.ndim - 2)))
    )
