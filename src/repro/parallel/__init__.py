"""Distribution: sharding rules, HeterPS stage pipeline, PS-style sparse path."""
