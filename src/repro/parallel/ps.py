"""Parameter-server-style sparse embedding path (HeterPS §3).

The paper keeps huge sparse embedding tables on CPU parameter servers:
workers *pull* only the touched rows, compute, and *push* sparse row
gradients back.  TPU mapping (DESIGN.md §2): the table is vocab-sharded
across the mesh; lookups are XLA gathers against the sharded table
(pull), and the update applies a COO scatter-add of (ids, row-grads)
without ever materializing a dense gradient (push).  The dense-layer
path, by contrast, allreduces full gradients — the paper's
ring-allreduce side.

``sparse_pull``/``sparse_push`` are jit-compatible and differentiable
building blocks; :class:`SparseEmbedding` packages them with a
row-frequency hook for the data-management tier monitor.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def sparse_pull(table, ids):
    """Pull rows: (V, D)[ids (…,)] → (…, D).  JAX's gather VJP is already
    the sparse push we want — a scatter-add of the touched rows' cotangent
    into a zero table (XLA keeps it as a scatter; no dense gradient
    materializes beyond the table-shaped accumulator)."""
    return table[ids]


def sparse_push(table, ids, row_grads, *, lr: float):
    """PS push: apply row gradients to the table without a dense grad.
    ids: (N,), row_grads: (N, D)."""
    return table.at[ids].add((-lr * row_grads).astype(table.dtype))


def segment_rowsum(ids, row_grads, *, num_rows: int):
    """Aggregate duplicate ids before the push (the PS's reduce step)."""
    return (
        jnp.zeros((num_rows, row_grads.shape[-1]), row_grads.dtype)
        .at[ids]
        .add(row_grads)
    )


def dedup_rows(ids, row_grads, *, fill_id: int):
    """Reduce an (ids, row_grads) COO stream to one entry per distinct id.

    jit-compatible (fixed output size: padding slots get id ``fill_id``
    and zero rows — push them with ``mode="drop"``).  Returns sorted
    unique ids ``(N,)`` and per-id summed rows ``(N, D)``; duplicates are
    accumulated in stream order via :func:`segment_rowsum`, so a push of
    the result is bit-identical to a dense-table segment sum.
    """
    ids = ids.reshape(-1)
    uids, inv = jnp.unique(ids, return_inverse=True, size=ids.size,
                           fill_value=fill_id)
    return uids, segment_rowsum(inv.reshape(-1), row_grads, num_rows=ids.size)


class SparseEmbedding:
    """Vocab-sharded embedding with PS-style sparse update + access stats."""

    def __init__(self, vocab: int, dim: int, key, *, monitor=None):
        self.vocab = vocab
        self.dim = dim
        self.table = jax.random.normal(key, (vocab, dim)) * (dim**-0.5)
        self.monitor = monitor  # repro.data.cache.AccessMonitor

    def lookup(self, ids):
        if self.monitor is not None:
            self.monitor.record(np.asarray(ids))
        return sparse_pull(self.table, ids)

    def apply_sparse_grads(self, ids, row_grads, *, lr: float,
                           dedup: bool = True):
        """Push row gradients.  With ``dedup`` (default) duplicate ids are
        aggregated once via :func:`dedup_rows` before the scatter, so an
        adaptive optimizer sitting on the PS sees each row exactly once
        per step.  ``dedup=False`` keeps the raw scatter-add of every
        occurrence — for plain SGD the two are an equal row sum (the
        SGD-sum equivalence), and tests pin that.
        """
        ids_flat = ids.reshape(-1)
        g_flat = row_grads.reshape(-1, self.dim)
        if dedup:
            uids, summed = dedup_rows(ids_flat, g_flat, fill_id=self.vocab)
            self.table = self.table.at[uids].add(
                (-lr * summed).astype(self.table.dtype), mode="drop")
        else:
            self.table = sparse_push(self.table, ids_flat, g_flat, lr=lr)
        return self.table
