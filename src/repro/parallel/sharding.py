"""Parameter / batch / cache sharding rules for the production mesh.

Baseline layout (recorded as such in EXPERIMENTS.md §Roofline):

* 2-D param sharding — tensor-parallel over ``model`` and FSDP-style over
  ``data`` wherever both dims divide evenly (Megatron × ZeRO hybrid); the
  ``pod`` axis is pure data parallelism (params replicated across pods).
* batch shards over ``("pod", "data")``; a batch of 1 (``long_500k``)
  replicates batch and shards the KV-cache *length* over ``data``.
* optimizer moments mirror the param specs (ZeRO falls out for free).

Rules are name/shape driven so one function covers every architecture's
parameter tree; anything unmatched (scalars, tiny LoRA factors, router
weights) is replicated.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

#: 2-D weights whose *input* dim contracts on the model axis (output
#: projections): shard (model, data).  Everything else 2-D that divides
#: evenly shards (data, model).
_OUT_PROJ_NAMES = {"wo", "w2", "out_proj"}


def _axis_sizes(mesh) -> tuple[int, int]:
    data = mesh.shape.get("data", 1)
    model = mesh.shape.get("model", 1)
    return data, model


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _spec_for(path: tuple, leaf, *, data: int, model: int, d_ff: int,
              stacked: bool = False) -> P:
    names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    name = names[-1] if names else ""
    shape = leaf.shape

    if stacked and "blocks" in names:
        # stacked pattern-block leaves carry a leading ``repeats`` dim:
        # compute the spec for the per-layer shape and prepend None.
        inner = _spec_for(
            path, jax.ShapeDtypeStruct(shape[1:], leaf.dtype),
            data=data, model=model, d_ff=d_ff, stacked=False,
        )
        return P(None, *inner)

    if len(shape) <= 1:
        return P()  # scalars & vectors: replicate (tiny)

    if name == "embed":
        # vocab → model (PS-style sharded table), d_model → data (ZeRO)
        return P("model" if _div(shape[0], model) else None,
                 "data" if _div(shape[1], data) else None)
    if name == "lm_head":
        return P("data" if _div(shape[0], data) else None,
                 "model" if _div(shape[1], model) else None)
    if name == "pos":
        return P(None, "model" if _div(shape[1], model) else None)

    if len(shape) == 3:  # MoE expert weights (E, in, out)
        e = "model" if _div(shape[0], model) else None
        if name == "w2":  # (E, F, D): F contracts; shard D over data
            return P(e, None, "data" if _div(shape[2], data) else None)
        return P(e, "data" if _div(shape[1], data) else None, None)

    if len(shape) == 2:
        out_proj = name in _OUT_PROJ_NAMES or (
            name == "wv" and shape[0] == d_ff  # rwkv channel-mix value proj
        )
        if out_proj:
            return P("model" if _div(shape[0], model) else None,
                     "data" if _div(shape[1], data) else None)
        return P("data" if _div(shape[0], data) else None,
                 "model" if _div(shape[1], model) else None)

    return P()


def param_specs(params, cfg, mesh) -> Any:
    """PartitionSpec pytree matching ``params`` (works on templates too)."""
    data, model = _axis_sizes(mesh)

    def f(path, leaf):
        return _spec_for(path, leaf, data=data, model=model, d_ff=cfg.d_ff,
                         stacked=True)

    return jax.tree_util.tree_map_with_path(f, params)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_specs(batch_template, mesh, *, batch_size: int) -> Any:
    """Shard the batch dim over ("pod","data") when divisible, else
    replicate (the ``long_500k`` B=1 case)."""
    axes = batch_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    lead = axes if _div(batch_size, total) else None

    def f(leaf):
        return P(lead, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(f, batch_template)


def cache_specs(cache_template, cfg, mesh, *, batch_size: int) -> Any:
    """Decode-cache sharding.  Leaves are stacked (repeats, B, …).

    * batch divisible → shard B over ("pod","data") and the KV cache
      *length* over model — flash-decode style: the q·K score contraction
      is then fully local per shard (only per-shard softmax stats/logits
      cross the mesh) instead of all-gathering K/V every layer (§Perf
      cycle 1: 103 GB/dev → logits-sized collectives on internlm2
      decode_32k);
    * B=1 (long_500k) → additionally shard the length over data.
    """
    axes = batch_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    shard_batch = _div(batch_size, total)
    data, model = _axis_sizes(mesh)

    def f(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1]
        s = [None] * leaf.ndim
        if shard_batch:
            s[1] = axes
        if name in ("k", "v", "ck", "cv"):
            # (repeats, B, L, KV, hd): L over model (+ data when B=1)
            if shard_batch:
                if _div(leaf.shape[2], model):
                    s[2] = "model"
            else:
                l_axes = tuple(a for a, n in (("data", data), ("model", model))
                               if _div(leaf.shape[2], n))
                if _div(leaf.shape[2], data * model):
                    s[2] = ("data", "model")
                elif l_axes:
                    s[2] = l_axes[0]
        elif name == "pos":
            if shard_batch:
                if _div(leaf.shape[2], model):
                    s[2] = "model"
            elif _div(leaf.shape[2], data * model):
                s[2] = ("data", "model")
            elif _div(leaf.shape[2], data):
                s[2] = "data"
        elif name in ("h", "conv"):           # mamba (…, din, N) / (…, W, din)
            din_axis = 2 if name == "h" else 3
            if _div(leaf.shape[din_axis], model):
                s[din_axis] = "model"
        elif name == "state":                 # rwkv (repeats, B, H, hd, hd)
            if _div(leaf.shape[2], model):
                s[2] = "model"
        elif name in ("tm_shift", "cm_shift"):
            if _div(leaf.shape[-1], model):
                s[-1] = "model"
        return P(*s)

    return jax.tree_util.tree_map_with_path(f, cache_template)
