"""Roofline table (deliverable g): per (arch × shape), the three roofline
terms from the compiled single-pod dry-run + MODEL_FLOPS/HLO_FLOPs ratio.

Reads results/dryrun_single_pod.json (produced by
``python -m repro.launch.dryrun --all --out results/dryrun_single_pod.json``);
rows marked missing if the dry-run artifact isn't present.
"""

from __future__ import annotations

import json
import os

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.launch.specs import SHAPES
from repro.roofline import model_flops

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_single_pod.json")


def active_params(cfg) -> tuple[float, float]:
    """(total, active) params — active counts top_k/E of expert weights."""
    from repro.launch.specs import param_templates

    params_t, _ = param_templates(cfg)
    total = 0.0
    expert = 0.0

    def visit(path, leaf):
        nonlocal total, expert
        n = float(np.prod(leaf.shape))
        total += n
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        if leaf.ndim == 4 and "ffn" in names:  # stacked (R, E, …) experts
            expert += n

    jax.tree_util.tree_map_with_path(visit, params_t)
    active = total - expert
    if cfg.has_moe and cfg.moe_experts:
        active += expert * cfg.moe_top_k / cfg.moe_experts
    return total, active


def run() -> None:
    if not os.path.exists(RESULTS):
        emit("roofline/missing", 0.0, f"run dryrun --all first ({RESULTS})")
        return
    with open(RESULTS) as f:
        records = json.load(f)
    for rec in records:
        name = f"roofline/{rec['arch']}/{rec['shape']}"
        if rec["status"] == "skipped":
            emit(name, 0.0, "skipped:" + rec["reason"][:40])
            continue
        if rec["status"] != "ok":
            emit(name, 0.0, "FAILED")
            continue
        from repro.roofline import PEAK_FLOPS, roofline_terms

        # raw (single-counted-loop) basis — matches render_roofline and
        # the EXPERIMENTS.md table; corrected compute floor separate.
        r = roofline_terms(
            flops=rec["cost"]["flops"],
            hbm_bytes=rec["cost"]["bytes_accessed"],
            collective_bytes=rec["collectives"]["total_bytes"],
        )
        corr = rec.get("scan_correction", 1)
        compute_corr = rec["cost"]["flops"] * corr / PEAK_FLOPS
        dom = r["dominant"]
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            total, active = active_params(cfg)
            mf = model_flops(active, tokens)  # fwd+bwd 6·N·D
            hlo_total = rec["cost"]["flops"] * corr * rec["num_devices"]
            ratio = mf / hlo_total if hlo_total else 0.0
        else:
            ratio = float("nan")
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(name, step_s * 1e6,
             f"dom={dom};compute_s={r['compute_s']:.4g};"
             f"memory_s={r['memory_s']:.4g};collective_s={r['collective_s']:.4g};"
             f"true_compute_s={compute_corr:.4g};"
             f"model_flops_ratio={ratio:.3f};"
             f"mem_gib={rec['memory']['peak_bytes_per_device'] / 2**30:.2f}")
