"""Render the EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
JSON artifacts.

  PYTHONPATH=src python -m benchmarks.render_roofline [results/dryrun_single_pod.json]
"""

from __future__ import annotations

import json
import sys


def render(path: str) -> str:
    with open(path) as f:
        records = json.load(f)
    from repro.roofline import PEAK_FLOPS, roofline_terms

    out = []
    out.append("| arch | shape | mem/dev GiB | HLO GFLOP/dev | HBM GB/dev | "
               "coll MB/dev | compute ms | memory ms | coll ms | dominant | "
               "true-compute ms | collectives |")
    out.append("|---|---|---:|---:|---:|---:|---:|---:|---:|---|---:|---|")
    for r in records:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — "
                       f"| — | skipped | — | {r['reason'][:45]} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAILED | | | | | | | | | |")
            continue
        # recompute terms on the raw (uncorrected) basis so old/new JSON
        # render identically; corrected compute floor shown separately.
        rf = roofline_terms(
            flops=r["cost"]["flops"],
            hbm_bytes=r["cost"]["bytes_accessed"],
            collective_bytes=r["collectives"]["total_bytes"],
        )
        corr = r["cost"]["flops"] * r.get("scan_correction", 1) / PEAK_FLOPS
        coll = r["collectives"]
        kinds = ",".join(f"{k.split('-')[-1][:4]}:{v}"
                         for k, v in sorted(coll["counts"].items()))
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['memory']['peak_bytes_per_device'] / 2**30:.2f} "
            f"| {r['cost']['flops'] / 1e9:.1f} "
            f"| {r['cost']['bytes_accessed'] / 1e9:.1f} "
            f"| {coll['total_bytes'] / 2**20:.1f} "
            f"| {rf['compute_s'] * 1e3:.2f} "
            f"| {rf['memory_s'] * 1e3:.2f} "
            f"| {rf['collective_s'] * 1e3:.2f} "
            f"| **{rf['dominant']}** "
            f"| {corr * 1e3:.1f} "
            f"| {kinds} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_single_pod.json"
    print(render(path))
