"""Chaos soak benchmark: fault masking, failure detection latency, and
crash-consistent checkpoint/restore under a seeded fault schedule.

Three gated measurements over the elastic CTR trainer:

* **masking** — a schedule of delayed / dropped / duplicated / transient
  faults must train to a **bit-exact** loss trajectory vs the fault-free
  run (the retry layer + server seq-dedup absorb everything), with the
  injected-fault and retry counts reported;
* **kill-both soak** — a correlated crash of a bucket's primary *and*
  backup mid-run must restore from the newest unified checkpoint and
  replay to the fault-free trajectory, with the soak's wall-clock
  overhead vs the calm run reported;
* **detection latency** — the multiproc heartbeat must notice a
  SIGKILLed worker (no request traffic at all) well inside its deadline.

  PYTHONPATH=src python benchmarks/bench_chaos.py [--smoke]
  PYTHONPATH=src python -m benchmarks.run --only chaos
"""

from __future__ import annotations

import argparse
import os
import signal
import tempfile
import time

try:
    from benchmarks.common import emit, write_artifact
except ImportError:   # direct `python benchmarks/bench_chaos.py` run
    from common import emit, write_artifact

#: every maskable fault kind, interleaved (same pins as
#: tests/test_chaos.py).  Each rule's budget stays below the retry
#: policy's max_attempts so no single request can burn every attempt —
#: more chaos comes from more windows, not bigger budgets.
MASK_SCHED = ("drop_reply,op=grad,after=10,times=2;"
              "drop_reply,op=grad,after=120,times=2;"
              "dup_reply,op=pull,after=5,times=2;"
              "dup_reply,op=pull,after=150,times=2;"
              "recv_error,after=30,times=2;"
              "recv_error,after=200,times=2;"
              "delay,delay_s=0.001,prob=0.3")

#: correlated primary+backup loss (attempt ~170 ≈ step 14 on 3 shards)
KILL_BOTH = ("crash,op=grad,shard=0,after=170,times=1;"
             "crash,op=grad,shard=1,after=170,times=1")


def _drift(a, b) -> float:
    return max(abs(x - y) for x, y in zip(a, b))


def bench_fault_masking(cfg, *, steps: int, fault_seed: int) -> None:
    from repro.ps.workload import train_ctr_elastic

    kw = dict(steps=steps, num_shards=3, optimizer="adagrad", mode="sync")
    t0 = time.perf_counter()
    base = train_ctr_elastic(cfg, **kw)
    calm_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    chaotic = train_ctr_elastic(cfg, **kw, fault_schedule=MASK_SCHED,
                                fault_seed=fault_seed)
    chaos_s = time.perf_counter() - t0
    n_inj = len(chaotic["injections"])
    retries = chaotic["transport_counters"]["retries"]
    drift = _drift(chaotic["losses"], base["losses"])
    emit("chaos_masked_faults", chaos_s / steps * 1e6,
         f"{n_inj} faults injected, {retries} retries, "
         f"{chaos_s / calm_s:.2f}x calm wall time")
    emit("chaos_masked_drift", drift * 1e6,
         f"max |loss drift| vs fault-free run = {drift:.2e} (target 0)")
    if n_inj == 0:
        raise RuntimeError("fault schedule never fired — dead benchmark")
    if drift != 0.0:
        raise RuntimeError(
            f"masked faults drifted the loss trajectory by {drift:.3e}")


def bench_kill_both_restore(cfg, *, steps: int, ckpt_every: int,
                            fault_seed: int) -> None:
    from repro.ps.workload import train_ctr_elastic

    kw = dict(steps=steps, num_shards=3, optimizer="adagrad", mode="sync")
    t0 = time.perf_counter()
    base = train_ctr_elastic(cfg, **kw)
    calm_s = time.perf_counter() - t0
    with tempfile.TemporaryDirectory(prefix="bench-chaos-ckpt-") as d:
        t0 = time.perf_counter()
        soak = train_ctr_elastic(cfg, **kw, fault_schedule=KILL_BOTH,
                                 fault_seed=fault_seed, ckpt_dir=d,
                                 ckpt_every=ckpt_every)
        soak_s = time.perf_counter() - t0
        residue = [e for e in os.listdir(d) if ".tmp-" in e]
    n_ckpt = len(soak["checkpoints"])
    ckpt_mb = sum(b for _, b in soak["checkpoints"]) / 1e6
    drift = _drift(soak["losses"], base["losses"])
    emit("chaos_killboth_restore", soak_s / steps * 1e6,
         f"{soak['restores']} restore(s), {n_ckpt} ckpts ({ckpt_mb:.1f}MB), "
         f"{soak_s / calm_s:.2f}x calm wall time")
    emit("chaos_killboth_drift", drift * 1e6,
         f"max |loss drift| after restore+replay = {drift:.2e} (target 0)")
    if soak["restores"] < 1:
        raise RuntimeError("kill-both schedule never forced a restore")
    if drift != 0.0:
        raise RuntimeError(
            f"restore+replay drifted the loss trajectory by {drift:.3e}")
    if residue:
        raise RuntimeError(f"checkpoint staging residue left behind: "
                           f"{residue}")


def bench_detection_latency(*, heartbeat_s: float = 0.05,
                            budget_s: float = 2.0) -> None:
    from repro.ps.transport import MultiprocTransport

    tr = MultiprocTransport(heartbeat_s=heartbeat_s)
    try:
        tr.add_shard(0, dim=8)
        tr.add_shard(1, dim=8)
        os.kill(tr._shards[0].proc.pid, signal.SIGKILL)
        t0 = time.perf_counter()
        while 0 in tr.live_shards:
            if time.perf_counter() - t0 > budget_s:
                raise RuntimeError(
                    f"heartbeat missed a SIGKILLed worker for {budget_s}s")
            time.sleep(0.005)
        latency = time.perf_counter() - t0
    finally:
        tr.close()
    emit("chaos_detection_latency", latency * 1e6,
         f"SIGKILL -> heartbeat eviction in {latency * 1e3:.0f}ms "
         f"(period {heartbeat_s * 1e3:.0f}ms, budget {budget_s:.1f}s)")


def run(smoke: bool = False, fault_seed: int | None = None) -> None:
    from repro.ps.workload import CTRConfig

    if fault_seed is None:
        fault_seed = int(os.environ.get("CHAOS_FAULT_SEED", "0"))
    if smoke:
        cfg = CTRConfig(vocab=5_000, emb_dim=8, slots=8, tower=(32,),
                        batch=64)
        steps = 30
    else:
        cfg = CTRConfig(vocab=50_000, emb_dim=16, slots=8, tower=(64,),
                        batch=128)
        steps = 60
    emit("chaos_seed", float(fault_seed), f"fault_seed={fault_seed}")
    bench_fault_masking(cfg, steps=steps, fault_seed=fault_seed)
    bench_kill_both_restore(cfg, steps=steps, ckpt_every=5,
                            fault_seed=fault_seed)
    bench_detection_latency()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for CI (<1 min)")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="seed for probabilistic fault rules (default: "
                         "$CHAOS_FAULT_SEED or 0)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    t0 = time.time()
    try:
        run(smoke=args.smoke, fault_seed=args.fault_seed)
    except BaseException as e:
        write_artifact("chaos", ok=False, error=repr(e),
                       seconds=time.time() - t0)
        raise
    write_artifact("chaos", ok=True, seconds=time.time() - t0)


if __name__ == "__main__":
    main()
