"""Paper Figs. 5–6: monetary cost per scheduling method on MATCHNET, with
growing numbers of resource types (2 → 8 → 32; the paper's claim: RL's
advantage widens as the fleet gets more heterogeneous).  Fig. 6's
"without CPU" variant drops the CPU from the fleet."""

from __future__ import annotations

from benchmarks.common import emit, fmt_cost
from repro.core import TrainingJob, default_fleet, make_fleet, paper_model_profiles
from repro.core.schedulers import ALL_SCHEDULERS

JOB = TrainingJob()
METHODS = ("RL-LSTM", "RL-RNN", "BO", "Genetic", "Greedy", "GPU", "CPU",
           "Heuristic")


def run() -> None:
    for T in (2, 8, 32):
        fleet = default_fleet() if T == 2 else make_fleet(T)
        profs = paper_model_profiles("MATCHNET", fleet)
        costs = {}
        for name in METHODS:
            kw = {"rounds": 50} if name.startswith("RL") else {}
            r = ALL_SCHEDULERS[name](**kw).schedule(profs, fleet, JOB)
            costs[name] = r.cost
            emit(f"fig5/T{T}/{name}", r.wall_time_s * 1e6,
                 f"cost={fmt_cost(r.cost)}")
        rl = costs["RL-LSTM"]
        worst = max((v for v in costs.values() if v == v and v != float("inf")),
                    default=rl)
        emit(f"fig5/T{T}/RL_advantage", 0.0,
             f"best_baseline_over_rl={min(v for k, v in costs.items() if k != 'RL-LSTM') / rl:.3f};worst_over_rl={worst / rl:.3f}")

    # Fig. 6: accelerator-only fleet (no CPU type)
    fleet = make_fleet(4)[1:]
    profs = paper_model_profiles("MATCHNET", fleet)
    for name in ("RL-LSTM", "BO", "Genetic", "Greedy", "GPU", "Heuristic"):
        kw = {"rounds": 50} if name.startswith("RL") else {}
        r = ALL_SCHEDULERS[name](**kw).schedule(profs, fleet, JOB)
        emit(f"fig6/noCPU/{name}", r.wall_time_s * 1e6,
             f"cost={fmt_cost(r.cost)}")
