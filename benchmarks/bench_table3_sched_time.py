"""Paper Table 3: scheduling time per method per model (MATCHNET, CTRDNN,
2EMB, NCE; plus MATCHNET with 32 resource types) — RL-LSTM's time does not
grow with the number of resource types.

Also measures the inner-loop plan-evaluation throughput (plans/s) of the
scalar oracle vs the batched cost model — every search scheduler now
routes plan scoring through the batched path, so this ratio is the direct
speedup of the scheduling hot loop.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, fmt_cost
from repro.core import (
    SchedulingPlan,
    TrainingJob,
    batched_soft_plan_cost,
    default_fleet,
    make_fleet,
    paper_model_profiles,
    soft_plan_cost,
)
from repro.core.schedulers import ALL_SCHEDULERS

JOB = TrainingJob()
METHODS = ("RL-LSTM", "RL-RNN", "BO", "Genetic", "Greedy", "GPU", "CPU",
           "Heuristic")


def bench_eval_throughput(model: str = "MATCHNET", n_plans: int = 2048,
                          seed: int = 0) -> None:
    """Plans/s of scalar soft_plan_cost loop vs batched_soft_plan_cost."""
    fleet = default_fleet()
    profs = paper_model_profiles(model, fleet)
    rng = np.random.default_rng(seed)
    A = rng.integers(0, len(fleet), (n_plans, len(profs)))

    n_scalar = min(n_plans, 256)  # the scalar loop is the slow one
    t0 = time.perf_counter()
    for row in A[:n_scalar]:
        soft_plan_cost(SchedulingPlan(tuple(int(x) for x in row)),
                       profs, fleet, JOB)
    t_scalar = time.perf_counter() - t0

    batched_soft_plan_cost(A[:8], profs, fleet, JOB)  # warm-up
    t0 = time.perf_counter()
    batched_soft_plan_cost(A, profs, fleet, JOB)
    t_batched = time.perf_counter() - t0

    scalar_ps = n_scalar / t_scalar
    batched_ps = n_plans / t_batched
    emit(f"table3/eval_throughput/{model}/scalar", t_scalar / n_scalar * 1e6,
         f"plans_per_s={scalar_ps:.0f}")
    emit(f"table3/eval_throughput/{model}/batched", t_batched / n_plans * 1e6,
         f"plans_per_s={batched_ps:.0f} speedup={batched_ps / scalar_ps:.1f}x")


def run() -> None:
    bench_eval_throughput()
    cases = [(m, default_fleet(), "") for m in
             ("MATCHNET", "CTRDNN", "2EMB", "NCE")]
    cases.append(("MATCHNET", make_fleet(32), "(32)"))
    for model, fleet, tag in cases:
        profs = paper_model_profiles(model, fleet)
        for name in METHODS:
            kw = {"rounds": 40} if name.startswith("RL") else {}
            sched = ALL_SCHEDULERS[name](**kw)
            r = sched.schedule(profs, fleet, JOB)
            emit(f"table3/{model}{tag}/{name}", r.wall_time_s * 1e6,
                 f"cost={fmt_cost(r.cost)}")
