"""Paper Table 3: scheduling time per method per model (MATCHNET, CTRDNN,
2EMB, NCE; plus MATCHNET with 32 resource types) — RL-LSTM's time does not
grow with the number of resource types."""

from __future__ import annotations

from benchmarks.common import emit, fmt_cost
from repro.core import TrainingJob, default_fleet, make_fleet, paper_model_profiles
from repro.core.schedulers import ALL_SCHEDULERS

JOB = TrainingJob()
METHODS = ("RL-LSTM", "RL-RNN", "BO", "Genetic", "Greedy", "GPU", "CPU",
           "Heuristic")


def run() -> None:
    cases = [(m, default_fleet(), "") for m in
             ("MATCHNET", "CTRDNN", "2EMB", "NCE")]
    cases.append(("MATCHNET", make_fleet(32), "(32)"))
    for model, fleet, tag in cases:
        profs = paper_model_profiles(model, fleet)
        for name in METHODS:
            kw = {"rounds": 40} if name.startswith("RL") else {}
            sched = ALL_SCHEDULERS[name](**kw)
            r = sched.schedule(profs, fleet, JOB)
            emit(f"table3/{model}{tag}/{name}", r.wall_time_s * 1e6,
                 f"cost={fmt_cost(r.cost)}")
