"""Paper Table 3: scheduling time per method per model (MATCHNET, CTRDNN,
2EMB, NCE; plus MATCHNET with 32 resource types) — RL-LSTM's time does not
grow with the number of resource types.

Three measurements:

* per-method scheduling wall time per model (the Table-3 reproduction);
  both RL methods schedule all five cases through ONE vmapped
  ``RLScheduler.schedule_many`` call per method;
* inner-loop plan-evaluation throughput (plans/s) of the scalar oracle vs
  the NumPy batched cost model;
* RL search-round throughput of the fused single-jit path vs the unfused
  per-round loop, with jit compile time warmed up and reported as a
  separate ``compile_s`` metric (steady-state ``rounds_per_s`` only).

``--smoke`` runs the throughput measurements and enforces the fused
speedup gate (exits nonzero below :data:`FUSED_GATE`) — wired into CI.
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

try:
    from benchmarks.common import emit, fmt_cost
except ImportError:  # direct-script invocation (python benchmarks/bench_...)
    from common import emit, fmt_cost
from repro.core import (
    SchedulingPlan,
    TrainingJob,
    batched_soft_plan_cost,
    default_fleet,
    make_fleet,
    paper_model_profiles,
    soft_plan_cost,
)
from repro.core.schedulers import ALL_SCHEDULERS, RLScheduler

JOB = TrainingJob()
METHODS = ("RL-LSTM", "RL-RNN", "BO", "Genetic", "Greedy", "GPU", "CPU",
           "Heuristic")

#: minimum fused-vs-unfused steady-state rounds/s ratio (CI smoke gate)
FUSED_GATE = 5.0


def bench_eval_throughput(model: str = "MATCHNET", n_plans: int = 2048,
                          seed: int = 0) -> None:
    """Plans/s of scalar soft_plan_cost loop vs batched_soft_plan_cost."""
    fleet = default_fleet()
    profs = paper_model_profiles(model, fleet)
    rng = np.random.default_rng(seed)
    A = rng.integers(0, len(fleet), (n_plans, len(profs)))

    n_scalar = min(n_plans, 256)  # the scalar loop is the slow one
    t0 = time.perf_counter()
    for row in A[:n_scalar]:
        soft_plan_cost(SchedulingPlan(tuple(int(x) for x in row)),
                       profs, fleet, JOB)
    t_scalar = time.perf_counter() - t0

    batched_soft_plan_cost(A[:8], profs, fleet, JOB)  # warm-up
    t0 = time.perf_counter()
    batched_soft_plan_cost(A, profs, fleet, JOB)
    t_batched = time.perf_counter() - t0

    scalar_ps = n_scalar / t_scalar
    batched_ps = n_plans / t_batched
    emit(f"table3/eval_throughput/{model}/scalar", t_scalar / n_scalar * 1e6,
         f"plans_per_s={scalar_ps:.0f}")
    emit(f"table3/eval_throughput/{model}/batched", t_batched / n_plans * 1e6,
         f"plans_per_s={batched_ps:.0f} speedup={batched_ps / scalar_ps:.1f}x")


def bench_rl_search_throughput(
    model: str = "MATCHNET",
    fused_rounds: int = 100,
    unfused_rounds: int = 30,
    seed: int = 0,
) -> float:
    """Steady-state REINFORCE rounds/s: fused single-jit vs per-round loop.

    Compile time is excluded from both sides: the fused scheduler reports
    its first-chunk compile separately (``extra["compile_s"]``), and the
    unfused loop gets an explicit warm-up run so its per-round jits
    (sampling, gradient) are cached before timing.  Returns the speedup.
    """
    fleet = default_fleet()
    profs = paper_model_profiles(model, fleet)
    stop_never = 10**9

    # fused: one run; chunk 0 pays the compile, chunks 1.. are steady state
    sched_f = RLScheduler(rounds=fused_rounds, seed=seed, fused=True,
                          chunk_rounds=20, early_stop_rounds=stop_never)
    r_f = sched_f.schedule(profs, fleet, JOB)
    compile_s = r_f.extra["compile_s"]
    fused_rps = r_f.extra["rounds_per_s"]

    # unfused: warm-up compiles the per-round jits, then time a fresh search
    # (extra["rounds_per_s"] covers the round loop only — same scope as the
    # fused metric, excluding anchors/greedy decode/final evaluation)
    RLScheduler(rounds=2, seed=seed, fused=False).schedule(profs, fleet, JOB)
    sched_u = RLScheduler(rounds=unfused_rounds, seed=seed, fused=False,
                          early_stop_rounds=stop_never)
    r_u = sched_u.schedule(profs, fleet, JOB)
    unfused_rps = r_u.extra["rounds_per_s"]

    speedup = fused_rps / unfused_rps
    emit(f"table3/rl_search/{model}/compile", compile_s * 1e6,
         f"compile_s={compile_s:.2f}")
    emit(f"table3/rl_search/{model}/fused", 1e6 / fused_rps,
         f"rounds_per_s={fused_rps:.1f}")
    emit(f"table3/rl_search/{model}/unfused", 1e6 / unfused_rps,
         f"rounds_per_s={unfused_rps:.1f} speedup={speedup:.1f}x")
    return speedup


def _cases():
    cases = [(m, default_fleet(), "") for m in
             ("MATCHNET", "CTRDNN", "2EMB", "NCE")]
    cases.append(("MATCHNET", make_fleet(32), "(32)"))
    return cases


def run() -> None:
    bench_eval_throughput()
    bench_rl_search_throughput()
    cases = _cases()
    specs = [(paper_model_profiles(m, fleet), fleet, JOB)
             for m, fleet, _ in cases]
    for name in METHODS:
        if name.startswith("RL"):
            # all five Table-3 cases in one schedule_many call (vmapped
            # per fleet-size group); wall time is the shared group time;
            # chunk_rounds divides rounds so no tail rounds are discarded
            results = ALL_SCHEDULERS[name](
                rounds=40, chunk_rounds=20).schedule_many(specs)
            for (model, _, tag), r in zip(cases, results):
                # wall_time_s is the whole vmapped group's wall; report
                # each model's amortized share so rows stay comparable
                # across group sizes (the Table-3 flat-in-types claim)
                share = r.wall_time_s / r.extra["vmapped_models"]
                emit(f"table3/{model}{tag}/{name}", share * 1e6,
                     f"cost={fmt_cost(r.cost)} rounds={r.extra['rounds']} "
                     f"vmapped={r.extra['vmapped_models']} "
                     f"group_wall_s={r.wall_time_s:.2f}")
        else:
            for model, fleet, tag in cases:
                profs = paper_model_profiles(model, fleet)
                r = ALL_SCHEDULERS[name]().schedule(profs, fleet, JOB)
                emit(f"table3/{model}{tag}/{name}", r.wall_time_s * 1e6,
                     f"cost={fmt_cost(r.cost)}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="throughput benchmarks only; enforce the fused "
                         f"speedup gate (>= {FUSED_GATE}x)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        bench_eval_throughput(n_plans=512)
        speedup = bench_rl_search_throughput(fused_rounds=60,
                                             unfused_rounds=15)
        if speedup < FUSED_GATE:
            print(f"# FAIL: fused RL search speedup {speedup:.1f}x < "
                  f"{FUSED_GATE}x gate", file=sys.stderr)
            raise SystemExit(1)
        print(f"# OK: fused RL search speedup {speedup:.1f}x >= "
              f"{FUSED_GATE}x", file=sys.stderr)
    else:
        run()


if __name__ == "__main__":
    main()
