"""Paper Fig. 12: heterogeneous HeterPS vs single-resource-type execution.

Two measurements:
1. Cost-model throughput of CTRDNN under HeterPS-CPU / HeterPS-GPU /
   HeterPS (RL heterogeneous plan) — the paper's simulated comparison
   (TF baselines are out of scope; HeterPS-CPU/GPU stand in for the
   single-type configurations).
2. A real wall-clock microbenchmark of the shard_map pipeline runtime:
   pipelined vs sequential execution of the same staged MLP (single CPU
   device — measures schedule overhead; the speedup claim needs multiple
   real devices and is validated structurally in tests/test_pipeline.py).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, fmt_cost
from repro.core import (
    SchedulingPlan, TrainingJob, build_stages, default_fleet,
    paper_model_profiles, pipeline_throughput,
)
from repro.core.provision import provision
from repro.core.schedulers import RLScheduler
from repro.parallel.pipeline import make_stage_mesh, pipeline_apply, stack_stage_params

FLEET = default_fleet()


def run() -> None:
    # --- 1. cost-model throughput, CTRDNN1 (low dim) / CTRDNN2 (paper) ---
    for tag, tp_limit in (("CTRDNN1", 100_000.0), ("CTRDNN2", 200_000.0)):
        job = TrainingJob(throughput_limit=tp_limit)
        profs = paper_model_profiles("CTRDNN", FLEET)
        plans = {
            "HeterPS-CPU": SchedulingPlan((0,) * len(profs)),
            "HeterPS-GPU": SchedulingPlan((1,) * len(profs)),
            "HeterPS": RLScheduler(rounds=40, seed=0)
            .schedule(profs, FLEET, job).plan,
        }
        base_tp = None
        for name, plan in plans.items():
            stages = build_stages(plan, profs, FLEET)
            prov = provision(stages, FLEET, job)
            tp = (pipeline_throughput(stages, prov, job.batch_size)
                  if prov else 0.0)
            if name == "HeterPS-CPU":
                base_tp = max(tp, 1e-9)
            emit(f"fig12/{tag}/{name}", 0.0,
                 f"throughput={tp:,.0f};x_over_cpu={tp / base_tp:.1f}")

    # --- 2. pipeline runtime microbenchmark (schedule overhead) ----------
    d, M, mb, S = 64, 8, 32, min(4, jax.device_count())
    key = jax.random.PRNGKey(0)
    params = stack_stage_params([
        {"w": jax.random.normal(jax.random.fold_in(key, i), (d, d)) * 0.3,
         "b": jnp.zeros((d,))}
        for i in range(S)
    ])
    xs = jax.random.normal(key, (M, mb, d))
    stage_fn = lambda p, x: jnp.tanh(x @ p["w"] + p["b"])
    mesh = make_stage_mesh(S)
    piped = jax.jit(lambda prm, x: pipeline_apply(stage_fn, prm, x, mesh))

    def seq(prm, x):
        h = x
        for i in range(S):
            p = jax.tree.map(lambda a: a[i], prm)
            h = jax.vmap(lambda xx: stage_fn(p, xx))(h)
        return h

    seqj = jax.jit(seq)
    piped(params, xs).block_until_ready()
    seqj(params, xs).block_until_ready()
    for name, fn in (("pipelined", piped), ("sequential", seqj)):
        t0 = time.perf_counter()
        for _ in range(20):
            fn(params, xs).block_until_ready()
        us = (time.perf_counter() - t0) / 20 * 1e6
        emit(f"fig12/microbench/{name}", us, f"stages={S};micro={M}")
