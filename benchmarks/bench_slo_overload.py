"""Overload-robustness suite: the 2× sustained-overload no-collapse gate
plus the preempt-resume bit-exactness gate (PR 10).

A thin registration wrapper over :mod:`benchmarks.bench_slo` so the
harness (``benchmarks/run.py``) and the ``serve-overload`` CI lane can
run the overload scenario as its own suite with its own artifact,
independent of the base open-loop SLO harness:

  PYTHONPATH=src python -m benchmarks.run --only slo-overload
  PYTHONPATH=src python benchmarks/bench_slo.py --smoke --scenario overload
"""

from __future__ import annotations

try:
    from benchmarks import bench_slo
except ImportError:  # run directly: python benchmarks/bench_slo_overload.py
    import bench_slo


def run() -> dict:
    """Harness entry: full-size overload scenario + preempt gate."""
    return {"overload": bench_slo.run_overload(smoke=False),
            "preempt": bench_slo.run_preempt_gate()}


if __name__ == "__main__":
    import sys

    sys.argv = [sys.argv[0], "--scenario", "overload"] + sys.argv[1:]
    bench_slo.main()
